"""mamba2-130m [arXiv:2405.21060; unverified].

24L d_model=768 attention-free, vocab=50280, ssm_state=128 (SSD).
Attention-free -> long_500k runs; the paper's stencil technique applies
directly (causal conv1d = 1-D stencil; see DESIGN.md §4).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=24, n_kv_heads=24,
    d_ff=0, vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True,
    notes="SSD; attention-free; long_500k runs",
)
