"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [hf:moonshotai/Moonlight-16B-A3B; hf].

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64 experts top-6.
(The HF model additionally uses shared experts / MLA-style details; the task
spec pins the config above — implemented exactly as specified.)
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, n_experts=64, top_k=6,
    rope_theta=50000.0,
    notes="MoE 64e top-6; full attention -> long_500k skipped",
)
