"""Architecture registry: ``--arch <id>`` resolution + the paper's own PDE
configs. Each LM config module pins the published hyperparameters; the
shapes table below is the assigned (arch x input-shape) grid."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from ..models.config import ArchConfig, RunConfig, smoke_variant

_ARCH_MODULES = {
    "moonshot-v1-16b-a3b": ".moonshot_v1_16b_a3b",
    "mixtral-8x7b": ".mixtral_8x7b",
    "phi-3-vision-4.2b": ".phi_3_vision_4_2b",
    "seamless-m4t-medium": ".seamless_m4t_medium",
    "minicpm-2b": ".minicpm_2b",
    "stablelm-3b": ".stablelm_3b",
    "qwen3-32b": ".qwen3_32b",
    "qwen2-72b": ".qwen2_72b",
    "zamba2-1.2b": ".zamba2_1_2b",
    "mamba2-130m": ".mamba2_130m",
}

ARCH_IDS = tuple(_ARCH_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def get_arch(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[name], __package__)
    return mod.CONFIG


def get_smoke(name: str) -> ArchConfig:
    return smoke_variant(get_arch(name))


def cell_runnable(cfg: ArchConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Is this (arch x shape) cell runnable? (task-spec skip rules)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""


def all_cells():
    """Yield (arch_name, shape, runnable, reason) for the 40-cell grid."""
    for a in ARCH_IDS:
        cfg = get_arch(a)
        for s in SHAPES.values():
            ok, why = cell_runnable(cfg, s)
            yield a, s, ok, why


def apply_overrides(cfg, overrides: dict):
    """CLI-style overrides: field=value with type coercion."""
    kw = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if cur is None:
            kw[k] = v
        elif isinstance(cur, bool):
            kw[k] = v in (True, "true", "True", "1", 1)
        else:
            kw[k] = type(cur)(v)
    return dataclasses.replace(cfg, **kw)
