"""qwen3-32b [hf:Qwen/Qwen3-8B; hf].

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, qk_norm.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
    d_ff=25600, vocab=151936, qk_norm=True, head_dim=128,
    rope_theta=1e6,
    notes="qk_norm + GQA; full attention -> long_500k skipped",
)
