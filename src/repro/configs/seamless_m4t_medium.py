"""seamless-m4t-medium [arXiv:2308.11596; hf].

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206 — encoder-decoder,
multimodal. Frame frontend stubbed (precomputed frame embeddings); 12 enc +
12 dec layers; decode shapes lower the *decoder* step with cross-attn cache.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, n_enc_layers=12, n_dec_layers=12,
    source_len=1024,
    notes="enc-dec; frontend stub; full attention -> long_500k skipped",
)
