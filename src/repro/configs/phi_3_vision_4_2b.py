"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct; hf].

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064; phi3-mini backbone +
CLIP frontend. Per task spec the vision frontend is a STUB: input_specs()
provides precomputed patch embeddings (n_patches, d_model).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, n_patches=576, rope_theta=10000.0,
    notes="VLM backbone; patch embeds stubbed; full attention -> long_500k skipped",
)
