"""zamba2-1.2b [arXiv:2411.15242; hf].

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64 —
Mamba2 backbone + one shared attention block applied every 6 layers
(per-invocation LoRA deltas omitted, DESIGN.md). Hybrid -> long_500k runs.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, ssm_state=64, attn_every=6,
    ssm_head_dim=64, ssm_expand=2,
    notes="Mamba2 + shared attn block; sub-quadratic -> long_500k runs",
)
