"""The paper's own experiment configs (Fig. 1 / Fig. 2 3-D heat diffusion).

``FIG1`` matches the listing in the paper exactly: 512^3 grid, lam = 1,
c0 = 2, unit cube, dt = min(dx,dy,dz)^2 / lam / max(Ci) / 6.1, nt = 100.
Smaller variants for CPU benchmarking / CI.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Diffusion3DConfig:
    nx: int = 512
    ny: int = 512
    nz: int = 512
    lx: float = 1.0
    ly: float = 1.0
    lz: float = 1.0
    lam: float = 1.0        # thermal conductivity
    c0: float = 2.0         # heat capacity
    nt: int = 100           # time steps
    dtype: str = "float32"
    backend: str = "pallas"  # pallas | jnp
    init_temp: float = 1.7

    @property
    def shape(self):
        return (self.nx, self.ny, self.nz)


FIG1 = Diffusion3DConfig()
BENCH_256 = dataclasses.replace(FIG1, nx=256, ny=256, nz=256, nt=20)
BENCH_128 = dataclasses.replace(FIG1, nx=128, ny=128, nz=128, nt=20)
SMOKE = dataclasses.replace(FIG1, nx=32, ny=32, nz=32, nt=5)
