"""minicpm-2b [arXiv:2404.06395; hf].

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753; llama-like arch with
tied embeddings; trained with the WSD schedule (optim/schedules.py).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, tie_embeddings=True,
    notes="WSD schedule; full attention -> long_500k skipped",
)
