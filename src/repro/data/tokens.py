"""Deterministic, shardable token pipeline with exact resume.

Two sources behind one interface:
  * SyntheticSource — a fixed-seed Zipf-ish token stream with local n-gram
    structure (so losses actually decrease), generated on the fly;
  * MemmapSource — flat binary token file (np.uint16/uint32 memmap), the
    production path.

Determinism contract (fault-tolerance critical): batch(step, shard) is a
pure function of (seed, step, shard_id, n_shards) — any host can
reconstruct any other host's batch after failover, and resume needs no
pipeline state beyond the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    source: str = "synthetic"       # synthetic | memmap
    path: Optional[str] = None       # memmap file
    n_shards: int = 1                # data-parallel host shards
    shard_id: int = 0

    @property
    def local_batch(self) -> int:
        if self.global_batch % self.n_shards:
            raise ValueError("global_batch must divide by n_shards")
        return self.global_batch // self.n_shards


class SyntheticSource:
    """Zipf marginals + order-1 mixing: next ~ 0.7 * f(prev) + 0.3 * zipf."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        self._perm = rng.permutation(cfg.vocab)  # deterministic f(prev)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 131 + cfg.shard_id) % (2**31 - 1))
        B, L, V = cfg.local_batch, cfg.seq_len, cfg.vocab
        ranks = rng.zipf(1.3, size=(B, L + 1)).astype(np.int64)
        base = np.minimum(ranks, V) - 1
        toks = np.empty((B, L + 1), np.int32)
        toks[:, 0] = base[:, 0]
        follow = rng.rand(B, L) < 0.7
        for t in range(1, L + 1):
            toks[:, t] = np.where(follow[:, t - 1],
                                  self._perm[toks[:, t - 1] % V] % V, base[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


class MemmapSource:
    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_tokens = len(self.data)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        B, L = cfg.local_batch, cfg.seq_len
        n_seq = self.n_tokens // (L + 1)
        rng = np.random.RandomState((cfg.seed + step) % (2**31 - 1))
        # global sample of global_batch sequence ids; take our shard's slice
        ids = rng.randint(0, n_seq, size=cfg.global_batch)
        ids = ids[cfg.shard_id * B:(cfg.shard_id + 1) * B]
        toks = np.stack([self.data[i * (L + 1):(i + 1) * (L + 1)] for i in ids])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def make_source(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticSource(cfg)
    if cfg.source == "memmap":
        return MemmapSource(cfg)
    raise ValueError(cfg.source)


def iterate(source, start_step: int = 0) -> Iterator[tuple[int, dict]]:
    step = start_step
    while True:
        yield step, source.batch(step)
        step += 1
