"""Initial/boundary conditions for the PDE solvers (paper experiments)."""
from __future__ import annotations

import jax.numpy as jnp

from ..core.grid import Grid


def gaussian_hotspot(grid: Grid, amplitude: float = 1.0, width: float = 0.1,
                     background: float = 0.0, dtype=jnp.float32):
    """Centered Gaussian temperature anomaly."""
    xs = grid.meshgrid(dtype)
    c = [l / 2 for l in grid.length]
    r2 = sum((x - ci) ** 2 for x, ci in zip(xs, c))
    return background + amplitude * jnp.exp(-r2 / (2 * width ** 2))


def random_porosity(key, grid: Grid, mean: float = 0.1, contrast: float = 2.0,
                    dtype=jnp.float32):
    """Smooth random porosity field for the two-phase flow solver."""
    import jax

    phi = jax.random.uniform(key, grid.shape, dtype)
    # crude smoothing: 3 passes of nearest-neighbor averaging
    for _ in range(3):
        pad = jnp.pad(phi, 1, mode="edge")
        acc = jnp.zeros_like(phi)
        nd = phi.ndim
        for ax in range(nd):
            lo = tuple(slice(0, -2) if a == ax else slice(1, -1) for a in range(nd))
            hi = tuple(slice(2, None) if a == ax else slice(1, -1) for a in range(nd))
            acc = acc + pad[lo] + pad[hi]
        phi = (phi + acc / (2 * nd)) / 2
    return mean * (1 + contrast * (phi - phi.mean()))


def vortex_wavefunction(grid: Grid, n_vortices: int = 2, dtype=jnp.complex64):
    """Initial condition for the Gross-Pitaevskii solver: uniform condensate
    with phase windings (quantized vortices) along z."""
    xs = grid.meshgrid(jnp.float32)
    cx, cy = grid.length[0] / 2, grid.length[1] / 2
    phase = jnp.zeros(grid.shape, jnp.float32)
    for i in range(n_vortices):
        ox = cx + (i - (n_vortices - 1) / 2) * grid.length[0] / (n_vortices + 1)
        phase = phase + jnp.arctan2(xs[1] - cy, xs[0] - ox)
    amp = jnp.ones(grid.shape, jnp.float32)
    return (amp * jnp.exp(1j * phase)).astype(dtype)
