from . import tokens, physics
from .tokens import DataConfig, make_source
__all__ = ["tokens", "physics", "DataConfig", "make_source"]
