"""The serving worker: one thread draining the queue through the engine.

A worker's loop: collect a batch (waiting up to ``collect_window_s`` to
aggregate), run chunks with harvest/refill between them (continuous
batching), and repeat. Its robustness duties:

  * every completed batch bumps a :class:`repro.distributed.fault.
    Heartbeat` (when ``policy.heartbeat_dir`` is set) — the supervisor's
    liveness signal across processes;
  * a batch whose retries are exhausted counts one breaker strike;
    ``policy.breaker_threshold`` consecutive strikes TRIP the worker: it
    re-queues all in-flight tickets (none are lost) and exits with
    ``tripped=True`` so the supervisor can replace it;
  * ``FaultPlan.worker_batch_done`` is called after each batch — the
    ``kill_worker_after`` injection dies there, leaving in-flight
    tickets for the supervisor to recover from ``in_flight()``;
  * a batch-level timeout (``policy.batch_timeout_s``) bounds wall time
    per batch so a pathological workload cannot wedge the worker.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from .. import telemetry as _telemetry
from ..distributed import fault
from .engine import BatchEngine, BatchState
from .queue import RequestQueue, Ticket

__all__ = ["Worker"]


class Worker:
    def __init__(self, name: str, engine: BatchEngine, queue: RequestQueue,
                 rank: int = 0):
        self.name = name
        self.engine = engine
        self.queue = queue
        self.policy = engine.policy
        self._state: Optional[BatchState] = None
        self._state_lock = threading.Lock()
        self._stop = threading.Event()
        self.tripped = False
        self.strikes = 0
        self.batches_done = 0
        self.heartbeat = (fault.Heartbeat(self.policy.heartbeat_dir,
                                          rank=rank,
                                          timeout_s=self.policy
                                          .heartbeat_timeout_s)
                          if self.policy.heartbeat_dir else None)
        self._thread = threading.Thread(target=self._run, name=name,
                                        daemon=True)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Worker":
        self._thread.start()
        return self

    def stop(self, join: bool = True) -> None:
        self._stop.set()
        if join and self._thread.is_alive():
            self._thread.join(timeout=30.0)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def in_flight(self) -> list[Ticket]:
        """Unresolved tickets currently bound to this worker's batch —
        what the supervisor re-queues when the worker dies."""
        with self._state_lock:
            if self._state is None:
                return []
            return [t for t in self._state.slots
                    if t is not None and not t.done]

    # -- main loop -----------------------------------------------------------
    def _run(self) -> None:
        col = _telemetry.get()
        while not self._stop.is_set():
            tickets = self.queue.take_batch(
                self.policy.max_batch,
                timeout=self.policy.collect_window_s,
                should_stop=self._stop.is_set)
            if not tickets:
                if self.queue.closed and len(self.queue) == 0:
                    return
                continue
            ok = self._serve_batch(tickets)
            if ok:
                self.strikes = 0
            else:
                self.strikes += 1
                col.count("serve.breaker_strikes", 1)
                if self.strikes >= self.policy.breaker_threshold:
                    # in-flight tickets were already re-queued by the
                    # failing _serve_batch; just hand the slot back
                    self.tripped = True
                    col.event("serve.breaker_tripped", worker=self.name,
                              strikes=self.strikes)
                    return
            plan = fault.FaultPlan.active()
            if plan is not None:
                plan.worker_batch_done()

    def _serve_batch(self, tickets: list[Ticket]) -> bool:
        """One batch to completion (with refill). True on success."""
        col = _telemetry.get()
        pol = self.policy
        try:
            state = self.engine.start(tickets)
        except Exception as e:
            col.count("serve.batch_failures", 1)
            col.event("serve.batch_failed", worker=self.name,
                      error=type(e).__name__, detail=str(e)[:200])
            self.queue.requeue([t for t in tickets if not t.done])
            return False
        with self._state_lock:
            self._state = state
        try:
            while state.n_live and not self._stop.is_set():
                if (pol.batch_timeout_s is not None
                        and time.monotonic() - state.started_at
                        > pol.batch_timeout_s):
                    self.engine.expire_all(state, "batch_timeout")
                    break
                self.engine.run_chunk(state)
                freed = self.engine.harvest(state)
                if freed:
                    # continuous batching: freed slots refill from the
                    # same bucket without waiting for the batch to drain
                    more = self.queue.take_batch(len(freed), timeout=0.0)
                    for slot, t in zip(freed, more):
                        if t.request.bucket == state.bucket:
                            state.bind(slot, t)
                            col.count("serve.refilled", 1)
                        else:       # rare cross-bucket race: hand back
                            self.queue.requeue([t])
            self.batches_done += 1
            col.count("serve.batches", 1)
            if self.heartbeat is not None:
                self.heartbeat.bump(self.batches_done)
            return True
        except Exception as e:
            # retries exhausted or a non-transient failure: the batch is
            # lost but its REQUESTS are not — unresolved tickets go back
            # to the front of the queue for the next worker/attempt
            col.count("serve.batch_failures", 1)
            col.event("serve.batch_failed", worker=self.name,
                      error=type(e).__name__, detail=str(e)[:200])
            pending = [t for t in state.slots
                       if t is not None and not t.done]
            if pending:
                self.queue.requeue(pending)
            return False
        finally:
            with self._state_lock:
                self._state = None
