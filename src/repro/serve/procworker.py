"""One OS-process serving worker draining a filesystem spool.

``python -m repro.serve.procworker --spool DIR [--kernel mod:factory]``

The thread-based :class:`~repro.serve.worker.Worker` shares its process
(and its failures) with the server; this worker is the multi-process
analogue used by :class:`~repro.serve.pool.ProcessWorkerPool` — a child
that can be SIGKILLed without taking the pool down. The wire protocol is
files (the spool survives a dead worker by construction):

  * ``pending/<seq>_<id>.npz`` — a request: field arrays plus a
    ``__meta__`` JSON blob (scalars, tol, max_iters, check_every);
  * claim = atomic ``os.rename`` into ``claimed/rank_<r>/`` (exactly one
    winner per request, no locks);
  * ``done/<name>.npz`` (result fields + ``__result__`` JSON) or
    ``done/<name>.err.json`` (typed failure) — written via tmp+rename so
    readers never see a torn file;
  * a crashed worker leaves its claims in ``claimed/rank_<r>/``; the
    pool's supervisor renames them back to ``pending/`` (the original
    ``<seq>`` prefix keeps recovered requests at the FRONT of the
    sorted-name order — recovery never reorders the unexpired backlog).

Liveness: the worker bumps a run-id-namespaced
:class:`~repro.distributed.fault.Heartbeat` every loop (idle included)
AND between solve chunks — a claimed request is solved in
adaptively-sized blocks of ``check_every`` iterations with a bump at
every block boundary, so a legitimately long solve keeps beating and a
stale heartbeat always means wedged, never busy or idle.
``FaultPlan.kill_worker_after`` dies after N completed requests;
``wedge_worker_after`` stops progressing (and bumping) while staying
alive — the injections the pool's exit-code and stale-heartbeat
recovery tests drive.
"""
from __future__ import annotations

import argparse
import importlib
import io
import json
import os
import sys
import time
from typing import Optional

import numpy as np

from ..distributed import fault
from ..launch.multihost import ENV_HEARTBEAT_DIR, ENV_PROCESS_ID, ENV_RUN_ID

__all__ = ["demo_kernel", "write_request", "read_request",
           "write_result", "read_result", "main"]

CLOSED_MARKER = "CLOSED"


# -- spool wire format -------------------------------------------------------
def write_request(path: str, fields: dict, meta: dict) -> None:
    """Atomically write one request/result npz (tmp + rename)."""
    buf = io.BytesIO()
    arrays = {f"field::{k}": np.asarray(v) for k, v in fields.items()}
    arrays["__meta__"] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    np.savez(buf, **arrays)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(buf.getvalue())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def read_request(path: str) -> tuple[dict, dict]:
    with np.load(path) as z:
        fields = {k[len("field::"):]: z[k] for k in z.files
                  if k.startswith("field::")}
        meta = json.loads(bytes(z["__meta__"]).decode())
    return fields, meta


write_result = write_request
read_result = read_request


def demo_kernel():
    """The built-in kernel factory (3-D diffusion — same as the fault
    tests), so the pool works out of the box and in CI."""
    from ..core import fd3d, init_parallel_stencil

    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"},
                 reductions={"err": "max_abs_diff(T2, T)"})
    def kern(T2, T, dt):
        return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                          + fd3d.d2_zi(T))}

    return kern


def _resolve_kernel(spec: str):
    mod, _, attr = spec.partition(":")
    factory = getattr(importlib.import_module(mod), attr or "demo_kernel")
    return factory()


def _claim(pending: str, claimed: str) -> Optional[str]:
    """Oldest unclaimed request, atomically moved into our claim dir
    (rename races lose silently — another worker won)."""
    for name in sorted(os.listdir(pending)):
        if not name.endswith(".npz"):
            continue
        src, dst = os.path.join(pending, name), os.path.join(claimed, name)
        try:
            os.rename(src, dst)
            return dst
        except OSError:
            continue
    return None


def _solve_beating(kernel, fields: dict, meta: dict, hb, served: int, *,
                   chunk_target_s: float = 1.0):
    """Solve one request in heartbeat-sized chunks.

    Each chunk is the same cached jitted while_loop as a plain
    ``solve_until`` call, capped at a multiple of ``check_every`` — the
    per-step math never sees the chunk boundary, so the result is
    bit-identical to the unchunked solve. Between chunks the worker's
    heartbeat is bumped, so a request whose solve outlasts the pool's
    ``heartbeat_timeout_s`` is not killed as wedged, requeued, and
    killed again (a poison-pill livelock). The chunk size starts at one
    check and doubles while chunks complete faster than
    ``chunk_target_s``, keeping the host-sync overhead negligible on
    long solves. Returns ``(fields, total_iters, err)``.
    """
    from ..core import iterate

    scalars = meta.get("scalars") or {}
    tol = float(meta.get("tol", 0.0))
    max_iters = int(meta.get("max_iters", 100))
    check_every = int(meta.get("check_every", 1))
    if hb is None or max_iters <= check_every:
        res = iterate.solve_until(kernel, fields, scalars, tol=tol,
                                  max_iters=max_iters,
                                  check_every=check_every)
        return res.fields, int(res.iters), float(res.err)
    cur, done, err = dict(fields), 0, float("inf")
    chunk = check_every
    while done < max_iters:
        hb.bump(served)
        take = min(chunk, max_iters - done)
        t0 = time.perf_counter()
        res = iterate.solve_until(kernel, cur, scalars, tol=tol,
                                  max_iters=take, check_every=check_every)
        dt = time.perf_counter() - t0
        cur, err = res.fields, float(res.err)
        done += int(res.iters)
        hb.bump(served)
        if int(res.iters) < take or iterate._crossed(err, tol, "below"):
            break
        if dt < chunk_target_s:
            chunk *= 2
        elif dt > 2 * chunk_target_s and chunk > check_every:
            chunk = max(check_every, chunk // 2)
    return cur, done, err


def serve_spool(spool: str, kernel, *, rank: int = 0,
                run_id: Optional[str] = None,
                heartbeat_dir: Optional[str] = None,
                idle_sleep_s: float = 0.02) -> int:
    """The worker loop: claim -> solve -> publish, until the pool drops
    the ``CLOSED`` marker and the backlog drains."""
    pending = os.path.join(spool, "pending")
    claimed = os.path.join(spool, "claimed", f"rank_{rank}")
    done = os.path.join(spool, "done")
    for d in (pending, claimed, done):
        os.makedirs(d, exist_ok=True)
    hb = (fault.Heartbeat(heartbeat_dir, rank=rank, run_id=run_id)
          if heartbeat_dir else None)
    plan = fault.FaultPlan.active()
    served = 0
    while True:
        if hb is not None:
            hb.bump(served)
        path = _claim(pending, claimed)
        if path is None:
            if os.path.exists(os.path.join(spool, CLOSED_MARKER)):
                return 0
            time.sleep(idle_sleep_s)
            continue
        name = os.path.basename(path)
        try:
            fields, meta = read_request(path)
            cur, iters, err = _solve_beating(kernel, fields, meta, hb, served)
            out = {k: np.asarray(v) for k, v in cur.items()}
            write_result(os.path.join(done, name), out,
                         {"iters": iters, "err": err, "rank": rank})
        except Exception as e:  # typed failure file — the request is
            # answered, never lost silently
            err = {"error": type(e).__name__, "detail": str(e)[:500],
                   "rank": rank}
            tmp = os.path.join(done, name + ".err.json.tmp")
            with open(tmp, "w") as f:
                json.dump(err, f)
            os.replace(tmp, os.path.join(done, name + ".err.json"))
        finally:
            try:
                os.unlink(path)
            except OSError:
                pass
        served += 1
        if hb is not None:
            hb.bump(served)
        if plan is not None:
            plan.worker_batch_done()


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.serve.procworker")
    ap.add_argument("--spool", required=True)
    ap.add_argument("--kernel", default="repro.serve.procworker:demo_kernel",
                    help="kernel factory as module:callable")
    ap.add_argument("--rank", type=int,
                    default=int(os.environ.get(ENV_PROCESS_ID, 0)))
    args = ap.parse_args(argv)
    return serve_spool(
        args.spool, _resolve_kernel(args.kernel), rank=args.rank,
        run_id=os.environ.get(ENV_RUN_ID) or None,
        heartbeat_dir=os.environ.get(ENV_HEARTBEAT_DIR) or None)


if __name__ == "__main__":
    sys.exit(main())
