"""Bounded request queue with backpressure, grid bucketing and deadlines.

Admission is synchronous and strict: ``submit`` either returns a
:class:`Ticket` (the request IS in the queue) or raises a typed
rejection (:class:`~repro.serve.errors.QueueFull` /
:class:`~repro.serve.errors.ServerClosed`) — there is no silent drop
and no unbounded buffering. The bound is the backpressure signal: a
full queue means the fleet is saturated and the caller should shed or
slow down, not that the server will quietly queue into OOM.

Requests are bucketed by field signature (shapes + dtypes): a batch
must stack samples on a leading axis, so only same-bucket requests can
share a launch. ``take_batch`` pops up to ``max_batch`` requests from
the oldest non-empty bucket (FIFO within a bucket), skipping — and
immediately failing — requests whose deadline already passed while
queued (a request that cannot make its deadline must not occupy a
batch slot).

``requeue`` puts in-flight requests back at the FRONT of their bucket
(they have already waited once) — the path a worker death takes.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional

from .. import telemetry as _telemetry
from ..distributed import fault
from . import errors

__all__ = ["SolveRequest", "Ticket", "RequestQueue", "bucket_key"]

_ids = itertools.count()


def bucket_key(fields: Mapping[str, Any]) -> tuple:
    """The batch-compatibility signature of a request's fields."""
    return tuple(sorted(
        (n, tuple(getattr(v, "shape", ())),
         str(getattr(v, "dtype", type(v).__name__)))
        for n, v in fields.items()))


@dataclass
class SolveRequest:
    """One user solve: initial fields + per-request scalars + policy."""

    fields: Mapping[str, Any]
    scalars: Mapping[str, Any] = field(default_factory=dict)
    tol: float = 1e-5
    max_iters: int = 1000
    deadline_s: Optional[float] = None     # wall seconds from submit
    request_id: str = ""

    def __post_init__(self):
        if not self.request_id:
            self.request_id = f"req-{next(_ids)}"

    @property
    def bucket(self) -> tuple:
        # scalar NAMES join the key: a batch stacks per-request scalar
        # values into (B,) vectors, so requests with different scalar
        # sets can never share a launch
        return (bucket_key(self.fields), tuple(sorted(self.scalars)))


@dataclass
class Ticket:
    """The caller's handle: resolves to a result dict or a ServeError.

    ``wait`` blocks; ``result()`` returns the payload or raises the
    pointed failure. One ticket resolves exactly once."""

    request: SolveRequest
    submitted_at: float = field(default_factory=time.monotonic)
    _done: threading.Event = field(default_factory=threading.Event)
    _result: Any = None
    _error: Optional[Exception] = None

    @property
    def deadline_at(self) -> Optional[float]:
        if self.request.deadline_s is None:
            return None
        return self.submitted_at + self.request.deadline_s

    def expired(self, now: Optional[float] = None) -> bool:
        d = self.deadline_at
        return d is not None and (time.monotonic() if now is None
                                  else now) >= d

    def resolve(self, result: Any) -> None:
        if not self._done.is_set():
            self._result = result
            self._done.set()

    def fail(self, exc: Exception) -> None:
        if not self._done.is_set():
            self._error = exc
            self._done.set()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._done.wait(timeout)

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request.request_id!r} still in flight")
        if self._error is not None:
            raise self._error
        return self._result


class RequestQueue:
    """Bounded, bucketed FIFO with typed shed."""

    def __init__(self, capacity: int = 64):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._buckets: dict[tuple, list[Ticket]] = {}
        self._order: list[tuple] = []       # bucket arrival order
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._closed = False

    # -- admission -----------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return sum(len(b) for b in self._buckets.values())

    def submit(self, request: SolveRequest) -> Ticket:
        """Admit or shed. Returns the ticket; raises QueueFull /
        ServerClosed (the caller keeps the request — nothing is lost)."""
        col = _telemetry.get()
        plan = fault.FaultPlan.active()
        with self._lock:
            if self._closed:
                col.count("serve.rejected", 1, reason="closed")
                raise errors.ServerClosed(request.request_id)
            depth = sum(len(b) for b in self._buckets.values())
            if depth >= self.capacity or (plan is not None
                                          and plan.on_submit()):
                col.count("serve.shed", 1)
                col.gauge("serve.queue_depth", depth)
                raise errors.QueueFull(request.request_id, self.capacity)
            t = Ticket(request)
            key = request.bucket
            if key not in self._buckets:
                self._buckets[key] = []
                self._order.append(key)
            self._buckets[key].append(t)
            col.count("serve.admitted", 1)
            col.gauge("serve.queue_depth", depth + 1)
            self._not_empty.notify_all()
            return t

    def requeue(self, tickets: list[Ticket]) -> None:
        """Put in-flight tickets back at the FRONT of their buckets
        (worker death path). Already-resolved tickets are skipped."""
        col = _telemetry.get()
        with self._lock:
            for t in reversed(tickets):
                if t.done:
                    continue
                key = t.request.bucket
                if key not in self._buckets:
                    self._buckets[key] = []
                    self._order.insert(0, key)
                self._buckets[key].insert(0, t)
                col.count("serve.requeued", 1)
            self._not_empty.notify_all()

    # -- dispatch ------------------------------------------------------------
    def take_batch(self, max_batch: int, timeout: Optional[float] = None,
                   should_stop: Optional[Callable[[], bool]] = None
                   ) -> list[Ticket]:
        """Pop up to ``max_batch`` same-bucket tickets (oldest bucket
        first, FIFO within it). Blocks up to ``timeout`` for work;
        returns [] on timeout or stop. Queue-expired tickets are failed
        here — with a pointed DeadlineExceeded — and don't occupy
        slots."""
        deadline = None if timeout is None else time.monotonic() + timeout
        expired: list[Ticket] = []
        try:
            with self._not_empty:
                while True:
                    now = time.monotonic()
                    batch = self._pop_locked(max_batch, now, expired)
                    if batch:
                        return batch
                    if should_stop is not None and should_stop():
                        return []
                    if self._closed and not self._buckets:
                        return []
                    wait = (None if deadline is None
                            else max(0.0, deadline - now))
                    if wait == 0.0:
                        return []
                    self._not_empty.wait(0.05 if wait is None
                                         else min(wait, 0.05))
                    if deadline is not None and time.monotonic() >= deadline:
                        return []
        finally:
            col = _telemetry.get()
            for t in expired:
                col.count("serve.expired", 1, where="queued")
                t.fail(errors.DeadlineExceeded(
                    t.request.request_id, t.request.deadline_s, "queued"))

    def _pop_locked(self, max_batch: int, now: float,
                    expired: list[Ticket]) -> list[Ticket]:
        for key in list(self._order):
            bucket = self._buckets.get(key, [])
            live: list[Ticket] = []
            keep: list[Ticket] = []
            for t in bucket:
                if t.done:
                    continue                    # resolved elsewhere
                if t.expired(now):
                    expired.append(t)
                elif len(live) < max_batch:
                    live.append(t)
                else:
                    keep.append(t)
            if keep:
                self._buckets[key] = keep
            else:
                self._buckets.pop(key, None)
                self._order.remove(key)
            if live:
                _telemetry.get().gauge(
                    "serve.queue_depth",
                    sum(len(b) for b in self._buckets.values()))
                return live
        return []

    # -- lifecycle -----------------------------------------------------------
    def close(self, drain: bool = True) -> None:
        """Stop admissions. ``drain=False`` fails everything queued."""
        with self._lock:
            self._closed = True
            if not drain:
                for bucket in self._buckets.values():
                    for t in bucket:
                        t.fail(errors.ServerClosed(t.request.request_id))
                self._buckets.clear()
                self._order.clear()
            self._not_empty.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed
