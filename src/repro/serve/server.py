"""SimulationServer: the hardened front door around the batch engine.

``submit`` admits (or sheds) a request and returns a ticket; a pool of
workers drains the queue through fixed-width device batches; a
supervisor thread watches worker health and replaces workers that trip
their circuit breaker or die, re-queuing their in-flight requests — a
request admitted to the queue always resolves, with a result or a
pointed error, even across a worker death.

Per-request latency is recorded as a ``serve.request`` span (queue wait
included) and the counters named in the README's Serving section tell
the load story: admitted/shed/completed/quarantined/expired/requeued.

Usage::

    server = SimulationServer(kernel, ServePolicy(max_batch=8))
    with server:
        t = server.submit(SolveRequest(fields={...}, scalars={"dt": 0.1},
                                       tol=1e-5, max_iters=500,
                                       deadline_s=2.0))
        out = t.result(timeout=10.0)   # or raises the pointed failure
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from .. import telemetry as _telemetry
from .engine import BatchEngine
from .policy import ServePolicy
from .queue import RequestQueue, SolveRequest, Ticket
from .worker import Worker

__all__ = ["SimulationServer"]


class SimulationServer:
    def __init__(self, kernel, policy: Optional[ServePolicy] = None,
                 workers: int = 1):
        self.policy = policy or ServePolicy()
        self.engine = BatchEngine(kernel, self.policy)
        self.queue = RequestQueue(self.policy.queue_capacity)
        self._workers: list[Worker] = []
        self._n_workers = workers
        self._restarts = 0
        self._seq = 0
        self._closing = threading.Event()
        self._supervisor: Optional[threading.Thread] = None
        self._started = False

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "SimulationServer":
        if self._started:
            return self
        self._started = True
        for _ in range(self._n_workers):
            self._spawn_worker()
        self._supervisor = threading.Thread(target=self._supervise,
                                            name="serve-supervisor",
                                            daemon=True)
        self._supervisor.start()
        return self

    def _spawn_worker(self) -> Worker:
        w = Worker(f"serve-worker-{self._seq}", self.engine, self.queue,
                   rank=self._seq)
        self._seq += 1
        self._workers.append(w)
        w.start()
        _telemetry.get().event("serve.worker_started", worker=w.name)
        return w

    def _supervise(self) -> None:
        """Replace tripped/dead workers (bounded restarts), re-queuing
        their unresolved in-flight tickets first."""
        col = _telemetry.get()
        while not self._closing.is_set():
            for w in list(self._workers):
                if w.alive:
                    continue
                self._workers.remove(w)
                orphans = w.in_flight()
                if orphans:
                    self.queue.requeue(orphans)
                done_reason = "tripped" if w.tripped else "died"
                col.event("serve.worker_ejected", worker=w.name,
                          reason=done_reason, requeued=len(orphans))
                if (not self.queue.closed
                        and self._restarts
                        < self.policy.max_worker_restarts):
                    self._restarts += 1
                    col.count("serve.worker_restarts", 1)
                    self._spawn_worker()
            self._closing.wait(0.05)

    def close(self, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop admissions; ``drain=True`` lets queued work finish."""
        self.queue.close(drain=drain)
        deadline = time.monotonic() + timeout
        if drain:
            while len(self.queue) and time.monotonic() < deadline:
                time.sleep(0.01)
        self._closing.set()
        for w in self._workers:
            w.stop(join=False)
        for w in self._workers:
            if w.alive:
                w._thread.join(timeout=max(0.0,
                                           deadline - time.monotonic()))
        if self._supervisor is not None:
            self._supervisor.join(timeout=1.0)
        # anything still unresolved after shutdown gets a pointed error
        for w in self._workers:
            for t in w.in_flight():
                from . import errors
                t.fail(errors.WorkerDied(t.request.request_id,
                                         "server shut down"))

    def __enter__(self) -> "SimulationServer":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- API -----------------------------------------------------------------
    def submit(self, request: SolveRequest) -> Ticket:
        """Admit or shed (raises QueueFull/ServerClosed). The returned
        ticket's latency span covers queue wait + compute."""
        if not self._started:
            self.start()
        t = self.queue.submit(request)
        col = _telemetry.get()
        if col.enabled:
            wall0, mono0 = time.time(), time.monotonic()
            rid = request.request_id

            def finish(_t=t):
                col.span_end("serve.request", wall0,
                             time.monotonic() - mono0,
                             {"request": rid,
                              "outcome": ("error:" + type(_t._error)
                                          .__name__ if _t._error
                                          else "ok")})
            _spy_on_resolve(t, finish)
        return t

    def solve(self, request: SolveRequest,
              timeout: Optional[float] = None):
        """Synchronous convenience: submit + result."""
        return self.submit(request).result(timeout)

    @property
    def workers_alive(self) -> int:
        return sum(1 for w in self._workers if w.alive)


def _spy_on_resolve(ticket: Ticket, callback) -> None:
    """Invoke ``callback`` once when the ticket resolves (telemetry)."""
    done = ticket._done
    orig_set = done.set

    def set_and_report():
        orig_set()
        try:
            callback()
        except Exception:
            pass
    done.set = set_and_report
