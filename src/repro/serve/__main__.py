"""``python -m repro.serve --demo``: a self-contained serving smoke.

Spins up a SimulationServer over the reference 3-D diffusion kernel,
submits a mixed workload — healthy requests, one with an unstable dt
(NaN quarantine), one with a hopeless deadline — and prints the
per-request outcomes plus the serving counters. Exits non-zero if any
healthy request fails, so it doubles as a CI smoke.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def _build_kernel():
    from repro.core import fd3d, init_parallel_stencil

    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"},
                 reductions={"err": "max_abs_diff(T2, T)"})
    def diffusion(T2, T, dt):
        return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                          + fd3d.d2_zi(T))}

    return diffusion


def _spike(n: int, amp: float = 1.0) -> np.ndarray:
    T = np.zeros((n, n, n), np.float32)
    T[n // 2, n // 2, n // 2] = amp
    return T


def demo(n: int = 16, requests: int = 10) -> int:
    from repro import telemetry
    from repro.serve import (SampleQuarantined, DeadlineExceeded,
                             ServePolicy, SimulationServer, SolveRequest)

    col = telemetry.configure(path=None)
    kernel = _build_kernel()
    pol = ServePolicy(max_batch=4, chunk_steps=32, check_every=4,
                      queue_capacity=64)
    outcomes: dict[str, str] = {}
    failures = 0
    with SimulationServer(kernel, pol) as server:
        tickets = []
        for i in range(requests):
            healthy = SolveRequest(
                fields={"T": _spike(n, 1.0 + 0.2 * i),
                        "T2": _spike(n, 1.0 + 0.2 * i)},
                scalars={"dt": 0.08 + 0.005 * (i % 4)},
                tol=1e-5, max_iters=600)
            tickets.append(server.submit(healthy))
        # one unstable request: dt far over the diffusion CFL -> NaN
        bad = server.submit(SolveRequest(
            fields={"T": _spike(n), "T2": _spike(n)},
            scalars={"dt": 5.0}, tol=1e-5, max_iters=600))
        # one hopeless deadline
        late = server.submit(SolveRequest(
            fields={"T": _spike(n), "T2": _spike(n)},
            scalars={"dt": 0.08}, tol=1e-12, max_iters=10**6,
            deadline_s=0.05))
        for t in tickets:
            try:
                r = t.result(timeout=60.0)
                outcomes[t.request.request_id] = (
                    f"converged in {r['iters']} steps (err {r['err']:.2e})")
            except Exception as e:
                outcomes[t.request.request_id] = f"FAILED: {e}"
                failures += 1
        for t, want in ((bad, SampleQuarantined), (late, DeadlineExceeded)):
            try:
                t.result(timeout=60.0)
                outcomes[t.request.request_id] = (
                    f"UNEXPECTED success (wanted {want.__name__})")
                failures += 1
            except want as e:
                outcomes[t.request.request_id] = f"(expected) {e}"
            except Exception as e:
                outcomes[t.request.request_id] = f"WRONG failure: {e}"
                failures += 1
    for rid, line in outcomes.items():
        print(f"  {rid:10s} {line}")
    print("\nserving counters:")
    for (name, labels), v in sorted(col.counters.items()):
        if name.startswith("serve."):
            tag = name + (str(dict(labels)) if labels else "")
            print(f"  {tag:40s} = {v}")
    print(f"\n{'OK' if failures == 0 else 'FAILED'}: "
          f"{requests} healthy + 1 quarantine + 1 deadline")
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Hardened simulation serving (see repro/serve).")
    ap.add_argument("--demo", action="store_true",
                    help="run the self-contained serving demo/smoke")
    ap.add_argument("--n", type=int, default=16, help="demo grid extent")
    ap.add_argument("--requests", type=int, default=10,
                    help="healthy demo requests")
    args = ap.parse_args(argv)
    if args.demo:
        return demo(n=args.n, requests=args.requests)
    ap.print_help()
    return 0


if __name__ == "__main__":
    sys.exit(main())
