"""The batch engine: fixed-width device batches with continuous refill.

One :class:`BatchState` owns ``max_batch`` SLOTS over a single grid
bucket. The carry is the batched solver's (see
:func:`repro.core.iterate.make_batched_solver`); a slot is either bound
to a ticket or dead (masked inactive — dead slots cost flops, not
correctness, and keep the jitted program's shapes fixed so it compiles
ONCE per bucket). Each :meth:`run_chunk` advances every live slot by up
to ``policy.chunk`` steps in one jitted call; between chunks the host

  * harvests finished slots (converged / quarantined / out-of-budget)
    and resolves their tickets with results or pointed errors,
  * fails live slots whose deadline passed (``DeadlineExceeded``),
  * refills freed slots from the queue (continuous batching: stragglers
    keep marching while new requests join at chunk boundaries),
  * applies the ``nan_at_step`` fault injection (poisons the scheduled
    sample's buffers so the device-side finite guard must catch it).

Transient batch failures (``FaultPlan.on_batch`` or a flaky runtime)
are retried with exponential backoff through ``fault.retry``.
"""
from __future__ import annotations

import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .. import telemetry as _telemetry
from ..core import iterate
from ..distributed import fault
from . import errors
from .queue import Ticket

__all__ = ["BatchEngine", "BatchState"]


class BatchState:
    """Slot table + device carry for one in-flight batch."""

    def __init__(self, engine: "BatchEngine", tickets: list[Ticket]):
        self.engine = engine
        pol = engine.policy
        kernel = engine.kernel
        b = pol.max_batch
        if len(tickets) > b:
            raise ValueError(f"{len(tickets)} tickets > max_batch {b}")
        self.slots: list[Optional[Ticket]] = list(tickets) + [None] * (
            b - len(tickets))
        t0 = tickets[0].request
        self.scalar_names = tuple(sorted(t0.scalars))
        self.bucket = t0.bucket
        for t in tickets:
            self._check_compatible(t)
        stacked = {
            n: jnp.stack([
                jnp.asarray(self.slots[i].request.fields[n], kernel.ps.dtype)
                if self.slots[i] is not None
                else jnp.zeros(t0.fields[n].shape, kernel.ps.dtype)
                for i in range(b)])
            for n in t0.fields}
        self.carry = iterate.init_batch_carry(
            kernel, stacked,
            active=np.array([s is not None for s in self.slots]))
        self.injected = False       # nan_at_step fires once per batch
        self.started_at = time.monotonic()

    def _check_compatible(self, t: Ticket):
        if t.request.bucket != self.bucket:
            raise ValueError(
                f"request {t.request.request_id!r} bucket does not match "
                "the batch (grid-bucketed queues should prevent this)")
        if tuple(sorted(t.request.scalars)) != self.scalar_names:
            raise ValueError(
                f"request {t.request.request_id!r} scalars "
                f"{tuple(sorted(t.request.scalars))} != batch scalars "
                f"{self.scalar_names}; one bucket must share scalar names")

    # -- slot views ----------------------------------------------------------
    @property
    def live(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    @property
    def n_live(self) -> int:
        return len(self.live)

    def _vec(self, get, fill, dtype):
        return np.array([fill if s is None else get(s)
                         for s in self.slots], dtype)

    def scalar_vectors(self) -> dict:
        return {n: self._vec(lambda s, n=n: s.request.scalars[n], 0.0,
                             np.float32)
                for n in self.scalar_names}

    # -- refill --------------------------------------------------------------
    def bind(self, slot: int, ticket: Ticket) -> None:
        """Bind a fresh ticket to a freed slot: reset its per-sample
        carry state and write its initial fields."""
        self._check_compatible(ticket)
        if self.slots[slot] is not None:
            raise ValueError(f"slot {slot} still bound")
        self.slots[slot] = ticket
        kernel = self.engine.kernel
        c = self.carry
        for n, v in ticket.request.fields.items():
            c.fields[n] = c.fields[n].at[slot].set(
                jnp.asarray(v, kernel.ps.dtype))
        inf = np.inf if self.engine.policy.until == "below" else -np.inf
        c.err = c.err.at[slot].set(np.float32(inf))
        c.steps = c.steps.at[slot].set(0)
        c.active = c.active.at[slot].set(True)
        c.converged = c.converged.at[slot].set(False)
        c.bad = c.bad.at[slot].set(False)

    def release(self, slot: int) -> Ticket:
        t = self.slots[slot]
        self.slots[slot] = None
        self.carry.active = self.carry.active.at[slot].set(False)
        return t

    def deactivate(self, slot: int) -> None:
        self.carry.active = self.carry.active.at[slot].set(False)

    def poison(self, slot: int) -> None:
        """NaN the slot's buffers (fault injection: the finite guard in
        the DEVICE loop must detect and quarantine it)."""
        c = self.carry
        for n in c.fields:
            c.fields[n] = c.fields[n].at[slot].set(jnp.nan)
        self.injected = True

    def result_for(self, slot: int) -> dict:
        """Materialize one finished slot's payload."""
        c = self.carry
        return {
            "fields": {n: np.asarray(v[slot]) for n, v in c.fields.items()},
            "reds": {n: float(v[slot]) for n, v in c.reds.items()},
            "err": float(c.err[slot]),
            "iters": int(c.steps[slot]),
        }


class BatchEngine:
    """Builds/caches the jitted batched solver and advances BatchStates."""

    def __init__(self, kernel, policy):
        self.kernel = kernel
        self.policy = policy
        self._solver = iterate.jitted_batched_solver(
            kernel, check_every=policy.check_every, error=policy.error,
            until=policy.until)

    def start(self, tickets: list[Ticket]) -> BatchState:
        return BatchState(self, tickets)

    def run_chunk(self, state: BatchState) -> None:
        """One jitted advance of up to ``policy.chunk`` steps, retried
        on transient failure. Raises the final failure when the retry
        budget is exhausted (the worker's breaker counts those)."""
        pol = self.policy
        c = state.carry
        scal = {n: jnp.asarray(v) for n, v in state.scalar_vectors().items()}
        tol = state._vec(lambda s: s.request.tol, 0.0, np.float32)
        budget = state._vec(lambda s: s.request.max_iters, 0, np.int32)
        plan = fault.FaultPlan.active()
        calls = {"n": 0}

        def exec_once():
            calls["n"] += 1
            if plan is not None:
                plan.on_batch()
            return self._solver(c.tuple(), scal, tol, budget, pol.chunk)

        col = _telemetry.get()
        with col.span("serve.chunk", live=state.n_live):
            final = fault.retry(exec_once, attempts=pol.retry_attempts,
                                backoff_s=pol.retry_backoff_s,
                                exceptions=(fault.TransientIOError,))
        if calls["n"] > 1:
            col.count("serve.batch_retries", calls["n"] - 1)
        state.carry = iterate.BatchCarry.from_tuple(final)

    # -- host-side pass between chunks --------------------------------------
    def harvest(self, state: BatchState) -> list[int]:
        """Resolve finished slots; fail expired live slots; apply the
        nan_at_step injection. Returns the freed slot indices."""
        col = _telemetry.get()
        c = state.carry
        # ONE host sync for the whole batch state (chunk boundary — the
        # same sync the refill decision needs anyway)
        active = np.asarray(c.active)
        converged = np.asarray(c.converged)
        bad = np.asarray(c.bad)
        steps = np.asarray(c.steps)
        err = np.asarray(c.err)
        now = time.monotonic()
        freed: list[int] = []

        plan = fault.FaultPlan.active()
        if plan is not None and not state.injected:
            victim = plan.serve_nan_due(int(steps[state.live[0]])
                                        if state.live else 0)
            if victim is not None and victim < len(state.slots) \
                    and state.slots[victim] is not None and active[victim]:
                state.poison(victim)
                col.event("serve.fault_injected", kind="nan",
                          slot=victim,
                          request=state.slots[victim].request.request_id)

        for i, ticket in enumerate(state.slots):
            if ticket is None:
                continue
            if not active[i]:
                t = state.release(i)
                freed.append(i)
                if bad[i]:
                    col.count("serve.quarantined", 1)
                    t.fail(errors.SampleQuarantined(
                        t.request.request_id, int(steps[i])))
                elif converged[i]:
                    col.count("serve.completed", 1)
                    t.resolve(state.result_for(i))
                else:
                    col.count("serve.budget_exhausted", 1)
                    t.fail(errors.BudgetExhausted(
                        t.request.request_id, int(steps[i]),
                        float(err[i])))
            elif ticket.expired(now):
                state.deactivate(i)
                t = state.release(i)
                freed.append(i)
                col.count("serve.expired", 1, where="in_batch")
                t.fail(errors.DeadlineExceeded(
                    t.request.request_id, t.request.deadline_s, "in_batch"))
        return freed

    def expire_all(self, state: BatchState, where: str) -> None:
        """Batch-level timeout: fail every still-live slot."""
        col = _telemetry.get()
        for i in list(state.live):
            state.deactivate(i)
            t = state.release(i)
            col.count("serve.expired", 1, where=where)
            t.fail(errors.DeadlineExceeded(
                t.request.request_id,
                t.request.deadline_s
                if t.request.deadline_s is not None
                else self.policy.batch_timeout_s, where))
