"""Multi-process serving: a pool of OS-process workers over a spool.

The thread-based :class:`~repro.serve.server.SimulationServer` dies with
its process; this pool is the serving analogue of the multihost gang —
N :mod:`repro.serve.procworker` child processes drain a shared
filesystem spool, watched by the SAME supervisor primitives the solve
launcher uses (:func:`repro.launch.multihost.kill_process`,
:func:`~repro.launch.multihost.heartbeat_ages`, run-id-namespaced
:class:`~repro.distributed.fault.Heartbeat` files with stale-run
retirement).

Recovery contract: when a worker dies (exit code) or wedges (stale
heartbeat -> SIGKILL), its claimed-but-unfinished request files are
renamed back into ``pending/`` — their original sequence prefix puts
them at the FRONT of the sorted backlog, so recovery never reorders the
waiting requests — and a replacement worker is spawned (up to
``max_worker_restarts``). The dead incarnation's heartbeat file is
retired before the respawn: a replacement starts with NO liveness file
and is not judged stale until after its own first bump, so the seconds
of interpreter/jax startup (and first-request compile) can never be
mistaken for a wedge by the leftover, already-stale file of the worker
it replaces. Zero requests are lost; each resolves with a result file
or a typed error file.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
import traceback
import uuid
from typing import Optional

from ..distributed import fault
from ..launch.multihost import (ENV_HEARTBEAT_DIR, ENV_PROCESS_ID,
                                ENV_RUN_ID, heartbeat_ages, kill_process)
from .errors import ServerClosed, WorkerDied
from .procworker import CLOSED_MARKER, read_result, write_request

__all__ = ["ProcessWorkerPool", "ProcTicket"]


class ProcTicket:
    """Handle to one spooled request; resolves from the ``done/`` dir."""

    def __init__(self, pool: "ProcessWorkerPool", name: str):
        self._pool = pool
        self.request_id = name

    def result(self, timeout: Optional[float] = None) -> tuple[dict, dict]:
        """Block for ``(fields, meta)``; raises the typed failure a
        worker recorded, or :class:`WorkerDied` if the pool shut down
        with this request unserved."""
        done = os.path.join(self._pool.spool, "done")
        ok = os.path.join(done, self.request_id)
        err = os.path.join(done, self.request_id + ".err.json")
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if os.path.exists(ok):
                return read_result(ok)
            if os.path.exists(err):
                with open(err) as f:
                    detail = json.load(f)
                raise WorkerDied(
                    self.request_id,
                    f"request {self.request_id!r} failed in worker "
                    f"{detail.get('rank')}: {detail.get('error')}: "
                    f"{detail.get('detail')}")
            # failed means ONE rank exhausted its restarts; surviving
            # workers keep draining the spool and may still serve this
            # request — only give up once nobody is left to serve it
            if self._pool.failed and not self._pool._procs:
                raise WorkerDied(self.request_id,
                                 f"request {self.request_id!r} unserved: "
                                 "pool exhausted its worker restarts and "
                                 "no live workers remain")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"request {self.request_id!r} not done in {timeout}s")
            time.sleep(0.01)


class ProcessWorkerPool:
    def __init__(self, spool: str, workers: int = 2, *,
                 kernel: str = "repro.serve.procworker:demo_kernel",
                 heartbeat_timeout_s: float = 30.0,
                 max_worker_restarts: int = 4,
                 grace_s: float = 2.0,
                 poll_s: float = 0.05,
                 run_id: Optional[str] = None,
                 env: Optional[dict] = None):
        self.spool = spool
        self.n_workers = int(workers)
        self.kernel = kernel
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.max_worker_restarts = max_worker_restarts
        self.grace_s = grace_s
        self.poll_s = poll_s
        self.run_id = run_id or f"pool{os.getpid()}"
        self.env = dict(env or {})
        self.heartbeat_dir = os.path.join(spool, "hb")
        self.restarts = 0
        self.recovered = 0
        self.failed = False
        self._seq = 0
        self._lock = threading.Lock()
        self._procs: dict[int, subprocess.Popen] = {}
        self._stop = threading.Event()
        self._watcher: Optional[threading.Thread] = None
        self._closed = False
        for d in ("pending", "done", "claimed", "hb"):
            os.makedirs(os.path.join(spool, d), exist_ok=True)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ProcessWorkerPool":
        fault.Heartbeat.retire_stale(self.heartbeat_dir)
        marker = os.path.join(self.spool, CLOSED_MARKER)
        if os.path.exists(marker):
            os.unlink(marker)
        for rank in range(self.n_workers):
            self._spawn(rank)
        self._watcher = threading.Thread(target=self._watch,
                                         name="pool-supervisor", daemon=True)
        self._watcher.start()
        return self

    def _spawn(self, rank: int) -> None:
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
        env["XLA_FLAGS"] = " ".join(flags)
        env.setdefault("JAX_PLATFORMS", "cpu")
        env.pop(fault.PLAN_ENV, None)   # plans reach workers via self.env
        env[ENV_PROCESS_ID] = str(rank)
        env[ENV_RUN_ID] = self.run_id
        env[ENV_HEARTBEAT_DIR] = self.heartbeat_dir
        env.update(self.env)
        self._procs[rank] = subprocess.Popen(
            [sys.executable, "-m", "repro.serve.procworker",
             "--spool", self.spool, "--kernel", self.kernel,
             "--rank", str(rank)],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)

    def _recover_claims(self, rank: int) -> int:
        """Dead worker's claimed requests go BACK to pending (names keep
        their sequence prefix -> front of the sorted backlog)."""
        claimed = os.path.join(self.spool, "claimed", f"rank_{rank}")
        pending = os.path.join(self.spool, "pending")
        n = 0
        if not os.path.isdir(claimed):
            return 0
        for name in sorted(os.listdir(claimed)):
            if not name.endswith(".npz"):
                continue
            try:
                os.rename(os.path.join(claimed, name),
                          os.path.join(pending, name))
                n += 1
            except OSError:
                continue
        return n

    def _retire_heartbeat(self, hb: fault.Heartbeat, rank: int) -> None:
        """Remove a dead incarnation's liveness file (and any torn tmp).
        Without this, the leftover file — already older than
        ``heartbeat_timeout_s`` — would condemn the freshly spawned
        replacement before it finishes interpreter startup, and the
        watcher would kill-loop replacements until the restart budget
        was gone."""
        for path in (hb.path(rank), hb.path(rank) + ".tmp"):
            try:
                os.unlink(path)
            except OSError:
                pass

    def _watch_once(self, hb: fault.Heartbeat) -> bool:
        """One supervision sweep; True when the drain is complete."""
        for rank, proc in list(self._procs.items()):
            rc = proc.poll()
            stale = (rc is None and heartbeat_ages(hb).get(rank, 0.0)
                     > self.heartbeat_timeout_s)
            if rc is None and not stale:
                continue
            if stale:
                kill_process(proc, self.grace_s)
            self._retire_heartbeat(hb, rank)
            if self._closed and proc.returncode == 0:
                del self._procs[rank]   # clean drain exit
                continue
            self.recovered += self._recover_claims(rank)
            if self.restarts >= self.max_worker_restarts:
                self.failed = True
                del self._procs[rank]
                continue
            self.restarts += 1
            # injected fault plans are one-shot: the replacement
            # worker must not inherit the schedule that killed it
            self.env.pop(fault.PLAN_ENV, None)
            self._spawn(rank)
        return self._closed and not self._procs

    def _watch(self) -> None:
        hb = fault.Heartbeat(self.heartbeat_dir,
                             timeout_s=self.heartbeat_timeout_s,
                             run_id=self.run_id)
        while not self._stop.is_set():
            try:
                if self._watch_once(hb):
                    return
            except Exception:   # supervision must not die silently: an
                # unexpected error (e.g. a filesystem hiccup outside the
                # handled paths) is logged and the next sweep retries
                sys.stderr.write("pool-supervisor: sweep failed "
                                 "(continuing)\n" + traceback.format_exc())
            time.sleep(self.poll_s)

    def close(self, timeout: Optional[float] = 60.0) -> None:
        """Drain: drop the CLOSED marker, let workers finish the backlog
        and exit, then stop the watcher (force-kill past ``timeout``)."""
        self._closed = True
        with open(os.path.join(self.spool, CLOSED_MARKER), "w") as f:
            f.write(self.run_id)
        deadline = None if timeout is None else time.monotonic() + timeout
        while self._procs:
            if deadline is not None and time.monotonic() > deadline:
                for proc in self._procs.values():
                    kill_process(proc, self.grace_s)
                break
            time.sleep(self.poll_s)
        self._stop.set()
        if self._watcher is not None:
            self._watcher.join(timeout=5.0)
        for proc in self._procs.values():
            kill_process(proc, self.grace_s)
        self._procs.clear()

    def __enter__(self) -> "ProcessWorkerPool":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission ----------------------------------------------------------
    def submit(self, fields: dict, scalars: Optional[dict] = None, *,
               tol: float = 0.0, max_iters: int = 100,
               check_every: int = 1) -> ProcTicket:
        if self._closed:
            raise ServerClosed("(pool)")
        with self._lock:
            seq = self._seq
            self._seq += 1
        name = f"{seq:08d}_{uuid.uuid4().hex[:8]}.npz"
        write_request(
            os.path.join(self.spool, "pending", name), fields,
            {"scalars": {k: float(v) for k, v in (scalars or {}).items()},
             "tol": float(tol), "max_iters": int(max_iters),
             "check_every": int(check_every)})
        return ProcTicket(self, name)
