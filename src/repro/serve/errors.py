"""Pointed, typed failure reasons for the serving layer.

Every way a request can fail maps to ONE exception class carrying the
request id and enough context to act on — "your sample went NaN at step
24" is a different operator page than "the queue was full". A request is
never lost silently: it resolves with a result or with exactly one of
these.
"""
from __future__ import annotations

__all__ = [
    "ServeError", "RequestRejected", "QueueFull", "ServerClosed",
    "DeadlineExceeded", "SampleQuarantined", "BudgetExhausted",
    "WorkerDied",
]


class ServeError(RuntimeError):
    """Base class; carries ``request_id``."""

    reason = "error"

    def __init__(self, request_id: str, msg: str):
        self.request_id = request_id
        super().__init__(msg)


class RequestRejected(ServeError):
    """Admission refused — the request never entered the queue."""

    reason = "rejected"


class QueueFull(RequestRejected):
    """Load shed: the bounded queue was at capacity (backpressure —
    resubmit later or raise the queue bound)."""

    reason = "queue_full"

    def __init__(self, request_id: str, capacity: int):
        self.capacity = capacity
        super().__init__(
            request_id,
            f"request {request_id!r} shed: queue at capacity {capacity}")


class ServerClosed(RequestRejected):
    """Admission after shutdown began."""

    reason = "closed"

    def __init__(self, request_id: str):
        super().__init__(request_id,
                         f"request {request_id!r} rejected: server closed")


class DeadlineExceeded(ServeError):
    """The request's deadline passed before it finished (it may have
    expired in the queue or mid-batch; ``where`` says which)."""

    reason = "deadline"

    def __init__(self, request_id: str, deadline_s: float, where: str):
        self.deadline_s = deadline_s
        self.where = where
        super().__init__(
            request_id,
            f"request {request_id!r} exceeded its {deadline_s:.3f}s "
            f"deadline ({where})")


class SampleQuarantined(ServeError):
    """The device-side finite guard tripped for this sample: its field
    went NaN/Inf at the reported step. The rest of the batch was
    unaffected — check the request's scalars (unstable dt?) or initial
    condition."""

    reason = "quarantined"

    def __init__(self, request_id: str, step: int):
        self.step = step
        super().__init__(
            request_id,
            f"request {request_id!r} quarantined: non-finite field "
            f"detected at step {step} (NaN/Inf guard). The remaining "
            "batch completed; check this request's scalars/IC")


class BudgetExhausted(ServeError):
    """The sample ran out of its iteration budget without converging
    (and without going non-finite)."""

    reason = "budget"

    def __init__(self, request_id: str, iters: int, err: float):
        self.iters = iters
        self.err = err
        super().__init__(
            request_id,
            f"request {request_id!r} did not converge in {iters} steps "
            f"(final err {err:.3e})")


class WorkerDied(ServeError):
    """The worker processing this request died and the request could
    not be re-queued (retries/requeues exhausted)."""

    reason = "worker_died"

    def __init__(self, request_id: str, detail: str = ""):
        super().__init__(
            request_id,
            f"request {request_id!r} lost its worker"
            + (f": {detail}" if detail else ""))
