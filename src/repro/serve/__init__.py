"""repro.serve — the hardened simulation-serving layer.

Accepts many independent solve requests (per-request scalars and
initial conditions on a common grid bucket) and runs them as
dynamically assembled, continuously refilled device batches through the
batch-axis solver (:func:`repro.core.iterate.solve_batch` machinery),
wrapped in production robustness: a bounded queue with backpressure and
typed load-shedding, per-request deadlines and batch-level timeouts,
retry-with-backoff for transient batch failures, a device-resident
NaN/Inf guard that quarantines diverging samples while the rest of the
batch completes, and a worker circuit-breaker/supervisor layer that
re-queues in-flight requests when a worker trips or dies.

Entry points::

    from repro.serve import SimulationServer, ServePolicy, SolveRequest
    python -m repro.serve --demo      # self-contained smoke demo

Failure taxonomy (all carry request_id): QueueFull / ServerClosed
(shed at admission), DeadlineExceeded, SampleQuarantined,
BudgetExhausted, WorkerDied.
"""
from .errors import (BudgetExhausted, DeadlineExceeded, QueueFull,
                     RequestRejected, SampleQuarantined, ServeError,
                     ServerClosed, WorkerDied)
from .policy import ServePolicy
from .pool import ProcessWorkerPool, ProcTicket
from .queue import RequestQueue, SolveRequest, Ticket, bucket_key
from .server import SimulationServer

__all__ = [
    "SimulationServer", "ServePolicy", "SolveRequest", "Ticket",
    "RequestQueue", "bucket_key",
    "ProcessWorkerPool", "ProcTicket",
    "ServeError", "RequestRejected", "QueueFull", "ServerClosed",
    "DeadlineExceeded", "SampleQuarantined", "BudgetExhausted",
    "WorkerDied",
]
