"""Serving policy: every tunable of the hardened serving path in one
frozen dataclass, so a server's behavior is one printable object."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

__all__ = ["ServePolicy"]


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    """Knobs for :class:`~repro.serve.server.SimulationServer`.

    Batching — ``max_batch`` is the slot count of the device batch (the
    jitted program is compiled once per bucket at this width; partial
    batches run with dead slots masked inactive). ``chunk_steps`` is how
    many steps each jitted call advances before the host looks again —
    the refill/deadline/quarantine cadence. It is rounded up to a whole
    number of ``check_every`` blocks. ``collect_window_s`` is how long a
    worker waits to aggregate a fuller batch before launching a partial
    one.

    Robustness — ``batch_timeout_s`` bounds one batch's wall time: when
    it expires, still-running samples fail with a pointed
    ``DeadlineExceeded`` rather than holding the worker. ``retry_*``
    drive :func:`repro.distributed.fault.retry` around transiently
    failing batch executions. ``breaker_threshold`` consecutive
    non-transient batch failures trip the worker's circuit breaker: its
    in-flight requests re-queue and the supervisor replaces the worker
    (up to ``max_worker_restarts``).
    """

    # batching
    max_batch: int = 8
    chunk_steps: int = 64
    check_every: int = 4
    collect_window_s: float = 0.02
    queue_capacity: int = 64

    # solve semantics (forwarded to the batched solver)
    error: Union[str, Callable, None] = None
    until: str = "below"

    # robustness
    batch_timeout_s: Optional[float] = None
    retry_attempts: int = 3
    retry_backoff_s: float = 0.05
    breaker_threshold: int = 3
    max_worker_restarts: int = 2
    heartbeat_dir: Optional[str] = None
    heartbeat_timeout_s: float = 60.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.check_every < 1:
            raise ValueError(
                f"check_every must be >= 1, got {self.check_every}")
        if self.chunk_steps < 1:
            raise ValueError(
                f"chunk_steps must be >= 1, got {self.chunk_steps}")
        if self.retry_attempts < 1:
            raise ValueError(
                f"retry_attempts must be >= 1, got {self.retry_attempts}")

    @property
    def chunk(self) -> int:
        """chunk_steps rounded up to whole check_every blocks."""
        m = self.check_every
        return ((self.chunk_steps + m - 1) // m) * m
