"""Generic Pallas stencil kernel builder (the paper's C3/C4 on TPU).

ParallelStencil's ``@parallel loopopt=true`` generates a CUDA kernel where a
thread block stages a halo-extended tile of the input fields in shared
memory/registers and sweeps it. The TPU-native equivalent built here:

  * the Pallas *grid* tiles the full array; every input field gets a
    **halo-extended VMEM window** (element-indexed, overlapping windows
    with OOB padding — ``pl.Element`` dims on new jax, the equivalent
    ``Unblocked`` indexing mode on jax <= 0.4.x) — this is the BlockSpec
    realization of shared-memory blocking;
  * the kernel body evaluates the *same math-close update function* the
    ``jnp`` backend uses, on the window, producing the block-interior
    update;
  * a per-block interior mask blends the update with the output field's
    previous (boundary) values, so one fused pass writes the full output
    array — boundary handling costs no extra kernel;
  * scalars ride in SMEM;
  * launch parameters (grid + block shapes) are **derived automatically**
    from the array bounds, stencil radius and a VMEM budget, mirroring
    ParallelStencil's automatic launch-parameter derivation;
  * **temporal blocking** (``nsteps=k``): the VMEM window halo grows to
    ``k*radius`` and the update function is swept ``k`` times inside one
    launch, the valid region shrinking by ``radius`` per sweep. Each field
    then crosses HBM once per *k* steps instead of once per step, cutting
    A_eff by ~k at the cost of redundant halo-cone recompute per block.

Caveats (documented): the update function must not read an *output* field's
halo ring (its window is only used as the boundary-copy source). All paper
solvers satisfy this — e.g. Fig. 1's ``T2`` is write-only. With ``nsteps>1``
the k-step result is bitwise-identical to k rotated single-step calls
provided the rotation buffers agree on their boundary rings (true for all
solvers here: both buffers start as copies and boundaries are never
updated).
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default VMEM working-set budget per kernel instance. v5e has 128 MiB of
# VMEM per core; leave generous headroom for Pallas pipelining (double
# buffering doubles the live window set) and spills.
DEFAULT_VMEM_BUDGET = 8 << 20


def _divisors_leq(n: int, cap: int) -> list[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def _pick_block(n: int, cap: int, align: int) -> int:
    """Largest divisor of n that is <= cap, preferring multiples of align."""
    divs = _divisors_leq(n, cap)
    aligned = [d for d in divs if d % align == 0]
    return (aligned or divs)[-1]


def derive_launch(
    shape: Sequence[int],
    radius: int,
    n_fields: int,
    itemsize: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    tile: Sequence[int] | None = None,
    nsteps: int = 1,
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Derive (grid, block_shape) from array bounds — ParallelStencil's
    automatic launch-parameter derivation, with TPU tiling constraints.

    The minor (last) axis prefers 128-lane multiples, the next-to-minor
    8-sublane multiples. Blocks must divide the array extents (the caller
    pads otherwise). The block set is shrunk until the halo-extended
    windows of all fields fit the VMEM budget. With temporal blocking
    (``nsteps > 1``) the window halo is ``nsteps * radius`` per side, so
    the same budget yields smaller blocks.
    """
    shape = tuple(int(s) for s in shape)
    nd = len(shape)
    halo = radius * max(int(nsteps), 1)
    if tile is not None:
        block = tuple(int(b) for b in tile)
        if len(block) != nd or any(s % b for s, b in zip(shape, block)):
            raise ValueError(f"tile {block} must divide shape {shape}")
    else:
        caps = [256 if a == nd - 1 else (64 if a == nd - 2 else 16) for a in range(nd)]
        aligns = [128 if a == nd - 1 else (8 if a == nd - 2 else 1) for a in range(nd)]
        block = [
            _pick_block(s, c, al) for s, c, al in zip(shape, caps, aligns)
        ]

        def window_bytes(blk):
            return n_fields * math.prod(b + 2 * halo for b in blk) * itemsize

        # Shrink the largest non-minor axis first; keep lane alignment longest.
        while window_bytes(block) > vmem_budget:
            cands = sorted(range(nd), key=lambda a: (a == nd - 1, -block[a]))
            for a in cands:
                smaller = [d for d in _divisors_leq(shape[a], block[a] - 1)]
                if smaller:
                    block[a] = smaller[-1]
                    break
            else:
                break  # cannot shrink further; let it ride
        block = tuple(block)
    grid = tuple(s // b for s, b in zip(shape, block))
    return grid, block


def halo_window_spec(
    block: Sequence[int],
    halo: Sequence[int | tuple[int, int]],
    index_map: Callable,
) -> pl.BlockSpec:
    """BlockSpec for an overlapping, halo-extended VMEM window.

    ``halo`` gives the per-dimension (lo, hi) extension (an int means
    symmetric). ``index_map`` must return *element* offsets in the padded
    coordinate system — for a stride-``block`` tiling that is simply
    ``pid * block`` per dim. Out-of-bounds cells read as garbage/NaN and
    must be masked by the kernel body.

    Version compat: jax >= 0.5 expresses this with ``pl.Element`` block
    dims; jax 0.4.x spells the identical semantics as the ``Unblocked``
    indexing mode with padding.
    """
    halo = tuple((h, h) if isinstance(h, int) else (int(h[0]), int(h[1]))
                 for h in halo)
    if hasattr(pl, "Element"):
        dims = tuple(
            pl.Element(b + lo + hi, padding=(lo, hi))
            for b, (lo, hi) in zip(block, halo)
        )
        return pl.BlockSpec(dims, index_map)
    win = tuple(b + lo + hi for b, (lo, hi) in zip(block, halo))
    return pl.BlockSpec(win, index_map, indexing_mode=pl.Unblocked(halo))


def compiler_params(nd: int):
    """All-parallel ``dimension_semantics`` for an nd stencil grid (every
    block is independent), letting Mosaic pipeline block revisits. Returns
    None when this jax has no TPU compiler-params surface."""
    cp = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cp is None:
        return None
    return cp(dimension_semantics=("parallel",) * nd)


def _interior_mask(block: tuple[int, ...], shape: tuple[int, ...], radius: int,
                   extent: int = 0):
    """Boolean mask marking globally-interior cells over this block extended
    by ``extent`` cells per side (extent=0: the block itself; temporal
    sweeps mask progressively shrinking super-blocks)."""
    nd = len(block)
    mshape = tuple(b + 2 * extent for b in block)
    m = None
    for a in range(nd):
        pid = pl.program_id(a)
        g = pid * block[a] - extent + jax.lax.broadcasted_iota(jnp.int32, mshape, a)
        ma = (g >= radius) & (g < shape[a] - radius)
        m = ma if m is None else (m & ma)
    return m


def build_stencil_call(
    update_fn: Callable[[Mapping[str, jax.Array], Mapping[str, jax.Array]], Mapping[str, jax.Array]],
    *,
    field_names: Sequence[str],
    out_names: Sequence[str],
    scalar_names: Sequence[str],
    shape: Sequence[int],
    radius: int,
    dtype,
    tile: Sequence[int] | None = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    interpret: bool | None = None,
    nsteps: int = 1,
    rotations: Mapping[str, str] | None = None,
) -> Callable[..., dict[str, jax.Array]]:
    """Build a fused Pallas stencil step (or a k-step temporal block).

    ``update_fn(fields, scalars) -> {out_name: interior_update}`` is traced
    on halo-extended VMEM windows. Returns ``run(fields, scalars)`` mapping
    full arrays -> dict of full output arrays.

    With ``nsteps=k > 1`` the update is swept k times inside the kernel:
    the windows carry a ``k*radius`` halo, each sweep shrinks them by
    ``radius`` per side, and ``rotations[out_name]`` names the input field
    the sweep's output becomes for the next sweep (the in-kernel analogue
    of the solver's ``T, T2 = T2, T`` double-buffer rotation).
    """
    shape = tuple(int(s) for s in shape)
    nd = len(shape)
    dtype = jnp.dtype(dtype)
    field_names = tuple(field_names)
    out_names = tuple(out_names)
    scalar_names = tuple(scalar_names)
    nsteps = int(nsteps)
    if nsteps < 1:
        raise ValueError(f"nsteps must be >= 1, got {nsteps}")
    for o in out_names:
        if o not in field_names:
            raise ValueError(
                f"output {o!r} must also be an input field (boundary-copy source)"
            )
    if nsteps > 1:
        rotations = dict(rotations or {})
        missing = set(out_names) - set(rotations)
        if missing:
            raise ValueError(
                f"nsteps={nsteps} needs rotations for outputs {sorted(missing)} "
                "(e.g. rotations={'T2': 'T'}: each sweep's T2 becomes next sweep's T)"
            )
        for o, tgt in rotations.items():
            if tgt not in field_names:
                raise ValueError(f"rotation target {tgt!r} is not a field")
            if tgt in out_names:
                raise ValueError(
                    f"rotation target {tgt!r} is an output; outputs only "
                    "provide boundary values and cannot receive sweep results"
                )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid, block = derive_launch(
        shape, radius, len(field_names), dtype.itemsize, vmem_budget, tile,
        nsteps=nsteps,
    )
    r = radius
    halo = r * nsteps

    def in_index_map(*pids):
        return tuple(pid * b for pid, b in zip(pids, block))

    def out_index_map(*pids):
        return pids

    n_s, n_f = len(scalar_names), len(field_names)
    center = tuple(slice(r, r + b) for b in block)

    def _crop(a, w: int):
        return a[tuple(slice(w, d - w) for d in a.shape)]

    def body(*refs):
        scal_refs = refs[:n_s]
        in_refs = refs[n_s : n_s + n_f]
        out_refs = refs[n_s + n_f :]
        scalars = {n: ref[0] for n, ref in zip(scalar_names, scal_refs)}
        windows = {n: ref[...] for n, ref in zip(field_names, in_refs)}
        for s in range(nsteps - 1):
            updates = update_fn(windows, scalars)
            ext = (nsteps - 1 - s) * r  # remaining halo extent after this sweep
            mask = _interior_mask(block, shape, r, ext)
            windows = {n: _crop(w, r) for n, w in windows.items()}
            for o in out_names:
                tgt = rotations[o]
                # Boundary cells keep carrying their original values (the
                # boundary condition is constant across sweeps).
                windows[tgt] = jnp.where(mask, updates[o].astype(dtype),
                                         windows[tgt])
        updates = update_fn(windows, scalars)
        missing = set(out_names) - set(updates)
        if missing:
            raise ValueError(f"update_fn did not produce outputs {missing}")
        mask = _interior_mask(block, shape, r)
        for name, oref in zip(out_names, out_refs):
            prev = windows[name][center]
            oref[...] = jnp.where(mask, updates[name].astype(dtype), prev)

    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM) for _ in scalar_names]
    in_specs += [
        halo_window_spec(block, (halo,) * nd, in_index_map) for _ in field_names
    ]
    out_specs = [pl.BlockSpec(block, out_index_map) for _ in out_names]
    out_shape = [jax.ShapeDtypeStruct(shape, dtype) for _ in out_names]

    kwargs = {}
    if not interpret:
        cp = compiler_params(nd)
        if cp is not None:
            kwargs["compiler_params"] = cp
    call = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs[0] if len(out_names) == 1 else out_specs,
        out_shape=out_shape[0] if len(out_names) == 1 else out_shape,
        interpret=interpret,
        **kwargs,
    )

    def run(fields: Mapping[str, jax.Array], scalars: Mapping[str, jax.Array]):
        ordered_scal = [
            jnp.asarray(scalars[n], dtype=dtype).reshape((1,)) for n in scalar_names
        ]
        ordered_fields = [jnp.asarray(fields[n], dtype=dtype) for n in field_names]
        for n, f in zip(field_names, ordered_fields):
            if f.shape != shape:
                raise ValueError(f"field {n!r} has shape {f.shape}, expected {shape}")
        outs = call(*ordered_scal, *ordered_fields)
        if len(out_names) == 1:
            outs = [outs]
        return dict(zip(out_names, outs))

    run.grid = grid
    run.block = block
    run.nsteps = nsteps
    run.window_bytes = len(field_names) * math.prod(
        b + 2 * halo for b in block
    ) * dtype.itemsize
    return run
