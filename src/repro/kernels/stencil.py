"""Generic Pallas stencil kernel builder (the paper's C3/C4 on TPU).

ParallelStencil's ``@parallel loopopt=true`` generates a CUDA kernel where a
thread block stages a halo-extended tile of the input fields in shared
memory/registers and sweeps it. The TPU-native equivalent built here:

  * the Pallas *grid* tiles the full array; every input field gets a
    **halo-extended VMEM window** (element-indexed, overlapping windows
    with OOB padding — ``pl.Element`` dims on new jax, the equivalent
    ``Unblocked`` indexing mode on jax <= 0.4.x) — this is the BlockSpec
    realization of shared-memory blocking;
  * the kernel body evaluates the *same math-close update function* the
    ``jnp`` backend uses, on the window, producing the block-interior
    update;
  * a per-block interior mask blends the update with the output field's
    previous (boundary) values, so one fused pass writes the full output
    array — boundary handling costs no extra kernel;
  * scalars ride in SMEM;
  * launch parameters (grid + block shapes) are **derived automatically**
    from the array bounds, stencil radius and a VMEM budget, mirroring
    ParallelStencil's automatic launch-parameter derivation;
  * **temporal blocking** (``nsteps=k``): the VMEM window halo grows to
    ``k*radius`` and the update function is swept ``k`` times inside one
    launch, the valid region shrinking by ``radius`` per sweep. Each field
    then crosses HBM once per *k* steps instead of once per step, cutting
    A_eff by ~k at the cost of redundant halo-cone recompute per block.

Coupled multi-field systems
---------------------------
One launch may carry several simultaneous output fields (``out_names``)
and **mixed-shape staggered fields**: a field whose extent along axis ``a``
is ``shape[a] - off`` with ``0 <= off <= radius`` lives on cell faces
(``off = 1`` is the classic face-centered flux next to cell-centered
scalars). Per-field halo windows are derived from the field's staggering:
a field with offset ``off`` gets a VMEM window of ``block + 2*halo - off``
per axis, which is exactly what makes the *relative slice* fd operators
(``d_xa``, ``av_xa``, ``inn``, ...) consume shapes on windows the same way
they do on full arrays — the single-source shape contract.

Per-output write semantics are likewise *derived from the update's shape*
along each axis (the engine's analogue of ParallelStencil's ``@inn(T2)``
vs ``@all(qx)`` left-hand sides):

  * update extent == window extent - 2*radius  ->  ``inn``: interior
    write; the output's boundary ring keeps its previous values.
  * update extent == window extent             ->  ``all``: every
    in-domain cell is written (no boundary ring). Staggered axes
    (``off > 0``) *must* use ``all`` semantics: an interior-style
    staggered write would leave the faces straddling block boundaries
    covered by no block.

Multi-output temporal blocking: with ``nsteps=k`` each sweep's outputs
rotate into their ``rotations[out]`` partner windows (the in-kernel
analogue of ``phi, phi2 = phi2, phi; Pe, Pe2 = Pe2, Pe``), so whole
coupled systems (porosity waves, Gross-Pitaevskii) advance k steps per
HBM round-trip.

Caveats (documented): the update function must not read an *output* field's
halo ring (its window is only used as the boundary-copy source). All paper
solvers satisfy this — e.g. Fig. 1's ``T2`` is write-only. With ``nsteps>1``
the k-step result is bitwise-identical to k rotated single-step calls
provided the rotation buffers agree on their boundary rings (true for all
solvers here: both buffers start as copies and boundaries are never
updated).
"""
from __future__ import annotations

import functools
import math
import os
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..ir.reductions import normalize_reductions as _normalize_reductions

# Default VMEM working-set budget per kernel instance. v5e has 128 MiB of
# VMEM per core; leave generous headroom for Pallas pipelining (double
# buffering doubles the live window set) and spills.
DEFAULT_VMEM_BUDGET = 8 << 20

# Hard per-core VMEM capacity (v5e: 128 MiB) for the preflight admission
# check. The soft budget above steers automatic tile derivation; THIS is
# the wall an explicit tile must not cross — beyond it the backend fails
# with an opaque allocation error long after tracing. Override per
# deployment with REPRO_VMEM_LIMIT_BYTES.
DEFAULT_VMEM_LIMIT = 128 << 20


class LaunchFootprintError(ValueError):
    """The derived launch's VMEM window footprint exceeds the device
    limit — raised at derivation time (preflight), not as an opaque
    backend allocation failure at compile/run time."""


def _vmem_limit(vmem_limit: int | None) -> int:
    if vmem_limit is not None:
        return int(vmem_limit)
    env = os.environ.get("REPRO_VMEM_LIMIT_BYTES", "")
    return int(env) if env else DEFAULT_VMEM_LIMIT


def preflight_vmem(block: Sequence[int], window_bytes: int,
                   vmem_limit: int | None = None, *,
                   explicit_tile: bool) -> None:
    """Admission check: refuse a launch whose halo-extended window set
    cannot fit device VMEM. Names the tile, the footprint and the limit,
    and says what to do about it."""
    limit = _vmem_limit(vmem_limit)
    if window_bytes <= limit:
        return
    source = ("explicit tile" if explicit_tile
              else "derived block (grid too small to shrink further)")
    raise LaunchFootprintError(
        f"launch preflight: {source} {tuple(block)} needs "
        f"{window_bytes / 2**20:.1f} MiB of VMEM windows, over the device "
        f"limit of {limit / 2**20:.1f} MiB — pass a smaller tile=, raise "
        "march_axis streaming, or (if the device really has more VMEM) "
        "set REPRO_VMEM_LIMIT_BYTES")


def default_compute_dtype(dtype) -> jnp.dtype:
    """The compute dtype a storage dtype implies: sub-f32 floats (bf16,
    f16, f8) widen to float32 — fields are *stored* narrow but all
    stencil arithmetic happens at f32 inside the VMEM window (cast on
    load, cast on store) — while f32/f64/int storage computes in its own
    precision. The engine-wide storage-vs-compute rule; override with
    ``compute_dtype=`` on ``init_parallel_stencil``/``build_stencil_call``."""
    st = jnp.dtype(dtype)
    if jnp.issubdtype(st, jnp.floating) and st.itemsize < 4:
        return jnp.dtype(jnp.float32)
    return st


def accum_dtype(compute_dtype) -> jnp.dtype:
    """Accumulation dtype for reduction epilogues: never narrower than
    f32 (bf16 partial sums saturate after ~256 increments — a 256^3
    ``sum`` would plateau at a tiny fraction of its value and a
    convergence check would silently lose its signal), and f64 compute
    keeps f64 accumulation."""
    return jnp.promote_types(jnp.float32, jnp.dtype(compute_dtype))


def _divisors_leq(n: int, cap: int) -> list[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def _pick_block(n: int, cap: int, align: int) -> int:
    """Largest divisor of n that is <= cap, preferring multiples of align."""
    divs = _divisors_leq(n, cap)
    aligned = [d for d in divs if d % align == 0]
    return (aligned or divs)[-1]


def _halo_pairs(halo, nd: int) -> tuple[tuple[int, int], ...]:
    """Normalize a halo spec — an int (symmetric, every axis) or a
    per-axis sequence of ints/(lo, hi) pairs — to per-axis pairs."""
    if isinstance(halo, int):
        return ((halo, halo),) * nd
    out = []
    for h in halo:
        if isinstance(h, int):
            out.append((h, h))
        else:
            lo, hi = h
            out.append((int(lo), int(hi)))
    return tuple(out)


def window_footprint_bytes(
    block: Sequence[int],
    halo,
    field_offsets: Sequence[Sequence[int]],
    itemsize: int,
) -> int:
    """VMEM bytes of a coupled field set's halo-extended windows: each
    field occupies ``prod(block + halo_lo + halo_hi - off)`` elements
    (``halo``: int or per-axis (lo, hi) pairs — footprint-inferred halos
    are per-axis and possibly asymmetric). The single shared accounting
    used by launch derivation, the autotuner's candidate filter and
    ``run.window_bytes`` — keep them consistent."""
    pairs = _halo_pairs(halo, len(tuple(block)))
    return sum(
        math.prod(b + lo + hi - o
                  for b, (lo, hi), o in zip(block, pairs, off))
        for off in field_offsets
    ) * itemsize


def march_queue_blocks(block_m: int, halo_m: tuple[int, int]) -> tuple[int, int, int]:
    """Rolling plane-queue geometry of a streamed (marching) launch along
    one axis: ``halo_m`` is the *total* (lo, hi) window halo along the
    march axis (single-sweep depths times ``nsteps``), ``block_m`` the
    march-axis block extent.  Returns ``(Q, Llo, Lhi)``: the queue depth
    in blocks and the low/high lookbehind/lookahead in blocks.  The queue
    holds ``Q * block_m`` planes — ``2*halo + support`` rounded up to
    block multiples — and the output lags the fetch by ``Lhi`` blocks
    (the drain/priming offset of the software pipeline)."""
    k_lo, k_hi = int(halo_m[0]), int(halo_m[1])
    bm = int(block_m)
    llo = -(-k_lo // bm)
    lhi = -(-k_hi // bm)
    return llo + 1 + lhi, llo, lhi


def streamed_footprint_bytes(
    block: Sequence[int],
    halo,
    field_offsets: Sequence[Sequence[int]],
    itemsize: int,
    march_axis: int,
) -> int:
    """VMEM bytes of a *streamed* launch: per field, the fetch window
    carries no halo along the march axis (new planes only — the reuse
    that kills the refetch) plus the rolling plane queue of
    ``Q * block_m`` planes carried in scratch across grid steps."""
    block = tuple(int(b) for b in block)
    nd = len(block)
    pairs = _halo_pairs(halo, nd)
    m = march_axis
    q, _, _ = march_queue_blocks(block[m], pairs[m])
    total = 0
    for off in field_offsets:
        other = [block[a] + pairs[a][0] + pairs[a][1] - off[a]
                 for a in range(nd) if a != m]
        area = math.prod(other) if other else 1
        total += (block[m] - off[m]) * area          # fetch window
        total += q * block[m] * area                 # scratch plane queue
    return total * itemsize


def derive_launch(
    shape: Sequence[int],
    radius: int,
    n_fields: int,
    itemsize: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    tile: Sequence[int] | None = None,
    nsteps: int = 1,
    field_offsets: Sequence[Sequence[int]] | None = None,
    halos: Sequence[tuple[int, int]] | None = None,
    march_axis: int | None = None,
    march_min_block: int = 1,
    vmem_limit: int | None = None,
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Derive (grid, block_shape) from array bounds — ParallelStencil's
    automatic launch-parameter derivation, with TPU tiling constraints.

    Every derived launch passes a preflight admission check against the
    hard device VMEM capacity (``vmem_limit``, default
    :data:`DEFAULT_VMEM_LIMIT` or ``REPRO_VMEM_LIMIT_BYTES``): an
    explicit ``tile`` whose halo-extended windows cannot fit raises a
    pointed :class:`LaunchFootprintError` here, before compile, instead
    of an opaque backend allocation failure later.

    The minor (last) axis prefers 128-lane multiples, the next-to-minor
    8-sublane multiples. Blocks must divide the array extents (the caller
    pads otherwise). The block set is shrunk until the halo-extended
    windows of all fields fit the VMEM budget. With temporal blocking
    (``nsteps > 1``) the window halo is ``nsteps * radius`` per side, so
    the same budget yields smaller blocks.

    ``halos`` overrides the symmetric ``radius`` halo with per-axis
    (lo, hi) single-sweep depths (the footprint-inferred geometry): the
    window extension becomes ``nsteps * (lo, hi)`` per axis, so an axis
    the kernel never differences costs no VMEM halo at all.

    ``field_offsets`` gives the per-field staggering offsets of the whole
    coupled field set (one tuple per field, entries subtracted from the
    base window extent); when present the VMEM footprint is the *sum of
    the per-field windows*, so a system with many fields gets smaller
    blocks than a single-field problem under the same budget.

    ``march_axis`` switches the VMEM accounting to the streamed launch
    geometry: the march axis carries no window halo (blocks fetch new
    planes only) but each field adds a rolling plane queue of
    ``Q * block_m`` planes held in scratch across grid steps.
    """
    shape = tuple(int(s) for s in shape)
    nd = len(shape)
    k = max(int(nsteps), 1)
    if halos is None:
        halo = _halo_pairs(radius * k, nd)
    else:
        halo = tuple((k * lo, k * hi) for lo, hi in _halo_pairs(halos, nd))
    if field_offsets is None:
        field_offsets = [(0,) * nd] * int(n_fields)
    field_offsets = [tuple(int(o) for o in off) for off in field_offsets]

    def window_bytes(blk):
        if march_axis is not None:
            return streamed_footprint_bytes(blk, halo, field_offsets,
                                            itemsize, march_axis)
        return window_footprint_bytes(blk, halo, field_offsets, itemsize)

    if tile is not None:
        block = tuple(int(b) for b in tile)
        if len(block) != nd or any(s % b for s, b in zip(shape, block)):
            raise ValueError(f"tile {block} must divide shape {shape}")
        preflight_vmem(block, window_bytes(block), vmem_limit,
                       explicit_tile=True)
    else:
        caps = [256 if a == nd - 1 else (64 if a == nd - 2 else 16) for a in range(nd)]
        aligns = [128 if a == nd - 1 else (8 if a == nd - 2 else 1) for a in range(nd)]
        block = [
            _pick_block(s, c, al) for s, c, al in zip(shape, caps, aligns)
        ]
        if march_axis is not None:
            # The march block should be *small*: each sequential grid
            # step fetches bm fresh planes, and the pipeline's drain
            # refetches up to one block per column — so bm beyond the
            # halo depth only inflates the queue and the drain traffic,
            # while a halo-sized bm keeps both at O(halo). The innermost
            # two axes keep their lane/sublane-aligned tiles.
            m = march_axis
            need = max(halo[m][0], halo[m][1], 1, int(march_min_block))
            fit = [d for d in _divisors_leq(shape[m], shape[m]) if d >= need]
            block[m] = fit[0] if fit else shape[m]

        # Shrink the largest non-minor axis first; keep lane alignment longest.
        while window_bytes(block) > vmem_budget:
            cands = sorted(range(nd), key=lambda a: (a == nd - 1, -block[a]))
            for a in cands:
                smaller = [d for d in _divisors_leq(shape[a], block[a] - 1)]
                if smaller:
                    block[a] = smaller[-1]
                    break
            else:
                break  # cannot shrink further; let it ride
        block = tuple(block)
        # "let it ride" can still exceed the soft budget — but never the
        # hard device capacity
        preflight_vmem(block, window_bytes(block), vmem_limit,
                       explicit_tile=False)
    grid = tuple(s // b for s, b in zip(shape, block))
    return grid, block


def halo_window_spec(
    block: Sequence[int],
    halo: Sequence[int | tuple[int, int]],
    index_map: Callable,
) -> pl.BlockSpec:
    """BlockSpec for an overlapping, halo-extended VMEM window.

    ``halo`` gives the per-dimension (lo, hi) extension (an int means
    symmetric). ``index_map`` must return *element* offsets in the padded
    coordinate system — for a stride-``block`` tiling that is simply
    ``pid * block`` per dim. Out-of-bounds cells read as garbage/NaN and
    must be masked by the kernel body.

    Version compat: jax >= 0.5 expresses this with ``pl.Element`` block
    dims; jax 0.4.x spells the identical semantics as the ``Unblocked``
    indexing mode with padding.
    """
    halo = tuple((h, h) if isinstance(h, int) else (int(h[0]), int(h[1]))
                 for h in halo)
    if hasattr(pl, "Element"):
        dims = tuple(
            pl.Element(b + lo + hi, padding=(lo, hi))
            for b, (lo, hi) in zip(block, halo)
        )
        return pl.BlockSpec(dims, index_map)
    win = tuple(b + lo + hi for b, (lo, hi) in zip(block, halo))
    return pl.BlockSpec(win, index_map, indexing_mode=pl.Unblocked(halo))


def compiler_params(nd: int, march: bool = False):
    """``dimension_semantics`` for an nd stencil grid. All-parallel by
    default (every block independent, letting Mosaic pipeline block
    revisits); with ``march=True`` the innermost (last) grid dimension is
    ``"arbitrary"`` — executed sequentially so the scratch plane queue
    carries state from one grid step to the next — while the leading tile
    dimensions stay ``"parallel"`` (Megacore may still split them).
    Returns None when this jax has no TPU compiler-params surface."""
    cp = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cp is None:
        return None
    if march:
        return cp(dimension_semantics=("parallel",) * (nd - 1) + ("arbitrary",))
    return cp(dimension_semantics=("parallel",) * nd)


def field_geometry(
    shape: Sequence[int],
    field_names: Sequence[str],
    field_shapes: Mapping[str, Sequence[int]] | None,
    radius: int,
) -> tuple[dict[str, tuple[int, ...]], dict[str, tuple[int, ...]]]:
    """Resolve per-field shapes and staggering offsets against the base
    (cell-centered) ``shape``; offsets must lie in ``[0, radius]``."""
    base = tuple(int(s) for s in shape)
    field_shapes = dict(field_shapes or {})
    shapes, offsets = {}, {}
    for n in field_names:
        s = tuple(int(x) for x in field_shapes.get(n, base))
        if len(s) != len(base):
            raise ValueError(
                f"field {n!r} shape {s} has rank {len(s)}, expected {len(base)}"
            )
        off = tuple(b - x for b, x in zip(base, s))
        if any(o < 0 or o > radius for o in off):
            raise ValueError(
                f"field {n!r} shape {s} is not within the staggering band of "
                f"base shape {base}: per-axis offsets {off} must lie in "
                f"[0, radius={radius}] (face-centered fields are at most "
                "`radius` shorter than the cell-centered base per axis)"
            )
        shapes[n] = s
        offsets[n] = off
    return shapes, offsets


def write_geometry(
    update_shape: Sequence[int],
    window_shape: Sequence[int],
    off: Sequence[int],
    name: str,
    ring: int | None = None,
) -> tuple[tuple[str, ...], tuple[int, ...]]:
    """Per-axis write semantics + interior-ring depth derived from the
    update's traced shape.

    ``all``: the update spans the field's whole window (ParallelStencil's
    ``@all(qx) = ...`` left-hand side — every in-domain cell is written;
    ring 0). ``inn``: it spans a symmetric window interior (``@inn(T2) =
    ...`` — a ``w``-cell boundary ring keeps its previous values).
    Staggered axes must be ``all``: an interior-style write on a
    face-centered axis would leave the faces straddling block boundaries
    written by no block.

    ``ring`` pins the accepted ``inn`` depth (the legacy declared-radius
    contract); ``None`` accepts any symmetric margin (the inferred-
    footprint engine, where the ring is whatever the kernel's own slicing
    produced).
    """
    modes, rings = [], []
    for a, (u, w, o) in enumerate(zip(update_shape, window_shape, off)):
        if u == w:
            modes.append("all")
            rings.append(0)
            continue
        margin = w - u
        if margin > 0 and margin % 2 == 0 and (ring is None or margin == 2 * ring):
            if o > 0:
                raise ValueError(
                    f"output {name!r} is staggered along axis {a} (offset "
                    f"{o}) but its update covers only the interior there; "
                    "staggered axes must be written at full extent "
                    "(`all` semantics, e.g. qx = -k_face * d_xa(Pe)/dx)"
                )
            modes.append("inn")
            rings.append(margin // 2)
            continue
        want = (f"{w - 2 * ring} (`inn` write) for window extent {w} at "
                f"radius {ring}" if ring is not None else
                f"an even interior margin (`inn` write) of window extent {w}")
        raise ValueError(
            f"output {name!r} update has extent {u} along axis {a}; "
            f"expected {w} (`all` write) or {want}"
        )
    return tuple(modes), tuple(rings)


def _write_modes(
    update_shape: Sequence[int],
    window_shape: Sequence[int],
    radius: int,
    off: Sequence[int],
    name: str,
) -> tuple[str, ...]:
    """Legacy declared-radius wrapper of :func:`write_geometry`."""
    modes, _ = write_geometry(update_shape, window_shape, off, name,
                              ring=radius)
    return modes


def _valid_mask(block, field_shape, off, rings, modes, ext, pids=None):
    """Mask of the cells this block may write for one output field, on
    the frame ``[pid*block - ext_lo, pid*block + block + ext_hi - off)``
    per axis (``ext``: per-axis (lo, hi) frame extensions; zeros with
    ``off=0`` is the plain out-block frame; temporal sweeps blend on
    progressively shrinking super-blocks).

    ``inn`` axes accept the field's global interior at that axis's ring
    depth; ``all`` axes accept every in-domain cell (OOB cells beyond a
    staggered field's extent stay masked and are cropped by the caller).

    ``pids`` supplies per-axis logical block ids when they differ from
    the raw grid position — the streamed path's march axis writes block
    ``i - Lhi`` while fetching block ``i``. ``None`` reads
    ``pl.program_id`` per axis (grid in array-axis order).
    """
    nd = len(block)
    ext = _halo_pairs(ext, nd)
    mshape = tuple(b + lo + hi - o
                   for b, (lo, hi), o in zip(block, ext, off))
    m = None
    for a in range(nd):
        pid = pl.program_id(a) if pids is None else pids[a]
        g = pid * block[a] - ext[a][0] + jax.lax.broadcasted_iota(
            jnp.int32, mshape, a)
        if modes[a] == "inn":
            w = rings[a] if not isinstance(rings, int) else rings
            ma = (g >= w) & (g < field_shape[a] - w)
        else:
            ma = (g >= 0) & (g < field_shape[a])
        m = ma if m is None else (m & ma)
    return m


def _interior_mask(block, shape, radius: int, extent: int = 0):
    """Collocated interior mask (the pre-coupled-engine special case of
    :func:`_valid_mask`; kept for the hand-specialized kernels)."""
    nd = len(block)
    return _valid_mask(block, tuple(shape), (0,) * nd, (radius,) * nd,
                       ("inn",) * nd, extent)


def _embed(a, frame: Sequence[int], starts: Sequence[int]):
    """Place ``a`` on a frame so element ``u`` lands at ``u + start`` per
    axis: negative starts crop the front, overhang crops the back, and
    shortfall zero-pads (padded cells are always masked out by the
    caller's validity mask). For the legacy symmetric geometry this
    reduces to the plain interior/`all` slices (no padding)."""
    sl, pads, need_pad = [], [], False
    for ext, st, d in zip(frame, starts, a.shape):
        lo_crop = max(0, -st)
        place = max(st, 0)
        take = min(d - lo_crop, ext - place)
        sl.append(slice(lo_crop, lo_crop + take))
        pads.append((place, ext - place - take))
        need_pad = need_pad or place > 0 or ext - place - take > 0
    a = a[tuple(sl)]
    if need_pad:
        a = jnp.pad(a, pads)
    return a


def _shift(a, axis: int, d: int):
    """``out[j] = a[j + d]`` along ``axis`` (zero-fill at the vacated
    end; only consumed under face predicates that never select fill)."""
    idx = [slice(None)] * a.ndim
    pad = [(0, 0)] * a.ndim
    if d > 0:
        idx[axis] = slice(d, None)
        pad[axis] = (0, d)
    else:
        idx[axis] = slice(0, a.shape[axis] + d)
        pad[axis] = (-d, 0)
    return jnp.pad(a[tuple(idx)], pad)


def _apply_bc_frame(arr, bc, field_shape, block, ext, dtype, pids=None):
    """Realize one output's dirichlet/neumann0 condition on a block frame
    ``[pid*block - ext_lo, pid*block + block + ext_hi - off)`` (``arr``'s
    own shape), bitwise-equal to the ``core.boundary`` post-pass.

    Face cells are located by global-index iotas; neumann0 copies travel
    through frame-local static shifts, applied axis-by-axis in the same
    sequential order as the post-pass (which is what defines the corner
    values). Periodic conditions cannot be realized from local windows
    (their sources live across the domain) and are handled by the caller
    as a face-slab scatter on the assembled output. ``pids`` carries
    per-axis logical block ids when they differ from the grid position
    (the streamed path); ``None`` reads ``pl.program_id``.
    """
    if bc is None or bc.kind == "periodic":
        return arr
    nd = len(block)
    ext = _halo_pairs(ext, nd)
    d = bc.depth

    def giota(a):
        pid = pl.program_id(a) if pids is None else pids[a]
        return pid * block[a] - ext[a][0] + \
            jax.lax.broadcasted_iota(jnp.int32, arr.shape, a)

    if bc.kind == "dirichlet":
        val = jnp.asarray(bc.value, dtype)
        face = None
        for a in bc.resolved_axes(nd):
            g = giota(a)
            n = field_shape[a]
            fa = ((g >= 0) & (g < d)) | ((g >= n - d) & (g < n))
            face = fa if face is None else (face | fa)
        return arr if face is None else jnp.where(face, val, arr)

    # neumann0
    for a in bc.resolved_axes(nd):
        g = giota(a)
        n = field_shape[a]
        arr = jnp.where((g >= 0) & (g < d), _shift(arr, a, d), arr)
        arr = jnp.where((g >= n - d) & (g < n), _shift(arr, a, -d), arr)
    return arr


def build_stencil_call(
    update_fn: Callable[[Mapping[str, jax.Array], Mapping[str, jax.Array]], Mapping[str, jax.Array]],
    *,
    field_names: Sequence[str],
    out_names: Sequence[str],
    scalar_names: Sequence[str],
    shape: Sequence[int],
    radius: int,
    dtype,
    compute_dtype=None,
    tile: Sequence[int] | None = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    interpret: bool | None = None,
    nsteps: int = 1,
    rotations: Mapping[str, str] | None = None,
    field_shapes: Mapping[str, Sequence[int]] | None = None,
    halos: Sequence[tuple[int, int]] | None = None,
    bc: Mapping[str, object] | None = None,
    march_axis: int | None = None,
    write_rings: Sequence[int] | None = None,
    reductions: Mapping[str, object] | None = None,
) -> Callable[..., dict[str, jax.Array]]:
    """Build a fused Pallas stencil step (or a k-step temporal block).

    ``update_fn(fields, scalars) -> {out_name: update}`` is traced on
    halo-extended VMEM windows. Returns ``run(fields, scalars)`` mapping
    full arrays -> dict of full output arrays.

    Mixed precision: ``dtype`` is the *storage* dtype — what the fields,
    VMEM windows, scratch plane queues and outputs hold, and what sizes
    the launch derivation's VMEM accounting. ``compute_dtype`` (default:
    :func:`default_compute_dtype` — f32 for sub-f32 float storage) is
    what the update arithmetic runs in: windows are cast on load right
    before ``update_fn`` sees them, updates cast back to storage on
    store, and scalars ride in SMEM at compute precision. Between
    temporal sweeps the rotated values round through storage dtype, so a
    k-fused launch stays bitwise-consistent with k sequential launches.
    Reduction partials always accumulate at :func:`accum_dtype` (>= f32)
    regardless of storage.

    ``shape`` is the *base* (cell-centered) extent; ``field_shapes`` may
    give smaller per-field extents for staggered fields (``shape - off``
    per axis, ``0 <= off <= radius``) — each field's window and write mask
    are derived from its own geometry (see module docstring).

    ``halos`` switches the window geometry from the legacy symmetric
    ``radius`` to footprint-inferred per-axis (lo, hi) depths (the
    stencil-IR path): windows extend ``nsteps * (lo, hi)`` per axis, and
    per-output interior rings are whatever the update's own slicing
    produced rather than being pinned to ``radius``. ``radius`` then only
    bounds the staggering band.

    ``bc`` maps output names to ``ir.BoundaryCondition``s, realized
    *inside* the launch (dirichlet/neumann0 — including between temporal
    sweeps) or as a face-slab scatter on the assembled output (periodic),
    bitwise-equal to applying the ``core.boundary`` post-pass after every
    step.

    With ``nsteps=k > 1`` the update is swept k times inside the kernel:
    the windows carry a ``k``-sweep halo, each sweep shrinks them by one
    sweep's depth per side, and ``rotations[out_name]`` names the input
    field the sweep's output becomes for the next sweep (the in-kernel
    analogue of the solver's ``T, T2 = T2, T`` double-buffer rotation) —
    for coupled systems every output rotates simultaneously.

    Streaming (``march_axis=a``): axis ``a`` becomes a *sequential* grid
    dimension (innermost, ``dimension_semantics`` "arbitrary") that the
    launch marches block-by-block. Each grid step fetches only the NEW
    planes of every field (the march-axis window carries no halo) and
    pushes them into a rolling plane queue held in VMEM scratch across
    grid steps; the halo-extended march window is then assembled from
    the queue, so each input element crosses HBM ~once per sweep instead
    of once per overlapping tile. The output lags the fetch by ``Lhi``
    blocks (priming steps write block 0 and are overwritten; ``Lhi``
    drain steps flush the tail), which is transparent to the caller.
    Fields staggered along the march axis are unsupported (ValueError);
    a march extent smaller than the queue falls back to the all-parallel
    path (``run.march_fallback``).

    Reductions (``reductions={name: ir.Reduction | "kind(field[, other])"}``):
    named convergence/conservation checks (``max_abs``, ``max_abs_diff``,
    ``sum``, ``sum_sq``) computed INSIDE the launch. Each grid tile folds
    its domain-masked partial over the out-block frame — output operands
    see the freshly blended values, input operands the current window —
    into a tiny per-tile partials output (one scalar per tile, written
    through the same lagged index map on the streamed path, so sequential
    march steps land their partials per written block and the drain
    flushes the tail), and ``run`` finishes with a scalar combine over
    the partials: no operand crosses HBM a second time. ``run`` then
    returns ``(outputs, reductions)``. With ``nsteps=k`` only the final
    sweep reduces (the k-step value — what a sequential checker sees).
    Operands must be collocated fields; periodic BCs are incompatible
    (their wrap scatter happens after the launch, so an in-launch fold
    would see pre-wrap face values).
    """
    shape = tuple(int(s) for s in shape)
    nd = len(shape)
    dtype = jnp.dtype(dtype)
    compute_dtype = (default_compute_dtype(dtype) if compute_dtype is None
                     else jnp.dtype(compute_dtype))
    acc_dtype = accum_dtype(compute_dtype)
    cast_compute = compute_dtype != dtype

    def call_update(windows, scalars):
        if cast_compute:
            windows = {n: w.astype(compute_dtype) for n, w in windows.items()}
        return update_fn(windows, scalars)

    field_names = tuple(field_names)
    out_names = tuple(out_names)
    scalar_names = tuple(scalar_names)
    nsteps = int(nsteps)
    if nsteps < 1:
        raise ValueError(f"nsteps must be >= 1, got {nsteps}")
    for o in out_names:
        if o not in field_names:
            raise ValueError(
                f"output {o!r} must also be an input field (boundary-copy source)"
            )
    shapes, offsets = field_geometry(shape, field_names, field_shapes, radius)
    reductions = _normalize_reductions(reductions, field_names)
    red_names = tuple(reductions)
    for rn, r in reductions.items():
        for op in r.operands:
            if any(b - s for b, s in zip(shape, shapes[op])):
                raise ValueError(
                    f"reduction {rn!r} = {r.describe()} reads staggered "
                    f"field {op!r} (shape {shapes[op]} vs base {shape}); "
                    "reduction operands must be collocated"
                )
    bc = dict(bc or {})
    inkernel_bc = {o: c for o, c in bc.items() if c.kind != "periodic"}
    post_bc = {o: c for o, c in bc.items() if c.kind == "periodic"}
    if reductions and post_bc:
        raise ValueError(
            "fused reductions cannot ride a launch with periodic boundary "
            "conditions: the wrap scatter runs after the launch, so the "
            "in-kernel fold would see pre-wrap face values — apply the "
            "reduction as a post-pass or use dirichlet/neumann0"
        )
    if post_bc and nsteps > 1:
        raise ValueError(
            "periodic boundary conditions cannot run inside a temporally-"
            "blocked launch (their wrap sources live outside every local "
            "window); the caller must realize nsteps>1 as sequential "
            "single-step launches"
        )
    if nsteps > 1:
        rotations = dict(rotations or {})
        missing = set(out_names) - set(rotations)
        if missing:
            raise ValueError(
                f"nsteps={nsteps} needs rotations for outputs {sorted(missing)} "
                "(e.g. rotations={'T2': 'T'}: each sweep's T2 becomes next sweep's T)"
            )
        for o, tgt in rotations.items():
            if tgt not in field_names:
                raise ValueError(f"rotation target {tgt!r} is not a field")
            if tgt in out_names:
                raise ValueError(
                    f"rotation target {tgt!r} is an output; outputs only "
                    "provide boundary values and cannot receive sweep results"
                )
            if o in shapes and shapes[o] != shapes[tgt]:
                raise ValueError(
                    f"rotation {o!r} -> {tgt!r} joins fields of different "
                    f"shapes {shapes[o]} vs {shapes[tgt]}; double-buffer "
                    "partners must share one staggering"
                )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    # Per-axis single-sweep halo depths: the declared radius (symmetric)
    # or the inferred footprint (possibly asymmetric / zero per axis).
    sweep_halo = _halo_pairs(radius if halos is None else halos, nd)
    if write_rings is not None:
        # The window must cover every block cell *structurally*, not just
        # its data footprint: an `inn`-written output's update expression
        # spans window - 2*ring, placed ring cells in — so a side whose
        # read halo is shallower than the write ring (one-sided/upwind
        # taps under inn-style slicing) would leave the seam cells of
        # interior blocks unreachable by any block. Extend each side to
        # at least the deepest output ring on that axis.
        sweep_halo = tuple(
            (max(lo, int(r)), max(hi, int(r)))
            for (lo, hi), r in zip(sweep_halo, write_rings)
        )
    ring = radius if halos is None else None  # legacy pins `inn` to radius
    march = march_axis
    march_fallback = False
    if march is not None:
        march = int(march)
        if not 0 <= march < nd:
            raise ValueError(
                f"march_axis {march} out of range for a {nd}-d stencil")
        for n in field_names:
            if offsets[n][march]:
                raise ValueError(
                    f"march_axis {march} points at a staggered axis: field "
                    f"{n!r} has offset {offsets[n][march]} there — streaming "
                    "slides collocated planes; stagger a non-marching axis "
                    "or drop march_axis"
                )

    def _derive(m):
        # Fused neumann0 conditions copy through frame-local shifts, so
        # the (small, halo-sized) march block must still hold 2*depth
        # cells along the marched axis.
        min_bm = 1
        if m is not None:
            for o, c in inkernel_bc.items():
                if c.kind == "neumann0" and m in c.resolved_axes(nd):
                    min_bm = max(min_bm, 2 * c.depth + offsets[o][m])
        return derive_launch(
            shape, radius, len(field_names), dtype.itemsize, vmem_budget,
            tile, nsteps=nsteps,
            field_offsets=[offsets[n] for n in field_names],
            halos=None if halos is None else sweep_halo,
            march_axis=m, march_min_block=min_bm,
        )

    grid, block = _derive(march)
    whalo = tuple((nsteps * lo, nsteps * hi) for lo, hi in sweep_halo)
    if march is not None:
        q_blocks, llo_b, lhi_b = march_queue_blocks(block[march], whalo[march])
        if shape[march] < q_blocks * block[march]:
            # The march extent cannot even fill the plane queue:
            # streaming would fetch mostly duplicate planes. Fall back to
            # the all-parallel launch (identical results, refetched halos).
            march, march_fallback = None, True
            grid, block = _derive(None)
    for o, c in inkernel_bc.items():
        if c.kind == "neumann0":
            for a in c.resolved_axes(nd):
                if block[a] < 2 * c.depth + offsets[o][a]:
                    raise ValueError(
                        f"fused neumann0 depth {c.depth} on axis {a} needs "
                        f"block extent >= {2 * c.depth + offsets[o][a]}, got "
                        f"{block[a]} (pass a larger tile)"
                    )

    if march is None:
        launch_grid = grid

        def in_index_map(*pids):
            return tuple(pid * b for pid, b in zip(pids, block))

        def out_index_map(*pids):
            return pids
    else:
        # Streamed launch: the march axis becomes the innermost (fastest
        # varying, sequential) grid dimension so consecutive grid steps
        # walk one tile column plane-block by plane-block and the scratch
        # queue stays column-coherent. The fetch leads the write by Lhi
        # blocks (lookahead); `Lhi` extra drain steps flush the tail, and
        # the fetch map clamps there (duplicate planes stand in for the
        # out-of-bounds padding of the all-parallel path — both only ever
        # reach masked boundary-ring cells).
        others = tuple(a for a in range(nd) if a != march)
        launch_grid = tuple(grid[a] for a in others) + (grid[march] + lhi_b,)

        def in_index_map(*pids):
            i = pids[-1]
            return tuple(
                jnp.minimum(i, grid[march] - 1) * block[march] if a == march
                else pids[others.index(a)] * block[a]
                for a in range(nd)
            )

        def out_index_map(*pids):
            i = pids[-1]
            return tuple(
                jnp.maximum(i - lhi_b, 0) if a == march
                else pids[others.index(a)]
                for a in range(nd)
            )

    n_s, n_f = len(scalar_names), len(field_names)

    def _crop(a):
        return a[tuple(slice(lo, d - hi)
                       for d, (lo, hi) in zip(a.shape, sweep_halo))]

    def _check_updates(updates):
        missing = set(out_names) - set(updates)
        if missing:
            raise ValueError(f"update_fn did not produce outputs {missing}")

    n_out = len(out_names)

    def body(*refs):
        scal_refs = refs[:n_s]
        in_refs = refs[n_s : n_s + n_f]
        out_refs = refs[n_s + n_f : n_s + n_f + n_out]
        red_refs = refs[n_s + n_f + n_out : n_s + n_f + n_out + len(red_names)]
        q_refs = refs[n_s + n_f + n_out + len(red_names) :]
        scalars = {n: ref[0] for n, ref in zip(scalar_names, scal_refs)}
        if march is None:
            pids = None
            windows = {n: ref[...] for n, ref in zip(field_names, in_refs)}
        else:
            i = pl.program_id(nd - 1)
            pids = tuple(
                jnp.maximum(i - lhi_b, 0) if a == march
                else pl.program_id(others.index(a))
                for a in range(nd)
            )
            if q_blocks == 1:
                # Zero march halo: nothing to carry — the fetched block
                # IS the window (streaming still sequences the axis).
                windows = {n: ref[...] for n, ref in zip(field_names,
                                                         in_refs)}
            else:
                # Roll each field's plane queue by one block and append
                # the newly fetched planes; the halo-extended march window
                # of the *written* block (o = i - Lhi) is a static slice
                # of the queue: queue plane q holds global plane
                # (i - Q + 1)*bm + q.
                bm = block[march]
                tail = tuple(slice(bm, None) if a == march else slice(None)
                             for a in range(nd))
                qs = llo_b * bm - whalo[march][0]
                wsl = tuple(
                    slice(qs, qs + bm + whalo[march][0] + whalo[march][1])
                    if a == march else slice(None)
                    for a in range(nd)
                )
                windows = {}
                for n, in_ref, q_ref in zip(field_names, in_refs, q_refs):
                    q = jnp.concatenate([q_ref[tail], in_ref[...]],
                                        axis=march)
                    q_ref[...] = q
                    windows[n] = q[wsl]
        for s in range(nsteps - 1):
            updates = call_update(windows, scalars)
            _check_updates(updates)
            win_shapes = {n: w.shape for n, w in windows.items()}
            m = nsteps - 1 - s  # remaining sweep margins after this sweep
            ext = tuple((m * lo, m * hi) for lo, hi in sweep_halo)
            windows = {n: _crop(w) for n, w in windows.items()}
            for o in out_names:
                tgt = rotations[o]
                modes, rings = write_geometry(
                    updates[o].shape, win_shapes[o], offsets[o], o, ring)
                # Place the update on the cropped target frame: element u
                # lands at u + ring - halo_lo per axis (`all`: crop the
                # sweep's consumed halo; `inn`: the interior already lines
                # up when ring == halo_lo, else _embed pads/crops).
                frame = tuple(b - off + lo + hi for b, off, (lo, hi)
                              in zip(block, offsets[o], ext))
                upd = _embed(
                    updates[o].astype(dtype), frame,
                    tuple(w - lo for w, (lo, _) in zip(rings, sweep_halo)))
                mask = _valid_mask(block, shapes[o], offsets[o], rings,
                                   modes, ext, pids)
                # Cells outside the mask (boundary ring of `inn` axes) keep
                # carrying their previous values; a fused bc then rewrites
                # that ring exactly like the post-pass would between steps.
                blended = jnp.where(mask, upd, windows[tgt])
                blended = _apply_bc_frame(blended, inkernel_bc.get(o),
                                          shapes[o], block, ext, dtype, pids)
                windows[tgt] = blended
        updates = call_update(windows, scalars)
        _check_updates(updates)
        blendeds = {}
        for o, oref in zip(out_names, out_refs):
            modes, rings = write_geometry(
                updates[o].shape, windows[o].shape, offsets[o], o, ring)
            # Lift update and previous values onto the out-block frame
            # [pid*block, pid*block + block).
            starts = tuple(w - lo for w, (lo, _) in zip(rings, sweep_halo))
            upd = _embed(updates[o].astype(dtype), block, starts)
            prev = _embed(windows[o],
                          block, tuple(-lo for lo, _ in sweep_halo))
            mask = _valid_mask(block, shapes[o], (0,) * nd, rings, modes,
                               (0,) * nd, pids)
            blended = jnp.where(mask, upd, prev)
            blended = _apply_bc_frame(blended, inkernel_bc.get(o),
                                      shapes[o], block, ((0, 0),) * nd,
                                      dtype, pids)
            oref[...] = blended
            blendeds[o] = blended
        # Fused reduction epilogue: fold each named check over the SAME
        # out-block frame the write just produced — output operands are
        # the blended values still live in registers/VMEM, input operands
        # the window's block slice (the value the boundary copy reads) —
        # masked to the operand's in-domain cells (each domain cell lies
        # in exactly one out block, so the per-tile partials tile the
        # whole-array reduction without overlap).
        if red_names:
            def frame_value(f):
                if f in blendeds:
                    return blendeds[f]
                return _embed(windows[f], block,
                              tuple(-lo for lo, _ in sweep_halo))

            dom = _valid_mask(block, shape, (0,) * nd, (0,) * nd,
                              ("all",) * nd, (0,) * nd, pids)
            for rn, rref in zip(red_names, red_refs):
                r = reductions[rn]
                # operands lift to the accumulation dtype BEFORE the
                # elementwise map: |T2 - T| and T*T happen at >= f32
                # even when the blended storage values are bf16
                mapped = r.map_element(*[frame_value(op).astype(acc_dtype)
                                         for op in r.operands])
                rref[...] = r.fold(mapped, dom).reshape((1,) * nd)

    # The march-axis fetch window carries no halo (streaming fetches new
    # planes only; the halo planes are carried in the scratch queue).
    field_halo = whalo if march is None else tuple(
        (0, 0) if a == march else whalo[a] for a in range(nd))
    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM) for _ in scalar_names]
    in_specs += [
        halo_window_spec(
            tuple(b - o for b, o in zip(block, offsets[n])),
            field_halo,
            in_index_map,
        )
        for n in field_names
    ]
    # Outputs are stored at the base extent (blocks tile it exactly) and
    # cropped back to their staggered extents on the way out. Reduction
    # partials ride as one-scalar-per-tile outputs through the same
    # (lagged, on the streamed path) block index map.
    out_specs = [pl.BlockSpec(block, out_index_map) for _ in out_names]
    out_shape = [jax.ShapeDtypeStruct(shape, dtype) for _ in out_names]
    out_specs += [pl.BlockSpec((1,) * nd, out_index_map) for _ in red_names]
    # partials stay at the accumulation dtype all the way to finish():
    # rounding them through a bf16 output would undo the f32 folds
    out_shape += [jax.ShapeDtypeStruct(grid, acc_dtype) for _ in red_names]

    kwargs = {}
    if march is not None and q_blocks > 1:
        # One rolling plane queue per field, persisted across grid steps
        # (the march dimension is sequential, so the previous step's
        # planes are still live when the next block arrives).
        kwargs["scratch_shapes"] = [
            pltpu.VMEM(
                tuple(q_blocks * block[march] if a == march
                      else block[a] + whalo[a][0] + whalo[a][1] - offsets[n][a]
                      for a in range(nd)),
                dtype,
            )
            for n in field_names
        ]
    if not interpret:
        cp = compiler_params(nd, march=march is not None)
        if cp is not None:
            kwargs["compiler_params"] = cp
    call = pl.pallas_call(
        body,
        grid=launch_grid,
        in_specs=in_specs,
        out_specs=out_specs[0] if len(out_specs) == 1 else out_specs,
        out_shape=out_shape[0] if len(out_shape) == 1 else out_shape,
        interpret=interpret,
        **kwargs,
    )

    def run(fields: Mapping[str, jax.Array], scalars: Mapping[str, jax.Array]):
        # scalars ride in SMEM at compute precision: dt/lam quantized to
        # bf16 would perturb every update even though the fields are the
        # only thing the mixed-precision trade wants narrowed
        ordered_scal = [
            jnp.asarray(scalars[n], dtype=compute_dtype).reshape((1,))
            for n in scalar_names
        ]
        ordered_fields = [jnp.asarray(fields[n], dtype=dtype) for n in field_names]
        for n, f in zip(field_names, ordered_fields):
            if f.shape != shapes[n]:
                raise ValueError(
                    f"field {n!r} has shape {f.shape}, expected {shapes[n]}"
                )
        outs = call(*ordered_scal, *ordered_fields)
        if n_out + len(red_names) == 1:
            outs = [outs]
        outs = list(outs)
        partials = outs[n_out:]
        outs = [
            o[tuple(slice(0, s) for s in shapes[n])] if shapes[n] != shape else o
            for n, o in zip(out_names, outs[:n_out])
        ]
        outs = dict(zip(out_names, outs))
        # Periodic faces wrap across the whole domain — realized as a
        # face-slab scatter fused into the surrounding jit (touches
        # O(N^(d-1) * depth) cells; no extra whole-array HBM round-trip).
        for o, c in post_bc.items():
            outs[o] = c.apply(outs[o])
        if not red_names:
            return outs
        # Finish each reduction with a scalar combine over its per-tile
        # partials (O(n_blocks) values — fused into the surrounding jit).
        reds = {rn: reductions[rn].finish(p)
                for rn, p in zip(red_names, partials)}
        return outs, reds

    run.grid = grid
    run.block = block
    run.nsteps = nsteps
    run.dtype = dtype
    run.compute_dtype = compute_dtype
    run.reductions = dict(reductions)
    run.field_shapes = dict(shapes)
    run.halo = sweep_halo
    run.march_axis = march
    run.march_fallback = march_fallback
    run.queue_planes = 0 if march is None else q_blocks * block[march]
    if march is None:
        run.window_bytes = window_footprint_bytes(
            block, whalo, [offsets[n] for n in field_names], dtype.itemsize)
    else:
        run.window_bytes = streamed_footprint_bytes(
            block, whalo, [offsets[n] for n in field_names], dtype.itemsize,
            march)
    return run
