"""Generic Pallas stencil kernel builder (the paper's C3/C4 on TPU).

ParallelStencil's ``@parallel loopopt=true`` generates a CUDA kernel where a
thread block stages a halo-extended tile of the input fields in shared
memory/registers and sweeps it. The TPU-native equivalent built here:

  * the Pallas *grid* tiles the full array; every input field gets a
    **halo-extended VMEM window** expressed with ``pl.Element`` block
    dimensions (element-indexed, overlapping windows with OOB padding) —
    this is the BlockSpec realization of shared-memory blocking;
  * the kernel body evaluates the *same math-close update function* the
    ``jnp`` backend uses, on the window, producing the block-interior
    update;
  * a per-block interior mask blends the update with the output field's
    previous (boundary) values, so one fused pass writes the full output
    array — boundary handling costs no extra kernel;
  * scalars ride in SMEM;
  * launch parameters (grid + block shapes) are **derived automatically**
    from the array bounds, stencil radius and a VMEM budget, mirroring
    ParallelStencil's automatic launch-parameter derivation.

Caveat (documented): the update function must not read an *output* field's
halo ring (its window is only used as the boundary-copy source). All paper
solvers satisfy this — e.g. Fig. 1's ``T2`` is write-only.
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default VMEM working-set budget per kernel instance. v5e has 128 MiB of
# VMEM per core; leave generous headroom for Pallas pipelining (double
# buffering doubles the live window set) and spills.
DEFAULT_VMEM_BUDGET = 8 << 20


def _divisors_leq(n: int, cap: int) -> list[int]:
    return [d for d in range(1, min(n, cap) + 1) if n % d == 0]


def _pick_block(n: int, cap: int, align: int) -> int:
    """Largest divisor of n that is <= cap, preferring multiples of align."""
    divs = _divisors_leq(n, cap)
    aligned = [d for d in divs if d % align == 0]
    return (aligned or divs)[-1]


def derive_launch(
    shape: Sequence[int],
    radius: int,
    n_fields: int,
    itemsize: int,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    tile: Sequence[int] | None = None,
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Derive (grid, block_shape) from array bounds — ParallelStencil's
    automatic launch-parameter derivation, with TPU tiling constraints.

    The minor (last) axis prefers 128-lane multiples, the next-to-minor
    8-sublane multiples. Blocks must divide the array extents (the caller
    pads otherwise). The block set is shrunk until the halo-extended
    windows of all fields fit the VMEM budget.
    """
    shape = tuple(int(s) for s in shape)
    nd = len(shape)
    if tile is not None:
        block = tuple(int(b) for b in tile)
        if len(block) != nd or any(s % b for s, b in zip(shape, block)):
            raise ValueError(f"tile {block} must divide shape {shape}")
    else:
        caps = [256 if a == nd - 1 else (64 if a == nd - 2 else 16) for a in range(nd)]
        aligns = [128 if a == nd - 1 else (8 if a == nd - 2 else 1) for a in range(nd)]
        block = [
            _pick_block(s, c, al) for s, c, al in zip(shape, caps, aligns)
        ]

        def window_bytes(blk):
            return n_fields * math.prod(b + 2 * radius for b in blk) * itemsize

        # Shrink the largest non-minor axis first; keep lane alignment longest.
        while window_bytes(block) > vmem_budget:
            cands = sorted(range(nd), key=lambda a: (a == nd - 1, -block[a]))
            for a in cands:
                smaller = [d for d in _divisors_leq(shape[a], block[a] - 1)]
                if smaller:
                    block[a] = smaller[-1]
                    break
            else:
                break  # cannot shrink further; let it ride
        block = tuple(block)
    grid = tuple(s // b for s, b in zip(shape, block))
    return grid, block


def _interior_mask(block: tuple[int, ...], shape: tuple[int, ...], radius: int):
    """Boolean mask over this block marking globally-interior cells."""
    nd = len(block)
    m = None
    for a in range(nd):
        pid = pl.program_id(a)
        g = pid * block[a] + jax.lax.broadcasted_iota(jnp.int32, block, a)
        ma = (g >= radius) & (g < shape[a] - radius)
        m = ma if m is None else (m & ma)
    return m


def build_stencil_call(
    update_fn: Callable[[Mapping[str, jax.Array], Mapping[str, jax.Array]], Mapping[str, jax.Array]],
    *,
    field_names: Sequence[str],
    out_names: Sequence[str],
    scalar_names: Sequence[str],
    shape: Sequence[int],
    radius: int,
    dtype,
    tile: Sequence[int] | None = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    interpret: bool | None = None,
) -> Callable[..., dict[str, jax.Array]]:
    """Build a fused Pallas stencil step.

    ``update_fn(fields, scalars) -> {out_name: interior_update}`` is traced
    on halo-extended VMEM windows. Returns ``run(fields, scalars)`` mapping
    full arrays -> dict of full output arrays.
    """
    shape = tuple(int(s) for s in shape)
    nd = len(shape)
    dtype = jnp.dtype(dtype)
    field_names = tuple(field_names)
    out_names = tuple(out_names)
    scalar_names = tuple(scalar_names)
    for o in out_names:
        if o not in field_names:
            raise ValueError(
                f"output {o!r} must also be an input field (boundary-copy source)"
            )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid, block = derive_launch(
        shape, radius, len(field_names), dtype.itemsize, vmem_budget, tile
    )
    r = radius
    win = tuple(
        pl.Element(b + 2 * r, padding=(r, r)) for b in block
    )

    def in_index_map(*pids):
        return tuple(pid * b for pid, b in zip(pids, block))

    def out_index_map(*pids):
        return pids

    n_s, n_f = len(scalar_names), len(field_names)
    center = tuple(slice(r, r + b) for b in block)

    def body(*refs):
        scal_refs = refs[:n_s]
        in_refs = refs[n_s : n_s + n_f]
        out_refs = refs[n_s + n_f :]
        scalars = {n: ref[0] for n, ref in zip(scalar_names, scal_refs)}
        windows = {n: ref[...] for n, ref in zip(field_names, in_refs)}
        updates = update_fn(windows, scalars)
        missing = set(out_names) - set(updates)
        if missing:
            raise ValueError(f"update_fn did not produce outputs {missing}")
        mask = _interior_mask(block, shape, r)
        for name, oref in zip(out_names, out_refs):
            prev = windows[name][center]
            oref[...] = jnp.where(mask, updates[name].astype(dtype), prev)

    in_specs = [pl.BlockSpec(memory_space=pltpu.SMEM) for _ in scalar_names]
    in_specs += [pl.BlockSpec(win, in_index_map) for _ in field_names]
    out_specs = [pl.BlockSpec(block, out_index_map) for _ in out_names]
    out_shape = [jax.ShapeDtypeStruct(shape, dtype) for _ in out_names]

    call = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs[0] if len(out_names) == 1 else out_specs,
        out_shape=out_shape[0] if len(out_names) == 1 else out_shape,
        interpret=interpret,
    )

    def run(fields: Mapping[str, jax.Array], scalars: Mapping[str, jax.Array]):
        ordered_scal = [
            jnp.asarray(scalars[n], dtype=dtype).reshape((1,)) for n in scalar_names
        ]
        ordered_fields = [jnp.asarray(fields[n], dtype=dtype) for n in field_names]
        for n, f in zip(field_names, ordered_fields):
            if f.shape != shape:
                raise ValueError(f"field {n!r} has shape {f.shape}, expected {shape}")
        outs = call(*ordered_scal, *ordered_fields)
        if len(out_names) == 1:
            outs = [outs]
        return dict(zip(out_names, outs))

    run.grid = grid
    run.block = block
    run.window_bytes = len(field_names) * math.prod(b + 2 * r for b in block) * dtype.itemsize
    return run
