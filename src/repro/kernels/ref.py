"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth used by tests (allclose sweeps
over shapes/dtypes) and doubles as the paper's "array programming" baseline
in benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# -- 3-D heat diffusion (paper Fig. 1) ---------------------------------------
def diffusion3d_step(T2, T, Ci, lam, dt, inv_dx, inv_dy, inv_dz):
    """One explicit Euler step of ``dT/dt = lam/c * lap(T)`` on the interior.

    Returns the new T2 (boundary kept from the input T2).
    """
    d2x = (T[2:, 1:-1, 1:-1] - 2 * T[1:-1, 1:-1, 1:-1] + T[:-2, 1:-1, 1:-1]) * inv_dx**2
    d2y = (T[1:-1, 2:, 1:-1] - 2 * T[1:-1, 1:-1, 1:-1] + T[1:-1, :-2, 1:-1]) * inv_dy**2
    d2z = (T[1:-1, 1:-1, 2:] - 2 * T[1:-1, 1:-1, 1:-1] + T[1:-1, 1:-1, :-2]) * inv_dz**2
    upd = T[1:-1, 1:-1, 1:-1] + dt * (lam * Ci[1:-1, 1:-1, 1:-1] * (d2x + d2y + d2z))
    return T2.at[1:-1, 1:-1, 1:-1].set(upd.astype(T2.dtype))


# -- generic 2nd-order laplacian step (used by property tests) ---------------
def laplacian_step(U, coeff, dt, inv_spacing):
    nd = U.ndim
    inner = tuple(slice(1, -1) for _ in range(nd))
    lap = jnp.zeros_like(U[inner])
    for a in range(nd):
        lo = tuple(slice(None, -2) if i == a else slice(1, -1) for i in range(nd))
        hi = tuple(slice(2, None) if i == a else slice(1, -1) for i in range(nd))
        lap = lap + (U[hi] - 2 * U[inner] + U[lo]) * inv_spacing[a] ** 2
    return U.at[inner].set(U[inner] + dt * coeff * lap)


# -- causal depthwise conv1d (Mamba2's stencil; kernels/conv1d.py) -----------
def conv1d_causal(x, w, b=None):
    """x: (B, L, C), w: (K, C) depthwise taps, causal (output t uses x[t-K+1..t]).

    Matches the Mamba short-conv: left-pad with zeros.
    """
    B, L, C = x.shape
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):
        out = out + xp[:, k : k + L, :] * w[K - 1 - k][None, None, :]
    if b is not None:
        out = out + b[None, None, :]
    return out


# -- flash attention oracle (kernels/attention.py) ----------------------------
def attention(q, k, v, causal=True, scale=None, window=None):
    """q: (B, Hq, Lq, D), k/v: (B, Hkv, Lk, D); GQA by head broadcast.

    window: sliding-window size (tokens attend to the last `window` keys),
    None for full attention. Computed in f32.
    """
    B, Hq, Lq, D = q.shape
    Hkv = k.shape[1]
    rep = Hq // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = (D ** -0.5) if scale is None else scale
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    Lk = k.shape[2]
    qpos = jnp.arange(Lq)[:, None] + (Lk - Lq)  # align ends (decode-friendly)
    kpos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# -- Mamba2 SSD oracle (kernels/ssd.py) ---------------------------------------
def ssd_scan(x, dt, A, B, C, D=None, h0=None):
    """Sequential state-space-duality reference (Mamba2, arXiv:2405.21060).

    x:  (batch, L, H, P)   inputs per head
    dt: (batch, L, H)      softplus-activated step sizes (already positive)
    A:  (H,)               negative state decay rate per head
    B:  (batch, L, G, N)   input projection (G state groups)
    C:  (batch, L, G, N)   output projection
    D:  (H,) or None       skip
    h0: (batch, H, P, N)   initial state or None
    Returns (y: (batch, L, H, P), h_final).
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2)  # (b, L, H, N)
    Ch = jnp.repeat(C, rep, axis=2)
    h = jnp.zeros((b, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (b,H,P), (b,H), (b,H,N), (b,H,N)
        dA = jnp.exp(dtt * A[None, :])  # (b,H)
        h = h * dA[..., None, None] + (dtt[..., None] * xt)[..., None] * Bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", h, Ct)
        return h, y

    xs = (
        x.astype(jnp.float32).transpose(1, 0, 2, 3),
        dt.astype(jnp.float32).transpose(1, 0, 2),
        Bh.astype(jnp.float32).transpose(1, 0, 2, 3),
        Ch.astype(jnp.float32).transpose(1, 0, 2, 3),
    )
    h, ys = jax.lax.scan(step, h, xs)
    y = ys.transpose(1, 0, 2, 3)  # (b, L, H, P)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h
