"""Pallas TPU kernels for the compute hot-spots, with jnp oracles in ref.py
and jit'd public wrappers in ops.py."""
from . import ref, stencil

__all__ = ["ref", "stencil"]
