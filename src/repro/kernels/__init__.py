"""Pallas TPU kernels for the compute hot-spots, with jnp oracles in ref.py
and jit'd public wrappers in ops.py."""
from . import autotune, ref, stencil

__all__ = ["autotune", "ref", "stencil"]
