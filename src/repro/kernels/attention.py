"""Flash attention (forward) as a Pallas TPU kernel.

Canonical TPU blocking: grid (batch, q_heads, q_blocks, k_blocks) with the
k-block axis innermost/sequential; running (m, l, acc) statistics live in
VMEM scratch across k-steps and the output block is finalized on the last
k-step. GQA is expressed in the k/v BlockSpec index maps (kv head =
q_head // group_size), causal and sliding-window masks via block iotas —
same masking discipline as the stencil kernels' interior mask.

Used for self-attention (Lq == Lk). Decode against a long cache is a
different memory regime and is handled by ops.decode_attention (jnp) /
the sequence-sharded flash-decoding path in distributed/sharding.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _body(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
          Bq, Bk, nk, scale, causal, window):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[...][0, 0].astype(jnp.float32)  # (Bq, D)
    k = k_ref[...][0, 0].astype(jnp.float32)  # (Bk, D)
    v = v_ref[...][0, 0].astype(jnp.float32)  # (Bk, D)
    s = jnp.dot(q, k.T) * scale  # (Bq, Bk)

    qpos = i * Bq + jax.lax.broadcasted_iota(jnp.int32, (Bq, Bk), 0)
    kpos = j * Bk + jax.lax.broadcasted_iota(jnp.int32, (Bq, Bk), 1)
    mask = jnp.ones((Bq, Bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = l_s[...][:, 0] * alpha + jnp.sum(p, axis=-1)
    acc_s[...] = acc_s[...] * alpha[:, None] + jnp.dot(p, v)
    m_s[...] = m_new[:, None]
    l_s[...] = l_new[:, None]

    @pl.when(j == nk - 1)
    def _fin():
        l = l_s[...][:, 0]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[...] = (acc_s[...] / safe[:, None])[None, None].astype(o_ref.dtype)


@functools.lru_cache(maxsize=64)
def _build(B, Hq, Hkv, L, D, Bq, Bk, dtype_name, scale, causal, window, interpret):
    dtype = jnp.dtype(dtype_name)
    rep = Hq // Hkv
    nk = L // Bk
    body = functools.partial(_body, Bq=Bq, Bk=Bk, nk=nk, scale=scale,
                             causal=causal, window=window)
    return pl.pallas_call(
        body,
        grid=(B, Hq, L // Bq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, Bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Bk, D), lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, Bk, D), lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, L, D), dtype),
        scratch_shapes=[
            pltpu.VMEM((Bq, 1), jnp.float32),
            pltpu.VMEM((Bq, 1), jnp.float32),
            pltpu.VMEM((Bq, D), jnp.float32),
        ],
        interpret=interpret,
    )


def flash_attention(q, k, v, causal=True, window=None, scale=None,
                    block_q=128, block_k=128, interpret=None):
    """q: (B, Hq, L, D), k/v: (B, Hkv, L, D) -> (B, Hq, L, D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, L, D = q.shape
    Hkv = k.shape[1]
    scale = (D ** -0.5) if scale is None else scale
    Bq, Bk = min(block_q, L), min(block_k, L)
    while L % Bq:
        Bq //= 2
    while L % Bk:
        Bk //= 2
    call = _build(B, Hq, Hkv, L, D, max(Bq, 1), max(Bk, 1), q.dtype.name,
                  float(scale), bool(causal), window, bool(interpret))
    return call(q, k, v)
