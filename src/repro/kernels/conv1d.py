"""Causal depthwise conv1d as a Pallas stencil (Mamba2's short convolution).

This is the paper's technique applied to an LM architecture: the causal
short-conv in every Mamba2 block *is* a 1-D stencil with a one-sided halo
of width K-1, so it runs through the exact same machinery as the PDE
kernels — halo-extended VMEM windows over the sequence axis,
with a validity mask standing in for the zero left-padding.

x: (B, L, C), w: (K, C) depthwise taps, optional bias (C,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .stencil import halo_window_spec


def _body(x_ref, w_ref, b_ref, o_ref, *, K, BL, silu):
    j = pl.program_id(1)
    xw = x_ref[...][0]  # (BL + K - 1, C) halo-extended window
    w = w_ref[...]      # (K, C)
    acc = jnp.zeros((BL, xw.shape[1]), jnp.float32)
    # out[t] = sum_d w[d] * x[t-d]; local slice for lag d starts at K-1-d.
    t = j * BL + jax.lax.broadcasted_iota(jnp.int32, (BL, 1), 0)
    for d in range(K):
        xs = xw[K - 1 - d : K - 1 - d + BL].astype(jnp.float32)
        valid = (t - d) >= 0  # zero left-padding instead of garbage OOB halo
        acc = acc + jnp.where(valid, xs, 0.0) * w[d].astype(jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)
    if silu:
        acc = acc * jax.nn.sigmoid(acc)
    o_ref[...] = acc[None].astype(o_ref.dtype)


@functools.lru_cache(maxsize=64)
def _build(B, L, C, K, BL, dtype_name, silu, interpret):
    dtype = jnp.dtype(dtype_name)
    body = functools.partial(_body, K=K, BL=BL, silu=silu)
    return pl.pallas_call(
        body,
        grid=(B, L // BL),
        in_specs=[
            halo_window_spec((1, BL, C), ((0, 0), (K - 1, 0), (0, 0)),
                             lambda b, j: (b, j * BL, 0)),
            pl.BlockSpec((K, C), lambda b, j: (0, 0)),
            pl.BlockSpec((C,), lambda b, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, BL, C), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, C), dtype),
        interpret=interpret,
    )


def conv1d_causal(x, w, b=None, silu: bool = False, block_l: int | None = None,
                  interpret: bool | None = None):
    """Fused causal depthwise conv (+ optional SiLU). Returns (B, L, C)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, L, C = x.shape
    K = w.shape[0]
    if b is None:
        b = jnp.zeros((C,), x.dtype)
    if block_l is None:
        block_l = min(L, 512)
        while L % block_l:
            block_l //= 2
        block_l = max(block_l, 1)
    call = _build(B, L, C, K, int(block_l), x.dtype.name, bool(silu), bool(interpret))
    return call(x, w.astype(x.dtype), b.astype(x.dtype))
