"""Empirical launch autotuner: sweep (tile, nsteps) and keep the winner.

ParallelStencil derives launch parameters analytically (stencil.derive_launch);
this module closes the loop empirically, the way production stencil
frameworks (and XLA's own autotuner) do: run each candidate configuration
through ``teff.measure`` and cache the fastest per (shape, dtype, radius,
n_fields) — so the search cost is paid once per problem class per process
(and optionally persisted to JSON across processes).

The candidate space is deliberately small and deterministic:

  * tiles — the analytically-derived block plus a few divisor-preserving
    perturbations of the non-minor axes (the minor axis stays lane-aligned);
  * nsteps — temporal-blocking depths; per-step time is what is compared,
    so a k-fused candidate wins only when its redundant halo compute is
    cheaper than the HBM traffic it saves.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Sequence

import jax.numpy as jnp

from .. import telemetry as _telemetry
from ..core import teff
from . import stencil as _stencil


@dataclasses.dataclass(frozen=True)
class TuneResult:
    tile: tuple[int, ...]
    nsteps: int
    per_step_s: float
    candidates_tried: int
    candidates_pruned: int = 0   # dropped by the analytic model pre-compile
    march_axis: int | None = None  # winning streaming axis (None: all-parallel)

    def to_json(self) -> dict:
        return {"tile": list(self.tile), "nsteps": self.nsteps,
                "per_step_s": self.per_step_s,
                "candidates_tried": self.candidates_tried,
                "candidates_pruned": self.candidates_pruned,
                "march_axis": self.march_axis}

    @classmethod
    def from_json(cls, d: dict) -> "TuneResult":
        march = d.get("march_axis")
        return cls(tuple(d["tile"]), int(d["nsteps"]), float(d["per_step_s"]),
                   int(d.get("candidates_tried", 0)),
                   int(d.get("candidates_pruned", 0)),
                   None if march is None else int(march))


_CACHE: dict[tuple, TuneResult] = {}

# Persistent-cache schema version. v2 added the engine-geometry fields
# (march axis candidates, per-axis halos) to the key; v3 adds the check
# workload (fused reduction set + cadence); v4 adds the (storage,
# compute) dtype pair — a bf16-storage run must never inherit an
# f32-tuned winner whose VMEM window footprints are 2x its own (or vice
# versa). Launches cached by older binaries carry shorter keys that can
# never match, so files without a matching version are IGNORED
# (re-tuned), never trusted.
CACHE_VERSION = 4


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


_window_bytes = _stencil.window_footprint_bytes


def tile_candidates(
    shape: Sequence[int],
    radius: int,
    n_fields: int,
    itemsize: int,
    vmem_budget: int = _stencil.DEFAULT_VMEM_BUDGET,
    max_candidates: int = 4,
    field_offsets: Sequence[Sequence[int]] | None = None,
) -> list[tuple[int, ...]]:
    """Derived block plus divisor-preserving halvings/doublings of the
    leading (non-minor) axes, all within the VMEM budget. The budget is
    checked against the *full coupled field set's* window footprint
    (``field_offsets``: one staggering tuple per field; defaults to
    ``n_fields`` collocated fields)."""
    shape = tuple(int(s) for s in shape)
    nd = len(shape)
    if field_offsets is None:
        field_offsets = [(0,) * nd] * n_fields
    _, base = _stencil.derive_launch(shape, radius, n_fields, itemsize,
                                     vmem_budget,
                                     field_offsets=field_offsets)
    halo = radius

    def fits(blk):
        return _window_bytes(blk, halo, field_offsets, itemsize) <= vmem_budget

    cands = [base]
    for axis in range(max(nd - 1, 1)):
        for factor in (2, 0.5):
            b = int(base[axis] * factor)
            if b < 1 or b > shape[axis] or shape[axis] % b:
                continue
            cand = tuple(b if a == axis else base[a] for a in range(nd))
            if fits(cand) and cand not in cands:
                cands.append(cand)
    return cands[:max_candidates]


def cache_key(shape, dtype, radius: int, n_fields: int, tag: str = "",
              nsteps_candidates: Sequence[int] = (),
              tiles=None, vmem_budget: int = 0,
              field_offsets: Sequence[Sequence[int]] | None = None,
              prune: tuple | None = None,
              march_candidates: Sequence[int | None] | None = None,
              halos: Sequence[tuple[int, int]] | None = None,
              reductions: Sequence[str] | None = None,
              check_every: int | None = None,
              dtypes: Sequence[str] | None = None) -> tuple:
    """Memo key covers the full search space: a call with a different
    candidate set must re-tune, not inherit another sweep's winner. The
    coupled field set's staggering (``field_offsets``) is part of the key:
    two systems with the same field count but different VMEM footprints
    tune independently. ``prune`` tags an analytic-pruning configuration
    (hardware name + ratio) — a pruned search must not inherit an
    unpruned sweep's winner or vice versa. The engine-geometry fields —
    ``march_candidates`` (streaming axes in the search space) and
    ``halos`` (per-axis (lo, hi) window depths) — key the launch
    geometry itself: a winner tuned for refetched halo windows must not
    be handed to a streamed-queue launch or vice versa. ``reductions``
    (the fused epilogue set, e.g. ``r.describe()`` strings) and
    ``check_every`` key the check workload: a winner tuned for a plain
    sweep must not be handed to a checked solver whose epilogue shifts
    the tile economics. ``dtypes`` — the (storage, compute) dtype-name
    pair — keys mixed precision: bf16 storage halves every window
    footprint, so an f32-tuned tile is wrong for it in both directions."""
    return (tag, tuple(int(s) for s in shape), jnp.dtype(dtype).name,
            int(radius), int(n_fields),
            tuple(int(k) for k in nsteps_candidates),
            None if tiles is None else tuple(tuple(int(b) for b in t)
                                             for t in tiles),
            int(vmem_budget),
            None if field_offsets is None else tuple(
                tuple(int(o) for o in off) for off in field_offsets),
            prune,
            None if march_candidates is None else tuple(
                None if m is None else int(m) for m in march_candidates),
            None if halos is None else tuple(
                (int(lo), int(hi)) for lo, hi in halos),
            None if reductions is None else tuple(sorted(
                str(r) for r in reductions)),
            None if check_every is None else int(check_every),
            None if dtypes is None else tuple(str(d) for d in dtypes))


def autotune(
    make_step: Callable[[tuple[int, ...], int], Callable[[], object]],
    *,
    shape: Sequence[int],
    dtype,
    radius: int = 1,
    n_fields: int = 3,
    itemsize: int | None = None,
    nsteps_candidates: Sequence[int] = (1, 2, 4),
    tiles: Sequence[Sequence[int]] | None = None,
    vmem_budget: int = _stencil.DEFAULT_VMEM_BUDGET,
    iters: int = 5,
    tag: str = "",
    cache_path: str | None = None,
    field_offsets: Sequence[Sequence[int]] | None = None,
    cost_model=None,
    hw=None,
    prune_ratio: float = 2.0,
    march_candidates: Sequence[int | None] | None = None,
    halos: Sequence[tuple[int, int]] | None = None,
    reductions: Sequence[str] | None = None,
    check_every: int | None = None,
    compute_dtype=None,
) -> TuneResult:
    """Find the fastest (tile, nsteps[, march_axis]) for a stencil
    problem class.

    ``reductions`` (epilogue descriptions, e.g.
    ``[r.describe() for r in kernel.reductions.values()]``) and
    ``check_every`` key the cached winner to the check workload, and the
    analytic pruner prices the check's amortized flops and traffic
    (``cost_model.predict_per_step_s(..., check_every=)``) so a checked
    solver never inherits a plain sweep's winner.

    ``make_step(tile, nsteps)`` must return a zero-arg callable advancing
    ``nsteps`` time steps with that configuration (typically a jit'd
    ``StencilKernel.run_steps`` closure). Per-step median wall time decides.
    Results are memoized per (shape, dtype, radius, field set, tag) in
    process memory and, when ``cache_path`` is given, in a JSON file.

    ``march_candidates`` adds the streaming axis to the search space
    (e.g. ``(None, 0)``: all-parallel vs marching the leading axis);
    ``make_step`` is then called as ``make_step(tile, nsteps,
    march_axis)``. ``halos`` (per-axis (lo, hi) depths, e.g. the traced
    ``ir.halo``) keys the cached winner to the launch geometry.

    For coupled systems pass ``field_offsets`` (one per-axis staggering
    tuple per field): the candidate filter and derived tiles then budget
    VMEM for the *sum* of all the system's windows, not a single field.

    Analytic pruning: with a ``cost_model`` (``ir.StencilCostModel``, e.g.
    ``kernel.cost_model(...)``) and ``hw`` (``teff.HardwareSpec``), every
    candidate gets a predicted per-step time from the kernel's exact
    flop/byte footprint — fetched-window traffic vs halo-cone recompute —
    and candidates slower than ``prune_ratio`` times the best prediction
    are dropped *before anything is built or compiled*. Only the
    survivors are measured; ``TuneResult.candidates_pruned`` records how
    many configs never paid a compile.
    """
    prune_tag = (None if cost_model is None or hw is None
                 else (getattr(hw, "name", "hw"), float(prune_ratio)))
    # Every tune keys the FULL (storage, compute) dtype pair — the v4
    # fix for the stale-cache bug where a bf16 run silently reused
    # f32-tuned tiles with half-wrong VMEM footprints.
    st = jnp.dtype(dtype)
    cd = (_stencil.default_compute_dtype(st) if compute_dtype is None
          else jnp.dtype(compute_dtype))
    key = cache_key(shape, dtype, radius, n_fields, tag, nsteps_candidates,
                    tiles, vmem_budget, field_offsets, prune_tag,
                    march_candidates, halos, reductions, check_every,
                    dtypes=(st.name, cd.name))
    col = _telemetry.get()
    if key in _CACHE:
        hit = _CACHE[key]
        if col.enabled:
            col.event("autotune.decision", tag=tag, cache="memory_hit",
                      tile=hit.tile, nsteps=hit.nsteps,
                      march_axis=hit.march_axis,
                      per_step_s=hit.per_step_s)
            col.count("autotune.cache_hits", 1)
        return hit
    if cache_path and os.path.exists(cache_path):
        disk = _load_cache(cache_path)
        hit = disk.get(_key_str(key))
        if hit is not None:
            _CACHE[key] = hit
            if col.enabled:
                col.event("autotune.decision", tag=tag, cache="disk_hit",
                          tile=hit.tile, nsteps=hit.nsteps,
                          march_axis=hit.march_axis,
                          per_step_s=hit.per_step_s)
                col.count("autotune.cache_hits", 1)
            return hit

    itemsize = jnp.dtype(dtype).itemsize if itemsize is None else itemsize
    nd = len(tuple(shape))
    offs = (field_offsets if field_offsets is not None
            else [(0,) * nd] * n_fields)
    derived_tiles = tiles is None
    if derived_tiles:
        tiles = tile_candidates(shape, radius, n_fields, itemsize, vmem_budget,
                                field_offsets=field_offsets)
    pass_march = march_candidates is not None
    marches = (None,) if march_candidates is None else tuple(march_candidates)
    cands: list[tuple[tuple[int, ...], int, int | None]] = []
    for tile in tiles:
        tile = tuple(int(b) for b in tile)
        for k in nsteps_candidates:
            k = int(k)
            for march in marches:
                if derived_tiles:
                    # Temporal blocking widens the halo to k*radius; enforce
                    # the VMEM budget at the depth actually being measured,
                    # summed over the full coupled field set — streamed
                    # candidates are costed with their plane queues instead
                    # of march-axis halos.
                    # (Explicitly-passed tiles bypass this: the caller may
                    # be tuning a backend where the budget is irrelevant,
                    # e.g. jnp.)
                    if march is None:
                        wb = _window_bytes(tile, radius * k, offs, itemsize)
                    else:
                        wb = _stencil.streamed_footprint_bytes(
                            tile, radius * k, offs, itemsize, march)
                    if wb > vmem_budget:
                        continue
                cands.append((tile, k, march))
    pruned = 0
    if prune_tag is not None and len(cands) > 1:
        preds = {c: cost_model.predict_per_step_s(c[0], c[1], hw, c[2],
                                                  check_every=check_every)
                 for c in cands}
        best_pred = min(preds.values())
        survivors = [c for c in cands if preds[c] <= prune_ratio * best_pred]
        pruned = len(cands) - len(survivors)
        cands = survivors
    best: TuneResult | None = None
    tried = 0
    for tile, k, march in cands:
        try:
            fn = make_step(tile, k, march) if pass_march else \
                make_step(tile, k)
            m = teff.measure(fn, iters=iters, warmup=1)
        except Exception:
            continue  # candidate not realizable (tile/shape mismatch etc.)
        tried += 1
        per_step = m.median_s / k
        if best is None or per_step < best.per_step_s:
            best = TuneResult(tile, k, per_step, tried, march_axis=march)
    if best is None:
        raise RuntimeError("no autotune candidate was runnable")
    best = dataclasses.replace(best, candidates_tried=tried,
                               candidates_pruned=pruned)
    if col.enabled:
        col.event("autotune.decision", tag=tag, cache="miss",
                  tile=best.tile, nsteps=best.nsteps,
                  march_axis=best.march_axis, per_step_s=best.per_step_s,
                  candidates_tried=tried, candidates_pruned=pruned)
        col.count("autotune.cache_misses", 1)
        col.count("autotune.candidates_pruned", pruned)
        col.count("autotune.candidates_tried", tried)
    _CACHE[key] = best
    if cache_path:
        disk = _load_cache(cache_path) if os.path.exists(cache_path) else {}
        disk[_key_str(key)] = best
        _save_cache(cache_path, disk)
    return best


def autotune_diffusion3d(
    shape: Sequence[int],
    dtype="float32",
    backend: str = "jnp",
    nsteps_candidates: Sequence[int] = (1, 2, 4),
    iters: int = 5,
    cache_path: str | None = None,
    hw=None,
    prune_ratio: float = 2.0,
    march_candidates: Sequence[int | None] | None = None,
) -> TuneResult:
    """Tune the Fig. 1 diffusion solver on this host.

    Uses the ``StencilKernel`` path (jit'd ``run_steps``) so the measured
    configuration is exactly what the solver runs. The jnp backend is the
    performance path on CPU hosts; on TPU pass ``backend="pallas"``.
    With ``hw`` (a ``teff.HardwareSpec``) the kernel's inferred cost model
    prunes the candidate grid analytically before anything compiles.
    ``march_candidates`` (e.g. ``(None, 0)``) adds streamed execution to
    the search space.
    """
    import jax
    import numpy as np

    from ..core import init_parallel_stencil, fd3d as fd

    shape = tuple(int(s) for s in shape)
    dtype = jnp.dtype(dtype)
    rng = np.random.RandomState(0)
    T = jnp.asarray(rng.rand(*shape), dtype)
    T2 = T.copy()  # distinct write buffer, as the solvers allocate
    Ci = jnp.asarray(rng.rand(*shape) + 0.5, dtype)
    sc = dict(lam=1.0, dt=1e-6, _dx=float(shape[0] - 1),
              _dy=float(shape[1] - 1), _dz=float(shape[2] - 1))

    # The jnp backend has no tiling knob — only sweep nsteps there.
    tiles = None
    if backend == "jnp":
        _, base = _stencil.derive_launch(shape, 1, 3, dtype.itemsize)
        tiles = [base]

    def _kernel(ps, tile=None, march=None):
        @ps.parallel(outputs=("T2",), tile=tile, rotations={"T2": "T"},
                     march_axis=march)
        def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
            return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
                fd.d2_xi(T) * _dx ** 2 + fd.d2_yi(T) * _dy ** 2 +
                fd.d2_zi(T) * _dz ** 2))}
        return kern

    probe = _kernel(init_parallel_stencil(backend=backend, dtype=dtype,
                                          ndims=3))
    halos = probe.stencil_ir(T2=shape, T=shape, Ci=shape, **sc).halo
    cost_model = None
    if hw is not None:
        cost_model = probe.cost_model(T2=shape, T=shape, Ci=shape, **sc)

    def make_step(tile, k, march=None):
        ps = init_parallel_stencil(backend=backend, dtype=dtype, ndims=3)
        kern = _kernel(ps, tile, march)
        step = jax.jit(lambda T2, T: kern.run_steps(k, T2=T2, T=T, Ci=Ci, **sc))
        return lambda: step(T2, T)

    return autotune(
        make_step, shape=shape, dtype=dtype, radius=1, n_fields=3,
        nsteps_candidates=nsteps_candidates, tiles=tiles, iters=iters,
        tag=f"diffusion3d/{backend}", cache_path=cache_path,
        cost_model=cost_model, hw=hw, prune_ratio=prune_ratio,
        march_candidates=march_candidates, halos=halos,
    )


# ---------------- JSON persistence ----------------
def _key_str(key: tuple) -> str:
    return json.dumps(key, separators=(",", ":"))


def _load_cache(path: str) -> dict[str, TuneResult]:
    """Load a persistent cache, IGNORING (not crashing on) files written
    by older schema versions: PR 1–3 binaries cached launches without the
    march/halos geometry in the key, so their winners may be invalid for
    the streamed engine — a version mismatch simply re-tunes. Transient
    read failures (shared filesystems hiccup) are retried with backoff
    before giving up on the cache."""
    from ..distributed import fault

    def read():
        fault.FaultPlan.active_on_io(path)
        with open(path) as f:
            return json.load(f)

    try:
        raw = fault.retry(read, exceptions=(OSError,))
        if not isinstance(raw, dict) or raw.get("version") != CACHE_VERSION:
            return {}
        return {k: TuneResult.from_json(v)
                for k, v in raw.get("entries", {}).items()}
    except (OSError, ValueError, KeyError, TypeError):
        return {}


def _save_cache(path: str, cache: dict[str, TuneResult]) -> None:
    from ..distributed import fault

    def write():
        fault.FaultPlan.active_on_io(path)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION,
                       "entries": {k: v.to_json() for k, v in cache.items()}},
                      f, indent=1)
        os.replace(tmp, path)

    fault.retry(write, exceptions=(OSError,))
