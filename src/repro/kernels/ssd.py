"""Mamba2 SSD (state-space duality) chunk scan as a Pallas TPU kernel.

The SSD algorithm (arXiv:2405.21060) splits the sequence into chunks; the
within-chunk part is a decay-masked quadratic form (MXU-friendly matmuls)
and the across-chunk part is a short recurrence on the (H, P, N) state.

TPU mapping (DESIGN.md): the Pallas grid is (batch, chunks) with the chunk
axis innermost — TPU grids execute sequentially, so the recurrent state
lives in VMEM scratch and is carried *across grid steps*, exactly like the
paper's `loopopt` register pipeline carries the z-column. One fused kernel
therefore performs what the jnp reference needs a scan + 5 einsums for.

Shapes (per call): x (B, L, H, P); dt (B, L, H) positive (post-softplus);
A (H,) negative; Bm/Cm (B, L, H, N) (groups pre-broadcast to heads);
D (H,) skip; h0 (B, H, P, N). L = nc * cs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _body(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref, y_ref, hout_ref,
          h_s, *, cs, nc):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        h_s[...] = h0_ref[...][0].astype(jnp.float32)

    x = x_ref[...][0].astype(jnp.float32)    # (cs, H, P)
    dt = dt_ref[...][0].astype(jnp.float32)  # (cs, H)
    A = A_ref[...].astype(jnp.float32)       # (H,)
    Bm = B_ref[...][0].astype(jnp.float32)   # (cs, H, N)
    Cm = C_ref[...][0].astype(jnp.float32)   # (cs, H, N)
    D = D_ref[...].astype(jnp.float32)       # (H,)

    la = dt * A[None, :]                      # log decay per step (<= 0)
    logcum = jnp.cumsum(la, axis=0)           # (cs, H); log s[t]
    s = jnp.exp(logcum)
    h_in = h_s[...]                           # (H, P, N)

    # inter-chunk: y_inter[t] = s[t] * C[t] . h_in
    y_inter = jnp.einsum("thn,hpn->thp", Cm, h_in) * s[..., None]

    # intra-chunk: decay-masked quadratic form
    cb = jnp.einsum("thn,uhn->tuh", Cm, Bm)   # (cs, cs, H)
    ldiff = logcum[:, None, :] - logcum[None, :, :]  # log s[t]/s[u]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (cs, cs), 1)
    )
    decay = jnp.exp(jnp.where(tri[..., None], ldiff, -1e30))  # mask pre-exp
    w = cb * decay * dt[None, :, :]           # weight over source u
    y = y_inter + jnp.einsum("tuh,uhp->thp", w, x) + x * D[None, :, None]
    y_ref[...] = y[None].astype(y_ref.dtype)

    # state update: h_out = s_last * h_in + sum_u (s_last/s[u]) dt[u] x[u] B[u]^T
    s_last = jnp.exp(logcum[-1])              # (H,)
    coeff = jnp.exp(logcum[-1][None, :] - logcum) * dt  # (cs, H)
    dh = jnp.einsum("uh,uhp,uhn->hpn", coeff, x, Bm)
    h_s[...] = h_in * s_last[:, None, None] + dh

    @pl.when(c == nc - 1)
    def _fin():
        hout_ref[...] = h_s[...][None].astype(hout_ref.dtype)


@functools.lru_cache(maxsize=64)
def _build(B, L, H, P, N, cs, dtype_name, interpret):
    nc = L // cs
    dtype = jnp.dtype(dtype_name)
    body = functools.partial(_body, cs=cs, nc=nc)
    return pl.pallas_call(
        body,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, cs, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, cs, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, cs, H, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, cs, H, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cs, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )


def ssd_chunk_scan(x, dt, A, Bm, Cm, D=None, h0=None, chunk: int = 64,
                   interpret: bool | None = None):
    """Fused SSD forward. Groups must be pre-broadcast to heads.

    Returns (y (B,L,H,P), h_final (B,H,P,N) in f32).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    cs = min(chunk, L)
    while L % cs:
        cs //= 2
    cs = max(cs, 1)
    if D is None:
        D = jnp.zeros((H,), x.dtype)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
    call = _build(B, L, H, P, N, cs, x.dtype.name, bool(interpret))
    return call(x, dt, A, Bm, Cm, D, h0)
