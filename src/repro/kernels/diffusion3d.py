"""Hand-specialized Pallas kernel for the paper's Fig. 1 diffusion step.

This is the "explicit notation" variant of the solver (paper §3 compares
math-close vs explicit): the stencil is written with raw window slices
instead of the fd.* operators, and the kernel is tuned by hand (tile
override, fused scalar folding). Numerically identical to
``ref.diffusion3d_step`` and to the math-close kernel built through
``core.parallel`` — tests assert all three agree.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import stencil as _stencil


def _body(scal_ref, T2_ref, T_ref, Ci_ref, o_ref, *, block, shape):
    lam, dt, idx2, idy2, idz2 = (scal_ref[i] for i in range(5))
    T = T_ref[...]
    Ci = Ci_ref[...]
    c = T[1:-1, 1:-1, 1:-1]
    lap = (
        (T[2:, 1:-1, 1:-1] - 2 * c + T[:-2, 1:-1, 1:-1]) * idx2
        + (T[1:-1, 2:, 1:-1] - 2 * c + T[1:-1, :-2, 1:-1]) * idy2
        + (T[1:-1, 1:-1, 2:] - 2 * c + T[1:-1, 1:-1, :-2]) * idz2
    )
    upd = c + dt * (lam * Ci[1:-1, 1:-1, 1:-1] * lap)
    mask = _stencil._interior_mask(block, shape, 1)
    o_ref[...] = jnp.where(mask, upd.astype(o_ref.dtype), T2_ref[...][1:-1, 1:-1, 1:-1])


@functools.lru_cache(maxsize=32)
def _build(shape, dtype_name, tile, interpret):
    dtype = jnp.dtype(dtype_name)
    grid, block = _stencil.derive_launch(shape, 1, 3, dtype.itemsize, tile=tile)
    win = tuple(pl.Element(b + 2, padding=(1, 1)) for b in block)
    body = functools.partial(_body, block=block, shape=shape)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(win, lambda i, j, k: (i * block[0], j * block[1], k * block[2])),
            pl.BlockSpec(win, lambda i, j, k: (i * block[0], j * block[1], k * block[2])),
            pl.BlockSpec(win, lambda i, j, k: (i * block[0], j * block[1], k * block[2])),
        ],
        out_specs=pl.BlockSpec(block, lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        interpret=interpret,
    )


def diffusion3d_step(T2, T, Ci, lam, dt, inv_dx, inv_dy, inv_dz,
                     tile=None, interpret=None):
    """Fused Pallas diffusion step; returns the new T2 (full array)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dtype = T.dtype
    scal = jnp.array(
        [lam, dt, inv_dx**2, inv_dy**2, inv_dz**2], dtype=dtype
    )
    call = _build(tuple(T.shape), dtype.name, tile if tile is None else tuple(tile),
                  bool(interpret))
    return call(scal, T2, T, Ci)
