"""Hand-specialized Pallas kernel for the paper's Fig. 1 diffusion step.

This is the "explicit notation" variant of the solver (paper §3 compares
math-close vs explicit): the stencil is written with raw window slices
instead of the fd.* operators, and the kernel is tuned by hand (tile
override, fused scalar folding, all-parallel ``dimension_semantics``,
in-place ``input_output_aliases`` double-buffer rotation). Numerically
identical to ``ref.diffusion3d_step`` and to the math-close kernel built
through ``core.parallel`` — tests assert all three agree.

``nsteps=k`` runs the temporally-blocked variant: the VMEM windows carry a
k-cell halo and the Euler update is swept k times per launch, so T/Ci cross
HBM once per k steps. The result is bitwise-identical to k rotated
single-step calls whenever T2 and T agree on the boundary ring (true for
the solvers: both buffers start as copies; boundaries are never updated).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import stencil as _stencil


def _body(scal_ref, T2_ref, T_ref, Ci_ref, o_ref, *, block, shape, nsteps):
    lam, dt, idx2, idy2, idz2 = (scal_ref[i] for i in range(5))
    T = T_ref[...]
    Ci = Ci_ref[...]
    for s in range(nsteps):
        c = T[1:-1, 1:-1, 1:-1]
        lap = (
            (T[2:, 1:-1, 1:-1] - 2 * c + T[:-2, 1:-1, 1:-1]) * idx2
            + (T[1:-1, 2:, 1:-1] - 2 * c + T[1:-1, :-2, 1:-1]) * idy2
            + (T[1:-1, 1:-1, 2:] - 2 * c + T[1:-1, 1:-1, :-2]) * idz2
        )
        upd = c + dt * (lam * Ci[1:-1, 1:-1, 1:-1] * lap)
        ext = nsteps - 1 - s  # remaining halo extent after this sweep
        mask = _stencil._interior_mask(block, shape, 1, ext)
        if s < nsteps - 1:
            # Rotate in-register: the sweep's T2 becomes the next sweep's T;
            # globally-boundary cells keep carrying their original values.
            T = jnp.where(mask, upd.astype(T.dtype), c)
            Ci = Ci[1:-1, 1:-1, 1:-1]
        else:
            k = nsteps
            prev = T2_ref[...][k:-k, k:-k, k:-k]
            o_ref[...] = jnp.where(mask, upd.astype(o_ref.dtype), prev)


@functools.lru_cache(maxsize=32)
def _build(shape, dtype_name, tile, interpret, nsteps, alias):
    dtype = jnp.dtype(dtype_name)
    grid, block = _stencil.derive_launch(shape, 1, 3, dtype.itemsize, tile=tile,
                                         nsteps=nsteps)
    halo = nsteps

    def win_map(i, j, k):
        return (i * block[0], j * block[1], k * block[2])

    body = functools.partial(_body, block=block, shape=shape, nsteps=nsteps)
    kwargs = {}
    if alias:
        # input order: (scal, T2, T, Ci) -> donate T2's buffer to the output
        # so the double-buffer rotates in place instead of allocating.
        kwargs["input_output_aliases"] = {1: 0}
    if not interpret:
        cp = _stencil.compiler_params(3)
        if cp is not None:
            kwargs["compiler_params"] = cp
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            _stencil.halo_window_spec(block, (halo,) * 3, win_map),
            _stencil.halo_window_spec(block, (halo,) * 3, win_map),
            _stencil.halo_window_spec(block, (halo,) * 3, win_map),
        ],
        out_specs=pl.BlockSpec(block, lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        interpret=interpret,
        **kwargs,
    )


def diffusion3d_step(T2, T, Ci, lam, dt, inv_dx, inv_dy, inv_dz,
                     tile=None, interpret=None, nsteps=1, alias=None):
    """Fused Pallas diffusion step(s); returns the temperature after
    ``nsteps`` explicit Euler steps as one full array (one launch).

    ``alias=True`` donates T2's buffer to the output (in-place rotation).
    Default: alias on real TPU only — eager donation on the interpret path
    invalidates the caller's T2, which the CPU test suites still read.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if alias is None:
        alias = not interpret
    nsteps = int(nsteps)
    if nsteps < 1:
        raise ValueError(f"nsteps must be >= 1, got {nsteps}")
    dtype = T.dtype
    scal = jnp.array(
        [lam, dt, inv_dx**2, inv_dy**2, inv_dz**2], dtype=dtype
    )
    call = _build(tuple(T.shape), dtype.name, tile if tile is None else tuple(tile),
                  bool(interpret), nsteps, bool(alias))
    return call(scal, T2, T, Ci)
