"""Public jit'd wrappers around the kernels.

Every op has (at least) three interchangeable implementations:

  * ``impl="pallas"`` — the Pallas TPU kernel (interpret-mode on CPU);
  * ``impl="chunked"`` — memory-bounded pure-jnp (lax.scan blocking). This
    is what the model/dry-run path uses: it compiles on any backend and its
    HLO has realistic (bounded) memory footprints at 32k–500k context;
  * ``impl="ref"`` — the O(L^2)-memory oracle in ref.py (tests/tiny shapes).

Tests sweep shapes/dtypes and assert all implementations agree.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref
from . import attention as _attn_kernel
from . import conv1d as _conv_kernel
from . import ssd as _ssd_kernel
from . import diffusion3d as _diff_kernel

NEG_INF = -1e30


def _pick_divisor(n: int, target: int) -> int:
    c = min(target, n)
    while n % c:
        c -= 1
    return max(c, 1)


# =====================================================================
# attention
# =====================================================================
def attention(q, k, v, causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None, impl: str = "chunked",
              q_chunk: int = 512, k_chunk: int = 1024):
    """Self-attention with GQA; q (B,Hq,L,D), k/v (B,Hkv,L,D)."""
    if impl == "ref":
        return _ref.attention(q, k, v, causal=causal, scale=scale, window=window)
    if impl == "pallas":
        return _attn_kernel.flash_attention(q, k, v, causal=causal, window=window,
                                            scale=scale)
    return _chunked_attention(q, k, v, causal, window, scale, q_chunk, k_chunk)


def _chunked_attention(q, k, v, causal, window, scale, q_chunk, k_chunk):
    """Memory-efficient attention: scan over q blocks; online softmax over
    k blocks; the per-q-block computation is rematerialized on backward
    (jax.checkpoint), so residual memory is O(L*D), not O(L^2)."""
    B, Hq, Lq, D = q.shape
    Hkv, Lk = k.shape[1], k.shape[2]
    R = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    Qc = _pick_divisor(Lq, q_chunk)
    Kc = _pick_divisor(Lk, k_chunk)
    nq, nk = Lq // Qc, Lk // Kc
    pos_off = Lk - Lq  # align sequence ends (prefill continuation friendly)

    qg = q.reshape(B, Hkv, R, Lq, D)
    # (nq, B, G, R, Qc, D)
    qs = jnp.moveaxis(qg.reshape(B, Hkv, R, nq, Qc, D), 3, 0)

    def q_block(qi, qblk):
        qf = qblk.astype(jnp.float32) * scale
        qpos = pos_off + qi * Qc + jnp.arange(Qc)

        def k_step(carry, ki):
            m, l, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(k, ki * Kc, Kc, axis=2)
            vblk = jax.lax.dynamic_slice_in_dim(v, ki * Kc, Kc, axis=2)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qf, kblk.astype(jnp.float32))
            kpos = ki * Kc + jnp.arange(Kc)
            mask = jnp.ones((Qc, Kc), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, R, Qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, R, Qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, R, Qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), jnp.arange(nk))
        l = jnp.where(l > 0, l, 1.0)
        return (acc / l[..., None]).astype(q.dtype)

    blk = jax.checkpoint(q_block, static_argnums=())
    _, outs = jax.lax.scan(lambda _, xs: (None, blk(xs[0], xs[1])),
                           None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 3)  # (B, G, R, nq, Qc, D)
    return out.reshape(B, Hq, Lq, D)


def decode_attention(q, k_cache, v_cache, pos: Optional[jax.Array] = None,
                     window: Optional[int] = None, scale: Optional[float] = None,
                     k_chunk: int = 2048):
    """One-token decode: q (B,Hq,D) against cache (B,Hkv,S,D) -> (B,Hq,D).

    One einsum over the full cache: with the cache's sequence axis sharded
    (launch/steps.py), GSPMD computes per-shard partials + one psum — the
    flash-decoding pattern. (A chunked lax.scan variant was measured WORSE
    here: dynamic-slicing the sharded S axis makes GSPMD gather per chunk —
    minicpm decode collective 10 ms -> 3.6 s. EXPERIMENTS.md §Perf, refuted.)
    ``pos``: current token index (masks cache > pos, applies the window);
    None attends to the whole cache.
    """
    B, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    R = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    qg = q.reshape(B, Hkv, R, D).astype(jnp.float32) * scale
    s = jnp.einsum("bgrd,bgkd->bgrk", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(S)
    mask = jnp.ones((S,), bool)
    if pos is not None:
        mask &= kpos <= pos
        if window is not None:
            mask &= kpos > pos - window
    elif window is not None:
        mask &= kpos > (S - 1) - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bgkd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


# =====================================================================
# Mamba2 SSD
# =====================================================================
def ssd(x, dt, A, Bm, Cm, D=None, h0=None, chunk: int = 64, impl: str = "chunked"):
    """SSD scan; Bm/Cm given per state-group (B, L, G, N) and broadcast to
    heads internally. Returns (y (B,L,H,P), h_final (B,H,P,N))."""
    B, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    if impl == "ref":
        return _ref.ssd_scan(x, dt, A, Bm, Cm, D=D, h0=h0)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2) if rep > 1 else Bm.reshape(B, L, H, N)
    Ch = jnp.repeat(Cm, rep, axis=2) if rep > 1 else Cm.reshape(B, L, H, N)
    if impl == "pallas":
        return _ssd_kernel.ssd_chunk_scan(x, dt, A, Bh, Ch, D=D, h0=h0, chunk=chunk)
    return _ssd_chunked_jnp(x, dt, A, Bh, Ch, D, h0, chunk)


def _ssd_chunked_jnp(x, dt, A, Bh, Ch, D, h0, chunk):
    """Vectorized chunked SSD (same math as the Pallas kernel, differentiable)."""
    B, L, H, P = x.shape
    N = Bh.shape[-1]
    cs = _pick_divisor(L, chunk)
    nc = L // cs
    f32 = jnp.float32
    xr = x.reshape(B, nc, cs, H, P).astype(f32)
    dtr = dt.reshape(B, nc, cs, H).astype(f32)
    Br = Bh.reshape(B, nc, cs, H, N).astype(f32)
    Cr = Ch.reshape(B, nc, cs, H, N).astype(f32)

    la = dtr * A[None, None, None, :].astype(f32)
    logcum = jnp.cumsum(la, axis=2)                     # (B,nc,cs,H)
    s_last = jnp.exp(logcum[:, :, -1])                  # (B,nc,H)

    # chunk-local quadratic part
    cb = jnp.einsum("bnthd,bnuhd->bntuh", Cr, Br)
    ldiff = logcum[:, :, :, None, :] - logcum[:, :, None, :, :]
    tri = (jnp.arange(cs)[:, None] >= jnp.arange(cs)[None, :])
    # mask BEFORE the exp: for u > t ldiff is positive and can overflow; a
    # post-exp where() would then backprop inf * 0 = NaN.
    decay = jnp.exp(jnp.where(tri[None, None, :, :, None], ldiff, -1e30))
    w = cb * decay * dtr[:, :, None, :, :]
    y_intra = jnp.einsum("bntuh,bnuhp->bnthp", w, xr)

    # per-chunk state contribution and the inter-chunk recurrence
    coeff = jnp.exp(logcum[:, :, -1:, :] - logcum) * dtr           # (B,nc,cs,H)
    G_ = jnp.einsum("bnuh,bnuhp,bnuhs->bnhps", coeff, xr, Br)       # (B,nc,H,P,N)

    h_init = (jnp.zeros((B, H, P, N), f32) if h0 is None else h0.astype(f32))

    def chunk_step(h, inp):
        sl, g = inp  # (B,H), (B,H,P,N)
        h_next = h * sl[..., None, None] + g
        return h_next, h  # emit state at chunk *start*

    hs_final, h_starts = jax.lax.scan(
        chunk_step, h_init,
        (jnp.moveaxis(s_last, 1, 0), jnp.moveaxis(G_, 1, 0)))
    h_starts = jnp.moveaxis(h_starts, 0, 1)                        # (B,nc,H,P,N)

    y_inter = jnp.einsum("bnths,bnhps->bnthp", Cr * jnp.exp(logcum)[..., None], h_starts)
    y = (y_intra + y_inter).reshape(B, L, H, P)
    if D is not None:
        y = y + x.astype(f32) * D[None, None, :, None].astype(f32)
    return y.astype(x.dtype), hs_final


def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t, D=None):
    """Single-token SSD recurrence. h (B,H,P,N) f32; x_t (B,H,P);
    dt_t (B,H); B_t/C_t (B,H,N). Returns (y_t, h_new)."""
    f32 = jnp.float32
    dA = jnp.exp(dt_t.astype(f32) * A.astype(f32)[None, :])
    h = h * dA[..., None, None] + (dt_t.astype(f32)[..., None] * x_t.astype(f32))[..., None] \
        * B_t.astype(f32)[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h, C_t.astype(f32))
    if D is not None:
        y = y + x_t.astype(f32) * D[None, :, None].astype(f32)
    return y.astype(x_t.dtype), h


# =====================================================================
# causal depthwise conv1d
# =====================================================================
def conv1d_causal(x, w, b=None, silu: bool = False, impl: str = "chunked"):
    if impl == "pallas":
        return _conv_kernel.conv1d_causal(x, w, b, silu=silu)
    out = _ref.conv1d_causal(x, w, b)
    if silu:
        out = out * jax.nn.sigmoid(out)
    return out


# =====================================================================
# 3-D diffusion step (paper Fig. 1)
# =====================================================================
def diffusion3d_step(T2, T, Ci, lam, dt, inv_dx, inv_dy, inv_dz,
                     impl: str = "pallas", tile=None):
    if impl == "pallas":
        return _diff_kernel.diffusion3d_step(T2, T, Ci, lam, dt, inv_dx, inv_dy,
                                             inv_dz, tile=tile)
    return _ref.diffusion3d_step(T2, T, Ci, lam, dt, inv_dx, inv_dy, inv_dz)
