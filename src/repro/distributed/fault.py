"""Fault tolerance and straggler mitigation for long multi-pod runs.

Pieces that run *around* the jitted step (host-side control plane):

  * StepMonitor — per-step wall-time EWMA + straggler flagging. On a real
    multi-host deployment every host appends its step time to a heartbeat
    file on shared storage; `check_peers` flags hosts whose EWMA exceeds
    the fleet median by `straggler_factor` (the mitigation at scale is to
    checkpoint + evict + elastic-restart, see elastic.py). Simulated
    multi-host in tests by writing several heartbeat files.

  * Heartbeat — liveness: a host that has not bumped its file within
    `timeout_s` is declared dead -> the launcher triggers restore from the
    last checkpoint on the surviving mesh.

  * retry — transient-failure wrapper for host-side I/O (checkpoint
    writes/reads, heartbeat bumps, autotune cache): exponential backoff
    with deterministic jitter so a thundering herd of 1000 hosts
    retrying a shared filesystem decorrelates.

  * FaultPlan — the deterministic fault-injection harness. A plan is a
    small JSON dict in the ``REPRO_FAULT_PLAN`` env var, so subprocess
    tests and CI can inject *real* failures (the process dies, a
    checkpoint is torn on disk, an open() raises) into unmodified
    ``solve_until`` runs at exactly reproducible points:

        REPRO_FAULT_PLAN='{"kill_at_step": 60}'            # SIGKILL-style death
        REPRO_FAULT_PLAN='{"hang_at_step": 40, "hang_s": 5}'  # straggler/hang
        REPRO_FAULT_PLAN='{"corrupt_checkpoint": 2}'       # tear the 2nd save
        REPRO_FAULT_PLAN='{"io_errors": 3}'                # 3 transient EIOs

    The engine's checkpointing drivers call the plan's hooks at their
    natural boundaries (``on_step`` at reduction-check/save boundaries,
    ``on_io`` before guarded host I/O, ``after_save`` after each
    checkpoint write); a process without the env var pays one cached
    ``None`` check.
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import time
from typing import Callable, Optional

from .. import telemetry as _telemetry

# exit code of a FaultPlan-injected kill: distinguishable from real crashes
# (tracebacks exit 1) so launchers/tests can assert the *planned* death
KILL_EXIT_CODE = 113


class TransientIOError(OSError):
    """Injected transient I/O failure (FaultPlan.on_io)."""


class RankFailure(RuntimeError):
    """A peer rank stopped heartbeating: checkpoint-restore on the
    surviving mesh is required. Carries ``.dead`` (sorted rank ids)."""

    def __init__(self, dead, msg: Optional[str] = None):
        self.dead = sorted(dead)
        super().__init__(msg or f"dead ranks (stale heartbeats): {self.dead}")


@dataclasses.dataclass
class StepStats:
    ewma_s: float = 0.0
    n: int = 0
    last_s: float = 0.0

    def update(self, dt: float, alpha: float = 0.1) -> None:
        self.last_s = dt
        self.ewma_s = dt if self.n == 0 else (1 - alpha) * self.ewma_s + alpha * dt
        self.n += 1


class Heartbeat:
    """Per-rank liveness file on shared storage.

    ``bump(step)`` atomically rewrites ``host_<rank>.json`` (retried —
    shared filesystems hiccup); ``dead_ranks(expected)`` returns the
    ranks whose file is missing or older than ``timeout_s``. Kept
    separate from :class:`StepMonitor` so a launcher can watch liveness
    without importing any timing state."""

    def __init__(self, directory: str, rank: int = 0, timeout_s: float = 300.0,
                 run_id: Optional[str] = None):
        self.dir = directory
        self.rank = rank
        self.timeout_s = timeout_s
        self.run_id = run_id
        os.makedirs(directory, exist_ok=True)

    def _prefix(self) -> str:
        return f"{self.run_id}." if self.run_id else ""

    def path(self, rank: Optional[int] = None) -> str:
        rank = self.rank if rank is None else rank
        return os.path.join(self.dir, f"{self._prefix()}host_{rank}.json")

    def bump(self, step: int, ewma_s: float = 0.0) -> None:
        def write():
            FaultPlan.active_on_io(self.path())
            tmp = self.path() + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "t": time.time(), "ewma_s": ewma_s,
                           "run_id": self.run_id}, f)
            os.replace(tmp, self.path())
        retry(write)

    def read_all(self) -> dict[int, dict]:
        """Heartbeats of THIS run only: files are matched by the run-id
        prefix, so liveness left behind by a previous (dead) world in the
        same directory can never vouch for a rank in this one."""
        beats = {}
        prefix = self._prefix() + "host_"
        for fn in os.listdir(self.dir):
            if not (fn.startswith(prefix) and fn.endswith(".json")):
                continue
            try:
                with open(os.path.join(self.dir, fn)) as f:
                    beats[int(fn[len(prefix):-5])] = json.load(f)
            except (json.JSONDecodeError, ValueError, OSError):
                continue  # torn write — treat as missing this round
        return beats

    @staticmethod
    def retire_stale(directory: str,
                     keep_run_id: Optional[str] = None) -> list[str]:
        """Delete heartbeat files in ``directory`` that do not belong to
        ``keep_run_id`` (all of them when None). Launchers call this at
        world startup so a fresh gang never reads a previous run's
        liveness. Concurrent deletion is tolerated; returns the retired
        file names."""
        if not os.path.isdir(directory):
            return []
        keep_prefix = f"{keep_run_id}.host_" if keep_run_id else None
        retired = []
        for fn in os.listdir(directory):
            if "host_" not in fn or not (fn.endswith(".json")
                                         or fn.endswith(".json.tmp")):
                continue
            if keep_prefix is not None and fn.startswith(keep_prefix):
                continue
            try:
                os.unlink(os.path.join(directory, fn))
                retired.append(fn)
            except OSError:
                continue
        return sorted(retired)

    def dead_ranks(self, expected: Optional[list[int]] = None,
                   now: Optional[float] = None) -> list[int]:
        now = time.time() if now is None else now
        beats = self.read_all()
        dead = [h for h, b in beats.items() if now - b["t"] > self.timeout_s]
        if expected is not None:
            dead += [h for h in expected if h not in beats]
        return sorted(set(dead))


class StepMonitor:
    def __init__(self, host_id: int = 0, heartbeat_dir: Optional[str] = None,
                 straggler_factor: float = 1.5, timeout_s: float = 300.0,
                 run_id: Optional[str] = None):
        self.host_id = host_id
        self.dir = heartbeat_dir
        self.factor = straggler_factor
        self.timeout_s = timeout_s
        self.stats = StepStats()
        self.heartbeat = (Heartbeat(heartbeat_dir, rank=host_id,
                                    timeout_s=timeout_s, run_id=run_id)
                          if heartbeat_dir else None)

    def record(self, step: int, dt: float) -> None:
        self.stats.update(dt)
        if self.heartbeat is not None:
            self.heartbeat.bump(step, ewma_s=self.stats.ewma_s)
        col = _telemetry.get()
        if col.enabled:
            col.gauge("fault.ewma_step_s", self.stats.ewma_s,
                      rank=self.host_id)
            col.gauge("fault.last_step_s", dt, rank=self.host_id)

    def check_peers(self, now: Optional[float] = None) -> dict:
        """Returns {"dead": [...], "stragglers": [...], "healthy": n}.

        With telemetry enabled the health verdict is also surfaced as
        gauges (healthy/straggler/dead counts, per-peer heartbeat lag
        and EWMA) — the run reports a straggling rank instead of only
        dying on a dead one."""
        now = time.time() if now is None else now
        if self.heartbeat is None:
            return {"dead": [], "stragglers": [], "healthy": 1}
        beats = self.heartbeat.read_all()
        dead = [h for h, b in beats.items() if now - b["t"] > self.timeout_s]
        alive = {h: b for h, b in beats.items() if h not in dead}
        if alive:
            med = sorted(b["ewma_s"] for b in alive.values())[len(alive) // 2]
            stragglers = [h for h, b in alive.items()
                          if med > 0 and b["ewma_s"] > self.factor * med]
        else:
            stragglers = []
        col = _telemetry.get()
        if col.enabled:
            col.gauge("fault.healthy_ranks", len(alive) - len(stragglers))
            col.gauge("fault.straggler_ranks", len(stragglers))
            col.gauge("fault.dead_ranks", len(dead))
            for h, b in beats.items():
                col.gauge("fault.heartbeat_lag_s", now - b["t"], rank=h)
                col.gauge("fault.peer_ewma_step_s", b.get("ewma_s", 0.0),
                          rank=h)
        return {"dead": sorted(dead), "stragglers": sorted(stragglers),
                "healthy": len(alive) - len(stragglers)}

    def snapshot(self) -> dict[int, dict[str, float]]:
        """Per-rank EWMA step stats: this rank's live :class:`StepStats`
        plus every peer's last heartbeat. This is what
        :class:`~repro.core.iterate.SolveResult.step_stats` carries out
        of a monitored solve (previously the stats died with the
        monitor on success)."""
        out = {self.host_id: {"ewma_s": self.stats.ewma_s,
                              "last_s": self.stats.last_s,
                              "n": self.stats.n}}
        if self.heartbeat is not None:
            for h, b in self.heartbeat.read_all().items():
                if h != self.host_id:
                    out[h] = {"ewma_s": b.get("ewma_s", 0.0),
                              "last_s": b.get("ewma_s", 0.0),
                              "n": b.get("step", 0)}
        col = _telemetry.get()
        if col.enabled:
            for h, s in out.items():
                col.gauge("fault.ewma_step_s", s["ewma_s"], rank=h)
        return out


def retry(fn: Callable, attempts: int = 4, backoff_s: float = 0.05,
          exceptions=(OSError, IOError), max_backoff_s: float = 2.0,
          jitter: float = 0.25, seed: Optional[int] = None,
          sleep: Callable[[float], None] = time.sleep):
    """Run fn(), retrying transient host-side failures with exponential
    backoff + jitter.

    The wait before attempt ``i+1`` is ``backoff_s * 2**i`` (capped at
    ``max_backoff_s``), scaled by a uniform factor in ``[1 - jitter,
    1 + jitter]`` so simultaneous retries across a fleet decorrelate.
    ``seed`` makes the jitter sequence deterministic (tests); ``sleep``
    is injectable for the same reason. The last failure propagates."""
    rng = random.Random(seed)
    for i in range(attempts):
        try:
            return fn()
        except exceptions:
            _telemetry.get().count("fault.io_retries", 1)
            if i == attempts - 1:
                raise
            wait = min(backoff_s * (2 ** i), max_backoff_s)
            if jitter:
                wait *= 1.0 + jitter * (2.0 * rng.random() - 1.0)
            sleep(wait)


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------
PLAN_ENV = "REPRO_FAULT_PLAN"
_active_plan: Optional["FaultPlan"] = None
_active_loaded = False


@dataclasses.dataclass
class FaultPlan:
    """Deterministic failure schedule for one process.

    ``kill_at_step``/``hang_at_step`` fire in :meth:`on_step` when the
    driver's completed-iteration counter reaches them (drivers call the
    hook at reduction-check/save boundaries, so a kill lands *between*
    an async checkpoint kickoff and the next block — exactly the window
    a preemption hits). ``corrupt_checkpoint`` tears the N-th completed
    checkpoint on disk (1-based; truncates one tensor file), modelling a
    partially-flushed save that atomic-rename cannot catch.
    ``io_errors`` makes the next N guarded I/O operations raise
    :class:`TransientIOError` (consumed by :meth:`on_io`), exercising
    the retry paths. ``kill_at_io`` dies mid-write: the N-th guarded
    I/O operation (1-based) ``os._exit``s the process INSIDE the write
    path — the window where a SIGKILL tears an in-flight checkpoint.
    ``kill_at_rendezvous`` dies on entry to the N-th
    ``jax.distributed`` rendezvous attempt (consumed by
    :meth:`on_rendezvous` in the multihost launcher) — the mid-init
    death that leaves peers waiting on the coordinator.

    Serving-path injections (consumed by ``repro.serve``):
    ``nan_at_step`` poisons sample ``nan_sample`` of every submitted
    batch with NaN once its step counter passes the threshold (the
    quarantine path); ``reject_after`` makes the request queue shed
    every admission after the N-th (backpressure under a full queue
    without needing real overload); ``kill_worker_after`` kills the
    worker process after it completes N batches (circuit breaker +
    re-queue); ``batch_errors`` makes the next N batch executions
    raise :class:`TransientIOError` before touching the device (the
    batch retry-with-backoff path); ``wedge_worker_after`` stops the
    worker cold after N completed batches — the process stays ALIVE but
    never progresses or bumps its heartbeat again, the stale-heartbeat
    (SIGKILL-and-replace) recovery path that an exit-code watcher alone
    cannot see."""

    kill_at_step: Optional[int] = None
    hang_at_step: Optional[int] = None
    hang_s: float = 5.0
    rank: int = 0                 # rank this plan applies to (default all == 0)
    kill_at_rendezvous: Optional[int] = None  # die entering the N-th rendezvous attempt
    corrupt_checkpoint: Optional[int] = None
    io_errors: int = 0
    kill_at_io: Optional[int] = None
    nan_at_step: Optional[int] = None
    nan_sample: int = 0
    reject_after: Optional[int] = None
    kill_worker_after: Optional[int] = None
    wedge_worker_after: Optional[int] = None
    batch_errors: int = 0
    _saves_seen: int = dataclasses.field(default=0, repr=False)
    _killed: bool = dataclasses.field(default=False, repr=False)
    _io_seen: int = dataclasses.field(default=0, repr=False)
    _submits_seen: int = dataclasses.field(default=0, repr=False)
    _batches_done: int = dataclasses.field(default=0, repr=False)

    # ---------------- construction ----------------
    @classmethod
    def from_env(cls, environ=None) -> Optional["FaultPlan"]:
        raw = (environ or os.environ).get(PLAN_ENV)
        if not raw:
            return None
        try:
            d = json.loads(raw)
        except json.JSONDecodeError as e:
            raise ValueError(f"{PLAN_ENV} is not valid JSON: {raw!r}") from e
        if not isinstance(d, dict):
            raise ValueError(f"{PLAN_ENV} must be a JSON object, got {raw!r}")
        known = {f.name for f in dataclasses.fields(cls) if not f.name.startswith("_")}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"{PLAN_ENV} has unknown keys {sorted(unknown)} "
                             f"(known: {sorted(known)})")
        return cls(**d)

    def to_env(self) -> str:
        d = {f.name: getattr(self, f.name) for f in dataclasses.fields(self)
             if not f.name.startswith("_")}
        defaults = {f.name: f.default for f in dataclasses.fields(self)}
        return json.dumps({k: v for k, v in d.items() if v != defaults[k]})

    @classmethod
    def active(cls) -> Optional["FaultPlan"]:
        """The process-wide plan parsed once from the environment (None
        when no plan is set — the common case costs one global check)."""
        global _active_plan, _active_loaded
        if not _active_loaded:
            _active_plan = cls.from_env()
            _active_loaded = True
        return _active_plan

    @classmethod
    def reset_active(cls) -> None:
        global _active_plan, _active_loaded
        _active_plan, _active_loaded = None, False

    @classmethod
    def active_on_io(cls, path: str = "") -> None:
        plan = cls.active()
        if plan is not None:
            plan.on_io(path)

    # ---------------- hooks ----------------
    def on_step(self, step: int, rank: int = 0) -> None:
        """Called by drivers with the completed-iteration counter at each
        check/save boundary. Kills or hangs the process when scheduled."""
        if rank != self.rank:
            return
        if (self.hang_at_step is not None and step >= self.hang_at_step):
            t, self.hang_at_step = self.hang_s, None  # hang once
            time.sleep(t)
        if (self.kill_at_step is not None and not self._killed
                and step >= self.kill_at_step):
            self._killed = True
            # a real preemption does not unwind the stack or flush
            # buffers; os._exit is the closest in-process equivalent
            os._exit(KILL_EXIT_CODE)

    def on_rendezvous(self, attempt: int, rank: int = 0) -> None:
        """Called by the multihost launcher's :func:`initialize` on entry
        to each rendezvous attempt (1-based). ``kill_at_rendezvous`` dies
        there — a process that is SIGKILLed mid-``jax.distributed``
        bring-up, leaving its peers to hit the initialization timeout."""
        if rank != self.rank:
            return
        if (self.kill_at_rendezvous is not None
                and attempt >= self.kill_at_rendezvous):
            os._exit(KILL_EXIT_CODE)

    def on_io(self, path: str = "") -> None:
        """Raise a transient error while the injection budget lasts, or
        die outright on the scheduled guarded operation (``kill_at_io``
        models SIGKILL landing mid-write: no unwind, no flush)."""
        self._io_seen += 1
        if self.kill_at_io is not None and self._io_seen >= self.kill_at_io:
            os._exit(KILL_EXIT_CODE)
        if self.io_errors > 0:
            self.io_errors -= 1
            raise TransientIOError(f"injected transient I/O error ({path})")

    # ---------------- serving-path hooks ----------------
    def on_submit(self) -> bool:
        """Called by the request queue per admission attempt. True ->
        shed this request (deterministic overload)."""
        self._submits_seen += 1
        return (self.reject_after is not None
                and self._submits_seen > self.reject_after)

    def on_batch(self) -> None:
        """Called by the batch engine before each batch execution; burns
        the transient-batch-failure budget (retry path)."""
        if self.batch_errors > 0:
            self.batch_errors -= 1
            raise TransientIOError("injected transient batch failure")

    def serve_nan_due(self, step: int) -> Optional[int]:
        """The sample index to poison with NaN once a batch's step
        counter passes ``nan_at_step`` (None -> no injection)."""
        if self.nan_at_step is not None and step >= self.nan_at_step:
            return self.nan_sample
        return None

    def worker_batch_done(self) -> None:
        """Called by the worker after each completed batch; dies when
        the scheduled batch count is reached (worker-kill injection),
        or wedges — alive but never progressing or heartbeating again,
        so only staleness detection can recover the worker."""
        self._batches_done += 1
        if (self.kill_worker_after is not None
                and self._batches_done >= self.kill_worker_after):
            os._exit(KILL_EXIT_CODE)
        if (self.wedge_worker_after is not None
                and self._batches_done >= self.wedge_worker_after):
            while True:
                time.sleep(60)

    def after_save(self, ckpt_dir: str) -> None:
        """Called after each completed checkpoint write with its final
        directory; tears the scheduled one (truncates a tensor file so
        restore sees a short read)."""
        self._saves_seen += 1
        if self.corrupt_checkpoint != self._saves_seen:
            return
        victims = sorted(f for f in os.listdir(ckpt_dir) if f.endswith(".npy"))
        if victims:
            path = os.path.join(ckpt_dir, victims[0])
            size = os.path.getsize(path)
            with open(path, "r+b") as f:
                f.truncate(max(size // 2, 1))


@dataclasses.dataclass
class FailurePolicy:
    """What the launcher does per health verdict (wired in launch/train.py)."""
    checkpoint_every: int = 100
    on_dead: str = "restore_elastic"   # restore last ckpt on surviving mesh
    on_straggler: str = "flag"          # flag -> operator / scheduler eviction
    max_restarts: int = 10
