"""Fault tolerance and straggler mitigation for long multi-pod runs.

Pieces that run *around* the jitted step (host-side control plane):

  * StepMonitor — per-step wall-time EWMA + straggler flagging. On a real
    multi-host deployment every host appends its step time to a heartbeat
    file on shared storage; `check_peers` flags hosts whose EWMA exceeds
    the fleet median by `straggler_factor` (the mitigation at scale is to
    checkpoint + evict + elastic-restart, see elastic.py). Simulated
    multi-host in tests by writing several heartbeat files.

  * Heartbeat — liveness: a host that has not bumped its file within
    `timeout_s` is declared dead -> the launcher triggers restore from the
    last checkpoint on the surviving mesh.

  * retry — transient-failure wrapper for host-side I/O (checkpoint
    writes, data reads).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional


@dataclasses.dataclass
class StepStats:
    ewma_s: float = 0.0
    n: int = 0
    last_s: float = 0.0

    def update(self, dt: float, alpha: float = 0.1) -> None:
        self.last_s = dt
        self.ewma_s = dt if self.n == 0 else (1 - alpha) * self.ewma_s + alpha * dt
        self.n += 1


class StepMonitor:
    def __init__(self, host_id: int = 0, heartbeat_dir: Optional[str] = None,
                 straggler_factor: float = 1.5, timeout_s: float = 300.0):
        self.host_id = host_id
        self.dir = heartbeat_dir
        self.factor = straggler_factor
        self.timeout_s = timeout_s
        self.stats = StepStats()
        if self.dir:
            os.makedirs(self.dir, exist_ok=True)

    def _path(self, host_id: int) -> str:
        return os.path.join(self.dir, f"host_{host_id}.json")

    def record(self, step: int, dt: float) -> None:
        self.stats.update(dt)
        if self.dir:
            tmp = self._path(self.host_id) + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "t": time.time(),
                           "ewma_s": self.stats.ewma_s}, f)
            os.replace(tmp, self._path(self.host_id))

    def check_peers(self, now: Optional[float] = None) -> dict:
        """Returns {"dead": [...], "stragglers": [...], "healthy": n}."""
        now = time.time() if now is None else now
        if not self.dir:
            return {"dead": [], "stragglers": [], "healthy": 1}
        beats = {}
        for fn in os.listdir(self.dir):
            if not (fn.startswith("host_") and fn.endswith(".json")):
                continue
            try:
                with open(os.path.join(self.dir, fn)) as f:
                    beats[int(fn[5:-5])] = json.load(f)
            except (json.JSONDecodeError, ValueError, OSError):
                continue  # torn write — treat as missing this round
        dead = [h for h, b in beats.items() if now - b["t"] > self.timeout_s]
        alive = {h: b for h, b in beats.items() if h not in dead}
        if alive:
            med = sorted(b["ewma_s"] for b in alive.values())[len(alive) // 2]
            stragglers = [h for h, b in alive.items()
                          if med > 0 and b["ewma_s"] > self.factor * med]
        else:
            stragglers = []
        return {"dead": sorted(dead), "stragglers": sorted(stragglers),
                "healthy": len(alive) - len(stragglers)}


def retry(fn: Callable, attempts: int = 3, backoff_s: float = 0.1,
          exceptions=(OSError, IOError)):
    """Run fn(), retrying transient host-side failures with backoff."""
    for i in range(attempts):
        try:
            return fn()
        except exceptions:
            if i == attempts - 1:
                raise
            time.sleep(backoff_s * (2 ** i))


@dataclasses.dataclass
class FailurePolicy:
    """What the launcher does per health verdict (wired in launch/train.py)."""
    checkpoint_every: int = 100
    on_dead: str = "restore_elastic"   # restore last ckpt on surviving mesh
    on_straggler: str = "flag"          # flag -> operator / scheduler eviction
    max_restarts: int = 10
