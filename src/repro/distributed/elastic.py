"""Elastic scaling: re-laying out solver state onto a different mesh.

A checkpoint written on one mesh must restore onto another (node failure
shrinks the pool; scale-up grows it). Checkpoints store *global* logical
tensors (shard files + a manifest, see checkpoint/manager.py), so restoring
is: rebuild the sharding for the new mesh from the same logical rules, then
``jax.device_put`` each tensor with its new NamedSharding. No tensor ever
needs all-to-all resharding on device — the host stream feeds each device
only its shard (jax.make_array_from_callback).

For the stencil engine this module adds the full elastic solve loop:

  * :func:`decompose_fields` / :func:`gather_fields` — global <-> per-rank
    ghost-ring layout (the ImplicitGlobalGrid decomposition, stacked with
    leading mesh-factor axes and placed through :func:`remesh`);
  * :func:`elastic_solve_until` — the distributed, checkpointing analogue
    of :func:`repro.core.iterate.solve_until`: ONE jitted
    ``shard_map``-ed ``lax.while_loop`` per chunk whose body runs
    ``overlap.sequential_step`` (grouped halo ppermutes + fused kernel +
    one ``pmax``/``psum`` per reduction), chunked at reduction-check
    boundaries for async checkpointing of the *global* carry. Because the
    checkpoint is mesh-agnostic, a run killed on an N-rank mesh resumes
    on an M-rank mesh: same iteration trajectory, allclose fields
    (reduction scalars reassociate across decompositions — never compare
    them bitwise);
  * :func:`plan_factors` / :func:`validate_stencil_factors` — shrink or
    regrow remeshing: pick a decomposition for the surviving world size
    and verify the interior still divides;
  * :func:`supervise` — the restart policy a launcher loops over
    (attempt -> exit code; planned kills re-plan the mesh and go again).

Also provides `remesh` for live resharding (device_put with a new sharding)
used when a run continues after swapping the mesh in-process.
"""
from __future__ import annotations

import math
import time
from typing import Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.iterate import SolveResult, _crossed, _resolve_error
from . import fault, halo as _halo, overlap

DEFAULT_AXES = ("x", "y", "z")


def remesh(tree, mesh: Mesh, spec_tree) -> object:
    """Reshard a pytree of arrays onto ``mesh`` with matching PartitionSpecs."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, spec_tree)


def from_host_callback(shape_dtype, spec: P, mesh: Mesh, read: Callable[[tuple], np.ndarray]):
    """Build a sharded array where each device's block is fetched on demand
    (``read(index)`` returns the numpy block for a global index tuple).
    This is the restore path that scales to 1000+ nodes: every host reads
    only the bytes its devices own."""
    sharding = NamedSharding(mesh, spec)

    def cb(index):
        return read(index)

    return jax.make_array_from_callback(shape_dtype.shape, sharding, cb)


def validate_divisibility(tree_specs, tree_shapes, mesh: Mesh) -> list[str]:
    """Return human-readable problems where a spec no longer divides a dim
    on the new mesh (elastic scale-down can break divisibility)."""
    problems = []

    def check(path, spec, shape):
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if shape[i] % size:
                problems.append(f"{path}: dim {i} ({shape[i]}) % mesh {axes} ({size}) != 0")

    def walk(prefix, specs, shapes):
        if isinstance(specs, P):
            check(prefix, specs, shapes)
            return
        if isinstance(specs, dict):
            for k in specs:
                walk(f"{prefix}/{k}", specs[k], shapes[k])
            return
        if isinstance(specs, (list, tuple)):
            for i, (sp, sh) in enumerate(zip(specs, shapes)):
                walk(f"{prefix}[{i}]", sp, sh)
            return

    walk("", tree_specs, tree_shapes)
    return problems


# ---------------------------------------------------------------------------
# stencil-field decomposition (global <-> stacked rank-local ghost layout)
# ---------------------------------------------------------------------------
def plan_factors(n_ranks: int, ndims: int = 1) -> tuple[int, ...]:
    """A near-balanced mesh decomposition for ``n_ranks`` over the leading
    ``ndims`` grid axes (largest factors first — row-major rank order).
    This is the shrink/regrow policy: after losing a rank, call it with
    the surviving world size."""
    if n_ranks < 1:
        raise ValueError(f"n_ranks must be >= 1, got {n_ranks}")
    factors = [1] * ndims
    rem = n_ranks
    for i in range(ndims - 1):
        f = 1
        for cand in range(int(math.isqrt(rem)), 0, -1):
            if rem % cand == 0:
                f = cand
                break
        factors[i] = max(rem // f, f) if ndims - i == 2 else f
        rem //= factors[i]
    factors[-1] = rem
    return tuple(sorted(factors, reverse=True))


def plan_compatible(shape: Sequence[int], radius: int, world: int,
                    ndims: int = 1) -> tuple[int, tuple[int, ...]]:
    """The supervisor's replan policy: the largest world size ``<= world``
    whose :func:`plan_factors` decomposition passes
    :func:`validate_stencil_factors` on this grid. After losing a rank, a
    4-rank world on an interior-16 grid must step down to 2, not 3 — 3
    does not divide. Returns ``(world, factors)``; raises a pointed error
    when not even a single rank fits (grid thinner than the ghost ring)."""
    for w in range(int(world), 0, -1):
        factors = plan_factors(w, ndims)
        try:
            validate_stencil_factors(shape, factors, radius)
        except ValueError:
            continue
        return w, factors
    raise ValueError(
        f"no world size in [1, {world}] decomposes grid {tuple(shape)} "
        f"(radius {radius}) over {ndims} axis/axes — the grid interior is "
        "thinner than one ghost ring")


def validate_stencil_factors(shape: Sequence[int], factors: Sequence[int],
                             radius: int) -> None:
    """The ghost-ring decomposition contract: every decomposed axis'
    interior (extent minus the 2r physical boundary ring) must divide by
    its factor, and each rank block must be at least one ghost ring
    wide. Raises a pointed ValueError naming the failing axis."""
    for ax, f in enumerate(factors):
        inner = shape[ax] - 2 * radius
        if inner <= 0 or inner % f:
            raise ValueError(
                f"axis {ax}: interior extent {inner} (= {shape[ax]} - 2*r, "
                f"r={radius}) does not divide over {f} ranks — choose a "
                f"mesh from divisors of {inner} (plan_factors of a "
                "compatible world size)")
        if inner // f < radius:
            raise ValueError(
                f"axis {ax}: rank block {inner // f} thinner than the "
                f"ghost ring (r={radius}) — fewer ranks needed")


def decompose_fields(fields: Mapping[str, np.ndarray],
                     factors: Sequence[int], radius: int) -> dict:
    """Split global arrays into the stacked rank-local ghost layout: each
    field becomes shape ``(*factors, *local_shape)`` — the layout
    ``shard_map`` splits one rank-block per device (host-side)."""
    out = {}
    for name, g in fields.items():
        locals_ = _halo.global_to_local(np.asarray(g), factors, radius=radius)
        out[name] = np.stack(locals_).reshape(
            tuple(factors) + locals_[0].shape)
    return out


def gather_fields(stacked: Mapping[str, np.ndarray],
                  factors: Sequence[int], radius: int) -> dict:
    """Inverse of :func:`decompose_fields` (interior stitching, host-side).
    This is what checkpoints store: the mesh-agnostic global arrays."""
    out = {}
    nrank = int(np.prod(factors))
    for name, st in stacked.items():
        a = np.asarray(st)
        locals_ = list(a.reshape((nrank,) + a.shape[len(factors):]))
        out[name] = _halo.local_to_global(locals_, factors, radius=radius)
    return out


def _field_specs(factors: Sequence[int], axes: Sequence[str], ndim: int) -> P:
    return P(*axes, *([None] * (ndim - len(factors))))


def fetch_global(stacked: Mapping[str, object], mesh: Mesh) -> dict:
    """``device_get`` for a dict of sharded arrays that also works when
    ``mesh`` spans OS processes. A process-spanning ``jax.Array`` cannot
    be fetched directly (its shards live in other processes' address
    spaces — ``jax.device_get`` raises); route those through a jitted
    identity re-sharded to fully-replicated, then read the local copy.
    Every participating process must call this (it runs a collective)."""
    spanning = {k: v for k, v in stacked.items()
                if isinstance(v, jax.Array) and not v.is_fully_addressable}
    out: dict = {}
    if spanning:
        rep = NamedSharding(mesh, P())
        replicated = jax.jit(
            lambda t: t,
            out_shardings={k: rep for k in spanning})(spanning)
        for k, v in replicated.items():
            out[k] = np.asarray(v.addressable_data(0))
    for k, v in stacked.items():
        if k not in out:
            out[k] = jax.device_get(v)
    return out


# ---------------------------------------------------------------------------
# the elastic solve loop
# ---------------------------------------------------------------------------
def make_elastic_solver(kernel, scalars: Mapping[str, object], mesh: Mesh,
                        factors: Sequence[int], axes: Sequence[str],
                        exchange: Sequence[str], *, check_every: int = 1,
                        error=None, until: str = "below",
                        periodic: bool = False):
    """Build the jitted chunk driver ``solver(stacked_fields, tol, block)
    -> (stacked_fields, reds, err, iters)``.

    One ``shard_map`` over the whole chunk: the rank-local body is the
    same m-steps-per-check ``lax.while_loop`` as
    :func:`repro.core.iterate.make_solver`, except every step runs
    ``overlap.sequential_step`` (grouped halo exchange + fused kernel)
    and the check's reductions arrive pre-combined across ranks (ONE
    ``pmax``/``psum``), so the loop condition is rank-uniform and the
    whole chunk needs zero host round-trips."""
    from ..compat import shard_map

    err_fn = _resolve_error(kernel, error)
    scalars = dict(scalars or {})
    plain = kernel.with_reductions(None)
    single = len(kernel.outputs) == 1
    rot = kernel.rotations
    if not rot or set(kernel.outputs) - set(rot):
        raise ValueError("elastic_solve_until needs rotations covering "
                         "every output (like solve_until)")
    nfac = len(factors)
    lead = (0,) * nfac

    def as_dict(res):
        return {kernel.outputs[0]: res} if single else dict(res)

    def rotate(cur, outs):
        cur = dict(cur)
        for o, tgt in rot.items():
            cur[o], cur[tgt] = cur[tgt], outs[o]
        return cur

    def rank_solver(cur0, tol, block):
        reds0 = {n: jnp.zeros((), jnp.float32) for n in kernel.reductions}
        err0 = jnp.float32(jnp.inf if until == "below" else -jnp.inf)

        def cond(state):
            _, _, err, it = state
            keep = err > tol if until == "below" else err <= tol
            return keep & (it < block)

        def body(state):
            cur, _, _, it = state
            for _ in range(check_every - 1):
                outs, fresh = overlap.sequential_step(
                    plain, cur, scalars, exchange, axes, periodic=periodic)
                cur = rotate(fresh, as_dict(outs))
            (outs, reds), fresh = overlap.sequential_step(
                kernel, cur, scalars, exchange, axes, periodic=periodic)
            cur = rotate(fresh, as_dict(outs))
            reds = {n: jnp.asarray(v, jnp.float32) for n, v in reds.items()}
            err = jnp.asarray(err_fn(reds), jnp.float32)
            return cur, reds, err, it + check_every

        return jax.lax.while_loop(
            cond, body, (cur0, reds0, err0, jnp.int32(0)))

    def local_chunk(stacked, tol, block):
        cur = {k: v[lead] for k, v in stacked.items()}
        cur, reds, err, it = rank_solver(cur, tol, block)
        cur = {k: v[(np.newaxis,) * nfac] for k, v in cur.items()}
        return cur, reds, err, it

    def solver(stacked, tol, block):
        field_spec = {k: _field_specs(factors, axes, stacked[k].ndim)
                      for k in stacked}
        f = shard_map(
            local_chunk, mesh=mesh,
            in_specs=(field_spec, P(), P()),
            out_specs=(field_spec,
                       {n: P() for n in kernel.reductions}, P(), P()),
            check_vma=False,
        )
        return f(stacked, tol, block)

    return jax.jit(solver)


def elastic_solve_until(
    kernel,
    fields: Mapping[str, np.ndarray],
    scalars: Mapping[str, object] | None = None,
    *,
    factors: Sequence[int],
    tol: float,
    max_iters: int,
    exchange: Sequence[str],
    check_every: int = 1,
    error=None,
    until: str = "below",
    periodic: bool = False,
    checkpoint=None,
    mesh_axes: Sequence[str] | None = None,
    radius: int | None = None,
) -> SolveResult:
    """Distributed, survivable ``solve_until``: iterate ``kernel`` over a
    ``factors``-decomposed mesh until the rank-combined fused error
    scalar crosses ``tol``.

    ``fields`` are GLOBAL arrays (physical boundary ring included);
    ``exchange`` names the fields whose ghost rings each check-step
    refreshes. ``checkpoint`` (path or
    :class:`~repro.core.iterate.Checkpointing`) chunks the loop at
    check boundaries and checkpoints the gathered *global* carry, so a
    killed run resumes on ANY compatible mesh — ``factors`` at resume
    time may differ from the mesh the checkpoint was written on
    (shrink after a rank failure, regrow after scale-up). Returned
    ``fields`` are global arrays again."""
    from ..core.iterate import Checkpointing

    scalars = dict(scalars or {})
    factors = tuple(int(f) for f in factors)
    axes = tuple(mesh_axes or DEFAULT_AXES[: len(factors)])
    n_ranks = int(np.prod(factors))
    if n_ranks > len(jax.devices()):
        raise ValueError(f"factors {factors} need {n_ranks} devices, have "
                         f"{len(jax.devices())}")
    field_arrays = {k: np.asarray(v) for k, v in fields.items()}
    if radius is None:
        radius, _, _ = overlap._kernel_geometry(
            kernel, {k: jnp.asarray(v) for k, v in field_arrays.items()},
            scalars, exchange, axes)
    sample = next(iter(field_arrays.values()))
    validate_stencil_factors(sample.shape, factors, radius)

    from ..launch.mesh import make_mesh

    mesh = make_mesh(factors, axes)
    ckpt = (Checkpointing(checkpoint) if isinstance(checkpoint, str)
            else checkpoint)
    mgr = ckpt.manager() if ckpt is not None else None
    save_every = int(ckpt.save_every) if ckpt is not None else 1
    block = (save_every * check_every if ckpt is not None
             else max_iters + check_every)

    err_host = np.float32(np.inf if until == "below" else -np.inf)
    reds_host = {n: np.float32(0) for n in kernel.reductions}
    done, resumed_from = 0, None
    if mgr is not None and ckpt.resume and mgr.latest_step() is not None:
        like = {"fields": field_arrays, "reds": reds_host,
                "err": err_host}
        tree, extra = mgr.restore(like)
        field_arrays = {k: np.asarray(v) for k, v in tree["fields"].items()}
        reds_host = tree["reds"]
        err_host = np.float32(tree["err"])
        done = int(extra.get("iters", extra["step"]))
        resumed_from = done

    # decompose onto THIS mesh (possibly not the checkpoint's) and place
    # each stacked field through the new mesh's NamedSharding
    stacked = decompose_fields(field_arrays, factors, radius)
    specs = {k: _field_specs(factors, axes, v.ndim)
             for k, v in stacked.items()}
    stacked = remesh(stacked, mesh, specs)

    solver = make_elastic_solver(
        kernel, scalars, mesh, factors, axes, exchange,
        check_every=check_every, error=error, until=until,
        periodic=periodic)

    plan = fault.FaultPlan.active()
    monitor = ckpt.monitor if ckpt is not None else None
    saved: list[int] = []
    err = jnp.float32(err_host)
    reds = {n: jnp.float32(v) for n, v in reds_host.items()}
    converged = done > 0 and _crossed(float(err), tol, until)
    while not converged and done < max_iters:
        take = min(block, max_iters - done)
        t0 = time.perf_counter()
        stacked, reds, err, it = solver(stacked, jnp.float32(tol),
                                        jnp.int32(take))
        n = int(it)                      # chunk-boundary host sync
        dt = time.perf_counter() - t0
        done += n
        converged = _crossed(float(err), tol, until)
        if monitor is not None:
            monitor.record(done, dt / max(n, 1))
            health = monitor.check_peers()
            if health["dead"]:
                if mgr is not None:
                    mgr.wait()
                raise fault.RankFailure(health["dead"])
        if mgr is not None:
            # the replicate-fetch is a collective: every process runs it,
            # but only process 0 writes (one writer per shared ckpt dir)
            global_now = gather_fields(fetch_global(stacked, mesh),
                                       factors, radius)
            if jax.process_index() == 0:
                mgr.save(done,
                         {"fields": global_now, "reds": reds, "err": err},
                         blocking=ckpt.blocking,
                         extra={"iters": done, "err": float(err),
                                "tol": float(tol),
                                "check_every": int(check_every),
                                "save_every": save_every, "until": until,
                                "factors": list(factors),
                                "radius": int(radius),
                                "converged": converged})
            saved.append(done)
        if plan is not None:
            plan.on_step(done)   # a kill lands between save and next chunk
    if mgr is not None:
        mgr.wait()
    final = gather_fields(fetch_global(stacked, mesh), factors, radius)
    return SolveResult(
        fields={k: jnp.asarray(v) for k, v in final.items()},
        reds=reds, err=err, iters=jnp.int32(done),
        resumed_from=resumed_from, saved_steps=tuple(saved))


def supervise(run_attempt: Callable[[int, int], int], world: int, *,
              replan: Callable[[int, int], int] | None = None,
              max_restarts: int = 3) -> tuple[int, int, list[int]]:
    """Launcher restart loop: call ``run_attempt(attempt, world)`` until it
    returns 0.

    On a nonzero exit (a planned :data:`~repro.distributed.fault.
    KILL_EXIT_CODE` death or a real crash) the world is re-planned —
    ``replan(world, rc)``, default: lose one rank, floor 1 — and the
    next attempt launches; the attempt's own checkpoint/resume logic
    carries the state across. Returns ``(attempts_used, final_world,
    exit_codes)``; raises after ``max_restarts`` failed restarts."""
    codes: list[int] = []
    attempt = 0
    while True:
        rc = int(run_attempt(attempt, world))
        codes.append(rc)
        if rc == 0:
            return attempt, world, codes
        if attempt >= max_restarts:
            raise RuntimeError(
                f"gave up after {attempt} restarts (exit codes {codes})")
        world = (replan(world, rc) if replan is not None
                 else max(world - 1, 1))
        attempt += 1
