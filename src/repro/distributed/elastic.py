"""Elastic scaling: re-laying out a training state onto a different mesh.

A checkpoint written on one mesh must restore onto another (node failure
shrinks the pool; scale-up grows it). Checkpoints store *global* logical
tensors (shard files + a manifest, see checkpoint/manager.py), so restoring
is: rebuild the sharding for the new mesh from the same logical rules, then
``jax.device_put`` each tensor with its new NamedSharding. No tensor ever
needs all-to-all resharding on device — the host stream feeds each device
only its shard (jax.make_array_from_callback).

Also provides `remesh` for live resharding (device_put with a new sharding)
used when a run continues after swapping the mesh in-process.
"""
from __future__ import annotations

from typing import Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def remesh(tree, mesh: Mesh, spec_tree) -> object:
    """Reshard a pytree of arrays onto ``mesh`` with matching PartitionSpecs."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, spec_tree)


def from_host_callback(shape_dtype, spec: P, mesh: Mesh, read: Callable[[tuple], np.ndarray]):
    """Build a sharded array where each device's block is fetched on demand
    (``read(index)`` returns the numpy block for a global index tuple).
    This is the restore path that scales to 1000+ nodes: every host reads
    only the bytes its devices own."""
    sharding = NamedSharding(mesh, spec)

    def cb(index):
        return read(index)

    return jax.make_array_from_callback(shape_dtype.shape, sharding, cb)


def validate_divisibility(tree_specs, tree_shapes, mesh: Mesh) -> list[str]:
    """Return human-readable problems where a spec no longer divides a dim
    on the new mesh (elastic scale-down can break divisibility)."""
    problems = []

    def check(path, spec, shape):
        for i, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if shape[i] % size:
                problems.append(f"{path}: dim {i} ({shape[i]}) % mesh {axes} ({size}) != 0")

    def walk(prefix, specs, shapes):
        if isinstance(specs, P):
            check(prefix, specs, shapes)
            return
        if isinstance(specs, dict):
            for k in specs:
                walk(f"{prefix}/{k}", specs[k], shapes[k])
            return
        if isinstance(specs, (list, tuple)):
            for i, (sp, sh) in enumerate(zip(specs, shapes)):
                walk(f"{prefix}[{i}]", sp, sh)
            return

    walk("", tree_specs, tree_shapes)
    return problems
