"""Distributed runtime: halo exchange + overlap (paper C6), sharding rules,
gradient compression, elasticity and fault handling."""
from . import halo, overlap, sharding, compression, fault, elastic

__all__ = ["halo", "overlap", "sharding", "compression", "fault", "elastic"]
