"""Sharding rules for the LM substrate (DESIGN.md §5).

One place defines how every logical tensor axis maps onto the production
mesh; model code only names logical axes. Layout:

  * batch        -> ("pod", "data")     activations, caches
  * vocab        -> "model"             embeddings / logits (fused CE)
  * heads / ffn  -> "model"             tensor parallelism
  * d_model      -> "data"              FSDP (ZeRO-3 style 2-D weight shard)
  * experts      -> "model"             expert parallelism (when divisible)
  * cache seq    -> "model" (+ "data" when batch == 1)   flash-decoding

`logical_to_spec` resolves a tuple of logical names to a PartitionSpec,
degrading gracefully when an axis is not divisible by the mesh extent
(falls back to replication for that axis — recorded so the dry-run report
can show which tensors degraded).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import axis_size as _named_axis_size


@dataclasses.dataclass(frozen=True)
class ShardRules:
    """Logical-axis -> mesh-axis mapping (None = replicate)."""

    batch: tuple[str, ...] = ("pod", "data")
    fsdp: Optional[str] = "data"      # d_model / reduction dims of weights
    tensor: Optional[str] = "model"   # heads / ffn / vocab / experts
    seq: Optional[str] = None          # sequence (context/sequence parallel)
    # sequence-parallel residual stream: activations at block boundaries are
    # sharded over this axis (Korthikanti et al. 2022). This is what keeps
    # the remat-saved (layers, B, L, D) stack inside HBM for the 70B-class
    # archs; GSPMD inserts the all-gather before / reduce-scatter after each
    # block — the LM analogue of the paper's halo-surface communication.
    seq_act: Optional[str] = "model"

    def for_mesh(self, mesh: Mesh) -> "ShardRules":
        names = set(mesh.axis_names)
        batch = tuple(a for a in self.batch if a in names)
        return ShardRules(
            batch=batch,
            fsdp=self.fsdp if self.fsdp in names else None,
            tensor=self.tensor if self.tensor in names else None,
            seq=self.seq if self.seq in names else None,
            seq_act=self.seq_act if self.seq_act in names else None,
        )


# paper-faithful baseline: pure data parallelism, replicated weights —
# the "naive translation" a ParallelStencil user would start from.
NAIVE_RULES = ShardRules(batch=("pod", "data"), fsdp=None, tensor=None,
                         seq=None, seq_act=None)
DEFAULT_RULES = ShardRules()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def logical_to_spec(
    mesh: Mesh,
    rules: ShardRules,
    logical: Sequence[Optional[str]],
    dims: Sequence[int],
) -> P:
    """Resolve logical axis names to a PartitionSpec, checking divisibility.

    logical entries: "batch" | "fsdp" | "tensor" | "seq" | "seq+batch" |
    None (replicate).
    """
    rules = rules.for_mesh(mesh)
    # joint MoE resolution: when the expert dim divides the tensor axis the
    # experts shard over it (EP); otherwise the per-expert ffn dim picks up
    # the tensor axis (TP-inside-experts) and the capacity dim picks up the
    # batch axes so dispatch buffers never replicate (Mixtral: 8e vs 16-wide
    # tensor axis).
    expert_on_tensor = True
    if "expert" in logical and rules.tensor is not None:
        e_dim = dims[list(logical).index("expert")]
        expert_on_tensor = e_dim % _axis_size(mesh, rules.tensor) == 0
    out = []
    for name, dim in zip(logical, dims):
        target = None
        if name == "batch":
            target = rules.batch or None
        elif name == "fsdp":
            target = rules.fsdp
        elif name == "tensor":
            target = rules.tensor
        elif name == "expert":
            target = rules.tensor if expert_on_tensor else None
        elif name == "expert_ffn":
            target = None if expert_on_tensor else rules.tensor
        elif name == "moe_cap":
            target = None if expert_on_tensor else (rules.batch or None)
        elif name == "seq":
            target = rules.seq
        elif name == "seq_act":
            target = rules.seq_act
        elif name == "seq+batch":
            cand = tuple(a for a in ((rules.seq,) + rules.batch) if a)
            target = cand or None
        elif name in (None, "layers"):
            target = None  # "layers" is the scan-stacking axis — never sharded
        else:
            raise ValueError(f"unknown logical axis {name!r}")
        if target is not None:
            if isinstance(target, str):
                target = (target,)
            if dim % _axis_size(mesh, target) != 0:
                # degrade: drop trailing mesh axes until divisible
                while target and dim % _axis_size(mesh, target) != 0:
                    target = target[:-1]
                target = target or None
        if target is None:
            out.append(None)
        elif len(target) == 1:
            out.append(target[0])
        else:
            out.append(tuple(target))
    return P(*out)


def named(mesh: Mesh, rules: ShardRules, logical, dims) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(mesh, rules, logical, dims))


def constrain(x, mesh: Mesh, rules: ShardRules, logical):
    """with_sharding_constraint by logical names (no-op outside jit)."""
    spec = logical_to_spec(mesh, rules, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# flash-decoding with a sequence-sharded KV cache
# ---------------------------------------------------------------------------
def seq_sharded_decode_attention(
    q, k_cache, v_cache, *, mesh: Mesh, seq_axes: tuple[str, ...],
    batch_axes: tuple[str, ...] = (), pos=None,
    window: Optional[int] = None, scale: Optional[float] = None,
):
    """Decode attention when the KV cache's sequence axis is sharded.

    Each shard computes a partial softmax (m, l, acc) over its local cache
    slice; partials combine with one pmax + two psums — O(B*H*D) bytes per
    device instead of all-gathering the cache (flash-decoding, adapted to
    the paper's "communicate only the reduced surface" discipline).

    q: (B, Hq, D) sharded over ``batch_axes``; caches (B, Hkv, S, D) with
    B over ``batch_axes`` and S over ``seq_axes``.
    """
    from ..compat import shard_map

    S = k_cache.shape[2]
    D = q.shape[-1]
    Hkv = k_cache.shape[1]
    scale_ = (D ** -0.5) if scale is None else scale
    bspec = _axes_entry(batch_axes)
    sspec = _axes_entry(seq_axes)

    def local_fn(q, kc, vc, pos_arr):
        b, Hq, _ = q.shape
        R = Hq // Hkv
        s_loc = kc.shape[2]
        # global offset of this shard's cache slice (row-major over seq_axes)
        off = jnp.int32(0)
        for ax in seq_axes:
            off = off * _named_axis_size(ax) + jax.lax.axis_index(ax)
        off = off * s_loc
        qg = q.reshape(b, Hkv, R, D).astype(jnp.float32) * scale_
        s = jnp.einsum("bgrd,bgkd->bgrk", qg, kc.astype(jnp.float32))
        kpos = off + jnp.arange(s_loc)
        mask = kpos <= pos_arr
        if window is not None:
            mask &= kpos > pos_arr - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bgrk,bgkd->bgrd", p, vc.astype(jnp.float32))
        # combine the partial softmaxes across the sequence shards
        m_g = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - m_g)
        l = jax.lax.psum(l * corr, seq_axes)
        acc = jax.lax.psum(acc * corr[..., None], seq_axes)
        l = jnp.where(l > 0, l, 1.0)
        return (acc / l[..., None]).reshape(b, Hq, D).astype(q.dtype)

    pos_arr = jnp.asarray(S - 1 if pos is None else pos, jnp.int32)
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(bspec), P(bspec, None, sspec, None),
                  P(bspec, None, sspec, None), P()),
        out_specs=P(bspec),
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, pos_arr)


def _axes_entry(axes: tuple[str, ...]):
    if not axes:
        return None
    if len(axes) == 1:
        return axes[0]
    return tuple(axes)


def tuned_rules(cfg, mesh: Mesh) -> ShardRules:
    """Per-arch rule tuning from the §Perf hillclimb (EXPERIMENTS.md).

    * Pure-SSM archs whose head count does not divide the tensor axis
      (mamba2-130m: H=24 vs 16) waste the model axis — worse, GSPMD inserts
      per-layer gathers of the chunk-state tensors. The model axis joins
      data parallelism instead (measured: 11x collective reduction, §Perf m1).
    * seq_act=None everywhere: with 2-D-sharded weights the FSDP gathers
      are small, and sequence-parallel activations turned out to COST wire
      (gathers redone in remat + f32 boundary converts) — qwen2-72b train:
      tl 147s -> 65s and tc 24.3s -> 12.2s (§Perf q4, hypothesis q2 partially
      refuted). The activation-memory job moves to microbatching.
    """
    if getattr(cfg, "family", None) == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        heads = d_inner // max(cfg.ssm_head_dim, 1)
        tsize = mesh.shape.get("model", 1)
        if heads % tsize:
            return ShardRules(batch=("pod", "data", "model"), fsdp="data",
                              tensor=None, seq=None, seq_act=None)
    return ShardRules(seq_act=None)
