"""Halo exchange for block domain decomposition (ImplicitGlobalGrid.jl, C6).

The global grid is distributed over a device mesh with `shard_map`; every
rank owns a local array that carries ``radius`` ghost layers per face.
``halo_exchange`` refreshes those ghost layers from the face-adjacent
neighbors with ``jax.lax.ppermute`` — one permute per (axis, direction),
exactly the neighbor pattern ImplicitGlobalGrid drives through MPI.

Non-periodic boundaries: ranks at the domain edge keep their existing ghost
values (which hold the physical boundary condition); periodic boundaries
wrap the permutation instead.

All functions here are *rank-local* (must run inside `shard_map`).
"""
from __future__ import annotations

from typing import Mapping, Sequence

import math

import jax
import jax.numpy as jnp
from jax import lax

from .. import telemetry as _telemetry
from ..compat import axis_size as _axis_size


# Wire formats for ghost payloads. Interiors can stay f32 while the
# ppermute payload ships narrower: "bf16" casts around the permute
# (exactly representable halves of the mantissa survive; error is one
# bf16 rounding, ~3 decimal digits), "int8" block-quantizes via
# ``distributed.compression`` (error <= scale/2 = max|payload|/254 per
# block — guarded, not exact; see README "Mixed precision"). Payloads
# already at (or below) the wire width, and non-float payloads, pass
# through uncompressed — compression never widens a message.
COMPRESS_MODES = (None, "bf16", "int8")


def _check_compress(compress):
    if compress not in COMPRESS_MODES:
        raise ValueError(
            f"compress={compress!r} is not one of {COMPRESS_MODES}")


def _permute(payload, mesh_ax, perm, compress):
    """``lax.ppermute`` with an optionally compressed wire format. The
    result is cast back to the payload dtype, so call sites are wire-
    format agnostic."""
    dt = payload.dtype
    is_float = jnp.issubdtype(dt, jnp.floating)
    if compress == "bf16" and is_float and dt.itemsize > 2:
        return lax.ppermute(payload.astype(jnp.bfloat16), mesh_ax,
                            perm).astype(dt)
    if compress == "int8" and is_float and dt.itemsize > 1:
        from . import compression as _comp

        q, scale, meta = _comp.quantize_int8(payload)
        q = lax.ppermute(q, mesh_ax, perm)
        scale = lax.ppermute(scale, mesh_ax, perm)
        return _comp.dequantize_int8(q, scale, meta).astype(dt)
    return lax.ppermute(payload, mesh_ax, perm)


def _slab(arr, axis: int, start: int, size: int):
    idx = [slice(None)] * arr.ndim
    idx[axis] = slice(start, start + size) if start >= 0 else slice(start, start + size or None)
    return arr[tuple(idx)]


def _axis_depths(depths, radius: int, n_axes: int):
    """Normalize per-axis exchange depths: None -> full radius; an int
    broadcasts; entries are (lo, hi) pairs or ints, clamped to the ghost
    width (the allocation contract stays ``radius`` layers)."""
    if depths is None:
        return [(radius, radius)] * n_axes
    if isinstance(depths, int):
        depths = [depths] * n_axes
    depths = list(depths)
    if len(depths) != n_axes:
        raise ValueError(
            f"exchange depths {depths} cover {len(depths)} axes but "
            f"{n_axes} array axes are decomposed — a short list would "
            "silently skip the trailing axes' ghost refresh"
        )
    out = []
    for d in depths:
        lo, hi = (d, d) if isinstance(d, int) else (int(d[0]), int(d[1]))
        if lo > radius or hi > radius or lo < 0 or hi < 0:
            raise ValueError(
                f"exchange depth {(lo, hi)} outside the allocated ghost "
                f"width [0, radius={radius}]"
            )
        out.append((lo, hi))
    return out


def halo_exchange(
    local: jax.Array,
    mesh_axes: Sequence[str],
    array_axes: Sequence[int] | None = None,
    radius: int = 1,
    periodic: bool | Sequence[bool] = False,
    depths=None,
    compress: str | None = None,
) -> jax.Array:
    """Refresh ghost layers of ``local`` along each decomposed axis.

    Args:
      local: rank-local array with ``radius`` ghost layers on decomposed axes.
      mesh_axes: mesh axis name per decomposed array axis.
      array_axes: which array axes are decomposed (default: first len(mesh_axes)).
      radius: ghost width (the allocation).
      periodic: global wrap per axis (scalar broadcasts).
      depths: optional per-axis (lo, hi) *exchange* depths <= radius (the
        footprint-inferred read depths): only the innermost ``lo`` cells
        of the low ghost ring / ``hi`` of the high ring are refreshed, so
        a field the stencil reads one-sided (or not at all) moves fewer
        (or no) bytes. ``None`` refreshes the full ring.
      compress: optional wire format for the ghost payload — ``"bf16"``
        (cast around the permute, 2 B/elt) or ``"int8"`` (block-
        quantized via ``distributed.compression``, ~1 B/elt). Ghosts
        land back at the array dtype; interiors are untouched. Single-
        rank self-wraps are local copies and stay exact.
    """
    _check_compress(compress)
    if array_axes is None:
        array_axes = list(range(len(mesh_axes)))
    if isinstance(periodic, bool):
        periodic = [periodic] * len(mesh_axes)
    r = radius
    depths = _axis_depths(depths, r, len(mesh_axes))
    for mesh_ax, arr_ax, per, (d_lo, d_hi) in zip(mesh_axes, array_axes,
                                                  periodic, depths):
        n = _axis_size(mesh_ax)
        if n == 1:
            if per:
                # self-wrap: ghost layers come from own opposite interior
                if d_lo:
                    lo_src = _slab(local, arr_ax, -(r + d_lo), d_lo)
                    local = _set_slab(local, arr_ax, r - d_lo, lo_src)
                if d_hi:
                    hi_src = _slab(local, arr_ax, r, d_hi)
                    local = _set_slab(local, arr_ax, -r, hi_src)
            continue
        idx = lax.axis_index(mesh_ax)
        if d_lo:
            # --- my high interior slab -> right neighbor's low ghost ---
            send_hi = _slab(local, arr_ax, -(r + d_lo), d_lo)
            perm_r = [(i, i + 1) for i in range(n - 1)]
            if per:
                perm_r.append((n - 1, 0))
            recv_lo = _permute(send_hi, mesh_ax, perm_r, compress)
            has_left = (idx > 0) | (per and n > 1)
            cur_lo = _slab(local, arr_ax, r - d_lo, d_lo)
            local = _set_slab(local, arr_ax, r - d_lo,
                              jnp.where(has_left, recv_lo, cur_lo))
        if d_hi:
            # --- my low interior slab -> left neighbor's high ghost ---
            send_lo = _slab(local, arr_ax, r, d_hi)
            perm_l = [(i + 1, i) for i in range(n - 1)]
            if per:
                perm_l.append((0, n - 1))
            recv_hi = _permute(send_lo, mesh_ax, perm_l, compress)
            has_right = (idx < n - 1) | (per and n > 1)
            cur_hi = _slab(local, arr_ax, -r, d_hi)
            local = _set_slab(local, arr_ax, -r,
                              jnp.where(has_right, recv_hi, cur_hi))
    return local


def _set_slab(arr, axis: int, start: int, value):
    idx = [slice(None)] * arr.ndim
    if start >= 0:
        idx[axis] = slice(start, start + value.shape[axis])
    else:
        stop = start + value.shape[axis]
        idx[axis] = slice(start, stop if stop < 0 else None)
    return arr.at[tuple(idx)].set(value)


def _field_depths(depths, names, radius: int, n_axes: int) -> dict:
    """Normalize a per-field depth mapping (missing fields or None ->
    full radius; entries follow :func:`_axis_depths`)."""
    out = {}
    for f in names:
        d = None if depths is None else depths.get(f)
        out[f] = _axis_depths(d, radius, n_axes)
    return out


def grouped_halo_exchange(
    fields: Mapping[str, jax.Array],
    names: Sequence[str],
    mesh_axes: Sequence[str],
    array_axes: Sequence[int] | None = None,
    radius: int = 1,
    periodic: bool | Sequence[bool] = False,
    depths: Mapping[str, object] | None = None,
    compress: str | None = None,
) -> dict:
    """Refresh ghost layers of *all* ``names`` with ONE message per
    (axis, direction) round-trip instead of one per field.

    The per-field face slabs are flattened and concatenated into a single
    ``ppermute`` payload (per dtype group — mixed-precision systems send
    one message per dtype), then split and scattered back. For a coupled
    system of F fields this turns ``2 * ndim * F`` permutes into
    ``2 * ndim`` — the latency win ImplicitGlobalGrid gets from posting
    all of a system's MPI messages together. Mixed-shape staggered fields
    group fine: only the flattened slab sizes differ.

    ``depths`` (per field, per axis (lo, hi) <= radius — the footprint-
    inferred read depths) shrinks each field's slab to what the stencil
    actually reads; a field with depth 0 on a side contributes nothing to
    that direction's payload.

    ``compress`` selects the wire format of the whole concatenated
    payload (``"bf16"``/``"int8"``, see :func:`halo_exchange`): each
    (axis, direction, dtype-group) message is compressed once, so the
    per-message scale metadata of ``"int8"`` amortizes over every field
    riding in it.

    Values are identical to per-field :func:`halo_exchange` calls
    (with matching ``compress``, which quantizes per concatenated
    payload here vs per field there — both within the int8 error bound).
    """
    _check_compress(compress)
    if array_axes is None:
        array_axes = list(range(len(mesh_axes)))
    if isinstance(periodic, bool):
        periodic = [periodic] * len(mesh_axes)
    out = dict(fields)
    r = radius
    fdep = _field_depths(depths, names, r, len(mesh_axes))
    # dtype groups (ppermute payloads must be homogeneous)
    groups: dict = {}
    for n in names:
        groups.setdefault(jnp.asarray(out[n]).dtype, []).append(n)
    for ax_i, (mesh_ax, arr_ax, per) in enumerate(
            zip(mesh_axes, array_axes, periodic)):
        n_ranks = _axis_size(mesh_ax)
        if n_ranks == 1:
            if per:
                for f in names:
                    d_lo, d_hi = fdep[f][ax_i]
                    if d_lo:
                        lo_src = _slab(out[f], arr_ax, -(r + d_lo), d_lo)
                        out[f] = _set_slab(out[f], arr_ax, r - d_lo, lo_src)
                    if d_hi:
                        hi_src = _slab(out[f], arr_ax, r, d_hi)
                        out[f] = _set_slab(out[f], arr_ax, -r, hi_src)
            continue
        idx = lax.axis_index(mesh_ax)
        perm_r = [(i, i + 1) for i in range(n_ranks - 1)]
        perm_l = [(i + 1, i) for i in range(n_ranks - 1)]
        if per:
            perm_r.append((n_ranks - 1, 0))
            perm_l.append((0, n_ranks - 1))
        has_left = (idx > 0) | (per and n_ranks > 1)
        has_right = (idx < n_ranks - 1) | (per and n_ranks > 1)
        for grp in groups.values():
            # --- high interior slabs -> right neighbors' low ghosts ---
            lo_grp = [f for f in grp if fdep[f][ax_i][0]]
            if lo_grp:
                send_hi = [
                    _slab(out[f], arr_ax, -(r + fdep[f][ax_i][0]),
                          fdep[f][ax_i][0]) for f in lo_grp
                ]
                recv = _permute(
                    jnp.concatenate([s.reshape(-1) for s in send_hi]),
                    mesh_ax, perm_r, compress)
                ofs = 0
                for f, s in zip(lo_grp, send_hi):
                    piece = recv[ofs:ofs + s.size].reshape(s.shape)
                    ofs += s.size
                    d_lo = fdep[f][ax_i][0]
                    cur = _slab(out[f], arr_ax, r - d_lo, d_lo)
                    out[f] = _set_slab(out[f], arr_ax, r - d_lo,
                                       jnp.where(has_left, piece, cur))
            # --- low interior slabs -> left neighbors' high ghosts ---
            hi_grp = [f for f in grp if fdep[f][ax_i][1]]
            if hi_grp:
                send_lo = [
                    _slab(out[f], arr_ax, r, fdep[f][ax_i][1])
                    for f in hi_grp
                ]
                recv = _permute(
                    jnp.concatenate([s.reshape(-1) for s in send_lo]),
                    mesh_ax, perm_l, compress)
                ofs = 0
                for f, s in zip(hi_grp, send_lo):
                    piece = recv[ofs:ofs + s.size].reshape(s.shape)
                    ofs += s.size
                    d_hi = fdep[f][ax_i][1]
                    cur = _slab(out[f], arr_ax, -r, d_hi)
                    out[f] = _set_slab(out[f], arr_ax, -r,
                                       jnp.where(has_right, piece, cur))
    return out


def _wire_bytes(n_elems: int, itemsize: int, is_float: bool,
                compress: str | None) -> int:
    """Wire bytes of one ppermute payload of ``n_elems`` homogeneous
    elements under a compressed wire format (mirrors :func:`_permute`:
    bf16 = 2 B/elt when narrowing applies; int8 = BLOCK-padded 1 B/elt
    plus one f32 scale per block)."""
    if compress == "bf16" and is_float and itemsize > 2:
        return n_elems * 2
    if compress == "int8" and is_float and itemsize > 1:
        from .compression import BLOCK

        n_blocks = -(-n_elems // BLOCK)
        return n_blocks * BLOCK + n_blocks * 4
    return n_elems * itemsize


def exchange_byte_counts(
    shapes: Mapping[str, Sequence[int]],
    itemsizes: Mapping[str, int],
    float_fields: Mapping[str, bool],
    n_axes: int,
    radius: int = 1,
    depths: Mapping[str, object] | None = None,
    compress: str | None = None,
    grouped: bool = True,
    active: Sequence[bool] | None = None,
    dtype_groups: Sequence[Sequence[str]] | None = None,
) -> dict:
    """Analytic per-rank payload bytes of ONE :func:`exchange_many` call.

    Pure host-side arithmetic over static shapes — safe to evaluate at
    trace time, which is where the telemetry instrumentation calls it
    (the counts are per exchange invocation; a solve taking N steps ships
    N times these bytes). Returns ``{"bytes_raw": ..., "bytes_wire":
    ..., "messages": ...}`` where *raw* prices every slab at its storage
    width and *wire* applies the compressed format per message
    (per dtype group when ``grouped``, per field otherwise).
    ``active`` masks axes whose mesh extent is 1 (no messages)."""
    names = list(shapes)
    fdep = _field_depths(depths, names, radius, n_axes)
    if active is None:
        active = [True] * n_axes
    if dtype_groups is None:
        if grouped:
            by_key: dict = {}
            for f in names:
                by_key.setdefault((itemsizes[f], float_fields[f]),
                                  []).append(f)
            dtype_groups = list(by_key.values())
        else:
            dtype_groups = [[f] for f in names]
    raw = wire = messages = 0
    for ax in range(n_axes):
        if not active[ax]:
            continue
        for side in (0, 1):
            for grp in dtype_groups:
                sent = [f for f in grp if fdep[f][ax][side]]
                if not sent:
                    continue
                elems = sum(
                    fdep[f][ax][side]
                    * math.prod(s for a, s in enumerate(shapes[f]) if a != ax)
                    for f in sent)
                isz = itemsizes[sent[0]]
                is_f = float_fields[sent[0]]
                raw += elems * isz
                wire += _wire_bytes(elems, isz, is_f, compress)
                messages += (2 if (compress == "int8" and is_f and isz > 1)
                             else 1)
    return {"bytes_raw": int(raw), "bytes_wire": int(wire),
            "messages": int(messages)}


def _emit_exchange_telemetry(col, fields, names, mesh_axes, radius, depths,
                             compress, grouped):
    """Trace-time byte accounting: fires once per compiled exchange
    geometry (shapes are static under the trace), never per step — the
    device program is untouched. Gauges carry per-exchange bytes;
    multiply by the step count for totals."""
    try:
        active = [_axis_size(ax) > 1 for ax in mesh_axes]
    except Exception:       # outside shard_map — assume every axis ships
        active = None
    shp = {f: tuple(fields[f].shape) for f in names}
    isz = {f: jnp.asarray(fields[f]).dtype.itemsize for f in names}
    isf = {f: jnp.issubdtype(jnp.asarray(fields[f]).dtype, jnp.floating)
           for f in names}
    counts = exchange_byte_counts(shp, isz, isf, len(mesh_axes),
                                  radius=radius, depths=depths,
                                  compress=compress, grouped=grouped,
                                  active=active)
    col.event("halo.exchange_traced", fields=list(names), radius=radius,
              compress=compress, grouped=grouped, **counts)
    col.gauge("halo.bytes_raw_per_exchange", counts["bytes_raw"],
              compress=str(compress))
    col.gauge("halo.bytes_wire_per_exchange", counts["bytes_wire"],
              compress=str(compress))
    col.count("halo.traced_exchanges", 1)


def exchange_many(
    fields: Mapping[str, jax.Array],
    names: Sequence[str],
    mesh_axes: Sequence[str],
    radius: int = 1,
    periodic=False,
    grouped: bool = True,
    depths: Mapping[str, object] | None = None,
    compress: str | None = None,
) -> dict:
    """Refresh ghost layers of several fields. ``grouped=True`` (default)
    sends the whole field group per (axis, direction) in one ppermute
    (:func:`grouped_halo_exchange`); ``grouped=False`` keeps the
    one-permute-per-field reference path. ``depths`` tightens each
    field's exchanged slab to its inferred per-axis (lo, hi) read depth;
    ``compress`` selects the ghost wire format (``"bf16"``/``"int8"``,
    see :func:`halo_exchange`)."""
    _check_compress(compress)
    col = _telemetry.get()
    if col.enabled:
        _emit_exchange_telemetry(col, fields, names, mesh_axes, radius,
                                 depths, compress, grouped)
    if grouped:
        return grouped_halo_exchange(fields, names, mesh_axes, radius=radius,
                                     periodic=periodic, depths=depths,
                                     compress=compress)
    out = dict(fields)
    for n in names:
        out[n] = halo_exchange(
            out[n], mesh_axes, radius=radius, periodic=periodic,
            depths=None if depths is None else depths.get(n),
            compress=compress)
    return out


def global_to_local(global_arr, factors: Sequence[int], radius: int = 1):
    """Split a global array (with physical boundary layers) into per-rank
    local blocks with ghost layers, returned as a flat list in row-major
    rank order. Host-side utility for tests and initialization."""
    import numpy as np

    g = np.asarray(global_arr)
    r = radius
    inner = [s - 2 * r for s in g.shape[: len(factors)]]
    locals_ = []
    for ridx in np.ndindex(*factors):
        sl = []
        for ax, (i, f) in enumerate(zip(ridx, factors)):
            step = inner[ax] // f
            sl.append(slice(i * step, i * step + step + 2 * r))
        sl += [slice(None)] * (g.ndim - len(factors))
        locals_.append(g[tuple(sl)].copy())
    return locals_


def local_to_global(locals_, factors: Sequence[int], radius: int = 1):
    """Inverse of :func:`global_to_local` (interior stitching)."""
    import numpy as np

    r = radius
    sample = np.asarray(locals_[0])
    inner = [s - 2 * r for s in sample.shape[: len(factors)]]
    gshape = [i * f + 2 * r for i, f in zip(inner, factors)]
    gshape += list(sample.shape[len(factors):])
    g = np.zeros(gshape, sample.dtype)
    for rank, ridx in enumerate(np.ndindex(*factors)):
        loc = np.asarray(locals_[rank])
        dst, src = [], []
        for ax, (i, f) in enumerate(zip(ridx, factors)):
            step = inner[ax]
            lo_g = i * step + (0 if i == 0 else r)
            hi_g = (i + 1) * step + (2 * r if i == f - 1 else r)
            dst.append(slice(lo_g, hi_g))
            lo_l = 0 if i == 0 else r
            hi_l = loc.shape[ax] - (0 if i == f - 1 else r)
            src.append(slice(lo_l, hi_l))
        dst += [slice(None)] * (g.ndim - len(factors))
        src += [slice(None)] * (g.ndim - len(factors))
        g[tuple(dst)] = loc[tuple(src)]
    return g
