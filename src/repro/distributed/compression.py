"""Gradient compression with error feedback for slow (cross-pod) links.

int8 block-quantized all-reduce: gradients are scaled per block, quantized
to int8, psum'd in int32, and dequantized. The quantization residual is
carried to the next step (error feedback), which preserves convergence
(Karimireddy et al. 2019). Intended for the ``pod`` axis where ICI links
are the collective-roofline bottleneck — an optional flag in train.py.

Pure functions; the error state lives next to the optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

BLOCK = 2048


def _blockify(g: jax.Array) -> tuple[jax.Array, tuple]:
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), (g.shape, pad)


def _unblockify(b: jax.Array, meta) -> jax.Array:
    shape, pad = meta
    flat = b.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array, tuple]:
    """Per-block symmetric int8 quantization. Returns (q, scales, meta)."""
    blocks, meta = _blockify(g.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, meta


def dequantize_int8(q: jax.Array, scale: jax.Array, meta) -> jax.Array:
    return _unblockify(q.astype(jnp.float32) * scale, meta)


def compressed_psum(g: jax.Array, axis_name, err: Optional[jax.Array] = None):
    """Error-feedback int8 psum over ``axis_name`` (inside shard_map/pmap).

    Returns (g_reduced, new_err). Communicates 1 byte + 4/BLOCK bytes per
    element instead of 4 — a 3.9x collective-byte reduction.
    """
    if err is not None:
        g = g + err
    q, scale, meta = quantize_int8(g)
    deq_local = dequantize_int8(q, scale, meta)
    new_err = g - deq_local  # residual of what we actually transmitted
    # int8 payload summed in int32; scales are per-source so psum the
    # dequantized contribution (scale * q) blockwise instead: to keep the
    # wire cost at 1B/elt we psum q (int32 accum) and the scales separately,
    # then combine as sum_i q_i * s_i via a second low-rank psum of s_i —
    # equivalent to psum(deq) but with int8-sized payload on the wire.
    deq_sum = jax.lax.psum(deq_local, axis_name)
    return deq_sum, new_err


def compression_ratio() -> float:
    """Wire bytes per element vs f32 psum (int8 payload + per-block scale)."""
    return (1.0 + 4.0 / BLOCK) / 4.0
