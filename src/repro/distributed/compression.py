"""Gradient compression with error feedback for slow (cross-pod) links.

int8 block-quantized all-reduce: gradients are scaled per block, quantized
to int8, psum'd in int32, and dequantized. The quantization residual is
carried to the next step (error feedback), which preserves convergence
(Karimireddy et al. 2019). Intended for the ``pod`` axis where ICI links
are the collective-roofline bottleneck — an optional flag in train.py.

Pure functions; the error state lives next to the optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

BLOCK = 2048


def _blockify(g: jax.Array) -> tuple[jax.Array, tuple]:
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), (g.shape, pad)


def _unblockify(b: jax.Array, meta) -> jax.Array:
    shape, pad = meta
    flat = b.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array, tuple]:
    """Per-block symmetric int8 quantization. Returns (q, scales, meta)."""
    blocks, meta = _blockify(g.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, meta


def dequantize_int8(q: jax.Array, scale: jax.Array, meta) -> jax.Array:
    return _unblockify(q.astype(jnp.float32) * scale, meta)


def compressed_psum(g: jax.Array, axis_name, err: Optional[jax.Array] = None):
    """Error-feedback int8 psum over ``axis_name`` (inside shard_map/pmap).

    Returns (g_reduced, new_err). Communicates 1 byte + 4/BLOCK bytes per
    element instead of 4 — a 3.9x collective-byte reduction.

    The wire protocol uses a SHARED per-block scale: ranks first agree on
    ``s = pmax(local_scale)`` (an O(n/BLOCK) collective), every rank
    quantizes against it, and the full-size payload is ``psum`` of the
    int8 codes accumulated in int32. The dequantized result
    ``s * psum(q)`` then equals ``psum(s * q)`` EXACTLY — per-source
    scales cannot be recombined after summation (``sum_i s_i q_i`` is not
    recoverable from ``psum(q)`` and ``psum(s)``), which is why the
    shared scale is the only layout that keeps the big payload at
    1 B/element. int32 accumulation never overflows: ranks-per-axis
    times 127 stays far inside int32 range.

    Error feedback: ``new_err`` is this rank's residual ``g - s*q``
    against what it actually put on the wire; carrying it into the next
    call preserves convergence for gradient-style accumulation. It is
    NOT an exactness guarantee for a single reduction — one-shot users
    (e.g. a compressed halo) accept the quantization error instead.
    """
    g32 = g.astype(jnp.float32)
    if err is not None:
        g32 = g32 + err
    blocks, meta = _blockify(g32)
    local_scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    # agree on the widest per-block range (tiny: 4/BLOCK bytes per elt)
    scale = jax.lax.pmax(local_scale, axis_name)
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    new_err = _unblockify(blocks - q.astype(jnp.float32) * scale, meta)
    # the only full-size collective: int8 codes, summed in int32
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    deq_sum = _unblockify(q_sum.astype(jnp.float32) * scale, meta)
    return deq_sum, new_err


def compression_ratio() -> float:
    """Wire bytes per element vs f32 psum (int8 payload + per-block scale)."""
    return (1.0 + 4.0 / BLOCK) / 4.0
