"""Communication/computation overlap (the paper's ``@hide_communication``).

ParallelStencil + ImplicitGlobalGrid hide the halo exchange behind the
stencil update of the interior: the boundary-adjacent cells are computed
in separate kernels once the halos arrive, while the bulk of the domain is
updated concurrently with communication. That is what gave the paper >95%
parallel efficiency on 1024 GPUs.

On TPU/XLA the overlap is *dataflow-structured* rather than stream-
structured: we build the program so that

    bulk update      — depends only on stale-halo local data
    halo ppermutes   — depend only on interior slabs
    shell re-update  — depends on both

and XLA's async collective-permute (start/done pairs) lets the bulk update
execute between start and done. ``overlapped_step`` implements the generic
pattern for any `StencilKernel`; tests assert bit-equality with the
sequential exchange-then-update reference.

The shell is recomputed per face from a slab of thickness ``3r`` (ghost r +
shell r + support r): face slabs span the full extent of the other axes, so
edge/corner cells are recomputed consistently by every adjacent face (the
kernel is pure — last write wins with identical values).
"""
from __future__ import annotations

from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from ..core.parallel import StencilKernel
from . import halo as _halo


def _face_slab(arr, axis: int, side: int, thickness: int):
    idx = [slice(None)] * arr.ndim
    idx[axis] = slice(0, thickness) if side == 0 else slice(-thickness, None)
    return arr[tuple(idx)]


def _paste_shell(dst, src, axis: int, side: int, radius: int):
    """Paste the shell ring (layers [r, 2r) from the face) of src into dst."""
    r = radius
    di = [slice(None)] * dst.ndim
    si = [slice(None)] * dst.ndim
    di[axis] = slice(r, 2 * r) if side == 0 else slice(-2 * r, -r)
    si[axis] = slice(r, 2 * r) if side == 0 else slice(-2 * r, -r)
    return dst.at[tuple(di)].set(src[tuple(si)])


def sequential_step(
    kernel: StencilKernel,
    fields: Mapping[str, jax.Array],
    scalars: Mapping[str, object],
    exchange: Sequence[str],
    mesh_axes: Sequence[str],
    periodic=False,
):
    """Reference: exchange halos, then update. No overlap."""
    r = kernel.radius
    fresh = _halo.exchange_many(fields, exchange, mesh_axes, radius=r, periodic=periodic)
    return kernel(**fresh, **scalars), fresh


def multi_step(
    kernel: StencilKernel,
    fields: Mapping[str, jax.Array],
    scalars: Mapping[str, object],
    exchange: Sequence[str],
    mesh_axes: Sequence[str],
    nsteps: int,
    periodic=False,
):
    """Temporal blocking across ranks: ONE deep halo exchange feeds k fused
    local steps — k× fewer messages (each k·r wide instead of r).

    Local arrays must carry ``nsteps * kernel.radius`` ghost layers. After
    the k local sweeps the owned interior (depth >= k·r from the local
    edge) is exact: sweep s only needs time-s-correct values at depth
    >= s·r, which the deep exchange provides. The ghost ring is stale
    afterwards and must be re-exchanged before the next k-step block.
    Rank-local (inside shard_map). Returns (final outputs, fresh fields).
    """
    r = kernel.radius
    fresh = _halo.exchange_many(fields, exchange, mesh_axes,
                                radius=nsteps * r, periodic=periodic)
    return kernel.run_steps(nsteps, **fresh, **scalars), fresh


def overlapped_step(
    kernel: StencilKernel,
    fields: Mapping[str, jax.Array],
    scalars: Mapping[str, object],
    exchange: Sequence[str],
    mesh_axes: Sequence[str],
    periodic=False,
):
    """@hide_communication: bulk update overlaps the halo ppermutes.

    Returns (updated_output, fresh_fields). Rank-local (inside shard_map).
    Single-output kernels only (extend by returning dicts if needed).
    """
    r = kernel.radius
    (out_name,) = kernel.outputs
    nd = fields[out_name].ndim

    # 1) launch halo exchange (independent subgraph)
    fresh = _halo.exchange_many(fields, exchange, mesh_axes, radius=r, periodic=periodic)

    # 2) bulk update with stale halos — correct except the shell ring
    bulk = kernel(**fields, **scalars)

    # 3) recompute the shell per face from fresh slabs and paste
    thickness = 3 * r
    for axis in range(min(len(mesh_axes), nd)):
        for side in (0, 1):
            slab_fields = {
                n: _face_slab(v, axis, side, thickness) for n, v in fresh.items()
            }
            slab_out = kernel(**slab_fields, **scalars)
            bulk = _paste_shell(bulk, slab_out, axis, side, r)
    return bulk, fresh
