"""Communication/computation overlap (the paper's ``@hide_communication``).

ParallelStencil + ImplicitGlobalGrid hide the halo exchange behind the
stencil update of the interior: the boundary-adjacent cells are computed
in separate kernels once the halos arrive, while the bulk of the domain is
updated concurrently with communication. That is what gave the paper >95%
parallel efficiency on 1024 GPUs.

On TPU/XLA the overlap is *dataflow-structured* rather than stream-
structured: we build the program so that

    bulk update      — depends only on stale-halo local data
    halo ppermutes   — depend only on interior slabs
    shell re-update  — depends on both

and XLA's async collective-permute (start/done pairs) lets the bulk update
execute between start and done. ``overlapped_step`` implements the generic
pattern for any `StencilKernel`; tests assert bit-equality with the
sequential exchange-then-update reference.

The shell is recomputed per face from a slab of thickness ``3r`` (ghost r +
shell r + support r): face slabs span the full extent of the other axes, so
edge/corner cells are recomputed consistently by every adjacent face (the
kernel is pure — last write wins with identical values).
"""
from __future__ import annotations

import time
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from .. import telemetry as _telemetry
from ..core.parallel import StencilKernel
from . import fault as _fault
from . import halo as _halo


def _face_slab(arr, axis: int, side: int, thickness: int, off: int = 0):
    """Face slab covering base positions [0, thickness) / [N-thickness, N).
    A field staggered by ``off`` along ``axis`` (extent ``N - off``) yields
    a ``thickness - off`` slab over the same physical region, so slab sets
    keep the coupled system's staggering intact for the kernel's shape
    contract."""
    t = thickness - off
    idx = [slice(None)] * arr.ndim
    idx[axis] = slice(0, t) if side == 0 else slice(arr.shape[axis] - t, None)
    return arr[tuple(idx)]


def _paste_shell(dst, src, axis: int, side: int, radius: int):
    """Paste the ghost + shell layers ([0, 2r) from the face) of src into
    dst. Including the ghost ring keeps overlapped_step bit-equal to the
    sequential reference for `@all`-write outputs too (whose ghost cells
    are computed from exchanged values, not just carried)."""
    r = radius
    di = [slice(None)] * dst.ndim
    si = [slice(None)] * dst.ndim
    di[axis] = slice(0, 2 * r) if side == 0 else slice(-2 * r, None)
    si[axis] = slice(0, 2 * r) if side == 0 else slice(-2 * r, None)
    return dst.at[tuple(di)].set(src[tuple(si)])


def _kernel_geometry(kernel: StencilKernel, fields, scalars,
                     exchange: Sequence[str], mesh_axes: Sequence[str]):
    """(effective radius, per-exchanged-field exchange depths, ir) for
    this field set. Footprint-inferred kernels tighten each field's ghost
    refresh to its actual per-axis/per-side read depth on the decomposed
    (leading) axes; the legacy declared-radius fallback exchanges the
    full ring (depths=None, ir=None)."""
    try:
        ir = kernel.stencil_ir(**fields, **scalars)
    except ValueError:
        if kernel.radius is None:
            raise  # untraceable AND undeclared: the kernel call would fail
        return kernel.radius, None, None
    r = kernel.radius if kernel.radius is not None \
        else max(ir.inferred_radius, 1)
    n_dec = len(mesh_axes)
    depths = {f: ir.field_halo[f][:n_dec]
              for f in exchange if f in ir.field_halo}
    return r, depths, ir


def finish_reductions(kernel: StencilKernel, reds: Mapping[str, jax.Array],
                      mesh_axes: Sequence[str]) -> dict[str, jax.Array]:
    """Finish a kernel's fused reductions across ranks: ONE ``pmax`` /
    ``psum`` per reduction over the rank-local fused values (which are
    valid partials — the combines are associative). Rank-local (inside
    ``shard_map``).

    Ownership contract: ``max``-combine kinds (``max_abs``,
    ``max_abs_diff``) are exact under the repo's ghost-ring
    decomposition — ghost cells duplicate neighbor values (or carry an
    unchanged physical ring whose diff is 0), and duplicates cannot
    change a max. ``sum``-combine kinds are exact over *disjoint* rank
    domains; with allocated ghost rings the psum double-counts the
    overlap, so conserved-quantity sums should be folded on ghost-free
    shards (or corrected by the caller)."""
    return {n: kernel.reductions[n].all_reduce(v, mesh_axes)
            for n, v in reds.items()}


class MonitoredStepper:
    """Rank-failure detection wired around the distributed step drivers.

    Wraps the *compiled host-level* step callable (a jitted
    ``shard_map`` around :func:`sequential_step` / :func:`multi_step` /
    :func:`overlapped_step` — those themselves are traced, so timing
    belongs out here): every call blocks on the result, records the
    wall time with the :class:`~repro.distributed.fault.StepMonitor`
    (which bumps this host's heartbeat file), and polls peer
    heartbeats. A stale peer raises
    :class:`~repro.distributed.fault.RankFailure` so the launcher can
    checkpoint-restore on the surviving mesh; stragglers are surfaced
    on ``.last_health`` without interrupting the run."""

    def __init__(self, step: Callable, monitor: "_fault.StepMonitor",
                 nsteps_per_call: int = 1, check_peers_every: int = 1):
        self.step = step
        self.monitor = monitor
        self.nsteps_per_call = max(int(nsteps_per_call), 1)
        self.check_peers_every = max(int(check_peers_every), 1)
        self.calls = 0
        self.last_health = {"dead": [], "stragglers": [], "healthy": 1}

    def __call__(self, *args, **kwargs):
        w0 = time.time()
        t0 = time.perf_counter()
        out = self.step(*args, **kwargs)
        out = jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        self.calls += 1
        self.monitor.record(self.calls * self.nsteps_per_call,
                            dt / self.nsteps_per_call)
        col = _telemetry.get()
        if col.enabled:
            col.span_end("distributed.step", w0, dt,
                         {"call": self.calls,
                          "steps": self.nsteps_per_call,
                          "per_step_s": dt / self.nsteps_per_call})
            col.count("distributed.steps", self.nsteps_per_call)
        if self.calls % self.check_peers_every == 0:
            self.last_health = self.monitor.check_peers()
            if self.last_health["dead"]:
                raise _fault.RankFailure(self.last_health["dead"])
        return out


def monitored(step: Callable, monitor: "_fault.StepMonitor",
              **kwargs) -> MonitoredStepper:
    """Convenience wrapper: ``monitored(jax.jit(shard_mapped_step),
    StepMonitor(...))`` — see :class:`MonitoredStepper`."""
    return MonitoredStepper(step, monitor, **kwargs)


def sequential_step(
    kernel: StencilKernel,
    fields: Mapping[str, jax.Array],
    scalars: Mapping[str, object],
    exchange: Sequence[str],
    mesh_axes: Sequence[str],
    periodic=False,
    halo_compress: str | None = None,
):
    """Reference: exchange halos, then update. No overlap. A kernel with
    fused reductions returns ``((outs, reds), fresh)`` with the rank
    partials already combined across ranks (:func:`finish_reductions`) —
    the whole convergence check costs one collective scalar.
    ``halo_compress`` selects the ghost wire format (``"bf16"``/
    ``"int8"`` — see :func:`..halo.halo_exchange`)."""
    r, depths, _ = _kernel_geometry(kernel, fields, scalars, exchange,
                                    mesh_axes)
    fresh = _halo.exchange_many(fields, exchange, mesh_axes, radius=r,
                                periodic=periodic, depths=depths,
                                compress=halo_compress)
    res = kernel(**fresh, **scalars)
    if kernel.reductions:
        outs, reds = res
        res = (outs, finish_reductions(kernel, reds, mesh_axes))
    return res, fresh


def multi_step(
    kernel: StencilKernel,
    fields: Mapping[str, jax.Array],
    scalars: Mapping[str, object],
    exchange: Sequence[str],
    mesh_axes: Sequence[str],
    nsteps: int,
    periodic=False,
    halo_compress: str | None = None,
):
    """Temporal blocking across ranks: ONE deep halo exchange feeds k fused
    local steps — k× fewer messages (each k·r wide instead of r).

    Local arrays must carry ``nsteps * r`` ghost layers (r: declared or
    inferred radius). After the k local sweeps the owned interior (depth
    >= k·r from the local edge) is exact: sweep s only needs
    time-s-correct values at depth >= s·d, which the deep exchange
    provides — footprint-inferred kernels refresh only ``k * depth(F)``
    per field, axis and side instead of the full ``k*r``. The ghost ring
    is stale afterwards and must be re-exchanged before the next k-step
    block. Rank-local (inside shard_map). Returns (final outputs, fresh
    fields).
    """
    r, depths, _ = _kernel_geometry(kernel, fields, scalars, exchange,
                                    mesh_axes)
    if depths is not None:
        depths = {
            f: tuple((nsteps * lo, nsteps * hi) for lo, hi in d)
            for f, d in depths.items()
        }
    fresh = _halo.exchange_many(fields, exchange, mesh_axes,
                                radius=nsteps * r, periodic=periodic,
                                depths=depths, compress=halo_compress)
    res = kernel.run_steps(nsteps, **fresh, **scalars)
    if kernel.reductions:
        outs, reds = res
        res = (outs, finish_reductions(kernel, reds, mesh_axes))
    return res, fresh


def overlapped_step(
    kernel: StencilKernel,
    fields: Mapping[str, jax.Array],
    scalars: Mapping[str, object],
    exchange: Sequence[str],
    mesh_axes: Sequence[str],
    periodic=False,
    march_axis: int | None = None,
    halo_compress: str | None = None,
):
    """@hide_communication: bulk update overlaps the halo ppermutes.

    Returns (updated_outputs, fresh_fields). Rank-local (inside
    shard_map). Coupled multi-output kernels update all their outputs in
    the same overlapped pass (the halo group travels in one round-trip);
    the return mirrors the kernel's call convention — a bare array for
    single-output kernels, an out-name dict for coupled systems.

    ``march_axis`` streams the *interior* (bulk) update — the big launch
    whose windows dominate the rank's HBM traffic — through the engine's
    marching mode (``kernel.marched``); the per-face shell re-updates
    stay all-parallel: their slabs are a few cells thick, thinner than a
    plane queue, so the streamed builder would fall back anyway.

    Fused reductions: the bulk launch's partials would fold stale-halo
    shell cells that the face re-updates are about to overwrite, so the
    overlapped path runs the reduction-free kernel variants and folds
    the reductions over the *pasted* outputs instead
    (``kernel.apply_reductions`` — whole-array jnp folds fused into the
    surrounding jit, then one :func:`finish_reductions` collective);
    returns ``((outs, reds), fresh)`` like :func:`sequential_step`.
    """
    r, _, ir = _kernel_geometry(kernel, fields, scalars, exchange,
                                mesh_axes)
    plain = kernel.with_reductions(None)
    nd = fields[kernel.outputs[0]].ndim
    single = len(kernel.outputs) == 1
    # Per-axis base extent of the coupled set: staggered fields (shorter by
    # their offset) get matching shorter face slabs so the slab set keeps
    # the system's staggering. Outputs staggered along a decomposed axis
    # would need offset-aware shell pastes across the shared rank face —
    # exchange the cell fields and recompute fluxes locally instead.
    base = tuple(max(v.shape[a] for v in fields.values()) for a in range(nd))
    for axis in range(min(len(mesh_axes), nd)):
        for o in kernel.outputs:
            if fields[o].shape[axis] != base[axis]:
                raise NotImplementedError(
                    f"output {o!r} is staggered along decomposed axis "
                    f"{axis}; overlapped_step supports staggered inputs "
                    "only — keep face fields rank-local (recompute from "
                    "exchanged cell fields)"
                )

    def as_dict(res):
        return {kernel.outputs[0]: res} if single else dict(res)

    # 1) launch grouped halo exchange (independent subgraph, one
    #    round-trip for the whole coupled field set)
    fresh = _halo.exchange_many(fields, exchange, mesh_axes, radius=r,
                                periodic=periodic, compress=halo_compress)

    # 2) bulk update with stale halos — correct except the shell ring
    #    (streamed along march_axis when requested: the interior tiles
    #    reuse their plane queues instead of refetching halo windows)
    bulk_kernel = plain if march_axis is None else plain.marched(march_axis)
    bulk = as_dict(bulk_kernel(**fields, **scalars))

    # 3) recompute the shell per face from fresh slabs and paste. The
    #    slab must contain the shell's reads (support) and its writes
    #    (ring): ghost r + shell r + max(support, ring) per face — the
    #    inferred footprint trims the legacy 3r when the kernel reads
    #    shallower than r toward that face.
    if ir is not None:
        w_max = tuple(max(rings[a] for rings in ir.write_rings.values())
                      for a in range(nd))
        thick = tuple(
            (2 * r + max(ir.halo[a][1], w_max[a]),   # low face reads "up"
             2 * r + max(ir.halo[a][0], w_max[a]))   # high face reads "down"
            for a in range(nd)
        )
    else:
        thick = ((3 * r, 3 * r),) * nd
    for axis in range(min(len(mesh_axes), nd)):
        for side in (0, 1):
            slab_fields = {
                n: _face_slab(v, axis, side, thick[axis][side],
                              off=base[axis] - v.shape[axis])
                for n, v in fresh.items()
            }
            slab_out = as_dict(plain(**slab_fields, **scalars))
            for o in kernel.outputs:
                bulk[o] = _paste_shell(bulk[o], slab_out[o], axis, side, r)
    res = bulk[kernel.outputs[0]] if single else bulk
    if kernel.reductions:
        reds = kernel.apply_reductions(bulk, fresh)
        res = (res, finish_reductions(kernel, reds, mesh_axes))
    return res, fresh
