"""repro — xPU stencil computations in JAX (ParallelStencil.jl reproduction)
plus the multi-pod LM substrate it shares its distributed runtime with."""
__version__ = "0.1.0"
