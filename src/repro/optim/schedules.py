"""LR schedules: cosine, constant, and WSD (Warmup-Stable-Decay).

WSD is the MiniCPM schedule (arXiv:2404.06395): linear warmup, a long
stable plateau at peak LR, then a short exponential/linear decay tail —
reproduced here because minicpm-2b is one of the assigned architectures.
"""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, base_lr * cos)


def constant(step, base_lr: float, warmup: int = 0, total: int = 0):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    return jnp.where(step < warmup, warm, base_lr)


def wsd(step, base_lr: float, warmup: int, total: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay: decay starts at (1-decay_frac)*total."""
    step = jnp.asarray(step, jnp.float32)
    decay_start = (1.0 - decay_frac) * total
    warm = base_lr * step / jnp.maximum(warmup, 1)
    stable = base_lr
    prog = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1), 0, 1)
    decay = base_lr * jnp.exp(jnp.log(final_frac) * prog)
    out = jnp.where(step < warmup, warm, jnp.where(step < decay_start, stable, decay))
    return out


SCHEDULES = {"cosine": warmup_cosine, "const": constant, "wsd": wsd}


def get(name: str):
    return SCHEDULES[name]
