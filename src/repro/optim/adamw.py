"""AdamW with mixed-precision master weights, global-norm clipping and
microbatch gradient accumulation. Pure pytree functions (no optax dep).

State layout (all sharded like the params they track):
  m, v      — f32 first/second moments
  master    — f32 master copy when params are low-precision (bf16)
  count     — int32 step
Optional error-feedback state for compressed cross-pod all-reduce rides in
``comp_err`` (see distributed/compression.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import schedules as sch


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"
    warmup_steps: int = 100
    total_steps: int = 1000
    # decay mask: skip 1-D tensors (norm scales, biases) — standard practice
    decay_min_ndim: int = 2


def init(params, cfg: AdamWConfig):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if any(p.dtype != jnp.float32 for p in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply(params, grads, state, cfg: AdamWConfig):
    """One AdamW update. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = sch.get(cfg.schedule)(count, cfg.lr, cfg.warmup_steps, cfg.total_steps)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)
    masters = state.get("master", params)

    def upd(g, m, v, p_master, p):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= cfg.decay_min_ndim and cfg.weight_decay:
            step = step + cfg.weight_decay * p_master
        new_master = p_master - lr * step
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(masters)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, ma, p)
           for g, m, v, ma, p in zip(flat_g, flat_m, flat_v, flat_ma, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), new_master, params)
    new_state = {"m": new_m, "v": new_v, "count": count}
    if "master" in state:
        new_state["master"] = new_master
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, new_state, metrics


def accumulate_grads(loss_fn: Callable, params, batches, n_micro: int):
    """Gradient accumulation over ``n_micro`` microbatches via lax.scan.
    ``batches``: pytree whose leaves have a leading (n_micro, ...) axis."""
    def step(acc, mb):
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        acc_g, acc_l = acc
        return (jax.tree.map(jnp.add, acc_g, g), acc_l + loss), None

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (g, loss), _ = jax.lax.scan(step, (zero, jnp.float32(0)), batches)
    inv = 1.0 / n_micro
    return jax.tree.map(lambda x: x * inv, g), loss * inv
