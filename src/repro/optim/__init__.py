from . import adamw, schedules
from .adamw import AdamWConfig
__all__ = ["adamw", "schedules", "AdamWConfig"]
