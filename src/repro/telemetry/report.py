"""``python -m repro.telemetry.report RUN.jsonl`` — render a run's event
log into per-phase summary tables and (optionally) the Perfetto trace.

Offline companion of the live exporters: everything here is a pure
function over the JSONL records so ``benchmarks/report.py`` can reuse the
same tables in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from . import export, schema

__all__ = ["phase_summary", "counter_totals", "last_gauges",
           "error_trajectory", "format_table", "main"]


def phase_summary(records) -> list[dict]:
    """Aggregate span records by name: count, total/mean/p50/p90/max."""
    by_name: dict[str, list[float]] = {}
    for r in records:
        if r.get("kind") == "span":
            by_name.setdefault(r["name"], []).append(float(r["dur_s"]))
    rows = []
    for name in sorted(by_name):
        d = by_name[name]
        rows.append({"phase": name, "count": len(d),
                     "total_s": float(sum(d)),
                     "mean_s": float(np.mean(d)),
                     "p50_s": float(np.percentile(d, 50)),
                     "p90_s": float(np.percentile(d, 90)),
                     "max_s": float(max(d))})
    rows.sort(key=lambda r: -r["total_s"])
    return rows


def counter_totals(records) -> list[dict]:
    totals: dict[tuple, float] = {}
    for r in records:
        if r.get("kind") == "counter":
            key = (r["name"], tuple(sorted((r.get("labels") or {}).items())))
            totals[key] = totals.get(key, 0.0) + float(r["value"])
    return [{"counter": name, "labels": dict(labels), "total": total}
            for (name, labels), total in sorted(totals.items())]


def last_gauges(records) -> list[dict]:
    last: dict[tuple, float] = {}
    for r in records:
        if r.get("kind") == "gauge":
            key = (r["name"], tuple(sorted((r.get("labels") or {}).items())))
            last[key] = float(r["value"])
    return [{"gauge": name, "labels": dict(labels), "value": value}
            for (name, labels), value in sorted(last.items())]


def error_trajectory(records) -> list[dict]:
    """(iters, err, per_step_s) from the chunk-boundary harvest events."""
    out = []
    for r in records:
        if r.get("kind") == "event" and r["name"] == "solve.trajectory":
            a = r.get("attrs", {})
            out.append({"iters": a.get("iters"), "err": a.get("err"),
                        "per_step_s": a.get("per_step_s")})
    return out


def format_table(rows: list[dict], cols: list[str],
                 title: str | None = None) -> str:
    """Plain fixed-width text table (markdown-pipe style)."""
    if not rows:
        return ""

    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        if isinstance(v, dict):
            return ",".join(f"{k}={x}" for k, x in v.items()) or "-"
        return str(v)

    cells = [[fmt(r.get(c, "")) for c in cols] for r in rows]
    widths = [max(len(c), *(len(row[i]) for row in cells))
              for i, c in enumerate(cols)]
    lines = []
    if title:
        lines.append(f"## {title}")
    lines.append(" | ".join(c.ljust(w) for c, w in zip(cols, widths)))
    lines.append("-|-".join("-" * w for w in widths))
    for row in cells:
        lines.append(" | ".join(v.ljust(w) for v, w in zip(row, widths)))
    return "\n".join(lines)


def render(records, out=None):
    out = out if out is not None else sys.stdout
    meta = next((r for r in records if r.get("kind") == "meta"), {})
    print(f"# telemetry report (schema {meta.get('schema', '?')}, "
          f"pid {meta.get('pid', '?')}, backend {meta.get('backend', '?')})",
          file=out)
    for title, rows, cols in (
        ("Per-phase spans", phase_summary(records),
         ["phase", "count", "total_s", "mean_s", "p50_s", "p90_s", "max_s"]),
        ("Counters", counter_totals(records), ["counter", "labels", "total"]),
        ("Gauges (last value)", last_gauges(records),
         ["gauge", "labels", "value"]),
        ("Error trajectory", error_trajectory(records),
         ["iters", "err", "per_step_s"]),
    ):
        t = format_table(rows, cols, title)
        if t:
            print("\n" + t, file=out)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Summarize a telemetry JSONL run log.")
    p.add_argument("log", help="telemetry JSONL file")
    p.add_argument("--trace", metavar="OUT.json", default=None,
                   help="also write the Chrome/Perfetto trace here")
    p.add_argument("--validate", action="store_true",
                   help="schema-validate the log first (exit 1 on drift)")
    args = p.parse_args(argv)
    if args.validate:
        schema.validate_file(args.log)
    records = schema.load_records(args.log)
    render(records)
    if args.trace:
        n = export.write_chrome_trace(records, args.trace)
        print(f"\nwrote {n} trace events -> {args.trace}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
