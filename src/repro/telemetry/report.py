"""``python -m repro.telemetry.report RUN.jsonl`` — render a run's event
log into per-phase summary tables and (optionally) the Perfetto trace.

Multi-process runs write one rank-stamped stream per process
(``rank_0.jsonl``, ``rank_1.jsonl``, ... — see
``telemetry.configure_rank``); ``--merge 'rank_*.jsonl'`` interleaves
them by timestamp into one timeline and adds a per-rank phase table, so
a straggling rank shows up as ITS span rows, not an averaged blur.

Offline companion of the live exporters: everything here is a pure
function over the JSONL records so ``benchmarks/report.py`` can reuse the
same tables in EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import glob as _glob
import sys

import numpy as np

from . import export, schema

__all__ = ["phase_summary", "counter_totals", "last_gauges",
           "error_trajectory", "format_table", "merge_records",
           "per_rank_phase_summary", "main"]


def phase_summary(records) -> list[dict]:
    """Aggregate span records by name: count, total/mean/p50/p90/max."""
    by_name: dict[str, list[float]] = {}
    for r in records:
        if r.get("kind") == "span":
            by_name.setdefault(r["name"], []).append(float(r["dur_s"]))
    rows = []
    for name in sorted(by_name):
        d = by_name[name]
        rows.append({"phase": name, "count": len(d),
                     "total_s": float(sum(d)),
                     "mean_s": float(np.mean(d)),
                     "p50_s": float(np.percentile(d, 50)),
                     "p90_s": float(np.percentile(d, 90)),
                     "max_s": float(max(d))})
    rows.sort(key=lambda r: -r["total_s"])
    return rows


def counter_totals(records) -> list[dict]:
    totals: dict[tuple, float] = {}
    for r in records:
        if r.get("kind") == "counter":
            key = (r["name"], tuple(sorted((r.get("labels") or {}).items())))
            totals[key] = totals.get(key, 0.0) + float(r["value"])
    return [{"counter": name, "labels": dict(labels), "total": total}
            for (name, labels), total in sorted(totals.items())]


def last_gauges(records) -> list[dict]:
    last: dict[tuple, float] = {}
    for r in records:
        if r.get("kind") == "gauge":
            key = (r["name"], tuple(sorted((r.get("labels") or {}).items())))
            last[key] = float(r["value"])
    return [{"gauge": name, "labels": dict(labels), "value": value}
            for (name, labels), value in sorted(last.items())]


def error_trajectory(records) -> list[dict]:
    """(iters, err, per_step_s) from the chunk-boundary harvest events."""
    out = []
    for r in records:
        if r.get("kind") == "event" and r["name"] == "solve.trajectory":
            a = r.get("attrs", {})
            out.append({"iters": a.get("iters"), "err": a.get("err"),
                        "per_step_s": a.get("per_step_s")})
    return out


def _stream_rank(path: str, records) -> object:
    """The rank a stream belongs to: record stamps win, then the meta
    head, then a ``rank_<i>`` filename; '?' when untagged."""
    for r in records:
        if "rank" in r:
            return r["rank"]
    import re
    m = re.search(r"rank_(\d+)", path)
    return int(m.group(1)) if m else "?"


def merge_records(paths: list[str]) -> list[dict]:
    """Interleave several per-rank JSONL streams into one timestamp-
    ordered record list. Every record carries a ``rank`` key afterwards
    (stamped from the stream when its own records were not). The sort is
    stable, so same-timestamp records keep per-stream order."""
    merged: list[dict] = []
    for path in paths:
        records = schema.load_records(path)
        rank = _stream_rank(path, records)
        for r in records:
            if "rank" not in r:
                r = dict(r, rank=rank)
            merged.append(r)
    merged.sort(key=lambda r: float(r.get("ts", 0.0)))
    return merged


def per_rank_phase_summary(records) -> list[dict]:
    """Span aggregates split by rank — rows ordered (phase, rank) so one
    rank's outlier durations sit next to its peers'."""
    by_key: dict[tuple, list[float]] = {}
    for r in records:
        if r.get("kind") == "span":
            by_key.setdefault((r["name"], r.get("rank", "?")),
                              []).append(float(r["dur_s"]))
    rows = []
    for (name, rank) in sorted(by_key, key=str):
        d = by_key[(name, rank)]
        rows.append({"phase": name, "rank": rank, "count": len(d),
                     "total_s": float(sum(d)),
                     "mean_s": float(np.mean(d)),
                     "p90_s": float(np.percentile(d, 90)),
                     "max_s": float(max(d))})
    return rows


def format_table(rows: list[dict], cols: list[str],
                 title: str | None = None) -> str:
    """Plain fixed-width text table (markdown-pipe style)."""
    if not rows:
        return ""

    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4g}"
        if isinstance(v, dict):
            return ",".join(f"{k}={x}" for k, x in v.items()) or "-"
        return str(v)

    cells = [[fmt(r.get(c, "")) for c in cols] for r in rows]
    widths = [max(len(c), *(len(row[i]) for row in cells))
              for i, c in enumerate(cols)]
    lines = []
    if title:
        lines.append(f"## {title}")
    lines.append(" | ".join(c.ljust(w) for c, w in zip(cols, widths)))
    lines.append("-|-".join("-" * w for w in widths))
    for row in cells:
        lines.append(" | ".join(v.ljust(w) for v, w in zip(row, widths)))
    return "\n".join(lines)


def render(records, out=None, per_rank: bool = False):
    out = out if out is not None else sys.stdout
    meta = next((r for r in records if r.get("kind") == "meta"), {})
    ranks = sorted({r["rank"] for r in records if "rank" in r}, key=str)
    head = (f"# telemetry report (schema {meta.get('schema', '?')}, "
            f"pid {meta.get('pid', '?')}, backend {meta.get('backend', '?')}")
    if per_rank and ranks:
        head += f", ranks {ranks}"
    print(head + ")", file=out)
    tables = [
        ("Per-phase spans", phase_summary(records),
         ["phase", "count", "total_s", "mean_s", "p50_s", "p90_s", "max_s"]),
    ]
    if per_rank:
        tables.append(
            ("Per-rank phases", per_rank_phase_summary(records),
             ["phase", "rank", "count", "total_s", "mean_s", "p90_s",
              "max_s"]))
    tables += [
        ("Counters", counter_totals(records), ["counter", "labels", "total"]),
        ("Gauges (last value)", last_gauges(records),
         ["gauge", "labels", "value"]),
        ("Error trajectory", error_trajectory(records),
         ["iters", "err", "per_step_s"]),
    ]
    for title, rows, cols in tables:
        t = format_table(rows, cols, title)
        if t:
            print("\n" + t, file=out)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.telemetry.report",
        description="Summarize a telemetry JSONL run log.")
    p.add_argument("log", nargs="*", help="telemetry JSONL file(s)")
    p.add_argument("--merge", metavar="GLOB", action="append", default=[],
                   help="interleave per-rank streams matching this glob "
                        "(e.g. 'rank_*.jsonl') by timestamp; adds a "
                        "per-rank phase table")
    p.add_argument("--trace", metavar="OUT.json", default=None,
                   help="also write the Chrome/Perfetto trace here")
    p.add_argument("--validate", action="store_true",
                   help="schema-validate the log first (exit 1 on drift)")
    args = p.parse_args(argv)
    paths = list(args.log)
    for pattern in args.merge:
        hits = sorted(_glob.glob(pattern))
        if not hits:
            print(f"# no files match {pattern!r}", file=sys.stderr)
        paths += hits
    if not paths:
        p.error("pass a JSONL file or --merge GLOB")
    if args.validate:
        for path in paths:
            schema.validate_file(path)
    merged = bool(args.merge) or len(paths) > 1
    records = merge_records(paths) if merged else schema.load_records(paths[0])
    render(records, per_rank=merged)
    if args.trace:
        n = export.write_chrome_trace(records, args.trace)
        print(f"\nwrote {n} trace events -> {args.trace}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
