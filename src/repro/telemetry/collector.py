"""The telemetry `Collector`: counters, gauges, histograms, spans, events.

Two hard rules keep this subsystem honest about the engine it measures:

1. **Disabled is a true no-op.** :data:`NULL` is a process-wide singleton
   whose every method is an empty function and whose ``span()`` returns a
   shared reusable null context manager — no allocation, no branching
   beyond one attribute call at each instrumentation site. No file is
   opened, nothing is imported lazily on the hot path.

2. **Nothing here runs inside compiled device code.** Instrumentation
   sites emit only from host-side control flow (chunk boundaries,
   checkpoint writers, autotune decisions) or at *trace* time (the halo
   byte accounting). The jitted program — and its jaxpr — is byte-for-byte
   identical with telemetry on or off; the zero-host-sync guarantee of
   ``solve_until`` is preserved by construction and asserted by test.

Events stream to a JSONL file as they happen (one JSON object per line,
flushed per event — events are rare: chunk boundaries, saves, decisions).
Emission is lock-guarded because the async checkpoint writer reports from
its background thread. The schema is documented and enforced by
:mod:`repro.telemetry.schema`.

A third rule joined with the serving layer: **telemetry must never kill
the solve it observes.** The JSONL writer is plumbing on a filesystem
that can hiccup (flaky NFS, full disk, an injected
``REPRO_FAULT_PLAN`` transient-IO budget), so every file write runs
through :func:`repro.distributed.fault.retry` and, when the retries are
exhausted, degrades to dropping THAT line — the record stays in memory,
the ``telemetry.dropped_records`` counter ticks, and the caller never
sees the exception. Because ``fault.retry`` itself counts its retries
through this collector, the write path keeps a thread-local reentrancy
guard: nested emissions defer their lines and are flushed best-effort
after the outer write completes (no deadlock on the non-reentrant lock,
no unbounded recursion while the filesystem is down).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

SCHEMA_VERSION = 1

__all__ = ["Collector", "NullCollector", "NULL", "SCHEMA_VERSION"]


class _SinkClosed(Exception):
    """Internal: the JSONL file handle was closed mid-write (shutdown
    race) — NOT an OSError, so fault.retry does not retry/count it."""


class _NullSpan:
    """Reusable do-nothing context manager (shared instance, zero alloc)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullCollector:
    """The disabled-mode singleton: every method is a no-op."""

    __slots__ = ()
    enabled = False
    path = None

    def count(self, name, value=1, **labels):
        pass

    def gauge(self, name, value, **labels):
        pass

    def observe(self, name, value, **labels):
        pass

    def event(self, name, **attrs):
        pass

    def span(self, name, **attrs):
        return _NULL_SPAN

    def span_end(self, name, wall_start, dur_s, attrs=None):
        pass

    def flush(self):
        pass

    def close(self):
        pass


NULL = NullCollector()


class _Span:
    """Context manager emitted by :meth:`Collector.span` — wall-clock
    start plus a monotonic duration, recorded on exit."""

    __slots__ = ("_col", "_name", "_attrs", "_t0", "_w0")

    def __init__(self, col: "Collector", name: str, attrs: dict):
        self._col = col
        self._name = name
        self._attrs = attrs

    def __enter__(self):
        self._w0 = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        dur = time.perf_counter() - self._t0
        if exc_type is not None:
            self._attrs = dict(self._attrs, error=exc_type.__name__)
        self._col.span_end(self._name, self._w0, dur, self._attrs)
        return False


def _jsonable(v):
    """Coerce attribute values to JSON-safe scalars (device scalars and
    numpy types arrive here; anything exotic degrades to repr)."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    try:
        import numpy as np

        if isinstance(v, np.integer):
            return int(v)
        if isinstance(v, (np.floating, np.ndarray)) and getattr(v, "size", 2) == 1:
            return float(v)
    except Exception:
        pass
    try:
        return float(v)  # jax device scalars
    except Exception:
        return repr(v)


class Collector:
    """An enabled telemetry collector.

    ``path=None`` keeps events in memory only (``.records``) — the mode
    tests and ad-hoc benchmarks use; a path streams JSONL write-through.
    """

    enabled = True

    # retry policy for JSONL writes: quick, bounded — telemetry is not
    # worth stalling a solve for; a line that cannot land in ~3 tries on
    # a ~10ms backoff is dropped (counted) rather than waited on
    IO_ATTEMPTS = 3
    IO_BACKOFF_S = 0.01
    IO_MAX_BACKOFF_S = 0.1

    def __init__(self, path: Optional[str] = None, *,
                 meta: Optional[dict] = None, rank: Optional[int] = None):
        self.path = path
        self.rank = rank       # stamps every record (multi-process streams)
        self.records: list[dict] = []
        self.counters: dict[tuple, float] = {}
        self.gauges: dict[tuple, float] = {}
        self.hists: dict[tuple, list[float]] = {}
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._fh = None
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            from ..distributed import fault  # lazy: fault imports telemetry

            try:
                self._fh = fault.retry(
                    lambda: (fault.FaultPlan.active_on_io(path),
                             open(path, "a"))[1],
                    attempts=self.IO_ATTEMPTS, backoff_s=self.IO_BACKOFF_S,
                    max_backoff_s=self.IO_MAX_BACKOFF_S)
            except OSError:
                # the sink never comes up: degrade to memory-only rather
                # than kill the caller; every would-be line counts dropped
                self._fh = None
        head = {"kind": "meta", "ts": time.time(), "schema": SCHEMA_VERSION,
                "pid": os.getpid()}
        if path and self._fh is None:
            head["sink_degraded"] = True
        head.update({k: _jsonable(v) for k, v in (meta or {}).items()})
        self._emit(head)

    # -- emission ------------------------------------------------------------
    def _emit(self, rec: dict):
        if self.rank is not None and "rank" not in rec:
            rec["rank"] = self.rank
        with self._lock:
            self.records.append(rec)
            fh = self._fh
        if self.path is None:
            return
        line = json.dumps(rec) + "\n"
        if fh is None:
            self._drop()
            return
        tls = self._tls
        if getattr(tls, "writing", False):
            # nested emission from inside the guarded write (fault.retry
            # counting its own retries) — defer; the outer write flushes
            tls.pending.append(line)
            return
        tls.writing, tls.pending = True, []
        try:
            self._write_guarded(line)
            while tls.pending:
                self._write_guarded(tls.pending.pop(0))
        finally:
            tls.writing = False

    def _write_guarded(self, line: str):
        """One retried JSONL write; exhaustion drops the line (counted),
        never raises."""
        from ..distributed import fault  # lazy: fault imports telemetry

        def write():
            fault.FaultPlan.active_on_io(self.path)
            with self._lock:
                if self._fh is None:
                    raise _SinkClosed  # closed under us: drop silently
                self._fh.write(line)
                self._fh.flush()

        try:
            fault.retry(write, attempts=self.IO_ATTEMPTS,
                        backoff_s=self.IO_BACKOFF_S,
                        max_backoff_s=self.IO_MAX_BACKOFF_S)
        except _SinkClosed:
            pass
        except OSError:
            self._drop()

    def _drop(self):
        """Account one dropped JSONL line. In-memory only BY DESIGN: a
        drop means the sink is failing, so emitting a record about it
        would recurse into the same failing write."""
        with self._lock:
            k = ("telemetry.dropped_records", ())
            self.counters[k] = self.counters.get(k, 0) + 1

    @property
    def dropped_records(self) -> int:
        return int(self.counters.get(("telemetry.dropped_records", ()), 0))

    @staticmethod
    def _key(name: str, labels: dict) -> tuple:
        return (name, tuple(sorted(labels.items()))) if labels else (name, ())

    def count(self, name: str, value: float = 1, **labels):
        """Increment a monotonic counter; the JSONL line records the
        increment, the in-memory total feeds the Prometheus export."""
        labels = {k: _jsonable(v) for k, v in labels.items()}
        k = self._key(name, labels)
        with self._lock:
            self.counters[k] = self.counters.get(k, 0) + value
        rec = {"kind": "counter", "ts": time.time(), "name": name,
               "value": _jsonable(value)}
        if labels:
            rec["labels"] = labels
        self._emit(rec)

    def gauge(self, name: str, value: float, **labels):
        """Set a point-in-time value (last write wins in exports)."""
        labels = {k: _jsonable(v) for k, v in labels.items()}
        with self._lock:
            self.gauges[self._key(name, labels)] = _jsonable(value)
        rec = {"kind": "gauge", "ts": time.time(), "name": name,
               "value": _jsonable(value)}
        if labels:
            rec["labels"] = labels
        self._emit(rec)

    def observe(self, name: str, value: float, **labels):
        """Record one histogram observation (summarized at export time)."""
        labels = {k: _jsonable(v) for k, v in labels.items()}
        k = self._key(name, labels)
        with self._lock:
            self.hists.setdefault(k, []).append(float(value))
        rec = {"kind": "observe", "ts": time.time(), "name": name,
               "value": _jsonable(value)}
        if labels:
            rec["labels"] = labels
        self._emit(rec)

    def event(self, name: str, **attrs):
        """A structured one-off event (autotune decision, resume, ...)."""
        self._emit({"kind": "event", "ts": time.time(), "name": name,
                    "attrs": {k: _jsonable(v) for k, v in attrs.items()}})

    def span(self, name: str, **attrs):
        """Time a ``with`` block; emits a span record on exit."""
        return _Span(self, name, {k: _jsonable(v) for k, v in attrs.items()})

    def span_end(self, name: str, wall_start: float, dur_s: float,
                 attrs: Optional[dict] = None):
        """Record an already-timed interval (for callers that cannot use
        the context-manager form, e.g. async completion callbacks)."""
        rec = {"kind": "span", "ts": wall_start, "name": name,
               "dur_s": float(dur_s), "tid": threading.get_ident() % 100000}
        if attrs:
            rec["attrs"] = {k: _jsonable(v) for k, v in attrs.items()}
        self._emit(rec)

    # -- lifecycle -----------------------------------------------------------
    def flush(self):
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self):
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None
