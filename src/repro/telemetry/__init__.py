"""Runtime telemetry for the stencil engine (metrics, spans, roofline gap).

Off by default and a true no-op when off: every instrumentation site in
the engine calls through the module-level singleton returned by
:func:`get`, which is the shared :data:`~repro.telemetry.collector.NULL`
object unless telemetry was enabled. Enabling:

* environment — ``REPRO_TELEMETRY=1`` (JSONL lands under
  ``$REPRO_TELEMETRY_DIR`` or ``./telemetry/``) or
  ``REPRO_TELEMETRY=/path/run.jsonl`` (explicit log path);
* code — ``telemetry.configure(path=...)``, or per-call via the
  ``telemetry=`` kwarg on ``solve_until`` (a ``Collector``, ``True``,
  ``False``, or ``None`` = inherit the global singleton).

The device program never changes: metrics derived from device values are
harvested only at host sync points that already exist (chunk/checkpoint
boundaries, final results) — see the package's test for the jaxpr proof.
"""
from __future__ import annotations

import atexit
import os
from typing import Optional, Union

from .collector import NULL, Collector, NullCollector, SCHEMA_VERSION

__all__ = [
    "Collector", "NullCollector", "NULL", "SCHEMA_VERSION",
    "get", "enabled", "configure", "configure_rank", "resolve", "reset",
    "count", "gauge", "observe", "event", "span",
]

_ACTIVE: Union[Collector, NullCollector, None] = None   # None = env not read yet


def _truthy(val: str) -> bool:
    return val.strip().lower() not in ("", "0", "false", "off", "no")


def _from_env() -> Union[Collector, NullCollector]:
    val = os.environ.get("REPRO_TELEMETRY", "")
    if not _truthy(val):
        return NULL
    if "/" in val or val.endswith(".jsonl"):
        path = val
    else:
        d = os.environ.get("REPRO_TELEMETRY_DIR", "telemetry")
        path = os.path.join(d, f"run_{os.getpid()}.jsonl")
    col = Collector(path, meta=_run_meta())
    atexit.register(col.close)
    return col


def _run_meta() -> dict:
    import sys

    meta = {"argv": sys.argv[:4]}
    try:
        import jax

        meta["backend"] = jax.default_backend()
    except Exception:
        pass
    return meta


def get() -> Union[Collector, NullCollector]:
    """The process-wide collector (the no-op singleton when disabled)."""
    global _ACTIVE
    if _ACTIVE is None:
        _ACTIVE = _from_env()
    return _ACTIVE


def enabled() -> bool:
    return get().enabled


def configure(path: Optional[str] = None, *, enabled: bool = True,
              meta: Optional[dict] = None,
              rank: Optional[int] = None) -> Union[Collector, NullCollector]:
    """Install (or disable) the global collector programmatically,
    overriding the environment. Returns the new active collector."""
    global _ACTIVE
    if _ACTIVE is not None and _ACTIVE.enabled:
        _ACTIVE.close()
    if not enabled:
        _ACTIVE = NULL
    else:
        _ACTIVE = Collector(path, meta={**_run_meta(), **(meta or {})},
                            rank=rank)
    return _ACTIVE


def configure_rank(rank: int,
                   path: Optional[str] = None) -> Union[Collector,
                                                        NullCollector]:
    """Per-rank stream for multi-process runs: when telemetry is enabled
    via the environment (or an explicit ``path`` is given), re-point the
    collector at ``rank_<rank>.jsonl`` beside the env-configured sink,
    with every record rank-stamped — the layout
    ``telemetry.report --merge 'rank_*.jsonl'`` interleaves. A no-op
    returning :data:`NULL` when telemetry is off (workers can call this
    unconditionally after rendezvous)."""
    if path is None:
        val = os.environ.get("REPRO_TELEMETRY", "")
        if not _truthy(val):
            return NULL
        if "/" in val or val.endswith(".jsonl"):
            d = os.path.dirname(val) or "."
        else:
            d = os.environ.get("REPRO_TELEMETRY_DIR", "telemetry")
        path = os.path.join(d, f"rank_{rank}.jsonl")
    return configure(path, meta={"rank": rank}, rank=rank)


def reset():
    """Forget any configured/env-resolved collector (tests)."""
    global _ACTIVE
    if _ACTIVE is not None and _ACTIVE.enabled:
        _ACTIVE.close()
    _ACTIVE = None


def resolve(telemetry) -> Union[Collector, NullCollector]:
    """Map a ``telemetry=`` kwarg to a collector: ``None`` inherits the
    global singleton, ``False`` forces the no-op, ``True`` forces an
    enabled collector (the global one if already enabled, else a fresh
    in-memory one), and a ``Collector`` is used as-is."""
    if telemetry is None:
        return get()
    if telemetry is False:
        return NULL
    if telemetry is True:
        g = get()
        return g if g.enabled else configure(None)
    return telemetry


def count(name, value=1, **labels):
    get().count(name, value, **labels)


def gauge(name, value, **labels):
    get().gauge(name, value, **labels)


def observe(name, value, **labels):
    get().observe(name, value, **labels)


def event(name, **attrs):
    get().event(name, **attrs)


def span(name, **attrs):
    return get().span(name, **attrs)
