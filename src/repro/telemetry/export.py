"""Exporters: Prometheus text exposition and Chrome/Perfetto traces.

The JSONL event log is the source of truth; both exports are pure
projections of it (or of a live :class:`~repro.telemetry.collector
.Collector`'s in-memory state), so they can be regenerated offline by
``python -m repro.telemetry.report`` long after the run.
"""
from __future__ import annotations

import json
import re
from typing import Iterable

import numpy as np

__all__ = ["prometheus_text", "chrome_trace_events", "write_chrome_trace"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(name: str) -> str:
    n = _NAME_RE.sub("_", name)
    return n if n.startswith("repro_") else f"repro_{n}"


def _prom_labels(labels) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_NAME_RE.sub("_", str(k))}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def prometheus_text(collector) -> str:
    """Render a collector's counters/gauges/histogram summaries in the
    Prometheus text exposition format (counters get ``_total``,
    histograms degrade to p50/p90/max summary gauges)."""
    lines: list[str] = []
    for (name, labels), v in sorted(collector.counters.items()):
        pn = _prom_name(name) + "_total"
        lines.append(f"# TYPE {pn} counter")
        lines.append(f"{pn}{_prom_labels(labels)} {v}")
    for (name, labels), v in sorted(collector.gauges.items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn}{_prom_labels(labels)} {v}")
    for (name, labels), samples in sorted(collector.hists.items()):
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} summary")
        for q, val in (("0.5", np.percentile(samples, 50)),
                       ("0.9", np.percentile(samples, 90)),
                       ("1", max(samples))):
            lab = dict(labels)
            lab["quantile"] = q
            lines.append(f"{pn}{_prom_labels(sorted(lab.items()))} {float(val)}")
        lines.append(f"{pn}_count{_prom_labels(labels)} {len(samples)}")
        lines.append(f"{pn}_sum{_prom_labels(labels)} {float(sum(samples))}")
    return "\n".join(lines) + "\n"


def chrome_trace_events(records: Iterable[dict]) -> list[dict]:
    """Project JSONL records onto Chrome ``trace_event`` objects
    (loadable by Perfetto / chrome://tracing): spans become complete
    ``"X"`` slices, counters and gauges become ``"C"`` counter tracks,
    events become instants."""
    out: list[dict] = []
    pid = 0
    counters: dict[str, float] = {}
    for rec in records:
        kind = rec.get("kind")
        ts_us = float(rec.get("ts", 0.0)) * 1e6
        if kind == "meta":
            pid = int(rec.get("pid", 0))
            out.append({"name": "process_name", "ph": "M", "pid": pid,
                        "args": {"name": "repro-telemetry"}})
        elif kind == "span":
            out.append({"name": rec["name"], "cat": "repro", "ph": "X",
                        "ts": ts_us, "dur": float(rec["dur_s"]) * 1e6,
                        "pid": pid, "tid": int(rec.get("tid", 0)),
                        "args": rec.get("attrs", {})})
        elif kind == "counter":
            counters[rec["name"]] = counters.get(rec["name"], 0.0) + rec["value"]
            out.append({"name": rec["name"], "cat": "repro", "ph": "C",
                        "ts": ts_us, "pid": pid,
                        "args": {rec["name"]: counters[rec["name"]]}})
        elif kind in ("gauge", "observe"):
            out.append({"name": rec["name"], "cat": "repro", "ph": "C",
                        "ts": ts_us, "pid": pid,
                        "args": {rec["name"]: rec["value"]}})
        elif kind == "event":
            out.append({"name": rec["name"], "cat": "repro", "ph": "i",
                        "ts": ts_us, "pid": pid, "tid": 0, "s": "g",
                        "args": rec.get("attrs", {})})
    return out


def write_chrome_trace(records: Iterable[dict], path: str) -> int:
    """Write the Perfetto-loadable trace JSON; returns the event count."""
    events = chrome_trace_events(records)
    with open(path, "w") as fh:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, fh)
    return len(events)
