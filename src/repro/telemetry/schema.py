"""JSONL event-log schema: documented record shapes + a strict validator.

Every line of a telemetry run log is one JSON object with a ``kind`` and
a float ``ts`` (unix seconds). Per kind:

``meta``     ``{kind, ts, schema, pid, ...}`` — first line of every log;
             ``schema`` is the integer :data:`~repro.telemetry.collector
             .SCHEMA_VERSION`.
``counter``  ``{kind, ts, name, value, labels?}`` — a monotonic increment.
``gauge``    ``{kind, ts, name, value, labels?}`` — point-in-time value.
``observe``  ``{kind, ts, name, value, labels?}`` — histogram sample.
``span``     ``{kind, ts, name, dur_s, tid?, attrs?}`` — a timed interval;
             ``ts`` is the wall-clock start, ``dur_s >= 0`` the duration.
``event``    ``{kind, ts, name, attrs}`` — structured one-off record.

``labels`` values must be JSON scalars; ``attrs`` any JSON value.
Multi-process streams additionally stamp every record with an integer
``rank`` (see ``telemetry.configure_rank``) — validators treat it like
any other extra key. The CI
telemetry job runs ``python -m repro.telemetry.schema RUN.jsonl`` over
every instrumented example run — an emitter drifting from this contract
fails the build, not the dashboard.
"""
from __future__ import annotations

import json
import sys
from typing import Mapping

KINDS = ("meta", "counter", "gauge", "observe", "span", "event")
_SCALAR = (bool, int, float, str, type(None))

__all__ = ["SchemaError", "validate_record", "validate_file", "load_records"]


class SchemaError(ValueError):
    pass


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_record(rec, lineno: int | None = None) -> str:
    """Validate one decoded record; returns its kind or raises
    :class:`SchemaError` naming the offending line/field."""
    where = f"line {lineno}: " if lineno is not None else ""
    if not isinstance(rec, Mapping):
        raise SchemaError(f"{where}record is not a JSON object")
    kind = rec.get("kind")
    if kind not in KINDS:
        raise SchemaError(f"{where}unknown kind {kind!r} (expected one of {KINDS})")
    if not _num(rec.get("ts")):
        raise SchemaError(f"{where}{kind}: 'ts' must be a number")
    if kind == "meta":
        if not isinstance(rec.get("schema"), int):
            raise SchemaError(f"{where}meta: integer 'schema' required")
        return kind
    if not isinstance(rec.get("name"), str) or not rec["name"]:
        raise SchemaError(f"{where}{kind}: non-empty string 'name' required")
    if kind in ("counter", "gauge", "observe"):
        if not _num(rec.get("value")):
            raise SchemaError(f"{where}{kind} {rec['name']!r}: numeric 'value' required")
        labels = rec.get("labels", {})
        if not isinstance(labels, Mapping) or any(
                not isinstance(v, _SCALAR) for v in labels.values()):
            raise SchemaError(f"{where}{kind} {rec['name']!r}: labels must map to scalars")
    elif kind == "span":
        if not _num(rec.get("dur_s")) or rec["dur_s"] < 0:
            raise SchemaError(f"{where}span {rec['name']!r}: 'dur_s' must be >= 0")
        if not isinstance(rec.get("attrs", {}), Mapping):
            raise SchemaError(f"{where}span {rec['name']!r}: attrs must be an object")
    elif kind == "event":
        if not isinstance(rec.get("attrs", {}), Mapping):
            raise SchemaError(f"{where}event {rec['name']!r}: attrs must be an object")
    return kind


def load_records(path: str) -> list[dict]:
    """Parse a JSONL log (no validation)."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def validate_file(path: str) -> dict[str, int]:
    """Validate every line of a JSONL log; returns per-kind counts."""
    counts: dict[str, int] = {}
    with open(path) as fh:
        for i, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise SchemaError(f"line {i}: invalid JSON ({e})") from None
            kind = validate_record(rec, i)
            counts[kind] = counts.get(kind, 0) + 1
    if counts.get("meta", 0) < 1:
        raise SchemaError("log has no 'meta' header record")
    return counts


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: python -m repro.telemetry.schema RUN.jsonl [...]",
              file=sys.stderr)
        return 2
    for path in argv:
        try:
            counts = validate_file(path)
        except (OSError, SchemaError) as e:
            print(f"{path}: INVALID — {e}", file=sys.stderr)
            return 1
        total = sum(counts.values())
        detail = ", ".join(f"{k}={counts[k]}" for k in sorted(counts))
        print(f"{path}: OK ({total} records: {detail})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
