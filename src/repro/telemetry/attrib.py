"""Roofline-gap attribution: measured per-step seconds vs the IR cost model.

The paper's argument is T_eff against the bandwidth roofline; a production
solve should therefore report, per kernel launch configuration,

    t_eff_measured  = A_eff / t_step_measured            (bytes/s)
    t_eff_model     = A_eff / t_step_model               (bytes/s)
    roofline_fraction = t_eff_measured / t_eff_model
                      = t_step_model / t_step_measured

where ``t_step_model`` comes from ``StencilCostModel.predict_per_step_s``
(max of the memory and compute roofline terms for the launch's actual
tile / temporal-blocking depth / march axis / check cadence). A fraction
near 1.0 means the launch runs at its modeled roofline; 0.58 means "this
kernel leaves 42% of its modeled throughput on the table" — a first-class
metric instead of an offline bench artifact.

The hardware spec defaults per jax backend (TPU -> v5e constants, GPU ->
A100, CPU -> a cached STREAM-copy measurement) and can be pinned with
``REPRO_TELEMETRY_BW_GBS`` / ``REPRO_TELEMETRY_FLOPS_G`` so CI numbers
don't depend on a noisy runner measurement.
"""
from __future__ import annotations

import os
from typing import Optional

__all__ = ["default_hardware", "attribute", "reset_hardware_cache"]

_HW_CACHE: list = []      # [HardwareSpec] once resolved


def reset_hardware_cache():
    _HW_CACHE.clear()


def default_hardware():
    """The roofline peak for the current process (cached after first use)."""
    if _HW_CACHE:
        return _HW_CACHE[0]
    from ..core import teff

    bw_env = os.environ.get("REPRO_TELEMETRY_BW_GBS")
    fl_env = os.environ.get("REPRO_TELEMETRY_FLOPS_G")
    if bw_env:
        bw = float(bw_env) * 1e9
        # CPU-ish ridge point unless pinned: ~8 flop/byte
        flops = float(fl_env) * 1e9 if fl_env else 8.0 * bw
        hw = teff.HardwareSpec("pinned", peak_bw=bw, peak_flops=flops)
    else:
        try:
            import jax

            backend = jax.default_backend()
        except Exception:
            backend = "cpu"
        if backend == "tpu":
            hw = teff.TPU_V5E
        elif backend == "gpu":
            hw = teff.A100_SXM4
        else:
            bw = teff.measure_host_bandwidth()
            flops = float(fl_env) * 1e9 if fl_env else 8.0 * bw
            hw = teff.HardwareSpec("host-cpu (STREAM-measured)",
                                   peak_bw=bw, peak_flops=flops)
    _HW_CACHE.append(hw)
    return hw


def attribute(col, kernel_name: str, per_step_s: float, cost, *,
              nsteps: int = 1, tile=None, march_axis: Optional[int] = None,
              check_every: Optional[int] = None, fused_checks: bool = True,
              hw=None) -> dict:
    """Emit the roofline-gap record for one measured launch config.

    ``cost`` is a :class:`~repro.ir.cost.StencilCostModel`; ``tile``
    defaults to the whole grid (the jnp backend's effective tile).
    Emits gauges ``roofline.t_eff_measured_GBs`` / ``..._model_GBs`` /
    ``roofline.fraction`` labeled by kernel, plus one ``roofline`` event
    carrying the full context; returns the computed dict."""
    if per_step_s <= 0:
        return {}
    hw = hw or default_hardware()
    tile = tuple(tile) if tile is not None else tuple(cost.shape)
    a = cost.a_eff_bytes(nsteps)
    t_model = cost.predict_per_step_s(tile, nsteps, hw,
                                      march_axis=march_axis,
                                      check_every=check_every,
                                      fused_checks=fused_checks)
    t_eff_measured = a / per_step_s
    t_eff_model = a / t_model if t_model > 0 else float("inf")
    frac = t_model / per_step_s
    out = {"kernel": kernel_name, "per_step_s": per_step_s,
           "model_per_step_s": t_model, "a_eff_bytes": a,
           "t_eff_measured": t_eff_measured, "t_eff_model": t_eff_model,
           "roofline_fraction": frac, "hw": hw.name,
           "peak_bw_GBs": hw.peak_bw / 1e9, "tile": tile, "nsteps": nsteps,
           "march_axis": march_axis, "check_every": check_every}
    col.gauge("roofline.t_eff_measured_GBs", t_eff_measured / 1e9,
              kernel=kernel_name)
    col.gauge("roofline.t_eff_model_GBs", t_eff_model / 1e9,
              kernel=kernel_name)
    col.gauge("roofline.fraction", frac, kernel=kernel_name)
    col.event("roofline", **out)
    return out
