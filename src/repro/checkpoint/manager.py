"""Checkpoint manager: atomic, versioned, async, elastic-restorable.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json            — tree structure, shapes, dtypes, step
        t_000000.npy ...         — one .npy per tensor (gathered global value)
    <root>/LATEST                — atomically updated pointer

Properties engineered for the 1000-node story:
  * atomicity — tensors land in ``step_X.tmp/`` and the directory is
    os.replace()'d into place, then LATEST is swapped; a crash mid-write
    never corrupts the previous checkpoint;
  * async — `save(..., blocking=False)` snapshots to host RAM
    (device_get) and writes on a background thread so the train loop
    only stalls for the device->host copy;
  * elastic restore — tensors are stored as *global* logical arrays, so
    restore just applies the new mesh's NamedSharding (device_put).  At
    real scale the same manifest format shards each tensor into per-host
    files (`shard_spec` records how); restore then uses
    jax.make_array_from_callback so each host reads only its bytes
    (distributed.elastic.from_host_callback).
  * keep-k retention + best-effort fsync.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    from ..compat import tree_flatten_with_path

    flat, treedef = tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat]
    vals = [v for _, v in flat]
    return paths, vals, treedef


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------------- save ----------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             extra: Optional[dict] = None) -> None:
        self.wait()
        paths, vals, _ = _flatten_with_paths(tree)
        host_vals = [np.asarray(jax.device_get(v)) for v in vals]  # snapshot

        def write():
            try:
                self._write(step, paths, host_vals, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _write(self, step, paths, host_vals, extra):
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "tensors": []}
        for i, (p, v) in enumerate(zip(paths, host_vals)):
            fn = f"t_{i:06d}.npy"
            np.save(os.path.join(tmp, fn), v)
            manifest["tensors"].append(
                {"path": p, "file": fn, "shape": list(v.shape),
                 "dtype": str(v.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        latest_tmp = os.path.join(self.root, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
        os.replace(latest_tmp, os.path.join(self.root, "LATEST"))
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from e

    # ---------------- restore ----------------
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.root, "LATEST")
        if os.path.exists(ptr):
            with open(ptr) as f:
                name = f.read().strip()
            if os.path.isdir(os.path.join(self.root, name)):
                return int(name[5:])
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like_tree``; if ``shardings``
        (matching pytree of NamedSharding) is given, place each tensor
        accordingly (elastic restore onto any mesh)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {t["path"]: t for t in manifest["tensors"]}
        paths, vals, treedef = _flatten_with_paths(like_tree)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(vals))
        out = []
        for p, like, sh in zip(paths, vals, shard_flat):
            t = by_path[p]
            arr = np.load(os.path.join(d, t["file"]))
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"{p}: checkpoint shape {arr.shape} != {like.shape}")
            arr = arr.astype(like.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else
                       jax.device_put(arr))
        return treedef.unflatten(out), manifest["extra"] | {"step": manifest["step"]}
