"""Checkpoint manager: atomic, versioned, async, elastic-restorable.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json            — tree structure, shapes, dtypes, step
        t_000000.npy ...         — one .npy per tensor (gathered global value)
    <root>/LATEST                — atomically updated pointer

Properties engineered for the 1000-node story:
  * atomicity — tensors land in ``step_X.tmp/`` and the directory is
    os.replace()'d into place, then LATEST is swapped; every tensor
    file, the manifest AND the parent directory entry are fsync'd
    before the swap, so a crash (or power cut) mid-write never corrupts
    the previous checkpoint and a completed swap is durable;
  * async — `save(..., blocking=False)` snapshots to host RAM
    (device_get) and writes on a background thread so the solve loop
    only stalls for the device->host copy;
  * elastic restore — tensors are stored as *global* logical arrays, so
    restore just applies the new mesh's NamedSharding (device_put).  At
    real scale the same manifest format shards each tensor into per-host
    files (`shard_spec` records how); restore then uses
    jax.make_array_from_callback so each host reads only its bytes
    (distributed.elastic.from_host_callback).
  * validation + fallback — restore() verifies the manifest against the
    tensor files and the requested tree (shape/dtype/short-read);
    a corrupt or truncated checkpoint raises :class:`CheckpointError`
    and, when the step was implicit (LATEST), restore falls back to the
    previous intact step;
  * keep-k retention that never deletes the step LATEST points at, and
    retry-with-backoff around every filesystem touch
    (:func:`repro.distributed.fault.retry` — shared filesystems hiccup).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from .. import telemetry as _telemetry
from ..distributed import fault

__all__ = ["CheckpointError", "CheckpointManager"]


class CheckpointError(ValueError):
    """A checkpoint is unreadable, torn, or inconsistent with the
    requested restore tree."""


def _flatten_with_paths(tree):
    from ..compat import tree_flatten_with_path

    flat, treedef = tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
             for kp, _ in flat]
    vals = [v for _, v in flat]
    return paths, vals, treedef


def _fsync_path(path: str) -> None:
    """fsync a file OR directory entry (durability of the rename)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, retry_attempts: int = 4,
                 retry_backoff_s: float = 0.05):
        self.root = root
        self.keep = keep
        self.retry_attempts = retry_attempts
        self.retry_backoff_s = retry_backoff_s
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def _retry(self, fn):
        return fault.retry(fn, attempts=self.retry_attempts,
                           backoff_s=self.retry_backoff_s)

    def step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    # ---------------- save ----------------
    def save(self, step: int, tree: Any, blocking: bool = True,
             extra: Optional[dict] = None) -> None:
        """Write checkpoint ``step``. ``blocking=False`` returns as soon
        as the device->host snapshot completes; the filesystem write runs
        on a daemon thread and any failure surfaces on the next
        ``save``/``wait`` call."""
        self.wait()
        col = _telemetry.get()
        paths, vals, _ = _flatten_with_paths(tree)
        with col.span("checkpoint.snapshot", step=step):
            host_vals = [np.asarray(jax.device_get(v)) for v in vals]
        nbytes = sum(v.nbytes for v in host_vals)

        def write():
            w0, t0 = time.time(), time.perf_counter()
            try:
                self._write(step, paths, host_vals, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._error = e
                return
            if col.enabled:   # emitted from the writer thread when async
                col.span_end("checkpoint.write", w0,
                             time.perf_counter() - t0,
                             {"step": step, "bytes": nbytes,
                              "blocking": blocking})
                col.count("checkpoint.saves", 1)
                col.count("checkpoint.bytes_written", nbytes)

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _write(self, step, paths, host_vals, extra):
        final = self.step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "tensors": []}
        for i, (p, v) in enumerate(zip(paths, host_vals)):
            fn = f"t_{i:06d}.npy"
            fpath = os.path.join(tmp, fn)

            def write_tensor(fpath=fpath, v=v):
                fault.FaultPlan.active_on_io(fpath)
                with open(fpath, "wb") as f:
                    np.save(f, v)
                    f.flush()
                    os.fsync(f.fileno())

            self._retry(write_tensor)
            manifest["tensors"].append(
                {"path": p, "file": fn, "shape": list(v.shape),
                 "dtype": str(v.dtype), "nbytes": int(v.nbytes)})

        def write_manifest():
            fault.FaultPlan.active_on_io(tmp)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())

        self._retry(write_manifest)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # durability of the rename itself: fsync the parent dir entry
        # BEFORE LATEST starts pointing at it
        self._retry(lambda: _fsync_path(self.root))
        latest_tmp = os.path.join(self.root, "LATEST.tmp")

        def swap_latest():
            fault.FaultPlan.active_on_io(latest_tmp)
            with open(latest_tmp, "w") as f:
                f.write(os.path.basename(final))
                f.flush()
                os.fsync(f.fileno())
            os.replace(latest_tmp, os.path.join(self.root, "LATEST"))
            _fsync_path(self.root)

        self._retry(swap_latest)
        plan = fault.FaultPlan.active()
        if plan is not None:
            plan.after_save(final)
        self._gc()

    def _gc(self):
        """keep-k retention. The step LATEST points at is never deleted,
        even when a fallback restore moved LATEST behind newer (broken)
        step directories."""
        if self.keep <= 0:
            return
        steps = self.list_steps()
        latest = self._latest_pointer()
        for s in steps[: -self.keep]:
            if s == latest:
                continue
            shutil.rmtree(self.step_dir(s), ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from e

    # ---------------- restore ----------------
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _latest_pointer(self) -> Optional[int]:
        """The step LATEST names (None when absent/dangling)."""
        ptr = os.path.join(self.root, "LATEST")
        if os.path.exists(ptr):
            try:
                with open(ptr) as f:
                    name = f.read().strip()
                if os.path.isdir(os.path.join(self.root, name)):
                    return int(name[5:])
            except (OSError, ValueError):
                return None
        return None

    def latest_step(self) -> Optional[int]:
        step = self._latest_pointer()
        if step is not None:
            return step
        steps = self.list_steps()
        return steps[-1] if steps else None

    def _load_manifest(self, step: int) -> dict:
        d = self.step_dir(step)
        mpath = os.path.join(d, "manifest.json")
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except FileNotFoundError as e:
            raise CheckpointError(f"step {step}: no manifest at {mpath}") from e
        except (json.JSONDecodeError, OSError) as e:
            raise CheckpointError(f"step {step}: unreadable manifest "
                                  f"({e})") from e
        for key in ("step", "tensors"):
            if key not in manifest:
                raise CheckpointError(f"step {step}: manifest missing "
                                      f"{key!r}")
        return manifest

    def _restore_step(self, step: int, like_tree: Any,
                      shardings: Any = None) -> tuple[Any, dict]:
        d = self.step_dir(step)
        manifest = self._load_manifest(step)
        by_path = {t["path"]: t for t in manifest["tensors"]}
        paths, vals, treedef = _flatten_with_paths(like_tree)
        shard_flat = (treedef.flatten_up_to(shardings)
                      if shardings is not None else [None] * len(vals))
        out = []
        for p, like, sh in zip(paths, vals, shard_flat):
            if p not in by_path:
                raise CheckpointError(
                    f"step {step}: tree leaf {p!r} absent from checkpoint "
                    f"(has {sorted(by_path)})")
            t = by_path[p]
            fpath = os.path.join(d, t["file"])
            try:
                arr = self._retry(lambda fpath=fpath: np.load(fpath))
            except (OSError, ValueError, EOFError) as e:
                raise CheckpointError(
                    f"step {step}: tensor {p!r} unreadable/truncated "
                    f"({t['file']}: {e})") from e
            # torn-storage guard: the bytes on disk must match what the
            # manifest recorded at write time
            if tuple(arr.shape) != tuple(t.get("shape", arr.shape)):
                raise CheckpointError(
                    f"step {step}: tensor {p!r} shape {tuple(arr.shape)} "
                    f"!= manifest {tuple(t['shape'])} (torn write?)")
            if "dtype" in t and str(arr.dtype) != t["dtype"]:
                raise CheckpointError(
                    f"step {step}: tensor {p!r} dtype {arr.dtype} != "
                    f"manifest {t['dtype']}")
            if tuple(arr.shape) != tuple(np.shape(like)):
                raise CheckpointError(
                    f"step {step}: tensor {p!r} shape {tuple(arr.shape)} "
                    f"does not match restore target {tuple(np.shape(like))}"
                    " — wrong grid/config for this checkpoint?")
            arr = arr.astype(np.asarray(like).dtype
                             if not hasattr(like, "dtype") else like.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None else
                       jax.device_put(arr))
        return (treedef.unflatten(out),
                dict(manifest.get("extra") or {}, step=manifest["step"]))

    def restore(self, like_tree: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like_tree``; if ``shardings``
        (matching pytree of NamedSharding) is given, place each tensor
        accordingly (elastic restore onto any mesh).

        With ``step=None`` the newest step is used, and a corrupt or
        truncated checkpoint falls back to the previous intact one
        (the torn step is reported in the returned extra dict under
        ``"skipped_corrupt"``). An explicitly requested ``step`` never
        falls back — its :class:`CheckpointError` propagates."""
        if step is not None:
            if not os.path.isdir(self.step_dir(step)):
                raise FileNotFoundError(
                    f"no checkpoint step {step} under {self.root}")
            with _telemetry.get().span("checkpoint.restore", step=step):
                return self._restore_step(step, like_tree, shardings)
        candidates = self.list_steps()
        latest = self.latest_step()
        if latest is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        # newest-first from LATEST (fallback walks strictly older steps)
        candidates = [s for s in reversed(candidates) if s <= latest]
        skipped: list[tuple[int, str]] = []
        col = _telemetry.get()
        for s in candidates:
            try:
                with col.span("checkpoint.restore", step=s):
                    tree, extra = self._restore_step(s, like_tree, shardings)
            except CheckpointError as e:
                skipped.append((s, str(e)))
                col.count("checkpoint.corrupt_skipped", 1)
                continue
            if skipped:
                extra["skipped_corrupt"] = skipped
            if col.enabled:
                col.count("checkpoint.restores", 1)
                col.count("checkpoint.bytes_read",
                          sum(int(getattr(v, "nbytes", 0))
                              for v in jax.tree.leaves(tree)))
            return tree, extra
        raise CheckpointError(
            f"every checkpoint under {self.root} failed validation: "
            + "; ".join(msg for _, msg in skipped))
