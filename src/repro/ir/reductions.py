"""Named reduction epilogues fused into stencil launches.

The paper's headline solvers are *iterative*: pseudo-transient and
explicit steppers that check ``err = max|dT|`` (or an L2 residual / a
conserved quantity) every few sweeps. A separate whole-array norm pass
re-reads the operand fields — for the 2-field diffusion check it roughly
doubles the memory traffic of a check step — and the host round-trip on
the result serializes the step loop. A :class:`Reduction` instead rides
*inside* the launch: each grid tile folds its domain-masked partial into
a tiny per-tile partials output while the updated block is still in
VMEM/registers, and a scalar combine over the partials finishes the
value — no second HBM pass, no host sync.

Kinds (all elementwise-map then associative-combine):

  * ``max_abs(F)``          — ``max |F|``            (residual / stability)
  * ``max_abs_diff(F, G)``  — ``max |F - G|``        (convergence check)
  * ``sum(F)``              — ``sum F``              (conserved quantity)
  * ``sum_sq(F)``           — ``sum F^2``            (L2 norm sq. / mass)
  * ``finite(F)``           — ``max 1[!isfinite F]`` (health guard: 0 iff
    every element is finite, 1 as soon as any NaN/Inf appears)
  * ``nan_count(F)``        — ``sum 1[!isfinite F]`` (how many cells blew up)

The ``finite``/``nan_count`` kinds fold a *non-finite indicator* — the
elementwise map turns NaN/Inf into exactly ``1.0`` and everything else
into ``0.0`` BEFORE the combine, so (unlike a raw ``max``) the folded
scalar is NaN-free and safe to branch on inside a ``lax.while_loop``.
They are the device-resident numerical health guard of the serving
layer (``repro.serve`` quarantines samples whose guard goes positive),
but work standalone like any other kind.

Operands name *fields of the launch*: an output operand reduces the
freshly written values, an input operand the current (boundary-source)
values — e.g. ``max_abs_diff(T2, T)`` is exactly ``max|T2_new - T|``.
Operands must be collocated (no staggering): the per-tile domain masks
of the partials fold over base-extent blocks.

Cross-program caveat (the reassociation rule): reductions reassociate,
so the fused value is *bitwise* reproducible only within one compiled
program. Comparisons against a separately compiled post-pass (or the
other backend) must use ``allclose`` tolerances, never equality.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

__all__ = ["Reduction", "normalize_reductions", "REDUCTION_KINDS"]

# kind -> (arity, combine): combine is "max" or "sum" (both associative
# and commutative — the partials may be folded in any tile order).
REDUCTION_KINDS = {
    "max_abs": (1, "max"),
    "max_abs_diff": (2, "max"),
    "sum": (1, "sum"),
    "sum_sq": (1, "sum"),
    "finite": (1, "max"),       # max of the non-finite indicator
    "nan_count": (1, "sum"),    # count of non-finite cells
}

# kinds whose elementwise map is the non-finite indicator
_INDICATOR_KINDS = ("finite", "nan_count")


@dataclasses.dataclass(frozen=True)
class Reduction:
    """One named reduction: ``kind`` over ``field`` (and ``other``)."""

    kind: str
    field: str
    other: str | None = None

    def __post_init__(self):
        if self.kind not in REDUCTION_KINDS:
            raise ValueError(
                f"reduction kind {self.kind!r} must be one of "
                f"{tuple(REDUCTION_KINDS)}"
            )
        arity, _ = REDUCTION_KINDS[self.kind]
        if arity == 2 and self.other is None:
            raise ValueError(
                f"reduction {self.kind!r} takes two operands, e.g. "
                f"Reduction('{self.kind}', 'T2', 'T')"
            )
        if arity == 1 and self.other is not None:
            raise ValueError(
                f"reduction {self.kind!r} takes one operand; got second "
                f"operand {self.other!r}"
            )

    @property
    def operands(self) -> tuple[str, ...]:
        return (self.field,) if self.other is None else (self.field,
                                                         self.other)

    @property
    def combine(self) -> str:
        return REDUCTION_KINDS[self.kind][1]

    # -- realizations -------------------------------------------------------
    def map_element(self, x, y=None):
        """The elementwise pre-combine map. Works on concrete arrays AND
        on :class:`..ir.sym.SymArray` windows (abs/sub/mul only), so the
        IR can trace the check expression for flop/byte accounting with
        the same code the backends execute."""
        if self.kind == "max_abs":
            return abs(x)
        if self.kind == "max_abs_diff":
            return abs(x - y)
        if self.kind == "sum":
            return x
        if self.kind in _INDICATOR_KINDS:
            # Non-finite indicator: 1.0 where NaN/Inf, else 0.0. On the
            # symbolic trace the indicator costs one compare-class op per
            # element with the operand's own footprint — modeled as the
            # |.|-node (same reads, adds-class flop) since SymArray has
            # no isfinite.
            if hasattr(x, "flop_kind"):      # SymArray (IR trace)
                return abs(x)
            import jax.numpy as jnp

            return (~jnp.isfinite(x)).astype(
                x.dtype if jnp.issubdtype(x.dtype, jnp.floating)
                else jnp.float32)
        return x * x  # sum_sq

    def fold(self, mapped, mask=None):
        """Fold one tile's mapped values into a scalar partial. Cells
        outside ``mask`` contribute the neutral element (0 works for both
        combines here: the max kinds fold |.| >= 0)."""
        import jax.numpy as jnp

        if mask is not None:
            mapped = jnp.where(mask, mapped, jnp.zeros_like(mapped))
        return jnp.max(mapped) if self.combine == "max" else jnp.sum(mapped)

    def finish(self, partials):
        """Combine per-tile partials into the launch's scalar."""
        import jax.numpy as jnp

        return (jnp.max(partials) if self.combine == "max"
                else jnp.sum(partials))

    def all_reduce(self, value, mesh_axes):
        """Finish across ranks: ONE pmax/psum over the rank partials
        (rank-local fused values ARE valid partials — the combines are
        associative)."""
        import jax

        axes = tuple(mesh_axes)
        return (jax.lax.pmax(value, axes) if self.combine == "max"
                else jax.lax.psum(value, axes))

    def describe(self) -> str:
        return (f"{self.kind}({self.field})" if self.other is None
                else f"{self.kind}({self.field}, {self.other})")


def _parse(spec: str) -> Reduction:
    """``"max_abs_diff(T2, T)"``-style compact form."""
    s = spec.strip()
    if "(" not in s or not s.endswith(")"):
        raise ValueError(
            f"cannot parse reduction spec {spec!r}; expected "
            "'kind(field)' or 'kind(field, other)'"
        )
    kind, rest = s.split("(", 1)
    ops = [p.strip() for p in rest[:-1].split(",") if p.strip()]
    if not 1 <= len(ops) <= 2:
        raise ValueError(f"reduction spec {spec!r} needs 1 or 2 operands")
    return Reduction(kind.strip(), ops[0],
                     ops[1] if len(ops) == 2 else None)


def normalize_reductions(
    reductions: Mapping[str, object] | None,
    field_names: Sequence[str] | None = None,
) -> dict[str, Reduction]:
    """Normalize ``{name: Reduction | "kind(field[, other])"}``. With
    ``field_names`` the operands are validated against the launch's
    field set (call sites that know it yet — the decorator does not)."""
    out: dict[str, Reduction] = {}
    for name, spec in (reductions or {}).items():
        r = spec if isinstance(spec, Reduction) else _parse(str(spec))
        if field_names is not None:
            for op in r.operands:
                if op not in field_names:
                    raise ValueError(
                        f"reduction {name!r} = {r.describe()} reads "
                        f"{op!r}, which is not a field of this launch "
                        f"(fields: {tuple(field_names)})"
                    )
        out[str(name)] = r
    return out
