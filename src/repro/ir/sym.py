"""Symbolic window objects — the tracing substrate of the stencil IR.

A :class:`SymArray` stands in for a field (or any expression derived from
one) during a single abstract evaluation of the user's update function.
It implements exactly the protocol the ``core.fd`` relative-slice
operators rely on — ``__getitem__`` with unit-stride slices plus
elementwise arithmetic — and records, per upstream field and axis, the
closed interval of *index offsets* the expression reads:

    element ``j`` (in the expression's own frame) reads field cells
    ``j + d`` for every ``d`` in ``reads[field][axis]``.

Slicing shifts the interval (``A[1:]``'s element ``j`` reads ``A[j+1]``);
combining two expressions unions the intervals. That is the accessor-
range analysis of generic stencil libraries (Bianco & Varetto), done on
plain Python objects in one pass — no jax tracing involved.

Unsupported constructs (integer indexing, strided slices, broadcasting
against mismatched shapes, ``jnp.*`` calls on symbolic values) raise
:class:`TraceError`; callers with a declared ``radius`` fall back to the
legacy symmetric-halo path, callers relying on inference get a pointed
error.
"""
from __future__ import annotations

import math
from typing import Mapping

__all__ = ["SymArray", "TraceError", "field"]


class TraceError(ValueError):
    """The update function used a construct the symbolic tracer cannot
    analyze. With a declared ``radius`` the engine falls back to the
    legacy symmetric-halo geometry; without one this propagates."""


Interval = tuple[int, int]
Reads = Mapping[str, tuple[Interval, ...]]

_FLOPS = {"add": "adds", "sub": "adds", "neg": "adds", "abs": "adds",
          "mul": "muls", "div": "divs", "pow": "pows"}


def _merge_reads(a: Reads, b: Reads, ndim: int) -> dict:
    out = {k: tuple(v) for k, v in a.items()}
    for f, iv in b.items():
        if f not in out:
            out[f] = tuple(iv)
        else:
            out[f] = tuple(
                (min(x[0], y[0]), max(x[1], y[1])) for x, y in zip(out[f], iv)
            )
    return out


def _is_scalar(v) -> bool:
    if isinstance(v, (int, float, complex, bool)):
        return True
    ndim = getattr(v, "ndim", None)
    return ndim == 0  # 0-d numpy/jax scalars combine like python numbers


class SymArray:
    """One node of the traced stencil expression graph."""

    __slots__ = ("op", "shape", "reads", "children", "scalar")
    # Keep jnp from trying to __iter__/__array__ us into oblivion.
    __array_priority__ = 1000

    def __init__(self, op: str, shape: tuple[int, ...], reads: Reads,
                 children: tuple = (), scalar=None):
        self.op = op
        self.shape = tuple(int(s) for s in shape)
        self.reads = {k: tuple(tuple(p) for p in v) for k, v in reads.items()}
        self.children = children
        self.scalar = scalar

    # -- numpy-ish surface --------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    def __repr__(self):
        return f"SymArray({self.op}, shape={self.shape})"

    def __bool__(self):
        raise TraceError(
            "symbolic stencil values have no truth value — control flow on "
            "field data cannot be traced (use jnp.where-free arithmetic or "
            "declare radius= explicitly)"
        )

    def __iter__(self):
        raise TraceError("symbolic stencil values are not iterable")

    # -- slicing ------------------------------------------------------------
    def __getitem__(self, idx):
        if not isinstance(idx, tuple):
            idx = (idx,)
        if any(i is Ellipsis for i in idx):
            n_given = sum(1 for i in idx if i is not Ellipsis)
            fill = (slice(None),) * (self.ndim - n_given)
            pos = idx.index(Ellipsis)
            idx = idx[:pos] + fill + idx[pos + 1:]
        idx = idx + (slice(None),) * (self.ndim - len(idx))
        if len(idx) > self.ndim:
            raise TraceError(
                f"too many indices for symbolic array of rank {self.ndim}"
            )
        shape, shifts = [], []
        for a, (sl, n) in enumerate(zip(idx, self.shape)):
            if not isinstance(sl, slice):
                raise TraceError(
                    f"unsupported index {sl!r} along axis {a} — the stencil "
                    "IR traces unit-stride slices only (no integer/fancy "
                    "indexing inside @parallel update functions)"
                )
            start, stop, step = sl.indices(n)
            if step != 1:
                raise TraceError(
                    f"strided slice (step={step}) along axis {a} is outside "
                    "the relative-slice protocol"
                )
            ext = stop - start
            if ext <= 0:
                raise TraceError(
                    f"slice {sl} along axis {a} of extent {n} is empty"
                )
            shape.append(ext)
            shifts.append(start)
        reads = {
            f: tuple((lo + sh, hi + sh) for (lo, hi), sh in zip(iv, shifts))
            for f, iv in self.reads.items()
        }
        return SymArray("slice", tuple(shape), reads, (self,))

    # -- arithmetic ---------------------------------------------------------
    def _binary(self, other, op: str, reflected: bool = False):
        if isinstance(other, SymArray):
            if other.shape != self.shape:
                raise TraceError(
                    f"shape mismatch in '{op}': {self.shape} vs "
                    f"{other.shape} — broadcasting between differently-"
                    "shaped stencil expressions is outside the relative-"
                    "slice protocol"
                )
            reads = _merge_reads(self.reads, other.reads, self.ndim)
            kids = (other, self) if reflected else (self, other)
            return SymArray(op, self.shape, reads, kids)
        if _is_scalar(other):
            return SymArray(op, self.shape, self.reads, (self,), scalar=other)
        raise TraceError(
            f"cannot combine symbolic stencil value with {type(other).__name__} "
            "in '" + op + "' — arrays must enter the kernel as field "
            "arguments to be traced"
        )

    def __add__(self, o):
        return self._binary(o, "add")

    def __radd__(self, o):
        return self._binary(o, "add", reflected=True)

    def __sub__(self, o):
        return self._binary(o, "sub")

    def __rsub__(self, o):
        return self._binary(o, "sub", reflected=True)

    def __mul__(self, o):
        return self._binary(o, "mul")

    def __rmul__(self, o):
        return self._binary(o, "mul", reflected=True)

    def __truediv__(self, o):
        return self._binary(o, "div")

    def __rtruediv__(self, o):
        return self._binary(o, "div", reflected=True)

    def __pow__(self, o):
        return self._binary(o, "pow")

    def __rpow__(self, o):
        return self._binary(o, "pow", reflected=True)

    def __neg__(self):
        return SymArray("neg", self.shape, self.reads, (self,))

    def __abs__(self):
        # |.| is what the reduction epilogues (max_abs, max_abs_diff)
        # trace through; counted with the adds (sign ops are ~free).
        return SymArray("abs", self.shape, self.reads, (self,))

    def __pos__(self):
        return self

    def astype(self, _dtype):
        return self

    def _no_compare(self, *_):
        raise TraceError(
            "comparisons on symbolic stencil values are not traceable"
        )

    __lt__ = __le__ = __gt__ = __ge__ = _no_compare

    def flop_kind(self) -> str | None:
        """Flop-counter category of this node (None for free ops)."""
        return _FLOPS.get(self.op)


def field(name: str, shape) -> SymArray:
    """A symbolic leaf: element ``j`` of field ``name`` reads exactly
    field cell ``j`` (offset interval ``[0, 0]`` per axis)."""
    shape = tuple(int(s) for s in shape)
    return SymArray("leaf", shape, {name: ((0, 0),) * len(shape)})
