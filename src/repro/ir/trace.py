"""Trace a stencil update function into a :class:`StencilIR`.

One abstract evaluation with :mod:`.sym` window objects yields, per
output field, the expression graph plus everything the engine used to
take from the hand-declared ``radius``:

  * per-output **write geometry** — per-axis ``all``/``inn`` mode and
    interior-ring depth, derived from the traced update's shape exactly
    the way the backends derive it from concrete updates;
  * per-(output, field) **read intervals** relative to the write
    position;
  * per-field **exchange depths** (``field_halo``) — how deep a rank's
    ghost layers must be refreshed per axis and side;
  * the coupled system's **window halo** (``halo``) — the per-axis
    (lo, hi) VMEM window extension that makes every read of every output
    land inside the fetched windows, staggering included;
  * the equivalent scalar ``inferred_radius`` used to cross-check an
    (optional) user-declared ``radius``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from . import sym
from .reductions import Reduction, normalize_reductions
from .sym import SymArray, TraceError

__all__ = ["StencilIR", "trace_stencil"]


@dataclasses.dataclass(frozen=True)
class StencilIR:
    """Symbolic description of one fused stencil launch."""

    base_shape: tuple[int, ...]
    field_shapes: dict[str, tuple[int, ...]]
    offsets: dict[str, tuple[int, ...]]           # staggering vs base_shape
    out_names: tuple[str, ...]
    out_shapes: dict[str, tuple[int, ...]]        # traced update extents
    write_modes: dict[str, tuple[str, ...]]       # 'all' | 'inn' per axis
    write_rings: dict[str, tuple[int, ...]]       # interior-ring depth w
    reads_rel: dict[str, dict[str, tuple[tuple[int, int], ...]]]
    field_halo: dict[str, tuple[tuple[int, int], ...]]
    halo: tuple[tuple[int, int], ...]             # system window halo
    inferred_radius: int
    exprs: dict[str, SymArray] = dataclasses.field(repr=False, default_factory=dict)
    reductions: dict[str, Reduction] = dataclasses.field(default_factory=dict)
    red_exprs: dict[str, SymArray] = dataclasses.field(repr=False, default_factory=dict)

    @property
    def ndim(self) -> int:
        return len(self.base_shape)

    @property
    def read_fields(self) -> tuple[str, ...]:
        """Fields actually read by the update (HBM read set)."""
        return tuple(
            f for f in self.field_shapes
            if any(f in r for r in self.reads_rel.values())
        )

    def io_counts(self) -> tuple[int, int]:
        """(n_read, n_write): the paper's A_eff field counting, derived
        instead of hand-supplied."""
        return len(self.read_fields), len(self.out_names)

    @property
    def check_read_fields(self) -> tuple[str, ...]:
        """Fields a SEPARATE check pass would have to re-read from HBM:
        every reduction operand (outputs were just written, inputs were
        just read — a post-pass pays for both again). The fused epilogue
        reads none of them a second time; this set prices the traffic
        the fusion eliminates."""
        seen: list[str] = []
        for r in self.reductions.values():
            for op in r.operands:
                if op not in seen:
                    seen.append(op)
        return tuple(seen)

    def check_io_bytes(self, itemsize: int,
                       field_itemsizes=None) -> int:
        """HBM bytes of one separate (unfused) check pass: each operand
        field streams in once (at its own storage width when
        ``field_itemsizes`` — a ``{field: itemsize}`` mapping — is
        given). The fused epilogue's extra traffic is the per-tile
        partials write — O(n_blocks), negligible — so this is the
        per-check saving of ``reductions=``."""
        import math

        isz = field_itemsizes or {}
        return sum(math.prod(self.field_shapes[f]) * isz.get(f, itemsize)
                   for f in self.check_read_fields)

    def io_bytes(self, itemsize: int, field_itemsizes=None) -> int:
        """Exact bytes that must cross HBM per step under perfect reuse:
        every read field streams in once, every output streams out once
        (staggered fields at their own, smaller extents; mixed-precision
        fields at their own storage width via ``field_itemsizes``, a
        ``{field: itemsize}`` mapping defaulting to ``itemsize``)."""
        import math

        isz = field_itemsizes or {}
        total = 0
        for f in self.read_fields:
            total += math.prod(self.field_shapes[f]) * isz.get(f, itemsize)
        for o in self.out_names:
            total += math.prod(self.field_shapes[o]) * isz.get(o, itemsize)
        return total

    def describe(self) -> str:
        """Human-readable footprint table (README/CI smoke surface)."""
        lines = [f"base shape {self.base_shape}, "
                 f"inferred radius {self.inferred_radius}, "
                 f"window halo {self.halo}"]
        for o in self.out_names:
            lines.append(
                f"  out {o}: modes {self.write_modes[o]} "
                f"rings {self.write_rings[o]}"
            )
            for f, iv in sorted(self.reads_rel[o].items()):
                lines.append(f"    reads {f}: {iv}")
        for f, d in sorted(self.field_halo.items()):
            if any(x or y for x, y in d):
                lines.append(f"  exchange depth {f}: {d}")
        for n, r in sorted(self.reductions.items()):
            lines.append(f"  reduction {n}: {r.describe()}")
        return "\n".join(lines)


def _write_geometry(update_shape, field_shape, off, name):
    """Per-axis (mode, ring) from the traced update's extent — the SAME
    rule the backends apply to concrete updates (one shared
    implementation; on full arrays the 'window' is the field itself)."""
    from ..kernels.stencil import write_geometry

    return write_geometry(update_shape, field_shape, off, name, ring=None)


def trace_stencil(
    update_fn: Callable[[Mapping[str, SymArray], Mapping[str, object]], Mapping],
    field_shapes: Mapping[str, Sequence[int]],
    out_names: Sequence[str],
    scalar_names: Sequence[str] = (),
    reductions: Mapping[str, object] | None = None,
) -> StencilIR:
    """Abstractly evaluate ``update_fn(fields, scalars)`` once.

    ``field_shapes`` are the concrete per-field extents (staggered fields
    shorter than the base along their face axes). Scalars are passed as
    the neutral value 1.0 — value-dependent control flow inside an update
    function is untraceable by design (it would not be a stencil).

    ``reductions`` declares the launch's fused reduction epilogues
    (``{name: Reduction | "kind(field[, other])"}``): operands are
    validated against the field set (collocated fields only) and each
    check's elementwise map is traced into ``red_exprs`` — the cost
    model then prices check flops exactly and check *traffic* at what a
    separate pass would pay (``check_io_bytes``).

    Raises :class:`TraceError` for untraceable constructs and plain
    ``ValueError`` for genuinely invalid kernels (bad write extents,
    interior writes on staggered axes, staggered reduction operands).
    """
    shapes = {n: tuple(int(x) for x in s) for n, s in field_shapes.items()}
    if not shapes:
        raise TraceError("no fields to trace")
    nd = len(next(iter(shapes.values())))
    base = tuple(max(s[a] for s in shapes.values()) for a in range(nd))
    offsets = {n: tuple(b - x for b, x in zip(base, s))
               for n, s in shapes.items()}
    out_names = tuple(out_names)
    for o in out_names:
        if o not in shapes:
            raise TraceError(f"output {o!r} is not a field")

    leaves = {n: sym.field(n, s) for n, s in shapes.items()}
    scalars = {n: 1.0 for n in scalar_names}
    try:
        updates = update_fn(leaves, scalars)
    except (TraceError, ValueError):
        raise
    except Exception as e:  # jnp.* on SymArray, numpy coercion, ...
        raise TraceError(
            f"update function is not symbolically traceable ({type(e).__name__}: "
            f"{e}); declare radius= explicitly to use the legacy geometry"
        ) from e
    missing = set(out_names) - set(updates)
    if missing:
        raise ValueError(f"update_fn did not produce outputs {sorted(missing)}")

    out_shapes, write_modes, write_rings, reads_rel = {}, {}, {}, {}
    for o in out_names:
        u = updates[o]
        if not isinstance(u, SymArray):
            raise TraceError(
                f"output {o!r} update is {type(u).__name__}, not a traced "
                "stencil expression"
            )
        modes, rings = _write_geometry(u.shape, shapes[o], offsets[o], o)
        out_shapes[o] = u.shape
        write_modes[o], write_rings[o] = modes, rings
        reads_rel[o] = {
            f: tuple((lo - w, hi - w) for (lo, hi), w in zip(iv, rings))
            for f, iv in u.reads.items()
        }

    field_halo = {n: ((0, 0),) * nd for n in shapes}
    halo = [(0, 0)] * nd
    for o in out_names:
        for f, iv in reads_rel[o].items():
            fh = list(field_halo[f])
            for a, (lo, hi) in enumerate(iv):
                fh[a] = (max(fh[a][0], -lo), max(fh[a][1], hi))
                halo[a] = (
                    max(halo[a][0], -lo),
                    max(halo[a][1], hi + offsets[f][a]),
                )
            field_halo[f] = tuple(fh)
    # A staggered `all`-write output must have its whole block frame
    # covered by the update: the window needs at least `off` extra cells
    # on the high side even when the kernel's *reads* are shallower
    # (update extent on a window is B - off + lo + hi; covering the
    # B-cell out frame needs hi >= off).
    for o in out_names:
        for a, off_a in enumerate(offsets[o]):
            halo[a] = (halo[a][0], max(halo[a][1], off_a))
    halo = tuple((max(lo, 0), max(hi, 0)) for lo, hi in halo)
    field_halo = {
        n: tuple((max(lo, 0), max(hi, 0)) for lo, hi in d)
        for n, d in field_halo.items()
    }
    r_inf = 0
    for lo, hi in halo:
        r_inf = max(r_inf, lo, hi)
    for rings in write_rings.values():
        r_inf = max(r_inf, *rings)

    reds = normalize_reductions(reductions, tuple(shapes))
    red_exprs: dict[str, SymArray] = {}
    for name, r in reds.items():
        for op in r.operands:
            if any(offsets[op]):
                raise ValueError(
                    f"reduction {name!r} = {r.describe()} reads staggered "
                    f"field {op!r} (offsets {offsets[op]}); reduction "
                    "operands must be collocated with the base grid"
                )
        ops = [sym.field(op, shapes[op]) for op in r.operands]
        red_exprs[name] = r.map_element(*ops)

    return StencilIR(
        base_shape=base,
        field_shapes=shapes,
        offsets=offsets,
        out_names=out_names,
        out_shapes=out_shapes,
        write_modes=write_modes,
        write_rings=write_rings,
        reads_rel=reads_rel,
        field_halo=field_halo,
        halo=halo,
        inferred_radius=r_inf,
        exprs={o: updates[o] for o in out_names},
        reductions=reds,
        red_exprs=red_exprs,
    )
