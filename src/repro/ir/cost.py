"""Analytic cost models from the traced stencil IR.

Exact per-launch flop counts (graph walk, shared subexpressions counted
once — what XLA's CSE executes) and HBM byte counts (per-field extents,
staggering included) yield:

  * ``a_eff`` inputs for ``core.teff`` without hand-supplied
    ``n_read``/``n_write`` (:meth:`StencilCostModel.a_eff_bytes`);
  * a per-candidate (tile, nsteps) runtime prediction for the autotuner,
    combining fetched-window traffic with the redundant halo-cone compute
    of temporal blocking — cheap enough to prune the search space before
    anything compiles (:meth:`StencilCostModel.predict_per_step_s`);
  * the kernel's roofline position (arithmetic intensity vs the hardware
    ridge) surfaced by ``launch.roofline.stencil_roofline``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

from .trace import StencilIR

__all__ = ["FlopCount", "count_flops", "StencilCostModel"]


@dataclasses.dataclass(frozen=True)
class FlopCount:
    """Elementwise operation counts (the FlopCount idiom of roofline
    tooling): adds/subs/negs, muls, divs and pow evaluations."""

    adds: int = 0
    muls: int = 0
    divs: int = 0
    pows: int = 0

    def total(self, pow_cost: int = 1) -> int:
        """Total flops; ``pow_cost`` weights transcendental pow calls."""
        return self.adds + self.muls + self.divs + pow_cost * self.pows

    def __add__(self, other: "FlopCount") -> "FlopCount":
        return FlopCount(self.adds + other.adds, self.muls + other.muls,
                         self.divs + other.divs, self.pows + other.pows)

    def __mul__(self, k: int) -> "FlopCount":
        return FlopCount(self.adds * k, self.muls * k, self.divs * k,
                         self.pows * k)

    __rmul__ = __mul__

    def to_dict(self) -> dict:
        return {"adds": self.adds, "muls": self.muls, "divs": self.divs,
                "pows": self.pows, "total": self.total()}


def count_flops(exprs: Mapping[str, object]) -> FlopCount:
    """Walk the expression graphs of all outputs, counting each unique
    node once (Python-level sharing == the sharing XLA's CSE recovers),
    at one op per element of the node's shape."""
    seen: set[int] = set()
    counts = {"adds": 0, "muls": 0, "divs": 0, "pows": 0}

    def walk(node):
        if id(node) in seen:
            return
        seen.add(id(node))
        for c in getattr(node, "children", ()):
            walk(c)
        kind = node.flop_kind()
        if kind is not None:
            counts[kind] += math.prod(node.shape)

    for e in exprs.values():
        walk(e)
    return FlopCount(**counts)


def _as_pairs(halo, nd: int) -> tuple[tuple[int, int], ...]:
    if isinstance(halo, int):
        return ((halo, halo),) * nd
    return tuple((int(p[0]), int(p[1])) if not isinstance(p, int) else (p, p)
                 for p in halo)


def halo_compute_overhead(block: Sequence[int],
                          halo: Sequence[tuple[int, int]] | int,
                          nsteps: int) -> float:
    """Redundant-work fraction of a k-fused launch vs k ideal sweeps,
    generalized to per-axis asymmetric halos (``teff.halo_compute_overhead``
    is the symmetric special case)."""
    k = max(int(nsteps), 1)
    block = tuple(int(b) for b in block)
    pairs = _as_pairs(halo, len(block))
    ideal = k * math.prod(block)
    total = sum(
        math.prod(b + (k - 1 - s) * (lo + hi)
                  for b, (lo, hi) in zip(block, pairs))
        for s in range(k)
    )
    return total / ideal - 1.0


@dataclasses.dataclass(frozen=True)
class StencilCostModel:
    """Analytic per-step cost of one fused stencil launch."""

    shape: tuple[int, ...]                    # base (cell-centered) extent
    itemsize: int
    flops: FlopCount                          # one sweep, whole grid
    read_bytes: int                           # exact per-sweep HBM reads
    write_bytes: int                          # exact per-sweep HBM writes
    halo: tuple[tuple[int, int], ...]         # per-axis (lo, hi), one sweep
    field_offsets: tuple[tuple[int, ...], ...]  # staggering of fetched fields
    check_read_bytes: int = 0                 # one SEPARATE check pass's reads
    check_flops: FlopCount = FlopCount()      # fused epilogue map + fold
    n_reductions: int = 0                     # named reductions per launch
    # Mixed precision: per-field STORAGE itemsizes, aligned with
    # ``field_offsets`` (None -> every field at ``itemsize``), and the
    # width reduction partials cross HBM at (accumulation dtype, never
    # narrower than f32 — None -> max(4, itemsize)). Keeping these
    # per-field keeps a_eff / roofline / autotune pruning honest when
    # bf16 storage rides next to f32 accumulators.
    field_itemsizes: tuple[int, ...] | None = None
    partials_itemsize: int | None = None

    @classmethod
    def from_ir(cls, ir: StencilIR, itemsize: int,
                field_itemsizes=None,
                partials_itemsize: int | None = None) -> "StencilCostModel":
        """``field_itemsizes`` may be a ``{field: itemsize}`` mapping or a
        sequence aligned with ``ir.field_shapes`` order; omitted fields /
        None fall back to ``itemsize``."""
        if field_itemsizes is None:
            by_name = {f: int(itemsize) for f in ir.field_shapes}
        elif isinstance(field_itemsizes, Mapping):
            by_name = {f: int(field_itemsizes.get(f, itemsize))
                       for f in ir.field_shapes}
        else:
            by_name = {f: int(s)
                       for f, s in zip(ir.field_shapes, field_itemsizes)}
            for f in ir.field_shapes:
                by_name.setdefault(f, int(itemsize))
        rb = sum(math.prod(ir.field_shapes[f]) * by_name[f]
                 for f in ir.read_fields)
        wb = sum(math.prod(ir.field_shapes[o]) * by_name[o]
                 for o in ir.out_names)
        # the reduction epilogue's flops: the traced elementwise map plus
        # one combine op per element for the fold tree
        cf = count_flops(ir.red_exprs)
        cf = cf + FlopCount(adds=sum(math.prod(e.shape)
                                     for e in ir.red_exprs.values()))
        return cls(
            shape=ir.base_shape,
            itemsize=int(itemsize),
            flops=count_flops(ir.exprs),
            read_bytes=rb,
            write_bytes=wb,
            halo=ir.halo,
            # the launch fetches a window for EVERY field argument
            # (outputs ride along as boundary-copy sources), so the
            # tile/k traffic model must count them all — only a_eff
            # (ideal reuse) restricts to the read set
            field_offsets=tuple(ir.offsets[f] for f in ir.field_shapes),
            check_read_bytes=ir.check_io_bytes(itemsize,
                                               field_itemsizes=by_name),
            check_flops=cf,
            n_reductions=len(ir.reductions),
            field_itemsizes=tuple(by_name[f] for f in ir.field_shapes),
            partials_itemsize=(max(4, int(itemsize))
                               if partials_itemsize is None
                               else int(partials_itemsize)),
        )

    def a_eff_bytes(self, nsteps: int = 1) -> float:
        """Ideal per-step HBM traffic (the paper's A_eff) under k-step
        temporal blocking — derived, not hand-counted."""
        return (self.read_bytes + self.write_bytes) / max(int(nsteps), 1)

    def check_bytes_per_step(self, check_every: int = 1,
                             fused: bool = True,
                             tile: Sequence[int] | None = None) -> float:
        """Per-step HBM traffic of the convergence check, amortized over
        its cadence (``check_every=m``: one check per m steps).

        ``fused=False`` prices the separate post-pass: every operand
        field streams in again (``check_read_bytes``). ``fused=True``
        prices the in-launch epilogue: only the per-tile partials cross
        HBM — one scalar per tile per reduction — which a ``tile``
        geometry makes exact and a missing one rounds to zero."""
        m = max(int(check_every), 1)
        if not fused:
            return self.check_read_bytes / m
        if tile is None or not self.n_reductions:
            return 0.0
        n_blocks = math.prod(-(-s // int(b))
                             for s, b in zip(self.shape, tile))
        # partials cross HBM at the accumulation width, not storage
        psz = (self.partials_itemsize if self.partials_itemsize is not None
               else max(4, self.itemsize))
        return n_blocks * self.n_reductions * psz / m

    @property
    def intensity(self) -> float:
        """Arithmetic intensity (flop/byte) of one sweep."""
        bytes_ = self.read_bytes + self.write_bytes
        return self.flops.total() / bytes_ if bytes_ else 0.0

    def fetched_bytes_per_step(self, tile: Sequence[int], nsteps: int,
                               march_axis: int | None = None,
                               check_every: int | None = None,
                               fused_checks: bool = True) -> float:
        """HBM bytes actually moved per time step by the tiled launch:
        every block fetches its (overlapping) halo-extended windows and
        writes its output block; a k-fused launch amortizes both over k
        steps. This is the footprint-aware refinement of ``a_eff`` that
        makes small tiles with deep halos look as expensive as they are.

        With ``march_axis`` the launch streams: windows overlap only on
        the *non*-marching axes — along the march axis each tile column
        fetches every plane once (plus ``Lhi`` clamped drain blocks), the
        halo planes riding in the scratch queue instead of being
        refetched. This is the model that makes temporal blocking and
        streaming composable in the autotuner: deep ``k*r`` halos stop
        multiplying the traffic along the marched axis.

        ``check_every=m`` adds the convergence-check traffic at its
        cadence (:meth:`check_bytes_per_step`): the fused epilogue costs
        ~one partial per tile, the separate post-pass re-reads every
        operand field — the honest accounting that keeps a checked
        solver's T_eff table from hiding its norm passes."""
        check = 0.0
        if check_every is not None:
            check = self.check_bytes_per_step(check_every, fused_checks,
                                              tile)
        k = max(int(nsteps), 1)
        tile = tuple(int(b) for b in tile)
        nd = len(tile)
        offs = self.field_offsets or ((0,) * nd,)
        # per-field storage widths (mixed precision); fall back to the
        # uniform itemsize when unset or misaligned with the offsets
        if self.field_itemsizes and len(self.field_itemsizes) == len(offs):
            sizes = self.field_itemsizes
        else:
            sizes = (self.itemsize,) * len(offs)
        if march_axis is None:
            n_blocks = math.prod(-(-s // b) for s, b in zip(self.shape, tile))
            win = sum(
                math.prod(b + k * (lo + hi) - o
                          for b, (lo, hi), o in zip(tile, self.halo, off))
                * isz
                for off, isz in zip(offs, sizes)
            )
            return (n_blocks * win + self.write_bytes) / k + check
        m = int(march_axis)
        bm = tile[m]
        lhi = -(-k * self.halo[m][1] // bm)
        planes = self.shape[m] + lhi * bm      # fetch steps * bm per column
        n_cols = math.prod(-(-s // b) for a, (s, b)
                           in enumerate(zip(self.shape, tile)) if a != m)
        win = sum(
            planes * math.prod(
                tile[a] + k * (self.halo[a][0] + self.halo[a][1]) - off[a]
                for a in range(nd) if a != m) * isz
            for off, isz in zip(offs, sizes)
        )
        return (n_cols * win + self.write_bytes) / k + check

    def a_eff_streamed(self, tile: Sequence[int], nsteps: int = 1,
                       march_axis: int = 0) -> float:
        """Analytic per-step HBM traffic of the *streamed* launch — the
        ``a_eff``-style number the roofline records report next to the
        ideal (:meth:`a_eff_bytes`) and the refetched all-parallel
        traffic (:meth:`fetched_bytes_per_step` without a march axis).
        Equals ``fetched_bytes_per_step(tile, nsteps, march_axis)``;
        named for the T_eff table column it fills. ``march_axis`` must
        name a real axis: for a launch that fell back to all-parallel
        (``run.march_axis is None``) use ``fetched_bytes_per_step`` —
        returning refetched traffic under this name would corrupt any
        table built from it."""
        if march_axis is None:
            raise ValueError(
                "a_eff_streamed needs a concrete march_axis; an all-"
                "parallel launch's traffic is fetched_bytes_per_step(...)"
            )
        return self.fetched_bytes_per_step(tile, nsteps, march_axis)

    def predict_per_step_s(self, tile: Sequence[int], nsteps: int,
                           hw, march_axis: int | None = None,
                           check_every: int | None = None,
                           fused_checks: bool = True) -> float:
        """Roofline-style per-step runtime prediction for one
        (tile, k, march_axis) candidate on ``hw`` (a ``teff.HardwareSpec``):
        max of the memory term (fetched windows — streamed traffic when
        marching, plus check traffic at its cadence) and the compute term
        inflated by the redundant halo-cone work of temporal blocking
        (plus the amortized check flops)."""
        k = max(int(nsteps), 1)
        t_mem = self.fetched_bytes_per_step(
            tile, k, march_axis, check_every=check_every,
            fused_checks=fused_checks) / hw.peak_bw
        overhead = halo_compute_overhead(tile, self.halo, k)
        flops = self.flops.total() * (1.0 + overhead)
        if check_every is not None:
            flops += self.check_flops.total() / max(int(check_every), 1)
        t_comp = flops / hw.peak_flops
        return max(t_mem, t_comp)
