"""Stencil IR — symbolic footprint inference, cost models, boundary specs.

The user's math-close update function is traced ONCE with symbolic window
objects (:mod:`.sym`) that implement the same relative-slice protocol as
the ``core.fd`` operators. The resulting per-output expression graph
(:class:`.trace.StencilIR`) carries everything the rest of the stack used
to take on faith from a hand-declared ``radius`` and hand-counted
``n_read``/``n_write``:

  * **footprints** — per-field, per-axis, per-side halo depths
    (``StencilIR.field_halo``) and the coupled system's window halo
    (``StencilIR.halo``), consumed by ``kernels.stencil`` (VMEM window
    geometry), ``distributed.halo`` (exchange depths) and
    ``distributed.overlap`` (face-slab widths);
  * **boundary conditions** (:mod:`.bc`) — declared per output field and
    realized inside the fused launch, bitwise-equal to the
    ``core.boundary`` post-pass;
  * **cost models** (:mod:`.cost`) — exact flop/byte counts per output
    feeding ``core.teff``, the autotuner's pre-compile candidate pruning
    and ``launch.roofline`` stencil positions.
"""
from .sym import SymArray, TraceError, field as sym_field
from .trace import StencilIR, trace_stencil
from .cost import FlopCount, StencilCostModel, count_flops
from .bc import BoundaryCondition
from .reductions import Reduction, normalize_reductions

__all__ = [
    "SymArray", "TraceError", "sym_field",
    "StencilIR", "trace_stencil",
    "FlopCount", "StencilCostModel", "count_flops",
    "BoundaryCondition",
    "Reduction", "normalize_reductions",
]
