"""Boundary-condition declarations for fused stencil launches.

A :class:`BoundaryCondition` is declared per *output field* on
``@parallel`` and realized by the engine itself — inside the fused
Pallas kernel (dirichlet/neumann0, including between the sweeps of a
``nsteps=k`` temporally-blocked launch) or as a face-slab scatter fused
into the surrounding jit (periodic, whose wrap sources live outside any
local window) — bitwise-equal to the ``core.boundary`` post-pass the
seed solvers applied as a separate whole-array step.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

__all__ = ["BoundaryCondition", "normalize_bcs"]

KINDS = ("dirichlet", "neumann0", "periodic")


@dataclasses.dataclass(frozen=True)
class BoundaryCondition:
    """One output field's boundary condition.

    ``axes=None`` means every axis (the ``core.boundary`` default);
    ``depth`` is the face thickness in cells; ``value`` only applies to
    ``dirichlet``.
    """

    kind: str
    value: float = 0.0
    axes: tuple[int, ...] | None = None
    depth: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"boundary condition kind {self.kind!r} must be one of {KINDS}"
            )
        if self.depth < 1:
            raise ValueError(f"bc depth must be >= 1, got {self.depth}")
        if self.axes is not None:
            object.__setattr__(self, "axes",
                               tuple(int(a) for a in self.axes))

    def resolved_axes(self, ndim: int) -> tuple[int, ...]:
        return tuple(range(ndim)) if self.axes is None else self.axes

    def apply(self, A):
        """The reference realization: the ``core.boundary`` post-pass.
        The fused in-kernel path is tested bitwise against this."""
        from ..core import boundary  # lazy: core.__init__ imports us back

        axes = self.resolved_axes(A.ndim)
        if self.kind == "dirichlet":
            return boundary.dirichlet(A, self.value, axes=axes,
                                      depth=self.depth)
        if self.kind == "neumann0":
            return boundary.neumann0(A, axes=axes, depth=self.depth)
        return boundary.periodic(A, axes=axes, depth=self.depth)


def normalize_bcs(
    bc: Mapping[str, BoundaryCondition | str] | None,
    out_names: Sequence[str],
    ndim: int,
    field_shapes: Mapping[str, Sequence[int]] | None = None,
) -> dict[str, BoundaryCondition]:
    """Validate a per-output bc mapping; bare kind strings are promoted
    to default-parameter conditions."""
    if not bc:
        return {}
    out = {}
    for name, spec in bc.items():
        if name not in out_names:
            raise ValueError(
                f"boundary condition declared for {name!r}, which is not an "
                f"output of this kernel (outputs: {tuple(out_names)})"
            )
        if isinstance(spec, str):
            spec = BoundaryCondition(spec)
        if not isinstance(spec, BoundaryCondition):
            raise ValueError(
                f"bc[{name!r}] must be a BoundaryCondition or kind string, "
                f"got {type(spec).__name__}"
            )
        for a in spec.resolved_axes(ndim):
            if not 0 <= a < ndim:
                raise ValueError(
                    f"bc[{name!r}] axis {a} out of range for ndim {ndim}"
                )
        if field_shapes is not None and name in field_shapes:
            from ..core import boundary  # lazy import (cycle via core)

            boundary.check_depth(tuple(field_shapes[name]), spec.kind,
                                 spec.resolved_axes(ndim), spec.depth)
        out[name] = spec
    return out
