"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = sum over collectives of ring-model bytes / link_bw

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (per-device numbers —
the costed module is the SPMD-partitioned per-device program);
``compiled.as_text()`` parsed for collective ops (GSPMD inserts them after
partitioning, so lowered-as_text would miss most of them).

Ring cost model per op (n = participants, S = *result* shard bytes on one
device):  all-gather moves S*(n-1)/n of the result per link step and the
result is n shards -> bytes_on_wire_per_device = S*(n-1)/n; all-reduce =
2*S*(n-1)/n (reduce-scatter + all-gather); reduce-scatter = S*(n-1)/n
(S = input shard); all-to-all = S*(n-1)/n; collective-permute = S.

Hardware constants are the task-specified TPU v5e numbers.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

import numpy as np

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link (per direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\(",
    re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}", re.S)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?)\}", re.S)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len([x for x in first.split(",") if x.strip()])
    m = _PAIRS_RE.search(line)
    if m:
        return 2
    return 1


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict       # raw per-device result bytes by op kind
    wire_bytes: float        # ring-model bytes on the busiest device's links
    by_op: list

    def to_json(self):
        return {"counts": self.counts, "result_bytes": self.result_bytes,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict = {}
    rbytes: dict = {}
    wire = 0.0
    by_op = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        size = _shape_bytes(shape_str)
        n = max(_group_size(line), 1)
        if op == "all-reduce":
            w = 2.0 * size * (n - 1) / n
        elif op == "collective-permute":
            w = float(size)
        else:  # all-gather / reduce-scatter / all-to-all
            w = size * (n - 1) / n
        counts[op] = counts.get(op, 0) + 1
        rbytes[op] = rbytes.get(op, 0) + size
        wire += w
        by_op.append({"op": op, "bytes": size, "group": n, "wire": w})
    return CollectiveStats(counts, rbytes, wire, by_op)


@dataclasses.dataclass
class Roofline:
    flops: float             # per-device
    hbm_bytes: float         # per-device
    wire_bytes: float        # per-device (ring model)
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0

    def to_json(self):
        return dataclasses.asdict(self)


def analyze(cost: dict, coll: CollectiveStats, n_devices: int,
            model_flops_global: float = 0.0, scan_collective_reps: float = 1.0,
            link_bw: float = LINK_BW) -> Roofline:
    """cost: compiled.cost_analysis() dict (per-device program).

    scan_collective_reps: collectives inside a lax.scan body appear once in
    HLO but execute once per layer — multiply wire bytes accordingly (we
    pass n_layers when the collective sits in the scanned block).
    """
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    wire = coll.wire_bytes * scan_collective_reps
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_l = wire / link_bw
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_l)),
              key=lambda kv: kv[1])[0]
    mf = model_flops_global / max(n_devices, 1)
    return Roofline(flops, hbm, wire, t_c, t_m, t_l, dom,
                    model_flops=mf,
                    useful_ratio=(mf / flops if flops else 0.0))


def stencil_roofline(cost_model, nsteps: int = 1, hw=None,
                     measured_s: float | None = None,
                     tile=None, march_axis: int | None = None) -> dict:
    """Roofline position of one fused stencil launch from its analytic
    cost model (``ir.StencilCostModel`` — exact flops/bytes traced from
    the kernel source, no hand counting).

    Returns a JSON-able record: arithmetic intensity vs the hardware
    ridge, the memory/compute time bounds, which one dominates, and —
    when a measured per-step time is supplied — the achieved fraction of
    the dominant bound. With a ``tile`` the record also distinguishes the
    *refetched* traffic of the all-parallel launch from the *streamed*
    traffic when ``march_axis`` slides that axis sequentially (the bytes
    the plane queue saves).
    """
    peak_flops = getattr(hw, "peak_flops", PEAK_FLOPS)
    peak_bw = getattr(hw, "peak_bw", HBM_BW)
    flops = float(cost_model.flops.total())
    bytes_step = float(cost_model.a_eff_bytes(nsteps))
    intensity = flops / bytes_step if bytes_step else 0.0
    ridge = peak_flops / peak_bw
    t_c = flops / peak_flops
    t_m = bytes_step / peak_bw
    bound = max(t_c, t_m)
    rec = {
        "flops_per_step": flops,
        "bytes_per_step": bytes_step,
        "intensity_flop_per_byte": intensity,
        "ridge_flop_per_byte": ridge,
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "dominant": "compute" if t_c >= t_m else "memory",
        "nsteps": nsteps,
        "flop_counts": cost_model.flops.to_dict(),
    }
    if tile is not None:
        rec["tile"] = list(tile)
        rec["refetched_bytes_per_step"] = float(
            cost_model.fetched_bytes_per_step(tile, nsteps))
        if march_axis is not None:
            rec["march_axis"] = int(march_axis)
            rec["streamed_bytes_per_step"] = float(
                cost_model.a_eff_streamed(tile, nsteps, march_axis))
    if measured_s is not None and measured_s > 0:
        rec["measured_s"] = float(measured_s)
        rec["frac_of_roofline"] = bound / measured_s
    return rec


def analyze_walk(mc, n_devices: int, model_flops_global: float = 0.0,
                 link_bw: float = LINK_BW) -> Roofline:
    """Roofline terms from a trip-count-aware hlo_analysis.Cost walk."""
    t_c = mc.flops / PEAK_FLOPS
    t_m = mc.bytes / HBM_BW
    t_l = mc.coll_wire / link_bw
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_l)),
              key=lambda kv: kv[1])[0]
    mf = model_flops_global / max(n_devices, 1)
    return Roofline(mc.flops, mc.bytes, mc.coll_wire, t_c, t_m, t_l, dom,
                    model_flops=mf,
                    useful_ratio=(mf / mc.flops if mc.flops else 0.0))


def analytic_bytes(cfg, mode: str, seq_len: int, global_batch: int,
                   n_dev: int, tensor_shard: int = 16,
                   batch_shard: int = 16, n_micro: int = 1) -> float:
    """Paper-style A_eff accounting of per-device HBM traffic per step.

    This is the T_eff methodology of the paper (count the bytes that MUST
    cross HBM under perfect on-chip reuse) applied to the LM step; the
    HLO-parsed byte count is reported alongside as a conservative upper
    bound (CPU HLO fuses far less than TPU, DESIGN.md §6).
    """
    P = cfg.param_count()
    Pa = cfg.active_param_count()
    D = cfg.d_model
    Ln = max(cfg.n_layers, 1)
    dt_p = 2  # bf16 params
    if mode == "train":
        # params bf16 r+w (2+2) + fp32 m,v,master r+w (24) per element
        opt_traffic = 28.0 * P / n_dev * n_micro ** 0  # once per step
        # per microbatch: read active params twice (fwd+bwd) beyond cache
        w_traffic = 2.0 * dt_p * Pa / n_dev * n_micro
        tok_loc = seq_len * global_batch / (n_dev / tensor_shard) / tensor_shard
        act = 12.0 * Ln * tok_loc * D * dt_p          # fwd+bwd+remat streams
        logits = 4.0 * tok_loc * cfg.vocab / tensor_shard * 4.0
        return opt_traffic + w_traffic + act + logits
    if mode == "prefill":
        tok_loc = seq_len * global_batch / (n_dev / tensor_shard) / tensor_shard
        act = 4.0 * Ln * tok_loc * D * dt_p
        cache = 2.0 * global_batch * seq_len * cfg.n_kv_heads * cfg.head_dim \
            * dt_p * Ln / n_dev
        return dt_p * Pa / n_dev + act + cache
    # decode: all resident weights stream once + cache read + state write
    w = dt_p * Pa / n_dev
    if cfg.family in ("ssm", "hybrid"):
        sc_state = global_batch * (cfg.ssm_expand * D // max(cfg.ssm_head_dim, 1)) \
            * cfg.ssm_head_dim * cfg.ssm_state * 4.0 * Ln / n_dev
        cache = 2.0 * sc_state
    else:
        cache = 2.0 * global_batch * seq_len * cfg.n_kv_heads * cfg.head_dim \
            * dt_p * Ln / n_dev
        if cfg.window is not None:
            cache *= min(cfg.window / seq_len, 1.0)
    return w + cache


def model_flops_train(cfg, seq_len: int, global_batch: int) -> float:
    """6 * N_active * D tokens (the standard training-FLOPs estimate)."""
    return 6.0 * cfg.active_param_count() * seq_len * global_batch


def model_flops_decode(cfg, global_batch: int) -> float:
    """2 * N_active per generated token (forward only)."""
    return 2.0 * cfg.active_param_count() * global_batch


def model_flops_prefill(cfg, seq_len: int, global_batch: int) -> float:
    return 2.0 * cfg.active_param_count() * seq_len * global_batch
