"""HLO-text analyzer: per-computation FLOPs / bytes / collectives with
while-loop trip-count multiplication.

Why this exists: ``compiled.cost_analysis()`` visits every computation
exactly once — a `lax.scan` over 80 layers reports one layer's FLOPs
(verified empirically in EXPERIMENTS.md §Dry-run calibration). All our
models are scan-stacked, and attention/loss/SSD use inner chunk scans, so
a faithful roofline needs the call graph walked with trip counts:

    cost(ENTRY) = Σ own ops + Σ while: trip × cost(body) + cost(cond)
                           + fusion/call/conditional: cost(callee)

Heuristics (documented, validated against cost_analysis on scan-free
modules in tests/test_hlo_analysis.py):
  * trip count: the max integer constant in the while's condition
    computation (scan induction starts at 0, condition is `lt N`);
  * FLOPs: 2 * result_elems * contraction_size for dot ops (+ convolution
    treated alike via window size); elementwise FLOPs are ignored — they
    are never compute-roofline-relevant on MXU hardware;
  * bytes: operand + result sizes of top-level ops, skipping pure
    plumbing (parameter/constant/tuple/get-tuple-element/bitcast/while/
    call/conditional); fusion internals are NOT counted (a fusion reads
    its operands and writes its result once — that is the point of fusion);
  * collectives: ring model as in roofline.py, multiplied by trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_INSTR_HEAD = re.compile(r"^\s+(ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OP_AFTER_SHAPE = re.compile(r"\s*([\w\-]+)\(")


def _split_instr(line: str):
    """-> (name, shape_str, op, rest_after_open_paren) or None.

    Robust to tuple result types containing `/*index=N*/` comments, which
    defeat any character-class regex over the shape."""
    m = _INSTR_HEAD.match(line)
    if not m:
        return None
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape, tail = rest[: end + 1], rest[end + 1:]
    else:
        j = rest.find(" ")
        if j < 0:
            return None
        shape, tail = rest[:j], rest[j:]
    m2 = _OP_AFTER_SHAPE.match(tail)
    if not m2:
        return None
    return m.group(2), shape, m2.group(1), tail[m2.end():]
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONST_INT = re.compile(r"constant\((\d+)\)")

PLUMBING = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "while", "call", "conditional", "after-all", "partition-id",
            "replica-id"}

COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "all-gather-start", "all-reduce-start",
               "collective-permute-start", "all-gather-done",
               "all-reduce-done", "collective-permute-done"}


def shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    shape_str: str
    rest: str
    operands: list

    @property
    def result_bytes(self) -> int:
        return shape_elems_bytes(self.shape_str)[1]

    @property
    def result_elems(self) -> int:
        return shape_elems_bytes(self.shape_str)[0]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        h = _COMP_HDR.match(line)
        if h:
            cur = Computation(h.group(2), bool(h.group(1)), [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        parsed = _split_instr(line)
        if parsed is None:
            continue
        name, shape_str, op, rest = parsed
        # operands: %refs inside the first balanced paren group
        depth, end = 1, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnds = _OPERAND.findall(rest[:end])
        cur.instrs.append(Instr(name, op, shape_str, rest, opnds))
    return comps


def _symbol_table(comp: Computation) -> dict[str, int]:
    return {i.name: i.result_bytes for i in comp.instrs}


def _dot_flops(instr: Instr, sym_elems: dict[str, tuple[int, int]]) -> float:
    """2 * result_elems * contraction size (from lhs shape + contracting dims)."""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if not m or not instr.operands:
        return 2.0 * instr.result_elems  # degenerate
    lhs = instr.operands[0]
    lhs_dims = sym_elems.get(lhs)
    if lhs_dims is None:
        return 2.0 * instr.result_elems
    contract = 1
    for d in (int(x) for x in m.group(1).split(",") if x.strip()):
        if d < len(lhs_dims):
            contract *= lhs_dims[d]
    # batch dims are shared between result and lhs — not re-multiplied
    return 2.0 * instr.result_elems * contract


def _dims_of(shape_str: str) -> tuple[int, ...]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d.strip())


def _trip_count(cond: Computation) -> int:
    best = 1
    for i in cond.instrs:
        for c in _CONST_INT.finditer(i.rest if i.op == "constant" else ""):
            best = max(best, int(c.group(1)))
        if i.op == "constant":
            m = re.search(r"constant\((\d+)\)", i.shape_str + " " + i.rest)
            if m:
                best = max(best, int(m.group(1)))
    # constants appear as `%c = s32[] constant(24)`
    return best


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_wire: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        cc = dict(self.coll_counts)
        for k, v in o.coll_counts.items():
            cc[k] = cc.get(k, 0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll_wire + o.coll_wire, cc)

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.coll_wire * k,
                    {kk: v * k for kk, v in self.coll_counts.items()})


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?", re.S)


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(rest)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len([x for x in first.split(",") if x.strip()])
    if "source_target_pairs" in rest:
        return 2
    return 1


def _coll_wire(instr: Instr) -> float:
    op = instr.op.replace("-start", "")
    size = instr.result_bytes
    if op.endswith("-done"):
        return 0.0
    n = max(_group_size(instr.rest), 1)
    if op == "all-reduce":
        return 2.0 * size * (n - 1) / n
    if op == "collective-permute":
        return float(size)
    return size * (n - 1) / n


class ModuleCost:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: dict[str, Cost] = {}
        self.entry = next((c for c in self.comps.values() if c.is_entry), None)
        if self.entry is None and self.comps:
            self.entry = list(self.comps.values())[0]

    def cost(self) -> Cost:
        return self._comp_cost(self.entry.name)

    def _callees(self, instr: Instr) -> list[str]:
        out = []
        for m in _CALLS.finditer(instr.rest):
            for nm in m.group(1).split(","):
                out.append(nm.strip().lstrip("%"))
        return out

    def _comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        self._memo[name] = Cost()  # cycle guard
        sym_dims = {i.name: _dims_of(i.shape_str) for i in comp.instrs}
        sym_bytes = {i.name: i.result_bytes for i in comp.instrs}
        total = Cost()
        for i in comp.instrs:
            op = i.op
            if op == "while":
                body, cond = None, None
                mb = re.search(r"body=%?([\w.\-]+)", i.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", i.rest)
                if mb:
                    body = mb.group(1)
                if mc:
                    cond = mc.group(1)
                mt = _TRIP_CFG.search(i.rest)
                trip = int(mt.group(1)) if mt else self._while_trip(cond)
                inner = self._comp_cost(body) if body else Cost()
                total = total + inner.scaled(trip)
                continue
            if op in ("call", "conditional", "async-start"):
                for cal in self._callees(i):
                    total = total + self._comp_cost(cal)
                continue
            if op == "fusion":
                # FLOPs from inside the fusion; bytes only at its boundary.
                callees = self._callees(i)
                inner = self._comp_cost(callees[0]) if callees else Cost()
                total = total + Cost(flops=inner.flops,
                                     coll_wire=inner.coll_wire,
                                     coll_counts=inner.coll_counts)
                total = total + Cost(bytes=self._fusion_bytes(i, callees, sym_bytes))
                continue
            if op in PLUMBING:
                continue
            if op in ("dot", "convolution"):
                total = total + Cost(flops=_dot_flops(i, sym_dims))
            if op in COLLECTIVES:
                w = _coll_wire(i)
                total = total + Cost(
                    coll_wire=w,
                    coll_counts={i.op.replace("-start", "").replace("-done", ""): 1}
                    if w else {})
            total = total + Cost(bytes=self._instr_bytes(i, sym_bytes))
        self._memo[name] = total
        return total

    def _while_trip(self, cond_name: Optional[str]) -> int:
        if not cond_name:
            return 1
        cond = self.comps.get(cond_name)
        if cond is None:
            return 1
        best = 1
        for i in cond.instrs:
            if i.op == "constant":
                # rest looks like "24), metadata=..." after the regex split
                m = re.match(r"(\d+)\)", i.rest)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    def _instr_bytes(self, i: Instr, sym_bytes: dict) -> float:
        """Realistic HBM traffic of one top-level op."""
        op = i.op
        if op in ("dynamic-slice", "gather", "slice"):
            return 2.0 * i.result_bytes  # reads only what it produces
        if op in ("dynamic-update-slice", "scatter"):
            upd = sym_bytes.get(i.operands[1], 0) if len(i.operands) > 1 else 0
            return 2.0 * upd  # in-place: touched bytes only
        if op in ("broadcast", "iota"):
            return float(i.result_bytes)
        opnd = sum(sym_bytes.get(o, 0) for o in i.operands)
        return float(opnd + i.result_bytes)

    def _fusion_bytes(self, i: Instr, callees: list, sym_bytes: dict) -> float:
        """Fusion boundary traffic with two in-place/sparse refinements:

        1. root (or tuple-element roots) dynamic-update-slice: the aliased
           full-size target never crosses HBM — count the update only;
        2. an operand whose *only* consumer inside the fusion is a
           dynamic-slice/gather contributes the sliced bytes, not its full
           size (decode-time cache reads, scan per-layer weight slices).
        """
        total = float(sum(sym_bytes.get(o, 0) for o in i.operands) + i.result_bytes)
        if not callees:
            return total
        comp = self.comps.get(callees[0])
        if comp is None or not comp.instrs:
            return total
        inner = {x.name: x for x in comp.instrs}
        inner_bytes = {x.name: x.result_bytes for x in comp.instrs}
        # --- (2) sliced params ---
        params = {}
        for x in comp.instrs:
            if x.op == "parameter":
                m = re.match(r"(\d+)\)", x.rest)
                if m:
                    params[x.name] = int(m.group(1))
        consumers: dict[str, list] = {}
        for x in comp.instrs:
            for o in x.operands:
                consumers.setdefault(o, []).append(x)
        adj = total
        for pname, pidx in params.items():
            cons = consumers.get(pname, [])
            if len(cons) == 1 and cons[0].op in ("dynamic-slice", "gather") \
                    and pidx < len(i.operands):
                full = sym_bytes.get(i.operands[pidx], 0)
                adj -= full
                adj += cons[0].result_bytes
        # --- (1) in-place DUS root ---
        root = comp.instrs[-1]
        dus_list = []
        if root.op == "dynamic-update-slice":
            dus_list = [root]
        elif root.op == "tuple":
            dus_list = [inner[o] for o in root.operands
                        if o in inner and inner[o].op == "dynamic-update-slice"]
        for d in dus_list:
            upd = inner_bytes.get(d.operands[1], 0) if len(d.operands) > 1 else 0
            adj -= 2.0 * d.result_bytes
            adj += 2.0 * upd
        return max(adj, 0.0)


def analyze_text(text: str) -> Cost:
    return ModuleCost(text).cost()
