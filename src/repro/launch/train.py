"""Training driver: real loop with checkpoint/restore, fault monitoring,
deterministic data, and optional cross-pod gradient compression.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 200 --seq-len 256 --global-batch 8 --smoke \
        --ckpt-dir /tmp/run1 [--resume]

On the production mesh this is the same code path the dry-run compiles;
on a CPU host it runs the smoke-scale configs end-to-end (examples/
train_lm.py drives it programmatically).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..checkpoint import CheckpointManager
from ..data import DataConfig, make_source
from ..distributed import fault, sharding as shd
from ..models import build, RunConfig
from ..optim import adamw
from . import mesh as mesh_mod
from . import steps as steps_mod


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    resume: bool = False
    seed: int = 0
    data_seed: int = 1234
    heartbeat_dir: Optional[str] = None


def train(arch: str, loop: TrainLoopConfig, rc: Optional[RunConfig] = None,
          smoke: bool = False, mesh=None, rules: shd.ShardRules = shd.DEFAULT_RULES,
          log_fn=print):
    cfg = configs.get_smoke(arch) if smoke else configs.get_arch(arch)
    rc = rc or RunConfig(param_dtype="float32", remat=False,
                         total_steps=loop.steps,
                         loss_chunk=min(256, loop.seq_len))
    model = build(cfg, rc)
    if mesh is None:
        mesh = mesh_mod.make_host_mesh()
    rules = rules.for_mesh(mesh)

    opt_cfg = adamw.AdamWConfig(
        lr=rc.lr, beta1=rc.beta1, beta2=rc.beta2, weight_decay=rc.weight_decay,
        grad_clip=rc.grad_clip, schedule=rc.schedule,
        warmup_steps=min(rc.warmup_steps, max(loop.steps // 10, 1)),
        total_steps=loop.steps)
    bundle = steps_mod.make_train_step(model, mesh, rules, opt_cfg,
                                       loop.seq_len, loop.global_batch)
    step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings,
                      donate_argnums=bundle.donate_argnums)

    # --- state init / restore -------------------------------------------
    p_shard = bundle.in_shardings[0]
    params, _ = model.init(jax.random.PRNGKey(loop.seed))
    params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, p_shard)
    opt_state = adamw.init(params, opt_cfg)
    opt_state = jax.tree.map(lambda x, s: jax.device_put(x, s),
                             opt_state, bundle.in_shardings[1])
    start_step = 0
    mgr = CheckpointManager(loop.ckpt_dir) if loop.ckpt_dir else None
    if mgr and loop.resume and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore(
            (params, opt_state),
            shardings=(bundle.in_shardings[0], bundle.in_shardings[1]))
        start_step = int(extra["step"])
        log_fn(f"resumed from step {start_step}")

    # --- data --------------------------------------------------------------
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=loop.seq_len,
                      global_batch=loop.global_batch, seed=loop.data_seed)
    source = make_source(dcfg)
    b_shard = bundle.in_shardings[2]

    monitor = fault.StepMonitor(host_id=jax.process_index(),
                                heartbeat_dir=loop.heartbeat_dir)
    history = []
    for step in range(start_step, loop.steps):
        host = source.batch(step)
        batch = {"tokens": jnp.asarray(host["tokens"]),
                 "labels": jnp.asarray(host["labels"])}
        if model.cfg.family == "vlm":
            n = model.cfg.n_patches
            key = jax.random.PRNGKey(step)
            batch["patch_embeds"] = (jax.random.normal(
                key, (loop.global_batch, n, cfg.d_model)) * 0.02).astype(rc.param_dtype)
            batch["tokens"] = batch["tokens"][:, :loop.seq_len - n]
            batch["labels"] = batch["labels"][:, :loop.seq_len - n]
        if model.cfg.family == "encdec":
            key = jax.random.PRNGKey(step)
            batch["frames"] = (jax.random.normal(
                key, (loop.global_batch, cfg.source_len, cfg.d_model)) * 0.02
            ).astype(rc.param_dtype)
        batch = jax.tree.map(lambda x, s: jax.device_put(x, s), batch, b_shard)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        metrics = jax.device_get(metrics)
        dt = time.perf_counter() - t0
        monitor.record(step, dt)
        history.append(float(metrics["loss"]))
        if step % loop.log_every == 0 or step == loop.steps - 1:
            health = monitor.check_peers()
            log_fn(f"step {step:5d} loss {metrics['loss']:.4f} "
                   f"lr {metrics['lr']:.2e} |g| {metrics['grad_norm']:.3f} "
                   f"{dt*1e3:.0f} ms"
                   + (f" [stragglers: {health['stragglers']}]"
                      if health["stragglers"] else ""))
        if mgr and ((step + 1) % loop.ckpt_every == 0 or step == loop.steps - 1):
            mgr.save(step + 1, (params, opt_state), blocking=False,
                     extra={"loss": float(metrics["loss"])})
    if mgr:
        mgr.wait()
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--heartbeat-dir", default=None)
    args = ap.parse_args()
    loop = TrainLoopConfig(steps=args.steps, seq_len=args.seq_len,
                           global_batch=args.global_batch,
                           ckpt_dir=args.ckpt_dir, resume=args.resume,
                           ckpt_every=args.ckpt_every,
                           heartbeat_dir=args.heartbeat_dir)
    _, _, hist = train(args.arch, loop, smoke=args.smoke)
    print(f"final loss {hist[-1]:.4f} (first {hist[0]:.4f})")


if __name__ == "__main__":
    main()
