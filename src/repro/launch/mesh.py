"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis crosses the (slower) inter-pod links; gradient all-reduce and
(optionally int8-compressed) collectives run there.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS *before* first jax init).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 exposes explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly "auto"
    AxisType = None


def _mesh_kwargs(n_axes: int) -> dict:
    return {} if AxisType is None else {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh with the same axis-type convention (tests, examples)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_mesh_kwargs(len(axes)))


def make_host_mesh(max_devices: int | None = None, axes=("data", "model")):
    """Best-effort mesh over whatever local devices exist (CPU tests)."""
    n = len(jax.devices()) if max_devices is None else max_devices
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return make_mesh((n // model, model), axes)
