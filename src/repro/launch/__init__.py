"""Launch layer: meshes, jit step builders, dry-run, train/serve drivers."""
