"""Launch layer: meshes, jit step builders, dry-run, train/serve
drivers, and the multi-process gang launcher/supervisor
(:mod:`repro.launch.multihost`)."""
