"""LM-decode example driver — NOT the simulation-serving entry point.

The production serving layer for the repo's headline workload
(iterative stencil solves) is :mod:`repro.serve`::

    PYTHONPATH=src python -m repro.serve --demo

which provides the hardened path: a bounded request queue with
backpressure and load-shedding, continuous batching with per-sample
convergence masking, per-request deadlines, NaN/Inf quarantine via the
device-resident finite guard, retry-with-backoff, and a worker
circuit-breaker/supervisor. See the README's "Serving" section and
``repro/serve/__init__.py`` for the API.

This module remains as the minimal *sequence-model* analogue used by
``examples/serve_lm.py`` and the system test: one jitted prefill then a
jitted single-token decode step (greedy or temperature sampling) over a
fixed synthetic batch — a shape-reference for decode-style serving, with
none of the robustness machinery. Its ``__main__`` forwards to
``repro.serve`` unless ``--arch`` explicitly selects the LM demo::

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
        --batch 4 --prompt-len 32 --gen-len 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..distributed import sharding as shd
from ..models import build, RunConfig, synth_batch
from . import mesh as mesh_mod
from . import steps as steps_mod


@dataclasses.dataclass
class ServeConfig:
    batch: int = 4
    prompt_len: int = 32
    gen_len: int = 32
    temperature: float = 0.0
    seed: int = 0


def serve(arch: str, scfg: ServeConfig, rc: Optional[RunConfig] = None,
          smoke: bool = False, mesh=None,
          rules: shd.ShardRules = shd.DEFAULT_RULES, log_fn=print):
    cfg = configs.get_smoke(arch) if smoke else configs.get_arch(arch)
    rc = rc or RunConfig(param_dtype="float32", remat=False)
    model = build(cfg, rc)
    if mesh is None:
        mesh = mesh_mod.make_host_mesh()
    rules = rules.for_mesh(mesh)
    max_seq = scfg.prompt_len + scfg.gen_len

    params, _ = model.init(jax.random.PRNGKey(scfg.seed))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_seq))
    decode = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos),
                     donate_argnums=(2,))

    key = jax.random.PRNGKey(scfg.seed + 1)
    batch = synth_batch(model, key, scfg.prompt_len, scfg.batch, mode="prefill")

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    def sample(logits, key):
        if scfg.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(key, logits / scfg.temperature).astype(jnp.int32)

    toks = [sample(logits, key)]
    t0 = time.perf_counter()
    for i in range(scfg.gen_len - 1):
        key, k = jax.random.split(key)
        pos = jnp.asarray(scfg.prompt_len + i, jnp.int32)
        logits, cache = decode(params, toks[-1], cache, pos)
        toks.append(sample(logits, k))
    jax.block_until_ready(toks[-1])
    t_decode = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in toks], axis=1)
    tok_s = scfg.batch * (scfg.gen_len - 1) / max(t_decode, 1e-9)
    log_fn(f"prefill {scfg.batch}x{scfg.prompt_len} in {t_prefill*1e3:.0f} ms; "
           f"decode {scfg.gen_len-1} steps @ {tok_s:.1f} tok/s")
    return gen, {"t_prefill_s": t_prefill, "t_decode_s": t_decode,
                 "tok_per_s": tok_s}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="LM-decode example driver. For simulation serving "
                    "use `python -m repro.serve --demo` (repro.serve).")
    ap.add_argument("--arch", choices=list(configs.ARCH_IDS),
                    help="run the LM-decode example for this arch; "
                         "without it, forwards to repro.serve")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args, rest = ap.parse_known_args(argv)
    if args.arch is None:
        # the documented serving entry point lives in repro.serve
        from ..serve.__main__ import main as serve_main

        return serve_main(rest or ["--demo"])
    serve(args.arch, ServeConfig(batch=args.batch, prompt_len=args.prompt_len,
                                 gen_len=args.gen_len,
                                 temperature=args.temperature),
          smoke=args.smoke)
    return 0


if __name__ == "__main__":
    main()
