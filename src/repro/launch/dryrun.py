import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with zero real allocation (ShapeDtypeStruct
stand-ins):
  * compiled.memory_analysis()  — proves the cell fits per-device HBM;
  * compiled.cost_analysis()    — FLOPs / bytes for §Roofline;
  * the partitioned HLO's collective ops (parsed) — collective roofline;
  * wall compile time.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from .. import configs
from ..distributed import sharding as shd
from ..models import build, RunConfig
from ..optim import adamw
from . import hlo_analysis
from . import mesh as mesh_mod
from . import roofline as rf
from . import steps as steps_mod


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules: shd.ShardRules | None = None,
             rc: RunConfig | None = None,
             extra_xla_text: bool = False) -> dict:
    """Lower+compile one cell; returns a JSON-able record."""
    cfg = configs.get_arch(arch)
    shape = configs.SHAPES[shape_name]
    ok, why = configs.cell_runnable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.mode, "runnable": ok}
    if not ok:
        rec["skip_reason"] = why
        return rec
    if rc is None:
        # microbatching policy (§Perf): gradient accumulation shrinks the
        # remat-saved (layers, B, L, D) stack to fit 16 GB HBM now that
        # activations are not sequence-sharded (tuned_rules).
        size = cfg.d_model * cfg.n_layers
        n_micro = (16 if size >= 512 * 1024 else
                   8 if size >= 64 * 1024 else
                   4 if size >= 24 * 1024 else 1)
        rc = RunConfig(n_microbatch=n_micro)
    model = build(cfg, rc)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    if rules is None:
        rules = shd.tuned_rules(cfg, mesh)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    if shape.mode == "train":
        opt_cfg = adamw.AdamWConfig(lr=rc.lr, beta1=rc.beta1, beta2=rc.beta2,
                                    weight_decay=rc.weight_decay,
                                    grad_clip=rc.grad_clip, schedule=rc.schedule,
                                    warmup_steps=rc.warmup_steps,
                                    total_steps=rc.total_steps)
        bundle = steps_mod.make_train_step(model, mesh, rules, opt_cfg,
                                           shape.seq_len, shape.global_batch,
                                           n_micro=rc.n_microbatch)
        mf = rf.model_flops_train(cfg, shape.seq_len, shape.global_batch)
    elif shape.mode == "prefill":
        bundle = steps_mod.make_prefill_step(model, mesh, rules,
                                             shape.seq_len, shape.global_batch)
        mf = rf.model_flops_prefill(cfg, shape.seq_len, shape.global_batch)
    else:  # decode
        bundle = steps_mod.make_decode_step(model, mesh, rules,
                                            shape.seq_len, shape.global_batch)
        mf = rf.model_flops_decode(cfg, shape.global_batch)

    with mesh:
        jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                         out_shardings=bundle.out_shardings,
                         donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.abstract_inputs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {}
    if mem is not None:
        for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                mem_rec[f] = int(v)
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    cost = dict(cost) if cost else {}

    # Trip-count-aware HLO walk (hlo_analysis.py): cost_analysis counts scan
    # bodies once (calibrated in tests/test_hlo_analysis.py), so FLOPs/bytes/
    # collective bytes all come from the analyzer; raw cost_analysis is kept
    # for reference.
    hlo = compiled.as_text()
    mc = hlo_analysis.ModuleCost(hlo).cost()
    roof = rf.analyze_walk(mc, n_dev, model_flops_global=mf)
    ab = rf.analytic_bytes(cfg, shape.mode, shape.seq_len, shape.global_batch,
                           n_dev, tensor_shard=mesh.shape.get("model", 1),
                           n_micro=rc.n_microbatch)
    rec.update({
        "n_devices": n_dev,
        "n_microbatch": rc.n_microbatch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "cost_raw_xla": {k: float(v) for k, v in cost.items()
                         if isinstance(v, (int, float)) and k in
                         ("flops", "bytes accessed")},
        "collectives": {"counts": mc.coll_counts, "wire_bytes": mc.coll_wire},
        "roofline": roof.to_json(),
        "analytic_bytes": ab,
        "t_memory_analytic": ab / rf.HBM_BW,
    })
    if extra_xla_text:
        rec["hlo_head"] = hlo[:4000]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--naive", action="store_true",
                    help="paper-faithful naive rules (pure DP) baseline")
    args = ap.parse_args()

    archs = list(configs.ARCH_IDS) if args.arch == "all" or args.all \
        else args.arch.split(",")
    shapes = list(configs.SHAPES) if args.shape == "all" or args.all \
        else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    rules = shd.NAIVE_RULES if args.naive else None  # None -> tuned per arch

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.naive:
                    tag += "__naive"
                out_path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_cell(arch, shape, mp, rules=rules)
                except Exception as e:  # a failing cell is a bug — record it
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "runnable": True, "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    n_fail += 1
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("SKIP" if not rec.get("runnable") else
                          ("FAIL" if "error" in rec else "ok"))
                roof = rec.get("roofline", {})
                print(f"[{status}] {tag} dom={roof.get('dominant','-')} "
                      f"compile={rec.get('compile_s','-')}s", flush=True)
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
