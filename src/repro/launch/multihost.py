"""True multi-process operation: launcher, rendezvous, gang supervisor.

Everything below runs the SAME ``elastic_solve_until`` that single-
process tests exercise — across genuinely separate OS processes joined
by ``jax.distributed``. Three layers:

  * :func:`initialize` — the per-process entry: resolves rank/world/
    coordinator from arguments or the ``REPRO_*`` environment, selects
    the gloo CPU collectives backend (CPU CI runs real cross-process
    collectives), and drives ``jax.distributed.initialize`` through a
    retrying, timeout-guarded rendezvous. A coordinator that is down, a
    joiner past the deadline, or a peer that died mid-init all surface
    as a pointed :class:`RendezvousError` within the configured budget —
    never an indefinite hang. Backoff between attempts goes through
    :func:`repro.distributed.fault.retry`;
    ``FaultPlan.kill_at_rendezvous`` injects mid-init death.

  * :class:`Supervisor` — the gang watcher: spawns one worker process
    per rank, namespaces their filesystem heartbeats by a per-attempt
    run id (and retires stale files from previous runs), and polls two
    liveness signals — exit codes and heartbeat staleness. One failed
    rank SIGTERMs then SIGKILLs the stragglers (peers wedge inside gloo
    collectives when a rank dies mid-step), re-plans the world to the
    largest checkpoint-compatible size, and relaunches; the workers'
    own checkpoint/resume logic carries the solve state across, so a
    SIGKILLed rank costs one restart and zero operator intervention.

  * the CLI — ``python -m repro.launch.multihost`` launches either the
    built-in demo solve (``--demo``, used by CI's multi-process smoke
    job) or an arbitrary per-rank command template::

        python -m repro.launch.multihost --world 4 --demo \
            --kill-rank 1 --kill-at 20      # supervised recovery demo
        python -m repro.launch.multihost --world 4 -- \
            python my_worker.py             # your own worker

Worker processes see ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` /
``REPRO_PROCESS_ID`` / ``REPRO_RUN_ID`` / ``REPRO_HEARTBEAT_DIR`` and
call :func:`initialize` with no arguments.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import socket
import subprocess
import sys
import time
from typing import Callable, Optional, Sequence

from ..distributed import fault

__all__ = [
    "RendezvousError", "DistContext", "Supervisor", "SuperviseOutcome",
    "initialize", "free_port", "default_coordinator",
    "kill_process", "heartbeat_ages",
    "ENV_COORDINATOR", "ENV_NUM_PROCESSES", "ENV_PROCESS_ID",
    "ENV_RUN_ID", "ENV_HEARTBEAT_DIR",
    "STALE_EXIT_CODE", "DEADLINE_EXIT_CODE",
]

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"
ENV_RUN_ID = "REPRO_RUN_ID"
ENV_HEARTBEAT_DIR = "REPRO_HEARTBEAT_DIR"

# supervisor-assigned exit reasons for ranks IT terminated (real worker
# exits keep their own codes; fault.KILL_EXIT_CODE marks planned kills)
STALE_EXIT_CODE = 114      # heartbeat went stale -> SIGKILLed as wedged
DEADLINE_EXIT_CODE = 115   # attempt exceeded its wall-clock deadline


class RendezvousError(RuntimeError):
    """``jax.distributed`` bring-up failed within the configured budget:
    coordinator unreachable, a joiner missed the deadline, or a peer
    died mid-init. Carries enough context to act on (who we dialed, as
    which rank, how long we tried)."""


def free_port() -> int:
    """An OS-assigned free TCP port (racy by nature — callers re-pick a
    fresh coordinator per attempt, so a rare collision costs one retry)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def default_coordinator() -> str:
    return f"127.0.0.1:{free_port()}"


@dataclasses.dataclass(frozen=True)
class DistContext:
    """What :func:`initialize` hands the worker: its place in the gang
    plus the liveness plumbing the supervisor watches."""

    rank: int
    world: int
    coordinator: Optional[str]
    run_id: Optional[str]
    heartbeat_dir: Optional[str]

    def monitor(self, timeout_s: float = 30.0,
                straggler_factor: float = 1.5) -> Optional[fault.StepMonitor]:
        """A run-id-namespaced :class:`~repro.distributed.fault.
        StepMonitor` bumping this rank's heartbeat (None when the
        launcher gave no heartbeat dir)."""
        if not self.heartbeat_dir:
            return None
        return fault.StepMonitor(
            host_id=self.rank, heartbeat_dir=self.heartbeat_dir,
            straggler_factor=straggler_factor, timeout_s=timeout_s,
            run_id=self.run_id)


def _env_int(name: str) -> Optional[int]:
    val = os.environ.get(name)
    return int(val) if val not in (None, "") else None


def _await_coordinator(coordinator: str, deadline_s: float,
                       probe_s: float = 1.0) -> None:
    """Block until something is LISTENING at ``coordinator`` or raise
    :class:`ConnectionError` after ``deadline_s``.

    This probe runs before ``jax.distributed.initialize`` on
    non-coordinator ranks because XLA's distributed client does not
    surface connect failures as Python exceptions — its error-polling
    thread terminates the whole process with ``LOG(FATAL)`` on a
    RegisterTask deadline. Probing first keeps the coordinator-down
    failure mode catchable (and retryable) in-process."""
    host, _, port = coordinator.rpartition(":")
    deadline = time.monotonic() + deadline_s
    while True:
        try:
            with socket.create_connection((host, int(port)), timeout=probe_s):
                return
        except OSError as e:
            if time.monotonic() >= deadline:
                raise ConnectionError(
                    f"nothing listening at coordinator {coordinator} "
                    f"within {deadline_s:.0f}s") from e
            time.sleep(min(probe_s, 0.2))


def initialize(coordinator: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None, *,
               timeout_s: float = 60.0,
               attempts: int = 2,
               backoff_s: float = 0.5,
               cpu_collectives: str = "gloo") -> DistContext:
    """Join the gang: ``jax.distributed.initialize`` with a bounded,
    retrying rendezvous. Arguments default from the ``REPRO_*``
    environment (set by :class:`Supervisor`); with no world configured
    this is a no-op returning a single-process context.

    Must run before any device-touching jax call — the CPU collectives
    backend can only be selected while the backend is uninitialized.
    Each attempt is bounded by ``timeout_s`` (jax's own
    ``initialization_timeout``); failures back off through
    :func:`fault.retry` and, once ``attempts`` are exhausted, raise a
    pointed :class:`RendezvousError` — never an indefinite hang."""
    coordinator = coordinator or os.environ.get(ENV_COORDINATOR) or None
    num_processes = (num_processes if num_processes is not None
                     else _env_int(ENV_NUM_PROCESSES))
    process_id = (process_id if process_id is not None
                  else _env_int(ENV_PROCESS_ID))
    run_id = os.environ.get(ENV_RUN_ID) or None
    hb_dir = os.environ.get(ENV_HEARTBEAT_DIR) or None

    if coordinator is None and (num_processes is None or num_processes <= 1):
        _rank_telemetry(0)
        return DistContext(rank=0, world=1, coordinator=None,
                           run_id=run_id, heartbeat_dir=hb_dir)
    if coordinator is None or num_processes is None or process_id is None:
        raise RendezvousError(
            "incomplete rendezvous config: need coordinator, num_processes "
            f"and process_id (got {coordinator!r}, {num_processes!r}, "
            f"{process_id!r}) — set {ENV_COORDINATOR}/{ENV_NUM_PROCESSES}/"
            f"{ENV_PROCESS_ID} or pass them explicitly")

    import jax

    if cpu_collectives:
        try:
            jax.config.update("jax_cpu_collectives_implementation",
                              cpu_collectives)
        except Exception:
            pass  # older jax: option absent; CPU collectives unavailable

    plan = fault.FaultPlan.active()
    state = {"attempt": 0}

    def attempt_once():
        state["attempt"] += 1
        if plan is not None:
            # plans arrive via this rank's own env, so no rank filter
            plan.on_rendezvous(state["attempt"])
        if process_id != 0:
            # rank 0 IS the coordinator; everyone else verifies it is up
            # before entering XLA (see _await_coordinator)
            _await_coordinator(coordinator, deadline_s=timeout_s)
        try:
            jax.distributed.initialize(
                coordinator_address=coordinator,
                num_processes=num_processes,
                process_id=process_id,
                initialization_timeout=max(int(timeout_s), 1))
        except Exception:
            try:
                jax.distributed.shutdown()
            except Exception:
                pass
            raise

    try:
        fault.retry(attempt_once, attempts=max(int(attempts), 1),
                    backoff_s=backoff_s, max_backoff_s=10.0,
                    exceptions=(RuntimeError, OSError, ValueError,
                                ConnectionError))
    except Exception as e:
        raise RendezvousError(
            f"rank {process_id}/{num_processes} failed to rendezvous with "
            f"coordinator {coordinator} after {state['attempt']} attempt(s) "
            f"x {timeout_s:.0f}s: {type(e).__name__}: {e} — check that the "
            "coordinator process is up, the address is reachable, and all "
            f"{num_processes} processes launched within the timeout") from e

    _rank_telemetry(process_id)
    return DistContext(rank=process_id, world=num_processes,
                       coordinator=coordinator, run_id=run_id,
                       heartbeat_dir=hb_dir)


def _rank_telemetry(rank: int) -> None:
    """Split the env-enabled telemetry stream per rank (rank-stamped
    records into ``rank_<i>.jsonl`` — see ``telemetry.report --merge``)."""
    from .. import telemetry
    telemetry.configure_rank(rank)


# ---------------------------------------------------------------------------
# process plumbing shared by the gang supervisor and the serve worker pool
# ---------------------------------------------------------------------------
def kill_process(proc: subprocess.Popen, grace_s: float = 3.0) -> int:
    """SIGTERM, wait up to ``grace_s``, then SIGKILL. Returns the exit
    code (negative = died by signal)."""
    if proc.poll() is None:
        try:
            proc.terminate()
        except OSError:
            pass
        try:
            proc.wait(timeout=grace_s)
        except subprocess.TimeoutExpired:
            try:
                proc.kill()
            except OSError:
                pass
            proc.wait()
    return proc.returncode


def heartbeat_ages(hb: fault.Heartbeat,
                   now: Optional[float] = None) -> dict[int, float]:
    """Seconds since each rank's last bump (ranks that never bumped are
    absent — cover them with an attempt deadline, not staleness)."""
    now = time.time() if now is None else now
    return {r: now - b["t"] for r, b in hb.read_all().items()}


@dataclasses.dataclass
class AttemptReport:
    attempt: int
    world: int
    run_id: str
    exit_codes: dict[int, int]
    reason: str
    duration_s: float


@dataclasses.dataclass
class SuperviseOutcome:
    restarts: int
    final_world: int
    exit_codes: list[int]          # per-attempt root-cause codes
    reports: list[AttemptReport]


class Supervisor:
    """Spawn-and-watch loop for one gang of worker processes.

    ``build_cmd(rank, world, attempt)`` returns the argv for one worker;
    ``rank_env(rank, world, attempt)`` optional per-rank env extras
    (fault-plan injection lives here). Each attempt gets a fresh
    coordinator port and a fresh run id (``<run_id>-a<attempt>``), so
    heartbeats from a dead attempt can never vouch for the new one;
    stale files are retired before spawning.

    Failure handling per attempt: the first nonzero exit code — or a
    heartbeat older than ``heartbeat_timeout_s`` (a wedged rank is
    SIGKILLed and charged :data:`STALE_EXIT_CODE`) — terminates the
    stragglers after ``grace_s`` and ends the attempt;
    ``attempt_deadline_s`` bounds everything else (rendezvous hangs,
    never-bumped ranks). :meth:`run` then re-plans the world via
    ``replan(world, rc)`` and relaunches, up to ``max_restarts``."""

    def __init__(self, build_cmd: Callable[[int, int, int], list[str]],
                 world: int, *,
                 heartbeat_dir: str,
                 run_id: Optional[str] = None,
                 heartbeat_timeout_s: float = 30.0,
                 grace_s: float = 3.0,
                 attempt_deadline_s: float = 600.0,
                 poll_s: float = 0.05,
                 env: Optional[dict] = None,
                 rank_env: Optional[Callable[[int, int, int], dict]] = None,
                 replan: Optional[Callable[[int, int], int]] = None,
                 max_restarts: int = 3,
                 verbose: bool = False):
        self.build_cmd = build_cmd
        self.world = int(world)
        self.heartbeat_dir = heartbeat_dir
        self.run_id = run_id or f"run{os.getpid()}"
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.grace_s = grace_s
        self.attempt_deadline_s = attempt_deadline_s
        self.poll_s = poll_s
        self.env = dict(env or {})
        self.rank_env = rank_env
        self.replan = replan
        self.max_restarts = max_restarts
        self.verbose = verbose
        self.reports: list[AttemptReport] = []
        os.makedirs(heartbeat_dir, exist_ok=True)

    def _say(self, msg: str) -> None:
        if self.verbose:
            print(f"[supervisor] {msg}", flush=True)

    def _base_env(self, attempt_run_id: str, coordinator: str,
                  world: int) -> dict:
        env = dict(os.environ)
        # each worker is exactly ONE process with ONE local CPU device;
        # an inherited fake-device flag would multiply the global mesh
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
        env["XLA_FLAGS"] = " ".join(flags)
        env.setdefault("JAX_PLATFORMS", "cpu")
        env.pop(fault.PLAN_ENV, None)   # plans are per-rank, via rank_env
        env[ENV_COORDINATOR] = coordinator
        env[ENV_NUM_PROCESSES] = str(world)
        env[ENV_RUN_ID] = attempt_run_id
        env[ENV_HEARTBEAT_DIR] = self.heartbeat_dir
        env.update(self.env)
        return env

    def run_attempt(self, attempt: int, world: int) -> int:
        """One gang launch to completion or first failure. Returns the
        attempt's root-cause exit code (0 = every rank exited 0)."""
        attempt_run_id = f"{self.run_id}-a{attempt}"
        fault.Heartbeat.retire_stale(self.heartbeat_dir)
        coordinator = default_coordinator()
        base = self._base_env(attempt_run_id, coordinator, world)
        t0 = time.monotonic()
        procs: dict[int, subprocess.Popen] = {}
        logs = []
        try:
            for rank in range(world):
                env = dict(base)
                env[ENV_PROCESS_ID] = str(rank)
                if self.rank_env is not None:
                    env.update(self.rank_env(rank, world, attempt) or {})
                log = open(os.path.join(
                    self.heartbeat_dir, f"{attempt_run_id}.rank{rank}.log"),
                    "wb")
                logs.append(log)
                procs[rank] = subprocess.Popen(
                    self.build_cmd(rank, world, attempt), env=env,
                    stdout=log, stderr=subprocess.STDOUT)
            self._say(f"attempt {attempt}: world={world} "
                      f"coordinator={coordinator} run_id={attempt_run_id}")
            rcs, reason = self._watch(procs, attempt_run_id, world)
        finally:
            for proc in procs.values():
                kill_process(proc, self.grace_s)
            for log in logs:
                log.close()
        root = self._root_cause(rcs)
        self.reports.append(AttemptReport(
            attempt=attempt, world=world, run_id=attempt_run_id,
            exit_codes=rcs, reason=reason,
            duration_s=time.monotonic() - t0))
        self._say(f"attempt {attempt}: rc={root} codes={rcs} ({reason})")
        return root

    def _watch(self, procs: dict[int, subprocess.Popen],
               attempt_run_id: str, world: int) -> tuple[dict[int, int], str]:
        hb = fault.Heartbeat(self.heartbeat_dir,
                             timeout_s=self.heartbeat_timeout_s,
                             run_id=attempt_run_id)
        deadline = time.monotonic() + self.attempt_deadline_s
        rcs: dict[int, int] = {}
        while True:
            for rank, proc in procs.items():
                if rank not in rcs and proc.poll() is not None:
                    rcs[rank] = proc.returncode
            live = [r for r in procs if r not in rcs]
            failed = sorted(r for r, c in rcs.items() if c != 0)
            if failed:
                reason = (f"rank(s) {failed} exited "
                          f"{[rcs[r] for r in failed]}; terminating "
                          f"{len(live)} straggler(s)")
                for rank in live:
                    rcs[rank] = kill_process(procs[rank], self.grace_s)
                return rcs, reason
            if not live:
                return rcs, "all ranks exited 0"
            ages = heartbeat_ages(hb)
            stale = sorted(r for r in live
                           if ages.get(r, 0.0) > self.heartbeat_timeout_s)
            if stale:
                reason = (f"rank(s) {stale} heartbeat stale "
                          f"(> {self.heartbeat_timeout_s:.0f}s) — SIGKILL")
                for rank in stale:
                    try:
                        procs[rank].kill()
                    except OSError:
                        pass
                    procs[rank].wait()
                    rcs[rank] = STALE_EXIT_CODE
                for rank in live:
                    if rank not in rcs:
                        rcs[rank] = kill_process(procs[rank], self.grace_s)
                return rcs, reason
            if time.monotonic() > deadline:
                reason = (f"attempt deadline {self.attempt_deadline_s:.0f}s "
                          "exceeded — terminating the gang")
                for rank in live:
                    kill_process(procs[rank], self.grace_s)
                    rcs[rank] = DEADLINE_EXIT_CODE
                return rcs, reason
            time.sleep(self.poll_s)

    @staticmethod
    def _root_cause(rcs: dict[int, int]) -> int:
        """The attempt's exit code: prefer a planned kill, then the first
        positive code (a real worker failure), then any nonzero
        (supervisor-terminated stragglers exit by signal = negative)."""
        codes = [rcs[r] for r in sorted(rcs)]
        if all(c == 0 for c in codes):
            return 0
        if fault.KILL_EXIT_CODE in codes:
            return fault.KILL_EXIT_CODE
        for c in codes:
            if c > 0:
                return c
        return next(c for c in codes if c != 0)

    def run(self) -> SuperviseOutcome:
        """The full supervised loop (delegates restart policy to
        :func:`repro.distributed.elastic.supervise`)."""
        from ..distributed import elastic

        restarts, final_world, codes = elastic.supervise(
            self.run_attempt, self.world,
            replan=self.replan, max_restarts=self.max_restarts)
        return SuperviseOutcome(restarts=restarts, final_world=final_world,
                                exit_codes=codes, reports=self.reports)


# ---------------------------------------------------------------------------
# built-in demo worker (CI smoke: real 4-process kill/replan/resume)
# ---------------------------------------------------------------------------
def _demo_worker() -> int:
    """One rank of the demo solve: rendezvous, then the same diffusion
    ``elastic_solve_until`` the single-process tests run — checkpointing
    globally so any later (smaller) world resumes it."""
    ctx = initialize(timeout_s=float(os.environ.get("REPRO_DEMO_RDV_S", 20)))

    import numpy as np

    from ..core import fd3d, init_parallel_stencil, iterate
    from ..distributed import elastic

    n = int(os.environ.get("REPRO_DEMO_N", 18))
    max_iters = int(os.environ.get("REPRO_DEMO_ITERS", 40))
    hb_timeout = float(os.environ.get("REPRO_DEMO_HB_S", 30))

    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"},
                 reductions={"err": "max_abs_diff(T2, T)"})
    def kern(T2, T, dt):
        return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                          + fd3d.d2_zi(T))}

    factors = elastic.plan_factors(ctx.world, 1)
    elastic.validate_stencil_factors((n, n, n), factors, radius=1)
    rng = np.random.RandomState(0)
    T0 = np.asarray(rng.rand(n, n, n), np.float32)
    ck = iterate.Checkpointing(
        os.environ["REPRO_DEMO_CKPT"], save_every=1, blocking=True,
        monitor=ctx.monitor(timeout_s=hb_timeout))
    res = elastic.elastic_solve_until(
        kern, dict(T2=T0, T=T0), dict(dt=1e-3), factors=factors,
        tol=0.0, max_iters=max_iters, exchange=("T",), check_every=4,
        checkpoint=ck)
    if ctx.rank == 0 and os.environ.get("REPRO_DEMO_OUT"):
        np.save(os.environ["REPRO_DEMO_OUT"], np.asarray(res.fields["T"]))
    print(f"DONE rank={ctx.rank} world={ctx.world} iters={int(res.iters)} "
          f"resumed_from={res.resumed_from}", flush=True)
    return 0


def demo_supervisor(world: int, workdir: str, *,
                    n: int = 18, max_iters: int = 40,
                    kill_rank: Optional[int] = None,
                    kill_at: Optional[int] = None,
                    kill_at_rendezvous: Optional[int] = None,
                    heartbeat_timeout_s: float = 30.0,
                    attempt_deadline_s: float = 240.0,
                    rendezvous_timeout_s: float = 20.0,
                    max_restarts: int = 3,
                    run_id: Optional[str] = None,
                    verbose: bool = True) -> Supervisor:
    """The supervised demo gang (also the CI smoke harness): optionally
    SIGKILL-injects ``kill_rank`` at iteration ``kill_at`` (or on entry
    to rendezvous attempt ``kill_at_rendezvous``) on attempt 0 via
    ``REPRO_FAULT_PLAN``, and re-plans with
    :func:`~repro.distributed.elastic.plan_compatible` so the shrunken
    world still divides the grid."""
    from ..distributed import elastic

    shape = (n, n, n)
    world, _ = _compatible_or_raise(shape, world)

    def build_cmd(rank: int, w: int, attempt: int) -> list[str]:
        return [sys.executable, "-m", "repro.launch.multihost", "--worker"]

    def rank_env(rank: int, w: int, attempt: int) -> dict:
        env = {
            "REPRO_DEMO_N": str(n),
            "REPRO_DEMO_ITERS": str(max_iters),
            "REPRO_DEMO_HB_S": str(heartbeat_timeout_s),
            "REPRO_DEMO_RDV_S": str(rendezvous_timeout_s),
            "REPRO_DEMO_CKPT": os.path.join(workdir, "ckpt"),
            "REPRO_DEMO_OUT": os.path.join(workdir, "out.npy"),
        }
        if attempt == 0 and kill_rank == rank:
            if kill_at is not None:
                env[fault.PLAN_ENV] = fault.FaultPlan(
                    kill_at_step=kill_at).to_env()
            elif kill_at_rendezvous is not None:
                env[fault.PLAN_ENV] = fault.FaultPlan(
                    kill_at_rendezvous=kill_at_rendezvous).to_env()
        return env

    def replan(w: int, rc: int) -> int:
        return elastic.plan_compatible(shape, 1, max(w - 1, 1))[0]

    return Supervisor(
        build_cmd, world,
        heartbeat_dir=os.path.join(workdir, "hb"),
        run_id=run_id, heartbeat_timeout_s=heartbeat_timeout_s,
        attempt_deadline_s=attempt_deadline_s, rank_env=rank_env,
        replan=replan, max_restarts=max_restarts, verbose=verbose)


def _compatible_or_raise(shape: Sequence[int], world: int) -> tuple[int, tuple]:
    from ..distributed import elastic

    w, factors = elastic.plan_compatible(shape, 1, world)
    if w != world:
        raise ValueError(
            f"world {world} does not decompose grid {tuple(shape)} "
            f"(radius 1); largest compatible world is {w}")
    return w, factors


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.multihost",
        description="multi-process launcher/supervisor (see module doc)")
    ap.add_argument("--worker", action="store_true",
                    help="internal: run one demo worker rank (env-driven)")
    ap.add_argument("--demo", action="store_true",
                    help="run the supervised demo solve")
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--workdir", default="multihost_demo")
    ap.add_argument("--n", type=int, default=18)
    ap.add_argument("--max-iters", type=int, default=40)
    ap.add_argument("--kill-rank", type=int, default=None,
                    help="demo: SIGKILL this rank on attempt 0 ...")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="... at this iteration (exercises recovery)")
    ap.add_argument("--heartbeat-timeout", type=float, default=30.0)
    ap.add_argument("--deadline", type=float, default=240.0,
                    help="per-attempt wall-clock bound (s)")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--run-id", default=None)
    ap.add_argument("cmd", nargs="*",
                    help="worker argv (after --) for non-demo gangs")
    args = ap.parse_args(argv)

    if args.worker:
        return _demo_worker()

    if args.demo:
        sup = demo_supervisor(
            args.world, args.workdir, n=args.n, max_iters=args.max_iters,
            kill_rank=args.kill_rank, kill_at=args.kill_at,
            heartbeat_timeout_s=args.heartbeat_timeout,
            attempt_deadline_s=args.deadline,
            max_restarts=args.max_restarts, run_id=args.run_id)
        out = sup.run()
        print(json.dumps({
            "restarts": out.restarts, "final_world": out.final_world,
            "exit_codes": out.exit_codes,
            "attempts": [dataclasses.asdict(r) for r in out.reports],
        }, indent=2))
        return 0

    if not args.cmd:
        ap.error("pass --demo, --worker, or a worker command after --")
    sup = Supervisor(
        lambda rank, world, attempt: list(args.cmd), args.world,
        heartbeat_dir=os.path.join(args.workdir, "hb"),
        run_id=args.run_id, heartbeat_timeout_s=args.heartbeat_timeout,
        attempt_deadline_s=args.deadline, max_restarts=args.max_restarts,
        verbose=True)
    out = sup.run()
    print(json.dumps({"restarts": out.restarts,
                      "final_world": out.final_world,
                      "exit_codes": out.exit_codes}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
