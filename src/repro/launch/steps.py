"""Jittable train / serve steps with full sharding resolution.

This is the glue between the model zoo, the optimizer, and the mesh:
  * resolve every parameter's logical names -> NamedSharding;
  * optimizer state shadows parameter shardings;
  * batch / cache shardings per DESIGN.md §5 (batch over ("pod","data"),
    KV-cache sequence over "model" — plus "data" when batch == 1, i.e. the
    long_500k flash-decoding layout);
  * build (step_fn, in_shardings, out_shardings) ready for jax.jit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import sharding as shd
from ..models.model import Model
from ..optim import adamw


# ---------------------------------------------------------------------------
# sharding resolution
# ---------------------------------------------------------------------------
def param_shardings(model: Model, mesh: Mesh, rules: shd.ShardRules):
    shapes, logical = model.abstract_params()
    is_tpl = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def resolve(lg, sh):
        return NamedSharding(mesh, shd.logical_to_spec(mesh, rules, lg, sh.shape))

    specs = jax.tree.map(resolve, logical, shapes,
                         is_leaf=lambda x: is_tpl(x))
    return shapes, specs


def opt_shardings(opt_state_shapes, p_shard, mesh: Mesh):
    """m/v/master shadow the param shardings; count is replicated."""
    rep = NamedSharding(mesh, P())
    out = {}
    for k, v in opt_state_shapes.items():
        out[k] = rep if k == "count" else p_shard
    return out


def batch_shardings(batch_specs, mesh: Mesh, rules: shd.ShardRules):
    def one(s):
        if s.ndim == 0:
            return NamedSharding(mesh, P())
        logical = ["batch"] + [None] * (s.ndim - 1)
        return NamedSharding(mesh, shd.logical_to_spec(mesh, rules, logical, s.shape))
    return jax.tree.map(one, batch_specs)


def cache_shardings(cache_shapes, mesh: Mesh, rules: shd.ShardRules,
                    global_batch: int):
    """KV caches (Ln, B, Hkv, S, Dh): B->batch, S->model (+data if B==1).
    Mamba states (Ln, B, H, P, N): H->tensor. Conv (Ln, B, K-1, Cin): Cin->tensor.
    Cross-attn memory (Ln, B, Hkv, S_src, Dh): like KV but source stays
    unsharded in seq (short)."""
    rules = rules.for_mesh(mesh)
    seq_axes = [rules.tensor] if rules.tensor else []
    if global_batch == 1:
        seq_axes = [a for a in (rules.fsdp, rules.tensor) if a]

    def one(name, s):
        if name in ("k", "v"):      # (Ln, B, Hkv, S, Dh): shard S (flash-decoding)
            spec = list(shd.logical_to_spec(
                mesh, rules, [None, "batch", None, None, None], s.shape))
            joint, sel = 1, []
            for a in seq_axes:
                if s.shape[3] % (joint * _size(mesh, a)) == 0:
                    sel.append(a)
                    joint *= _size(mesh, a)
            if sel:
                spec[3] = tuple(sel) if len(sel) > 1 else sel[0]
            return NamedSharding(mesh, P(*spec))
        if name in ("mk", "mv"):    # cross-attn memory (Ln, B, Hkv, S_src, Dh)
            return NamedSharding(mesh, shd.logical_to_spec(
                mesh, rules, [None, "batch", None, None, None], s.shape))
        if name == "ssm":           # (Ln, B, H, P, N)
            return NamedSharding(mesh, shd.logical_to_spec(
                mesh, rules, [None, "batch", "tensor", None, None], s.shape))
        if name == "conv":          # (Ln, B, K-1, Cin)
            return NamedSharding(mesh, shd.logical_to_spec(
                mesh, rules, [None, "batch", None, "tensor"], s.shape))
        logical = [None, "batch"] + [None] * (s.ndim - 2)
        return NamedSharding(mesh, shd.logical_to_spec(mesh, rules, logical, s.shape))

    from ..compat import tree_flatten_with_path

    flat, treedef = tree_flatten_with_path(cache_shapes)
    out = []
    for kp, v in flat:
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out.append(one(path[-1] if path else "", v))
    return treedef.unflatten(out)


def _size(mesh, axes):
    import numpy as np
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    return int(np.prod([mesh.shape[a] for a in axes]))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StepBundle:
    fn: Any
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()
    abstract_inputs: tuple = ()


def make_train_step(model: Model, mesh: Mesh, rules: shd.ShardRules,
                    opt_cfg: adamw.AdamWConfig, seq_len: int,
                    global_batch: int, n_micro: int = 1) -> StepBundle:
    p_shapes, p_shard = param_shardings(model, mesh, rules)
    opt_shapes = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), p_shapes)
    o_shard = opt_shardings(opt_shapes, p_shard, mesh)
    b_specs = model.input_specs(seq_len, global_batch, "train")
    b_shard = batch_shardings(b_specs, mesh, rules)

    def constrain(x, logical):
        return shd.constrain(x, mesh, rules, logical)

    def train_step(params, opt_state, batch):
        if n_micro > 1:
            # gradient accumulation: scan over microbatches; activation
            # footprint shrinks by n_micro at the cost of an f32 grad
            # accumulator (param-sized, already sharded like the params).
            mb = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)
            grads, loss = adamw.accumulate_grads(
                lambda p, b: model.loss_fn(p, b, constrain), params, mb, n_micro)
        else:
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch,
                                                            constrain)
        new_p, new_o, metrics = adamw.apply(params, grads, opt_state, opt_cfg)
        return new_p, new_o, {"loss": loss, **metrics}

    return StepBundle(
        fn=train_step,
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard,
                       {"loss": NamedSharding(mesh, P()),
                        "lr": NamedSharding(mesh, P()),
                        "grad_norm": NamedSharding(mesh, P())}),
        donate_argnums=(0, 1),
        abstract_inputs=(p_shapes, opt_shapes, b_specs),
    )


def make_prefill_step(model: Model, mesh: Mesh, rules: shd.ShardRules,
                      seq_len: int, global_batch: int,
                      max_seq: Optional[int] = None) -> StepBundle:
    max_seq = max_seq or seq_len
    p_shapes, p_shard = param_shardings(model, mesh, rules)
    b_specs = model.input_specs(seq_len, global_batch, "prefill")
    b_shard = batch_shardings(b_specs, mesh, rules)
    cache_shapes = jax.eval_shape(lambda: model.init_cache(global_batch, max_seq))
    c_shard = cache_shardings(cache_shapes, mesh, rules, global_batch)

    def constrain(x, logical):
        return shd.constrain(x, mesh, rules, logical)

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq, constrain)

    V = model.cfg.vocab
    logits_shard = NamedSharding(
        mesh, shd.logical_to_spec(mesh, rules, ["batch", "tensor"],
                                  (global_batch, V)))
    return StepBundle(
        fn=prefill_step,
        in_shardings=(p_shard, b_shard),
        out_shardings=(logits_shard, c_shard),
        abstract_inputs=(p_shapes, b_specs),
    )


def make_decode_step(model: Model, mesh: Mesh, rules: shd.ShardRules,
                     seq_len: int, global_batch: int) -> StepBundle:
    """One-token decode against a cache of length seq_len."""
    p_shapes, p_shard = param_shardings(model, mesh, rules)
    d = model.input_specs(seq_len, global_batch, "decode")
    tok_shard = batch_shardings(d["token"], mesh, rules)
    c_shard = cache_shardings(d["cache"], mesh, rules, global_batch)
    pos_shard = NamedSharding(mesh, P())

    def constrain(x, logical):
        return shd.constrain(x, mesh, rules, logical)

    def decode_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos, constrain)

    V = model.cfg.vocab
    logits_shard = NamedSharding(
        mesh, shd.logical_to_spec(mesh, rules, ["batch", "tensor"],
                                  (global_batch, V)))
    return StepBundle(
        fn=decode_step,
        in_shardings=(p_shard, tok_shard, c_shard, pos_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(2,),
        abstract_inputs=(p_shapes, d["token"], d["cache"], d["pos"]),
    )
