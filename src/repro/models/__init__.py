"""Functional model zoo for the assigned architecture pool."""
from .config import ArchConfig, RunConfig, smoke_variant
from .model import Model, build, synth_batch

__all__ = ["ArchConfig", "RunConfig", "smoke_variant", "Model", "build",
           "synth_batch"]
