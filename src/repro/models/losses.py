"""Losses. The cross-entropy never materializes (B, L, V) logits:

  * the lm-head matmul + log-softmax run per sequence-chunk inside a
    rematerialized lax.scan (peak live logits = B * chunk * V_shard);
  * the vocab dim is sharded over the ``tensor`` mesh axis, so per-chunk
    reductions (max / logsumexp / label gather) lower to one small
    all-reduce each — this is what makes qwen2-72b's 152k vocab fit the
    dry-run memory budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

IGNORE = -100


def _chunk_xent(h_c, w, labels_c, z_loss: float):
    """h_c (B, Lc, D) @ w (D, V) -> per-chunk (sum_loss, count)."""
    logits = jnp.einsum("bld,dv->blv", h_c.astype(jnp.float32), w.astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(logits, axis=-1)          # (B, Lc)
    safe_labels = jnp.maximum(labels_c, 0)
    ll = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    mask = labels_c != IGNORE
    per_tok = lse - ll
    if z_loss:
        per_tok = per_tok + z_loss * lse**2
    loss = jnp.sum(jnp.where(mask, per_tok, 0.0))
    return loss, jnp.sum(mask)


def chunked_softmax_xent(hidden, w, labels, chunk: int = 512,
                         z_loss: float = 0.0):
    """hidden (B, L, D), w (D, V), labels (B, L) with IGNORE padding.
    Returns mean loss over non-ignored tokens."""
    B, L, D = hidden.shape
    c = min(chunk, L)
    while L % c:
        c -= 1
    nc = L // c
    hs = hidden.reshape(B, nc, c, D).swapaxes(0, 1)     # (nc, B, c, D)
    ls = labels.reshape(B, nc, c).swapaxes(0, 1)

    body = jax.checkpoint(functools.partial(_chunk_xent, z_loss=z_loss),
                          static_argnums=())

    def step(carry, xs):
        h_c, l_c = xs
        loss, n = body(h_c, w, l_c)
        return (carry[0] + loss, carry[1] + n), None

    (loss_sum, n_tok), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.int32(0)),
                                        (hs, ls))
    return loss_sum / jnp.maximum(n_tok, 1)


def logits_last(hidden_last, w):
    """Final-position logits for serving. hidden_last (B, D) -> (B, V)."""
    return (hidden_last.astype(jnp.float32) @ w.astype(jnp.float32))
