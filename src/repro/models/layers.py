"""Attention (GQA / qk-norm / QKV-bias / sliding-window / RoPE), MLP and MoE
building blocks, functional style.

Every `*_init` returns a Leaf-tree (value + logical sharding names); every
`*_apply` is a pure function. Weight layout: activations keep d_model
unsharded at block boundaries; weights are 2-D sharded (fsdp × tensor).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import common as cm
from ..kernels import ops


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None           # sliding-window size (Mixtral SWA)
    rope_theta: float = 10000.0
    causal: bool = True


def attn_init(key, cfg: AttnCfg, dtype):
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = D ** -0.5
    p = {
        "wq": cm.leaf(cm.normal(ks[0], (D, H * Dh), sc, dtype), ("fsdp", "tensor")),
        "wk": cm.leaf(cm.normal(ks[1], (D, Hkv * Dh), sc, dtype), ("fsdp", "tensor")),
        "wv": cm.leaf(cm.normal(ks[2], (D, Hkv * Dh), sc, dtype), ("fsdp", "tensor")),
        "wo": cm.leaf(cm.normal(ks[3], (H * Dh, D), (H * Dh) ** -0.5, dtype),
                      ("tensor", "fsdp")),
    }
    if cfg.qkv_bias:
        p["bq"] = cm.leaf(cm.zeros((H * Dh,), dtype), ("tensor",))
        p["bk"] = cm.leaf(cm.zeros((Hkv * Dh,), dtype), ("tensor",))
        p["bv"] = cm.leaf(cm.zeros((Hkv * Dh,), dtype), ("tensor",))
    if cfg.qk_norm:
        p["q_norm"] = cm.leaf(cm.ones((Dh,), dtype), (None,))
        p["k_norm"] = cm.leaf(cm.ones((Dh,), dtype), (None,))
    return p


def _project_qkv(p, x, cfg: AttnCfg, positions):
    B, L, D = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, L, H, Dh)
    k = k.reshape(B, L, Hkv, Dh)
    v = v.reshape(B, L, Hkv, Dh)
    if "q_norm" in p:
        q = cm.rms_norm(q, p["q_norm"])
        k = cm.rms_norm(k, p["k_norm"])
    q = cm.apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)
    k = cm.apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta)
    return q, k, v.swapaxes(1, 2)  # (B, H, L, Dh) / (B, Hkv, L, Dh)


def attn_apply(p, x, cfg: AttnCfg, positions=None, attn_impl: str = "chunked"):
    """Self-attention over the full sequence (train / prefill)."""
    B, L, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(L), (B, L))
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = ops.attention(q, k, v, causal=cfg.causal, window=cfg.window,
                        impl=attn_impl)
    out = out.swapaxes(1, 2).reshape(B, L, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], (k, v)


def attn_decode(p, x, cfg: AttnCfg, k_cache, v_cache, pos):
    """One-token decode. x: (B, 1, D); caches (B, Hkv, S, Dh); pos: scalar.

    Returns (out (B,1,D), (k_cache', v_cache')); caches updated at ``pos``.
    """
    B = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (B, 1)).astype(jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=2)
    out = ops.decode_attention(q[:, :, 0], k_cache, v_cache, pos=pos,
                               window=cfg.window)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], (k_cache, v_cache)


# --- MLP ---------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    sc_in, sc_out = d_model ** -0.5, d_ff ** -0.5
    return {
        "wg": cm.leaf(cm.normal(ks[0], (d_model, d_ff), sc_in, dtype), ("fsdp", "tensor")),
        "wu": cm.leaf(cm.normal(ks[1], (d_model, d_ff), sc_in, dtype), ("fsdp", "tensor")),
        "wd": cm.leaf(cm.normal(ks[2], (d_ff, d_model), sc_out, dtype), ("tensor", "fsdp")),
    }


def mlp_apply(p, x):
    return (cm.swiglu(x @ p["wg"], x @ p["wu"])) @ p["wd"]


# --- norms --------------------------------------------------------------------
def norm_init(d: int, dtype):
    return {"scale": cm.leaf(cm.ones((d,), dtype), (None,))}


def norm_apply(p, x, eps: float = 1e-6):
    return cm.rms_norm(x, p["scale"], eps)
