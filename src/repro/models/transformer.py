"""Decoder-only LM stack (dense / MoE / VLM-backbone / pure-SSM families).

Layers are scan-stacked: parameters carry a leading ``layers`` axis and the
forward pass is one `lax.scan` whose body is (optionally) rematerialized —
compile time and HLO size are depth-independent, which is what keeps the
512-device qwen2-72b dry-run tractable.

Three entry points per model: ``loss_fn`` (training), ``prefill`` and
``decode_step`` (serving; KV / SSM-state caches as pytrees).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import common as cm
from . import layers as ly
from . import losses as lo
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ArchConfig, RunConfig

Identity = lambda x, logical=None: x
AUX_COEF = 0.01


def remat_policy(rc: "RunConfig"):
    """None = save nothing (recompute everything); "dots" saves matmul
    outputs, trading HBM for ~25% less backward recompute FLOPs (§Perf)."""
    if rc.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def attn_cfg(cfg: ArchConfig) -> ly.AttnCfg:
    return ly.AttnCfg(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        window=cfg.window, rope_theta=cfg.rope_theta)


def ssm_cfg(cfg: ArchConfig) -> ssm_mod.SSMCfg:
    return ssm_mod.SSMCfg(
        d_model=cfg.d_model, d_state=cfg.ssm_state, d_conv=cfg.ssm_conv,
        expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim, n_groups=cfg.ssm_groups)


def moe_cfg(cfg: ArchConfig, rc: RunConfig) -> moe_mod.MoECfg:
    return moe_mod.MoECfg(
        d_model=cfg.d_model, d_ff=cfg.d_ff, n_experts=cfg.n_experts,
        top_k=cfg.top_k, capacity_factor=rc.capacity_factor)


def block_kind(cfg: ArchConfig) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.is_moe:
        return "attn_moe"
    return "attn_mlp"


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def block_init(key, cfg: ArchConfig, rc: RunConfig, dtype):
    kind = block_kind(cfg)
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {
            "norm": ly.norm_init(cfg.d_model, dtype),
            "ssm": ssm_mod.ssm_init(ks[0], ssm_cfg(cfg), dtype),
        }
    p = {
        "attn_norm": ly.norm_init(cfg.d_model, dtype),
        "attn": ly.attn_init(ks[0], attn_cfg(cfg), dtype),
        "mlp_norm": ly.norm_init(cfg.d_model, dtype),
    }
    if kind == "attn_moe":
        p["moe"] = moe_mod.moe_init(ks[1], moe_cfg(cfg, rc), dtype)
    else:
        p["mlp"] = ly.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def model_init(key, cfg: ArchConfig, rc: RunConfig):
    """Returns a Leaf-tree; use common.split() -> (params, logical specs)."""
    dtype = jnp.dtype(rc.param_dtype)
    ks = jax.random.split(key, 4)
    tree = {
        "embed": cm.leaf(cm.normal(ks[0], (cfg.vocab, cfg.d_model), 0.02, dtype),
                         ("tensor", "fsdp")),
        "blocks": cm.stack_layers(
            ks[1], cfg.n_layers, lambda k: block_init(k, cfg, rc, dtype)),
        "norm_f": ly.norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = cm.leaf(
            cm.normal(ks[2], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dtype),
            ("fsdp", "tensor"))
    return tree


def head_weight(params, cfg: ArchConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def block_apply(bp, h, cfg: ArchConfig, rc: RunConfig, positions,
                constrain: Callable = Identity):
    """Residual stream h is sequence-parallel (batch, seq_act, None) at the
    block boundary. The pre-attention / pre-MLP norm outputs are explicitly
    re-constrained to full sequence so the big einsums are pure TP (weights
    gathered over fsdp ONLY — 58 MB/layer, not 924 MB, for qwen2-72b); the
    residual add re-constrains to seq_act, which lowers the o/down-proj's
    psum into a reduce-scatter. This is the Korthikanti-style SP boundary —
    the LM analogue of the paper's exchange-only-the-halo discipline
    (§Perf iteration q2/m2)."""
    kind = block_kind(cfg)
    if kind == "ssm":
        hn = ly.norm_apply(bp["norm"], h, cfg.norm_eps)
        hn = constrain(hn, ("batch", None, None))
        out, _ = ssm_mod.ssm_apply(bp["ssm"], hn, ssm_cfg(cfg),
                                   ssd_impl=rc.ssd_impl, conv_impl=rc.conv_impl)
        return constrain(h + out, ("batch", "seq_act", None)), jnp.float32(0.0)
    a_in = ly.norm_apply(bp["attn_norm"], h, cfg.norm_eps)
    a_in = constrain(a_in, ("batch", None, None))
    a, _ = ly.attn_apply(bp["attn"], a_in, attn_cfg(cfg), positions,
                         attn_impl=rc.attn_impl)
    h = constrain(h + a, ("batch", "seq_act", None))
    hn = ly.norm_apply(bp["mlp_norm"], h, cfg.norm_eps)
    hn = constrain(hn, ("batch", None, None))
    if kind == "attn_moe":
        m, aux = moe_mod.moe_apply(bp["moe"], hn, moe_cfg(cfg, rc), constrain)
    else:
        m, aux = ly.mlp_apply(bp["mlp"], hn), jnp.float32(0.0)
    return constrain(h + m, ("batch", "seq_act", None)), aux


def forward_hidden(params, cfg: ArchConfig, rc: RunConfig, embeds,
                   positions=None, constrain: Callable = Identity):
    B, L, _ = embeds.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(h, bp):
        h2, aux = block_apply(bp, h, cfg, rc, positions, constrain)
        return h2, aux

    if rc.remat:
        body = jax.checkpoint(body, policy=remat_policy(rc))
    h, auxs = jax.lax.scan(body, embeds, params["blocks"])
    h = ly.norm_apply(params["norm_f"], h, cfg.norm_eps)
    return h, jnp.mean(auxs)


def embed_tokens(params, cfg: ArchConfig, tokens, prefix_embeds=None):
    emb = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:  # VLM / audio stub frontends
        emb = jnp.concatenate([prefix_embeds.astype(emb.dtype), emb], axis=1)
    return emb


def loss_fn(params, cfg: ArchConfig, rc: RunConfig, tokens, labels,
            prefix_embeds=None, constrain: Callable = Identity):
    """tokens (B, L) int32; labels (B, L) with lo.IGNORE padding."""
    emb = embed_tokens(params, cfg, tokens, prefix_embeds)
    if prefix_embeds is not None:
        pad = jnp.full(prefix_embeds.shape[:2], lo.IGNORE, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    emb = constrain(emb, ("batch", "seq_act", None))
    h, aux = forward_hidden(params, cfg, rc, emb, constrain=constrain)
    loss = lo.chunked_softmax_xent(h, head_weight(params, cfg), labels,
                                   chunk=rc.loss_chunk, z_loss=rc.z_loss)
    if cfg.is_moe:
        loss = loss + AUX_COEF * aux
    return loss


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, rc: RunConfig, batch: int, max_seq: int,
               dtype=None):
    dtype = jnp.dtype(rc.param_dtype) if dtype is None else dtype
    Ln = cfg.n_layers
    if block_kind(cfg) == "ssm":
        sc = ssm_cfg(cfg)
        return {
            "conv": jnp.zeros((Ln, batch, sc.d_conv - 1, sc.d_conv_in), dtype),
            "ssm": jnp.zeros((Ln, batch, sc.n_heads, sc.head_dim, sc.d_state),
                             jnp.float32),
        }
    return {
        "k": jnp.zeros((Ln, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), dtype),
        "v": jnp.zeros((Ln, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), dtype),
    }


def prefill(params, cfg: ArchConfig, rc: RunConfig, tokens, max_seq: int,
            prefix_embeds=None, constrain: Callable = Identity):
    """Full-sequence pass; returns (last-position logits (B, V), cache)."""
    emb = embed_tokens(params, cfg, tokens, prefix_embeds)
    B, L, _ = emb.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    kind = block_kind(cfg)

    def body(h, bp):
        if kind == "ssm":
            hn = ly.norm_apply(bp["norm"], h, cfg.norm_eps)
            out, st = ssm_mod.ssm_apply(bp["ssm"], hn, ssm_cfg(cfg),
                                        ssd_impl=rc.ssd_impl,
                                        conv_impl=rc.conv_impl, return_state=True)
            return h + out, st
        a_in = ly.norm_apply(bp["attn_norm"], h, cfg.norm_eps)
        a, (k, v) = ly.attn_apply(bp["attn"], a_in, attn_cfg(cfg), positions,
                                  attn_impl=rc.attn_impl)
        h = h + a
        hn = ly.norm_apply(bp["mlp_norm"], h, cfg.norm_eps)
        if kind == "attn_moe":
            m, _ = moe_mod.moe_apply(bp["moe"], hn, moe_cfg(cfg, rc), constrain)
        else:
            m = ly.mlp_apply(bp["mlp"], hn)
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, max_seq - L), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, max_seq - L), (0, 0)))
        return h + m, (kp, vp)

    h, caches = jax.lax.scan(body, emb, params["blocks"])
    h = ly.norm_apply(params["norm_f"], h, cfg.norm_eps)
    logits = lo.logits_last(h[:, -1], head_weight(params, cfg))
    if kind == "ssm":
        cache = caches  # {"conv": (Ln,B,K-1,Cin), "ssm": (Ln,B,H,P,N)}
    else:
        cache = {"k": caches[0], "v": caches[1]}
    return logits, cache


def decode_step(params, cfg: ArchConfig, rc: RunConfig, token, cache, pos,
                constrain: Callable = Identity):
    """token (B,) int32; pos: scalar int32 (position being written).
    Returns (logits (B, V), new cache)."""
    emb = jnp.take(params["embed"], token[:, None], axis=0)
    kind = block_kind(cfg)

    if kind == "ssm":
        def body(h, xs):
            bp, conv_c, ssm_c = xs
            hn = ly.norm_apply(bp["norm"], h, cfg.norm_eps)
            out, st = ssm_mod.ssm_decode(bp["ssm"], hn, ssm_cfg(cfg),
                                         {"conv": conv_c, "ssm": ssm_c})
            return h + out, (st["conv"], st["ssm"])

        h, (convs, ssms) = jax.lax.scan(
            body, emb, (params["blocks"], cache["conv"], cache["ssm"]))
        new_cache = {"conv": convs, "ssm": ssms}
    else:
        def body(h, xs):
            bp, kc, vc = xs
            a_in = ly.norm_apply(bp["attn_norm"], h, cfg.norm_eps)
            a, (kc, vc) = ly.attn_decode(bp["attn"], a_in, attn_cfg(cfg), kc, vc, pos)
            h = h + a
            hn = ly.norm_apply(bp["mlp_norm"], h, cfg.norm_eps)
            if block_kind(cfg) == "attn_moe":
                m, _ = moe_mod.moe_apply(bp["moe"], hn, moe_cfg(cfg, rc),
                                         constrain)
            else:
                m = ly.mlp_apply(bp["mlp"], hn)
            return h + m, (kc, vc)

        h, (ks, vs) = jax.lax.scan(body, emb, (params["blocks"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}

    h = ly.norm_apply(params["norm_f"], h, cfg.norm_eps)
    logits = lo.logits_last(h[:, -1], head_weight(params, cfg))
    return logits, new_cache
