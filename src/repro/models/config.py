"""Architecture + runtime configuration (the framework's config system).

ArchConfig carries the *published* architecture hyperparameters (one file
per arch under repro/configs); RunConfig carries deployment knobs (dtypes,
remat, kernel impls, loss chunking, mesh rules). Both are plain frozen
dataclasses — reproducible, hashable, CLI-overridable via
``configs.registry.apply_overrides``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None     # sliding-window attention
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    # hybrid (Zamba2): one shared attention block applied every k ssm layers
    attn_every: int = 0
    # enc-dec
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # vlm / audio stub frontend
    n_patches: int = 0               # patch/frame embeddings provided by stub
    source_len: int = 0              # encoder source length (enc-dec)
    notes: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 500k-context decode cell?"""
        return self.family in ("ssm", "hybrid") or self.window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        H, Hkv, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = D * (H + 2 * Hkv) * Dh + H * Dh * D
        if self.qkv_bias:
            attn += (H + 2 * Hkv) * Dh
        mlp = 3 * D * F
        moe = 0
        if self.is_moe:
            moe = self.n_experts * 3 * D * F + D * self.n_experts
            mlp = 0
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            din = self.ssm_expand * D
            nh = din // self.ssm_head_dim
            dconv_in = din + 2 * self.ssm_groups * self.ssm_state
            proj = D * (2 * din + 2 * self.ssm_groups * self.ssm_state + nh)
            ssm = proj + self.ssm_conv * dconv_in + dconv_in + 3 * nh + din + din * D
        emb = V * D * (1 if self.tie_embeddings else 2)
        norms = 2 * D * self.n_layers + D
        if self.family == "dense" or self.family == "vlm":
            per_layer = attn + mlp
            total = self.n_layers * per_layer
        elif self.family == "moe":
            total = self.n_layers * (attn + moe)
        elif self.family == "ssm":
            total = self.n_layers * ssm
        elif self.family == "hybrid":
            n_attn = self.n_layers // max(self.attn_every, 1)
            total = self.n_layers * ssm + (attn + mlp)  # shared block counted once
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp)
            dec = self.n_dec_layers * (2 * attn + mlp)  # self + cross
            total = enc + dec
        else:
            total = self.n_layers * (attn + mlp)
        return int(total + emb + norms)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        full_moe = self.n_layers * self.n_experts * 3 * D * F
        active_moe = self.n_layers * self.top_k * 3 * D * F
        return int(self.param_count() - full_moe + active_moe)


@dataclasses.dataclass(frozen=True)
class RunConfig:
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_impl: str = "chunked"       # chunked | pallas | ref
    ssd_impl: str = "chunked"
    conv_impl: str = "chunked"
    remat: bool = True               # rematerialize each block in backward
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    n_microbatch: int = 1            # gradient-accumulation microbatches
    loss_chunk: int = 512
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    capacity_factor: float = 1.25
    z_loss: float = 0.0
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    schedule: str = "cosine"          # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 1000
    # serving
    max_seq: int = 4096


SMOKE_OVERRIDES = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, n_patches=4, source_len=8,
)


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(SMOKE_OVERRIDES)
    if cfg.is_moe:
        kw.update(n_experts=4, top_k=2)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_expand=2)
    if cfg.family == "hybrid":
        kw.update(attn_every=2)
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2, n_dec_layers=2)
    if cfg.n_kv_heads == cfg.n_heads:
        kw["n_kv_heads"] = kw["n_heads"]
    if cfg.window is not None:
        kw["window"] = 16
    return dataclasses.replace(cfg, **kw)
