"""Mixture-of-Experts layer with capacity-based dispatch (GShard-style).

Token routing: top-k softmax gate -> position-within-expert via one-hot
cumsum -> scatter into per-expert capacity buffers -> stacked-expert
einsum (SwiGLU) -> weighted gather-combine. FLOPs scale with *active*
experts only; the (E, C, D) buffers shard over the ``tensor`` axis (expert
parallelism), so the scatter/gather lower to all-to-alls under pjit —
the TPU-native version of the paper's "communicate only what moves"
discipline. Aux loss: standard load-balancing (Switch/Mixtral).

When E doesn't divide the tensor axis (Mixtral's 8 experts on a 16-wide
axis) the expert dim degrades to replicated and the d_ff dim picks up the
tensor sharding instead (see distributed.sharding.logical_to_spec).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import common as cm


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff: int               # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


def moe_init(key, cfg: MoECfg, dtype):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    sc_in, sc_out = D ** -0.5, F ** -0.5
    return {
        "router": cm.leaf(cm.normal(ks[0], (D, E), sc_in, jnp.float32), ("fsdp", None)),
        "wg": cm.leaf(cm.normal(ks[1], (E, D, F), sc_in, dtype),
                      ("expert", "fsdp", "expert_ffn")),
        "wu": cm.leaf(cm.normal(ks[2], (E, D, F), sc_in, dtype),
                      ("expert", "fsdp", "expert_ffn")),
        "wd": cm.leaf(cm.normal(ks[3], (E, F, D), sc_out, dtype),
                      ("expert", "expert_ffn", "fsdp")),
    }


def moe_apply(p, x, cfg: MoECfg, constrain=lambda x, logical=None: x):
    """x: (B, L, D) -> (out, aux_loss)."""
    B, L, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * L
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                        # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (mean prob * mean assignment fraction)
    assign = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(1)        # (T, E)
    aux = E * jnp.mean(probs.mean(0) * assign.mean(0))

    # floor keeps tiny decode batches drop-free (worst case: all T tokens
    # route their K choices to one expert)
    capacity = int(max(K * cfg.capacity_factor * T / E, min(T * K, 8)))
    # position of each (token, k) within its expert's buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)                 # (T, K, E)
    flatoh = onehot.reshape(T * K, E)
    pos_in_e = jnp.cumsum(flatoh, axis=0) - flatoh                        # (T*K, E)
    pos = jnp.sum(pos_in_e * flatoh, axis=-1).reshape(T, K)               # (T, K)
    keep = pos < capacity                                                 # drop overflow
    gate_vals = gate_vals * keep

    # scatter tokens into (E, C, D) buffers; under EP the scatter lowers to
    # an all-to-all, under TP-experts the capacity dim stays batch-sharded
    buf = jnp.zeros((E, capacity, D), x.dtype)
    e_flat = gate_idx.reshape(-1)
    c_flat = jnp.where(keep, pos, capacity - 1).reshape(-1)
    src = jnp.repeat(xt[:, None, :], K, axis=1).reshape(T * K, D)
    src = jnp.where(keep.reshape(-1, 1), src, 0)
    buf = buf.at[e_flat, c_flat].add(src)
    buf = constrain(buf, ("expert", "moe_cap", None))

    # stacked-expert SwiGLU
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = cm.swiglu(g, u)
    h = constrain(h, ("expert", "moe_cap", "expert_ffn"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"])                      # (E, C, D)
    out_buf = constrain(out_buf, ("expert", "moe_cap", None))

    # gather-combine weighted by gates
    picked = out_buf[e_flat, c_flat].reshape(T, K, D)
    out = jnp.sum(picked * gate_vals[..., None].astype(x.dtype), axis=1)
    return out.reshape(B, L, D), aux
