"""Shared plumbing for the functional model zoo.

Parameters are plain nested dicts of jax.Arrays. Init functions build trees
whose leaves are ``Leaf(array, logical)`` — the logical sharding names ride
along with the value — and ``split`` separates them into (params, specs)
once at model-build time. No framework dependency; everything composes with
pjit/scan/shard_map directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Leaf:
    value: jax.Array
    logical: tuple  # logical sharding names per dim (see distributed.sharding)


def leaf(value, logical):
    assert len(logical) == value.ndim, (value.shape, logical)
    return Leaf(value, tuple(logical))


def split(tree):
    """-> (params_tree, logical_tree) with identical structure."""
    leaves_is = lambda x: isinstance(x, Leaf)
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=leaves_is)
    logical = jax.tree.map(lambda l: l.logical, tree, is_leaf=leaves_is)
    return params, logical


def normal(key, shape, scale, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * scale


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype)


# --- numerics ----------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def rope_freqs(dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., L, D) with D even; positions: (..., L) int."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def swiglu(gate, up):
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def stack_layers(key, n: int, init_one):
    """Initialize n layers and stack every leaf along axis 0 (scan layout)."""
    keys = jax.random.split(key, n)
    trees = [init_one(k) for k in keys]
    is_leaf = lambda x: isinstance(x, Leaf)

    def merge(*ls):
        v = jnp.stack([l.value for l in ls])
        return Leaf(v, ("layers",) + ls[0].logical)

    return jax.tree.map(merge, *trees, is_leaf=is_leaf)
