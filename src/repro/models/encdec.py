"""Encoder–decoder transformer (Seamless-M4T backbone).

Per the task spec the modality frontend is a stub: the encoder consumes
precomputed frame embeddings (B, S_src, D) from input_specs(). Encoder =
bidirectional self-attention blocks; decoder = causal self-attention +
cross-attention + MLP. Cross K/V are computed once at prefill and cached.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import common as cm
from . import layers as ly
from . import losses as lo
from .config import ArchConfig, RunConfig
from .transformer import attn_cfg, head_weight, Identity


def _enc_attn_cfg(cfg):
    import dataclasses
    return dataclasses.replace(attn_cfg(cfg), causal=False, window=None)


def _cross_init(key, cfg: ArchConfig, dtype):
    # cross-attention: q from decoder, k/v from encoder memory
    return ly.attn_init(key, attn_cfg(cfg), dtype)


def enc_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn_norm": ly.norm_init(cfg.d_model, dtype),
        "attn": ly.attn_init(ks[0], _enc_attn_cfg(cfg), dtype),
        "mlp_norm": ly.norm_init(cfg.d_model, dtype),
        "mlp": ly.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def dec_block_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "self_norm": ly.norm_init(cfg.d_model, dtype),
        "self_attn": ly.attn_init(ks[0], attn_cfg(cfg), dtype),
        "cross_norm": ly.norm_init(cfg.d_model, dtype),
        "cross_attn": _cross_init(ks[1], cfg, dtype),
        "mlp_norm": ly.norm_init(cfg.d_model, dtype),
        "mlp": ly.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def model_init(key, cfg: ArchConfig, rc: RunConfig):
    dtype = jnp.dtype(rc.param_dtype)
    ks = jax.random.split(key, 5)
    tree = {
        "embed": cm.leaf(cm.normal(ks[0], (cfg.vocab, cfg.d_model), 0.02, dtype),
                         ("tensor", "fsdp")),
        "enc_blocks": cm.stack_layers(ks[1], cfg.n_enc_layers,
                                      lambda k: enc_block_init(k, cfg, dtype)),
        "dec_blocks": cm.stack_layers(ks[2], cfg.n_dec_layers,
                                      lambda k: dec_block_init(k, cfg, dtype)),
        "enc_norm_f": ly.norm_init(cfg.d_model, dtype),
        "norm_f": ly.norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = cm.leaf(
            cm.normal(ks[3], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dtype),
            ("fsdp", "tensor"))
    return tree


def _cross_attend(p, x, memory_kv, cfg):
    """x (B, Lq, D) attends to precomputed encoder K/V (B, Hkv, S, Dh)."""
    B, Lq, D = x.shape
    acfg = attn_cfg(cfg)
    H, Dh = acfg.n_heads, acfg.head_dim
    q = (x @ p["wq"]).reshape(B, Lq, H, Dh).swapaxes(1, 2)
    mk, mv = memory_kv
    from ..kernels import ops
    if Lq == 1:
        out = ops.decode_attention(q[:, :, 0], mk, mv)[:, None]  # (B,1,H*Dh)? -> reshape
        out = out.reshape(B, 1, H * Dh)
    else:
        out = ops.attention(q, mk, mv, causal=False, impl="chunked")
        out = out.swapaxes(1, 2).reshape(B, Lq, H * Dh)
    return out @ p["wo"]


def encode(params, cfg: ArchConfig, rc: RunConfig, frames,
           constrain: Callable = Identity):
    """frames: (B, S_src, D) stub frontend embeddings -> encoder memory."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(h, bp):
        a_in = ly.norm_apply(bp["attn_norm"], h, cfg.norm_eps)
        a_in = constrain(a_in, ("batch", None, None))  # SP boundary
        a, _ = ly.attn_apply(bp["attn"], a_in, _enc_attn_cfg(cfg), positions,
                             attn_impl=rc.attn_impl)
        h = constrain(h + a, ("batch", "seq_act", None))
        hn = ly.norm_apply(bp["mlp_norm"], h, cfg.norm_eps)
        hn = constrain(hn, ("batch", None, None))
        h = constrain(h + ly.mlp_apply(bp["mlp"], hn),
                      ("batch", "seq_act", None))
        return h, None

    if rc.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, frames.astype(jnp.dtype(rc.param_dtype)),
                        params["enc_blocks"])
    return ly.norm_apply(params["enc_norm_f"], h, cfg.norm_eps)


def _memory_kv(bp, memory, cfg):
    """Precompute cross-attention K/V from encoder memory for one layer."""
    B, S, _ = memory.shape
    acfg = attn_cfg(cfg)
    Hkv, Dh = acfg.n_kv_heads, acfg.head_dim
    k = (memory @ bp["wk"]).reshape(B, S, Hkv, Dh).swapaxes(1, 2)
    v = (memory @ bp["wv"]).reshape(B, S, Hkv, Dh).swapaxes(1, 2)
    return k, v


def decode_train(params, cfg: ArchConfig, rc: RunConfig, memory, tokens,
                 constrain: Callable = Identity):
    emb = jnp.take(params["embed"], tokens, axis=0)
    B, L, _ = emb.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(h, bp):
        a_in = ly.norm_apply(bp["self_norm"], h, cfg.norm_eps)
        a_in = constrain(a_in, ("batch", None, None))  # SP boundary
        a, _ = ly.attn_apply(bp["self_attn"], a_in, attn_cfg(cfg), positions,
                             attn_impl=rc.attn_impl)
        h = constrain(h + a, ("batch", "seq_act", None))
        c_in = ly.norm_apply(bp["cross_norm"], h, cfg.norm_eps)
        c_in = constrain(c_in, ("batch", None, None))
        mkv = _memory_kv(bp["cross_attn"], memory, cfg)
        h = constrain(h + _cross_attend(bp["cross_attn"], c_in, mkv, cfg),
                      ("batch", "seq_act", None))
        hn = ly.norm_apply(bp["mlp_norm"], h, cfg.norm_eps)
        hn = constrain(hn, ("batch", None, None))
        h = constrain(h + ly.mlp_apply(bp["mlp"], hn),
                      ("batch", "seq_act", None))
        return h, None

    if rc.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, emb, params["dec_blocks"])
    return ly.norm_apply(params["norm_f"], h, cfg.norm_eps)


def loss_fn(params, cfg: ArchConfig, rc: RunConfig, tokens, labels,
            frames=None, constrain: Callable = Identity):
    memory = encode(params, cfg, rc, frames, constrain)
    h = decode_train(params, cfg, rc, memory, tokens, constrain)
    return lo.chunked_softmax_xent(h, head_weight(params, cfg), labels,
                                   chunk=rc.loss_chunk, z_loss=rc.z_loss)


def init_cache(cfg: ArchConfig, rc: RunConfig, batch: int, max_seq: int,
               dtype=None):
    dtype = jnp.dtype(rc.param_dtype) if dtype is None else dtype
    Ln = cfg.n_dec_layers
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim
    S = cfg.source_len
    return {
        "k": jnp.zeros((Ln, batch, Hkv, max_seq, Dh), dtype),
        "v": jnp.zeros((Ln, batch, Hkv, max_seq, Dh), dtype),
        "mk": jnp.zeros((Ln, batch, Hkv, S, Dh), dtype),
        "mv": jnp.zeros((Ln, batch, Hkv, S, Dh), dtype),
    }


def prefill(params, cfg: ArchConfig, rc: RunConfig, tokens, max_seq: int,
            frames=None, constrain: Callable = Identity):
    """Encode source + teacher-forced decoder pass; returns (logits, cache)."""
    memory = encode(params, cfg, rc, frames, constrain)
    emb = jnp.take(params["embed"], tokens, axis=0)
    B, L, _ = emb.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def body(h, bp):
        a_in = ly.norm_apply(bp["self_norm"], h, cfg.norm_eps)
        a, (k, v) = ly.attn_apply(bp["self_attn"], a_in, attn_cfg(cfg), positions,
                                  attn_impl=rc.attn_impl)
        h = h + a
        c_in = ly.norm_apply(bp["cross_norm"], h, cfg.norm_eps)
        mk, mv = _memory_kv(bp["cross_attn"], memory, cfg)
        h = h + _cross_attend(bp["cross_attn"], c_in, (mk, mv), cfg)
        h = h + ly.mlp_apply(bp["mlp"], ly.norm_apply(bp["mlp_norm"], h, cfg.norm_eps))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, max_seq - L), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, max_seq - L), (0, 0)))
        return h, (kp, vp, mk, mv)

    h, (ks, vs, mks, mvs) = jax.lax.scan(body, emb, params["dec_blocks"])
    h = ly.norm_apply(params["norm_f"], h, cfg.norm_eps)
    logits = lo.logits_last(h[:, -1], head_weight(params, cfg))
    return logits, {"k": ks, "v": vs, "mk": mks, "mv": mvs}


def decode_step(params, cfg: ArchConfig, rc: RunConfig, token, cache, pos,
                constrain: Callable = Identity):
    emb = jnp.take(params["embed"], token[:, None], axis=0)

    def body(h, xs):
        bp, kc, vc, mk, mv = xs
        a_in = ly.norm_apply(bp["self_norm"], h, cfg.norm_eps)
        a, (kc, vc) = ly.attn_decode(bp["self_attn"], a_in, attn_cfg(cfg), kc, vc, pos)
        h = h + a
        c_in = ly.norm_apply(bp["cross_norm"], h, cfg.norm_eps)
        h = h + _cross_attend(bp["cross_attn"], c_in, (mk, mv), cfg)
        h = h + ly.mlp_apply(bp["mlp"], ly.norm_apply(bp["mlp_norm"], h, cfg.norm_eps))
        return h, (kc, vc)

    h, (ks, vs) = jax.lax.scan(
        body, emb, (params["dec_blocks"], cache["k"], cache["v"],
                    cache["mk"], cache["mv"]))
    h = ly.norm_apply(params["norm_f"], h, cfg.norm_eps)
    logits = lo.logits_last(h[:, -1], head_weight(params, cfg))
    return logits, {"k": ks, "v": vs, "mk": cache["mk"], "mv": cache["mv"]}
