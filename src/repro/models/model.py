"""Family dispatch: one facade the launcher / dry-run / tests drive.

``build(cfg, rc)`` returns a Model whose methods are pure functions of
(params, batch) — ready for jax.jit with in/out shardings. input_specs()
produces ShapeDtypeStruct stand-ins for every entry point (the dry-run
allocates nothing).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import common as cm
from . import encdec as encdec_mod
from . import hybrid as hybrid_mod
from . import transformer as tf_mod
from .config import ArchConfig, RunConfig
from .losses import IGNORE


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    rc: RunConfig

    # ---- init ----------------------------------------------------------
    def init(self, key):
        """-> (params, logical_spec_tree)."""
        if self.cfg.family == "hybrid":
            tree = hybrid_mod.model_init(key, self.cfg, self.rc)
        elif self.cfg.family == "encdec":
            tree = encdec_mod.model_init(key, self.cfg, self.rc)
        else:
            tree = tf_mod.model_init(key, self.cfg, self.rc)
        return cm.split(tree)

    def abstract_params(self):
        """(ShapeDtypeStruct tree, logical tree) without allocating any
        parameter memory — the logical sharding names are static trace-time
        metadata, captured by closure during eval_shape."""
        captured = {}

        def f(k):
            params, logical = self.init(k)
            captured["logical"] = logical
            return params

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, captured["logical"]

    # ---- training -------------------------------------------------------
    def loss_fn(self, params, batch, constrain: Callable = tf_mod.Identity):
        cfg, rc = self.cfg, self.rc
        if cfg.family == "encdec":
            return encdec_mod.loss_fn(params, cfg, rc, batch["tokens"],
                                      batch["labels"], frames=batch["frames"],
                                      constrain=constrain)
        if cfg.family == "hybrid":
            return hybrid_mod.loss_fn(params, cfg, rc, batch["tokens"],
                                      batch["labels"], constrain=constrain)
        prefix = batch.get("patch_embeds")
        return tf_mod.loss_fn(params, cfg, rc, batch["tokens"], batch["labels"],
                              prefix_embeds=prefix, constrain=constrain)

    # ---- serving ---------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int):
        mod = {"hybrid": hybrid_mod, "encdec": encdec_mod}.get(
            self.cfg.family, tf_mod)
        return mod.init_cache(self.cfg, self.rc, batch, max_seq)

    def prefill(self, params, batch, max_seq: int,
                constrain: Callable = tf_mod.Identity):
        cfg, rc = self.cfg, self.rc
        if cfg.family == "encdec":
            return encdec_mod.prefill(params, cfg, rc, batch["tokens"], max_seq,
                                      frames=batch["frames"], constrain=constrain)
        if cfg.family == "hybrid":
            return hybrid_mod.prefill(params, cfg, rc, batch["tokens"], max_seq,
                                      constrain=constrain)
        return tf_mod.prefill(params, cfg, rc, batch["tokens"], max_seq,
                              prefix_embeds=batch.get("patch_embeds"),
                              constrain=constrain)

    def decode_step(self, params, token, cache, pos,
                    constrain: Callable = tf_mod.Identity):
        mod = {"hybrid": hybrid_mod, "encdec": encdec_mod}.get(
            self.cfg.family, tf_mod)
        return mod.decode_step(params, self.cfg, self.rc, token, cache, pos,
                               constrain=constrain)

    # ---- dry-run inputs ---------------------------------------------------
    def input_specs(self, seq_len: int, global_batch: int, mode: str = "train"):
        """ShapeDtypeStruct stand-ins per entry point.

        mode: "train" -> loss_fn batch; "prefill" -> prefill batch;
              "decode" -> (token, cache, pos) with cache length seq_len.
        """
        cfg = self.cfg
        B, L = global_batch, seq_len
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        dt = jnp.dtype(self.rc.param_dtype)
        if mode in ("train", "prefill"):
            batch = {"tokens": sd((B, L), i32)}
            if mode == "train":
                batch["labels"] = sd((B, L), i32)
            if cfg.family == "vlm":
                n = cfg.n_patches
                batch["tokens"] = sd((B, L - n), i32)
                if mode == "train":
                    batch["labels"] = sd((B, L - n), i32)
                batch["patch_embeds"] = sd((B, n, cfg.d_model), dt)
            if cfg.family == "encdec":
                batch["frames"] = sd((B, cfg.source_len, cfg.d_model), dt)
            return batch
        if mode == "decode":
            cache = jax.eval_shape(lambda: self.init_cache(B, L))
            return {"token": sd((B,), i32), "cache": cache,
                    "pos": sd((), i32)}
        raise ValueError(mode)


def build(cfg: ArchConfig, rc: Optional[RunConfig] = None) -> Model:
    return Model(cfg, rc or RunConfig())


def synth_batch(model: Model, key, seq_len: int, global_batch: int,
                mode: str = "train"):
    """Concrete random batch matching input_specs (smoke tests/examples)."""
    specs = model.input_specs(seq_len, global_batch, mode)
    out = {}
    for name, s in specs.items():
        if name == "cache":
            out[name] = jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype), s)
        elif jnp.issubdtype(s.dtype, jnp.integer):
            key, k = jax.random.split(key)
            hi = model.cfg.vocab if name in ("tokens", "labels", "token") else 2**30
            out[name] = jax.random.randint(k, s.shape, 0, hi, s.dtype)
        else:
            key, k = jax.random.split(key)
            out[name] = (jax.random.normal(k, s.shape, jnp.float32) * 0.02).astype(s.dtype)
    if "pos" in out:
        out["pos"] = jnp.asarray(seq_len // 2, jnp.int32)
    return out
