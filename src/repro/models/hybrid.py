"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block.

The shared transformer block (attention + MLP with its own norms) is
applied after every ``attn_every`` Mamba2 layers, re-using the *same*
parameters at each application (Zamba2's parameter-sharing trick;
per-invocation LoRA deltas are omitted — noted in DESIGN.md).

Scan layout: the mamba stack is grouped as (n_groups, attn_every, ...) so
the forward is scan(groups){ scan(inner mamba) ; shared attn } — HLO stays
depth-independent and the shared block appears once per group, which keeps
cost_analysis faithful (an unrolled python loop would inflate HLO size; a
per-layer lax.cond would miscount FLOPs).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import common as cm
from . import layers as ly
from . import losses as lo
from . import ssm as ssm_mod
from .config import ArchConfig, RunConfig
from .transformer import attn_cfg, ssm_cfg, head_weight, Identity


def _group_layout(cfg: ArchConfig) -> tuple[int, int, int]:
    k = max(cfg.attn_every, 1)
    n_groups, rem = divmod(cfg.n_layers, k)
    return n_groups, k, rem


def model_init(key, cfg: ArchConfig, rc: RunConfig):
    dtype = jnp.dtype(rc.param_dtype)
    ks = jax.random.split(key, 6)
    n_groups, k, rem = _group_layout(cfg)

    def mamba_layer(kk):
        return {"norm": ly.norm_init(cfg.d_model, dtype),
                "ssm": ssm_mod.ssm_init(kk, ssm_cfg(cfg), dtype)}

    tree = {
        "embed": cm.leaf(cm.normal(ks[0], (cfg.vocab, cfg.d_model), 0.02, dtype),
                         ("tensor", "fsdp")),
        # (n_groups, attn_every, ...) stacked mamba params
        "mamba": cm.stack_layers(
            ks[1], n_groups, lambda kk: cm.stack_layers(kk, k, mamba_layer)),
        "shared": {
            "attn_norm": ly.norm_init(cfg.d_model, dtype),
            "attn": ly.attn_init(ks[2], attn_cfg(cfg), dtype),
            "mlp_norm": ly.norm_init(cfg.d_model, dtype),
            "mlp": ly.mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype),
        },
        "norm_f": ly.norm_init(cfg.d_model, dtype),
    }
    if rem:
        tree["mamba_tail"] = cm.stack_layers(ks[4], rem, mamba_layer)
    if not cfg.tie_embeddings:
        tree["lm_head"] = cm.leaf(
            cm.normal(ks[5], (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5, dtype),
            ("fsdp", "tensor"))
    return tree


def _mamba_scan(stacked, h, cfg, rc, remat):
    def body(hc, bp):
        hn = ly.norm_apply(bp["norm"], hc, cfg.norm_eps)
        out, _ = ssm_mod.ssm_apply(bp["ssm"], hn, ssm_cfg(cfg),
                                   ssd_impl=rc.ssd_impl, conv_impl=rc.conv_impl)
        return hc + out, None
    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, stacked)
    return h


def _shared_attn(sp, h, cfg, rc, positions, constrain=Identity):
    # sequence-parallel boundary (see transformer.block_apply)
    a_in = ly.norm_apply(sp["attn_norm"], h, cfg.norm_eps)
    a_in = constrain(a_in, ("batch", None, None))
    a, _ = ly.attn_apply(sp["attn"], a_in, attn_cfg(cfg), positions,
                         attn_impl=rc.attn_impl)
    h = constrain(h + a, ("batch", "seq_act", None))
    hn = ly.norm_apply(sp["mlp_norm"], h, cfg.norm_eps)
    hn = constrain(hn, ("batch", None, None))
    m = ly.mlp_apply(sp["mlp"], hn)
    return constrain(h + m, ("batch", "seq_act", None))


def forward_hidden(params, cfg: ArchConfig, rc: RunConfig, embeds,
                   positions=None, constrain: Callable = Identity):
    B, L, _ = embeds.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))

    def group_body(h, gp):
        h = _mamba_scan(gp, h, cfg, rc, rc.remat)
        h = _shared_attn(params["shared"], h, cfg, rc, positions, constrain)
        return constrain(h, ("batch", "seq_act", None)), None

    h, _ = jax.lax.scan(group_body, embeds, params["mamba"])
    if "mamba_tail" in params:
        h = _mamba_scan(params["mamba_tail"], h, cfg, rc, rc.remat)
    h = ly.norm_apply(params["norm_f"], h, cfg.norm_eps)
    return h, jnp.float32(0.0)


def loss_fn(params, cfg: ArchConfig, rc: RunConfig, tokens, labels,
            prefix_embeds=None, constrain: Callable = Identity):
    emb = jnp.take(params["embed"], tokens, axis=0)
    emb = constrain(emb, ("batch", "seq_act", None))
    h, _ = forward_hidden(params, cfg, rc, emb, constrain=constrain)
    return lo.chunked_softmax_xent(h, head_weight(params, cfg), labels,
                                   chunk=rc.loss_chunk, z_loss=rc.z_loss)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, rc: RunConfig, batch: int, max_seq: int,
               dtype=None):
    dtype = jnp.dtype(rc.param_dtype) if dtype is None else dtype
    n_groups, k, rem = _group_layout(cfg)
    sc = ssm_cfg(cfg)
    Ln = cfg.n_layers
    return {
        "conv": jnp.zeros((Ln, batch, sc.d_conv - 1, sc.d_conv_in), dtype),
        "ssm": jnp.zeros((Ln, batch, sc.n_heads, sc.head_dim, sc.d_state),
                         jnp.float32),
        # shared attention block: one KV cache per *application* (n_groups)
        "k": jnp.zeros((n_groups, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), dtype),
        "v": jnp.zeros((n_groups, batch, cfg.n_kv_heads, max_seq, cfg.head_dim), dtype),
    }


def _tree_slice(tree, i, size):
    return jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(x, i, size, 0), tree)


def _tree_update(tree, update, i):
    return jax.tree.map(
        lambda x, u: jax.lax.dynamic_update_slice_in_dim(x, u, i, 0), tree, update)


def prefill(params, cfg: ArchConfig, rc: RunConfig, tokens, max_seq: int,
            prefix_embeds=None, constrain: Callable = Identity):
    emb = jnp.take(params["embed"], tokens, axis=0)
    B, L, _ = emb.shape
    positions = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    n_groups, k, rem = _group_layout(cfg)
    sc = ssm_cfg(cfg)

    convs, ssms, kcs, vcs = [], [], [], []
    h = emb

    def mamba_with_state(bp, hc):
        hn = ly.norm_apply(bp["norm"], hc, cfg.norm_eps)
        out, st = ssm_mod.ssm_apply(bp["ssm"], hn, ssm_cfg(cfg), ssd_impl=rc.ssd_impl,
                                    conv_impl=rc.conv_impl, return_state=True)
        return hc + out, st

    def run_stack(stacked, h, n):
        def body(hc, bp):
            return mamba_with_state(bp, hc)
        return jax.lax.scan(body, h, stacked)

    for g in range(n_groups):
        gp = jax.tree.map(lambda x: x[g], params["mamba"])
        h, st = run_stack(gp, h, k)
        convs.append(st["conv"])
        ssms.append(st["ssm"])
        a_in = ly.norm_apply(params["shared"]["attn_norm"], h, cfg.norm_eps)
        a, (kk, vv) = ly.attn_apply(params["shared"]["attn"], a_in, attn_cfg(cfg),
                                    positions, attn_impl=rc.attn_impl)
        h = h + a
        h = h + ly.mlp_apply(params["shared"]["mlp"],
                             ly.norm_apply(params["shared"]["mlp_norm"], h, cfg.norm_eps))
        kcs.append(jnp.pad(kk, ((0, 0), (0, 0), (0, max_seq - L), (0, 0))))
        vcs.append(jnp.pad(vv, ((0, 0), (0, 0), (0, max_seq - L), (0, 0))))
    if rem:
        h, st = run_stack(params["mamba_tail"], h, rem)
        convs.append(st["conv"])
        ssms.append(st["ssm"])
    h = ly.norm_apply(params["norm_f"], h, cfg.norm_eps)
    logits = lo.logits_last(h[:, -1], head_weight(params, cfg))
    cache = {
        "conv": jnp.concatenate(convs, axis=0),
        "ssm": jnp.concatenate(ssms, axis=0),
        "k": jnp.stack(kcs), "v": jnp.stack(vcs),
    }
    return logits, cache


def decode_step(params, cfg: ArchConfig, rc: RunConfig, token, cache, pos,
                constrain: Callable = Identity):
    emb = jnp.take(params["embed"], token[:, None], axis=0)
    n_groups, k, rem = _group_layout(cfg)
    h = emb
    new_conv, new_ssm = cache["conv"], cache["ssm"]
    new_k, new_v = cache["k"], cache["v"]

    def mamba_stack_decode(stacked, h, conv_c, ssm_c):
        def body(hc, xs):
            bp, cc, sc_ = xs
            hn = ly.norm_apply(bp["norm"], hc, cfg.norm_eps)
            out, st = ssm_mod.ssm_decode(bp["ssm"], hn, ssm_cfg(cfg),
                                         {"conv": cc, "ssm": sc_})
            return hc + out, (st["conv"], st["ssm"])
        h, (cs, ss) = jax.lax.scan(body, h, (stacked, conv_c, ssm_c))
        return h, cs, ss

    for g in range(n_groups):
        gp = jax.tree.map(lambda x: x[g], params["mamba"])
        conv_c = jax.lax.dynamic_slice_in_dim(new_conv, g * k, k, 0)
        ssm_c = jax.lax.dynamic_slice_in_dim(new_ssm, g * k, k, 0)
        h, cs, ss = mamba_stack_decode(gp, h, conv_c, ssm_c)
        new_conv = jax.lax.dynamic_update_slice_in_dim(new_conv, cs, g * k, 0)
        new_ssm = jax.lax.dynamic_update_slice_in_dim(new_ssm, ss, g * k, 0)
        a_in = ly.norm_apply(params["shared"]["attn_norm"], h, cfg.norm_eps)
        a, (kc, vc) = ly.attn_decode(params["shared"]["attn"], a_in, attn_cfg(cfg),
                                     new_k[g], new_v[g], pos)
        h = h + a
        h = h + ly.mlp_apply(params["shared"]["mlp"],
                             ly.norm_apply(params["shared"]["mlp_norm"], h, cfg.norm_eps))
        new_k = new_k.at[g].set(kc)
        new_v = new_v.at[g].set(vc)
    if rem:
        conv_c = jax.lax.dynamic_slice_in_dim(new_conv, n_groups * k, rem, 0)
        ssm_c = jax.lax.dynamic_slice_in_dim(new_ssm, n_groups * k, rem, 0)
        h, cs, ss = mamba_stack_decode(params["mamba_tail"], h, conv_c, ssm_c)
        new_conv = jax.lax.dynamic_update_slice_in_dim(new_conv, cs, n_groups * k, 0)
        new_ssm = jax.lax.dynamic_update_slice_in_dim(new_ssm, ss, n_groups * k, 0)
    h = ly.norm_apply(params["norm_f"], h, cfg.norm_eps)
    logits = lo.logits_last(h[:, -1], head_weight(params, cfg))
    return logits, {"conv": new_conv, "ssm": new_ssm, "k": new_k, "v": new_v}
