"""Mamba2 (SSD) layer — functional, train + prefill + decode paths.

The causal short-conv runs through the stencil machinery (kernels/conv1d,
a 1-D halo stencil — DESIGN.md §4) and the SSD scan through kernels/ssd
(Pallas) or its chunked-jnp twin (ops._ssd_chunked_jnp) for compiled
multi-device paths. Decode keeps (conv window, ssm state) as the cache —
O(1) per token, which is why the ssm/hybrid archs run the long_500k cell.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import common as cm
from ..kernels import ops


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_model: int
    d_state: int = 128       # N
    d_conv: int = 4          # K
    expand: int = 2
    head_dim: int = 64       # P
    n_groups: int = 1        # G
    chunk: int = 64
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def d_conv_in(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def ssm_init(key, cfg: SSMCfg, dtype):
    D, Din, H = cfg.d_model, cfg.d_inner, cfg.n_heads
    GN = cfg.n_groups * cfg.d_state
    d_proj = 2 * Din + 2 * GN + H  # z, x, B, C, dt
    ks = jax.random.split(key, 5)
    # dt bias: softplus^{-1} of log-uniform dt in [dt_min, dt_max]
    u = jax.random.uniform(ks[3], (H,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min)) + jnp.log(cfg.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    A0 = jax.random.uniform(ks[4], (H,), jnp.float32, 1.0, 16.0)
    return {
        "in_proj": cm.leaf(cm.normal(ks[0], (D, d_proj), D ** -0.5, dtype),
                           ("fsdp", "tensor")),
        "conv_w": cm.leaf(cm.normal(ks[1], (cfg.d_conv, cfg.d_conv_in),
                                    cfg.d_conv ** -0.5, dtype), (None, "tensor")),
        "conv_b": cm.leaf(cm.zeros((cfg.d_conv_in,), dtype), ("tensor",)),
        "dt_bias": cm.leaf(dt_bias.astype(jnp.float32), ("tensor",)),
        "A_log": cm.leaf(jnp.log(A0), ("tensor",)),
        "D": cm.leaf(cm.ones((H,), jnp.float32), ("tensor",)),
        "norm": cm.leaf(cm.ones((Din,), dtype), ("tensor",)),
        "out_proj": cm.leaf(cm.normal(ks[2], (Din, D), Din ** -0.5, dtype),
                            ("tensor", "fsdp")),
    }


def _split_proj(zxbcdt, cfg: SSMCfg):
    Din, GN, H = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :Din]
    xBC = zxbcdt[..., Din : Din + cfg.d_conv_in]
    dt = zxbcdt[..., Din + cfg.d_conv_in :]
    return z, xBC, dt


def _split_xbc(xBC, cfg: SSMCfg):
    Din, GN = cfg.d_inner, cfg.n_groups * cfg.d_state
    x = xBC[..., :Din]
    B = xBC[..., Din : Din + GN]
    C = xBC[..., Din + GN :]
    return x, B, C


def ssm_apply(p, h, cfg: SSMCfg, ssd_impl: str = "chunked",
              conv_impl: str = "chunked", return_state: bool = False):
    """h: (B, L, D) -> (out, state|None). Full-sequence (train / prefill)."""
    Bb, L, D = h.shape
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = h @ p["in_proj"]
    z, xBC, dt_raw = _split_proj(zxbcdt, cfg)
    xBC = ops.conv1d_causal(xBC, p["conv_w"], p["conv_b"], silu=True,
                            impl=conv_impl)
    x, Bm, Cm = _split_xbc(xBC, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ops.ssd(
        x.reshape(Bb, L, H, P), dt, A,
        Bm.reshape(Bb, L, G, N), Cm.reshape(Bb, L, G, N),
        D=p["D"], chunk=cfg.chunk, impl=ssd_impl)
    y = y.reshape(Bb, L, cfg.d_inner)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype), p["norm"])
    out = y @ p["out_proj"]
    if return_state:
        # conv window: last K-1 *pre-activation* conv inputs
        pad = max(cfg.d_conv - 1 - L, 0)
        zxbcdt_tail = h[:, L - (cfg.d_conv - 1 - pad):] @ p["in_proj"]
        xBC_tail = _split_proj(zxbcdt_tail, cfg)[1]
        if pad:
            xBC_tail = jnp.pad(xBC_tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"conv": xBC_tail, "ssm": state}
    return out, None


def ssm_decode(p, h, cfg: SSMCfg, cache):
    """One token. h: (B, 1, D); cache {"conv": (B, K-1, Cin), "ssm": (B,H,P,N)}."""
    Bb = h.shape[0]
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = h[:, 0] @ p["in_proj"]
    z, xBC_t, dt_raw = _split_proj(zxbcdt, cfg)
    # conv over the rolling window [conv_state, current]
    win = jnp.concatenate([cache["conv"], xBC_t[:, None]], axis=1)  # (B, K, Cin)
    w = p["conv_w"].astype(jnp.float32)  # (K, Cin); out = sum_d w[d] x[t-d]
    conv = jnp.sum(win.astype(jnp.float32) * w[::-1][None], axis=1) + \
        p["conv_b"].astype(jnp.float32)
    conv = (conv * jax.nn.sigmoid(conv)).astype(h.dtype)
    x, Bm, Cm = _split_xbc(conv, cfg)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    rep = H // G
    Bh = jnp.repeat(Bm.reshape(Bb, G, N), rep, axis=1)
    Ch = jnp.repeat(Cm.reshape(Bb, G, N), rep, axis=1)
    y, ssm_new = ops.ssd_decode_step(cache["ssm"], x.reshape(Bb, H, P), dt, A,
                                     Bh, Ch, D=p["D"])
    y = y.reshape(Bb, cfg.d_inner)
    y = cm.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype), p["norm"])
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": win[:, 1:], "ssm": ssm_new}
