"""Cartesian grid description for stencil computations.

Mirrors ParallelStencil's implicit convention: arrays carry their boundary
points; stencil kernels update the interior (``@inn``) region only. A
:class:`Grid` records the *global* array extent, physical spacing and the
stencil halo width (radius) so that launch parameters, halo exchanges and
T_eff accounting can all be derived from one object.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Grid:
    """A structured grid with uniform spacing.

    Attributes:
      shape: global number of grid points per axis, boundary included
        (the paper's ``nx, ny, nz``).
      length: physical domain extent per axis (the paper's ``lx, ly, lz``).
      radius: stencil halo width in points. 1 for 2nd-order FD.
    """

    shape: tuple[int, ...]
    length: tuple[float, ...] = ()
    radius: int = 1

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        if not self.length:
            object.__setattr__(self, "length", tuple(1.0 for _ in self.shape))
        object.__setattr__(self, "length", tuple(float(l) for l in self.length))
        if len(self.length) != len(self.shape):
            raise ValueError(f"length {self.length} incompatible with shape {self.shape}")
        if any(s < 2 * self.radius + 1 for s in self.shape):
            raise ValueError(f"shape {self.shape} too small for radius {self.radius}")

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def spacing(self) -> tuple[float, ...]:
        """Physical distance between adjacent points (``dx = lx/(nx-1)``)."""
        return tuple(l / (s - 1) for l, s in zip(self.length, self.shape))

    @property
    def inv_spacing(self) -> tuple[float, ...]:
        return tuple(1.0 / d for d in self.spacing)

    @property
    def n_points(self) -> int:
        return int(np.prod(self.shape))

    @property
    def interior_shape(self) -> tuple[int, ...]:
        return tuple(s - 2 * self.radius for s in self.shape)

    @property
    def interior_slice(self) -> tuple[slice, ...]:
        r = self.radius
        return tuple(slice(r, s - r) for s in self.shape)

    def coords(self, dtype=jnp.float32) -> tuple[jnp.ndarray, ...]:
        """Per-axis coordinate vectors (including boundary points)."""
        return tuple(
            jnp.linspace(0.0, l, s, dtype=dtype) for l, s in zip(self.length, self.shape)
        )

    def meshgrid(self, dtype=jnp.float32) -> tuple[jnp.ndarray, ...]:
        return tuple(jnp.meshgrid(*self.coords(dtype), indexing="ij"))

    def stable_diffusion_dt(self, diffusivity: float, safety: float = 6.1) -> float:
        """The paper's explicit-diffusion time-step bound (Fig. 1, line 33)."""
        return min(d ** 2 for d in self.spacing) / diffusivity / safety

    def subgrid(self, factors: Sequence[int]) -> "Grid":
        """Local grid for one rank of a block domain decomposition.

        The local array keeps one halo layer of width ``radius`` on every
        face (interior sizes must divide evenly).
        """
        if len(factors) != self.ndim:
            raise ValueError("one decomposition factor per axis required")
        r = self.radius
        local = []
        for s, f in zip(self.shape, factors):
            inner = s - 2 * r
            if inner % f:
                raise ValueError(f"interior extent {inner} not divisible by {f}")
            local.append(inner // f + 2 * r)
        return Grid(tuple(local), tuple(l / f for l, f in zip(self.length, factors)), r)


def volume_bytes(shape: Sequence[int], dtype) -> int:
    return int(np.prod(shape)) * jnp.dtype(dtype).itemsize


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0 or unit == "TiB":
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} TiB"
