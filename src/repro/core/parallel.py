"""`@parallel` — single-source xPU stencil kernels (the paper's C1/C2/C3).

Usage, mirroring Fig. 1 of the paper::

    from repro.core import parallel as P
    from repro.core.fd import fd3d as fd

    ps = P.init_parallel_stencil(backend="pallas", dtype="float32", ndims=3)

    @ps.parallel(outputs=("T2",))
    def step(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx**2 + fd.d2_yi(T) * _dy**2 + fd.d2_zi(T) * _dz**2))}

    T2 = step(T2=T2, T=T, Ci=Ci, lam=lam, dt=dt, _dx=_dx, _dy=_dy, _dz=_dz)

The same kernel source runs on every backend (the xPU property):

  * ``backend="jnp"``    — the update is traced on full arrays and scattered
    into the interior; XLA fuses the chain. This doubles as the paper's
    "array programming" comparison baseline when called op-by-op unjitted.
  * ``backend="pallas"`` — the update is traced on halo-extended VMEM
    windows inside a fused Pallas TPU kernel with derived launch parameters
    (kernels/stencil.py). On non-TPU hosts it validates via interpret mode.

Arguments are classified by value: arrays of the kernel's dimensionality
are *fields*, everything else is a *scalar*. Every name in ``outputs``
must be a field argument; its previous contents provide the boundary
values (the paper's ``@inn(T2) = ...`` semantics).

Coupled systems: ``outputs`` may name several fields — the whole coupled
update runs as ONE fused Pallas launch. Fields may be staggered: a field
up to ``radius`` shorter than the (per-axis maximal) base shape lives on
cell faces, e.g. the Darcy flux ``qx`` of shape ``(nx-1, ny)`` next to
cell-centered ``phi``/``Pe`` of shape ``(nx, ny)``. Per-output write
semantics follow the shape of the returned update along each axis:
``base - 2*radius`` extent writes the interior (``@inn``, boundary ring
preserved), full-field extent writes everything (``@all`` — mandatory on
staggered axes). See kernels/stencil.py for the window geometry.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import stencil as _stencil

_BACKENDS = ("jnp", "pallas")


@dataclasses.dataclass(frozen=True)
class ParallelStencil:
    """Backend/dtype/ndims context (the paper's ``@init_parallel_stencil``)."""

    backend: str = "jnp"
    dtype: Any = jnp.float32
    ndims: int = 3
    interpret: bool | None = None  # None -> auto (True unless on real TPU)

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}")
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype))

    def parallel(
        self,
        outputs: Sequence[str],
        radius: int = 1,
        tile: Sequence[int] | None = None,
        vmem_budget: int = _stencil.DEFAULT_VMEM_BUDGET,
        rotations: Mapping[str, str] | None = None,
    ) -> Callable[[Callable], "StencilKernel"]:
        """``rotations`` maps each output field to the input field it becomes
        on the next time step (e.g. ``{"T2": "T"}``) — required for the
        temporally-blocked ``run_steps(k>1)`` path."""
        def deco(fn: Callable) -> StencilKernel:
            return StencilKernel(self, fn, tuple(outputs), radius, tile,
                                 vmem_budget, rotations)

        return deco


def init_parallel_stencil(
    backend: str = "jnp", dtype: Any = jnp.float32, ndims: int = 3,
    interpret: bool | None = None,
) -> ParallelStencil:
    return ParallelStencil(backend=backend, dtype=dtype, ndims=ndims, interpret=interpret)


class StencilKernel:
    """A compiled-on-first-use, shape-polymorphic stencil kernel."""

    def __init__(self, ps: ParallelStencil, fn: Callable, outputs: tuple[str, ...],
                 radius: int, tile, vmem_budget: int,
                 rotations: Mapping[str, str] | None = None):
        self.ps = ps
        self.fn = fn
        self.outputs = outputs
        self.radius = radius
        self.tile = tile
        self.vmem_budget = vmem_budget
        self.rotations = dict(rotations) if rotations else None
        self._cache: dict = {}
        functools.update_wrapper(self, fn)

    # -- argument classification ------------------------------------------
    def _split(self, kwargs: Mapping[str, Any]):
        fields, scalars = {}, {}
        for name, v in kwargs.items():
            if hasattr(v, "ndim") and getattr(v, "ndim", 0) == self.ps.ndims:
                fields[name] = v
            else:
                scalars[name] = v
        if not fields:
            raise ValueError("no field arguments found")
        shapes = {n: tuple(np.shape(v)) for n, v in fields.items()}
        base = tuple(
            max(s[a] for s in shapes.values()) for a in range(self.ps.ndims)
        )
        r = self.radius
        for n, s in shapes.items():
            off = tuple(b - x for b, x in zip(base, s))
            if any(o > r for o in off):
                raise ValueError(
                    f"field {n!r} shape {s} is inconsistent with the coupled "
                    f"system's base shape {base}: per-axis offsets {off} "
                    f"exceed the staggering band [0, radius={r}] — fields of "
                    "one system must agree up to face/cell staggering"
                )
        for o in self.outputs:
            if o not in fields:
                raise ValueError(f"output {o!r} is not a field argument")
        return fields, scalars, base, shapes

    # -- backends -----------------------------------------------------------
    def _run_jnp(self, fields, scalars, base):
        updates = self.fn(**fields, **scalars)
        r = self.radius
        out = {}
        for name in self.outputs:
            prev = fields[name]
            upd = updates[name].astype(self.ps.dtype)
            # Per-axis write semantics from the update's shape — the SAME
            # derivation the pallas backend applies to windows (including
            # the staggered-axes-must-be-`all` rule), so a kernel that
            # traces on one backend traces on both.
            off = tuple(b - s for b, s in zip(base, prev.shape))
            modes = _stencil._write_modes(upd.shape, prev.shape, r, off, name)
            idx = tuple(
                slice(None) if m == "all" else slice(r, prev.shape[a] - r)
                for a, m in enumerate(modes)
            )
            out[name] = prev.at[idx].set(upd)
        return out

    def _run_pallas(self, fields, scalars, base, shapes, nsteps: int = 1):
        key = (base, tuple(sorted(shapes.items())), tuple(sorted(scalars)),
               nsteps)
        run = self._cache.get(key)
        if run is None:
            field_names = tuple(fields)
            scalar_names = tuple(scalars)

            def update(fdict, sdict):
                return self.fn(**fdict, **sdict)

            run = _stencil.build_stencil_call(
                update,
                field_names=field_names,
                out_names=self.outputs,
                scalar_names=scalar_names,
                shape=base,
                radius=self.radius,
                dtype=self.ps.dtype,
                tile=self.tile,
                vmem_budget=self.vmem_budget,
                interpret=self.ps.interpret,
                nsteps=nsteps,
                rotations=self.rotations,
                field_shapes=shapes,
            )
            self._cache[key] = run
        return run(fields, scalars)

    def __call__(self, **kwargs):
        fields, scalars, base, shapes = self._split(kwargs)
        if self.ps.backend == "pallas":
            outs = self._run_pallas(fields, scalars, base, shapes)
        else:
            outs = self._run_jnp(fields, scalars, base)
        if len(self.outputs) == 1:
            return outs[self.outputs[0]]
        return outs

    def run_steps(self, nsteps: int, **kwargs):
        """Advance ``nsteps`` fused time steps; returns the *final* outputs
        (same structure as ``__call__``).

        The pallas backend runs one temporally-blocked kernel launch
        (``k*radius`` halo windows, k in-kernel sweeps — each field crosses
        HBM once per k steps). The jnp backend realizes the identical
        semantics as k unrolled single steps with the ``rotations``
        double-buffer rotation; under ``jax.jit`` XLA fuses the chain and
        elides the intermediate buffers. Both are bitwise-consistent with
        k sequential ``__call__``s when the rotation buffers agree on their
        boundary rings.
        """
        nsteps = int(nsteps)
        if nsteps < 1:
            raise ValueError(f"nsteps must be >= 1, got {nsteps}")
        if nsteps == 1:
            return self(**kwargs)
        if not self.rotations or set(self.outputs) - set(self.rotations):
            raise ValueError(
                "run_steps(nsteps>1) requires rotations covering every output "
                "(pass rotations={'T2': 'T'}-style mapping to @parallel)"
            )
        fields, scalars, base, shapes = self._split(kwargs)
        if self.ps.backend == "pallas":
            outs = self._run_pallas(fields, scalars, base, shapes, nsteps)
        else:
            # True double-buffer rotation, unrolled: sweep s scatters into
            # the stale buffer of the (out, target) pair, which is dead two
            # sweeps later — under jit XLA turns those scatters into
            # in-place updates instead of per-launch copies.
            cur = dict(fields)
            for s in range(nsteps):
                outs = self._run_jnp(cur, scalars, base)
                if s < nsteps - 1:
                    for o, tgt in self.rotations.items():
                        cur[o], cur[tgt] = cur[tgt], outs[o]
        if len(self.outputs) == 1:
            return outs[self.outputs[0]]
        return outs

    @property
    def launch_info(self) -> dict:
        """Derived launch parameters of compiled instances (for inspection)."""
        return {
            k: {"grid": v.grid, "block": v.block, "window_bytes": v.window_bytes}
            for k, v in self._cache.items()
        }
