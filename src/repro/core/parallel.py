"""`@parallel` — single-source xPU stencil kernels (the paper's C1/C2/C3).

Usage, mirroring Fig. 1 of the paper::

    from repro.core import parallel as P
    from repro.core.fd import fd3d as fd

    ps = P.init_parallel_stencil(backend="pallas", dtype="float32", ndims=3)

    @ps.parallel(outputs=("T2",))
    def step(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx**2 + fd.d2_yi(T) * _dy**2 + fd.d2_zi(T) * _dz**2))}

    T2 = step(T2=T2, T=T, Ci=Ci, lam=lam, dt=dt, _dx=_dx, _dy=_dy, _dz=_dz)

The same kernel source runs on every backend (the xPU property):

  * ``backend="jnp"``    — the update is traced on full arrays and scattered
    into the interior; XLA fuses the chain. This doubles as the paper's
    "array programming" comparison baseline when called op-by-op unjitted.
  * ``backend="pallas"`` — the update is traced on halo-extended VMEM
    windows inside a fused Pallas TPU kernel with derived launch parameters
    (kernels/stencil.py). On non-TPU hosts it validates via interpret mode.

Arguments are classified by value: arrays of the kernel's dimensionality
are *fields* (must share one shape), everything else is a *scalar*. Every
name in ``outputs`` must be a field argument; its previous contents provide
the boundary values (the paper's ``@inn(T2) = ...`` semantics).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import stencil as _stencil

_BACKENDS = ("jnp", "pallas")


@dataclasses.dataclass(frozen=True)
class ParallelStencil:
    """Backend/dtype/ndims context (the paper's ``@init_parallel_stencil``)."""

    backend: str = "jnp"
    dtype: Any = jnp.float32
    ndims: int = 3
    interpret: bool | None = None  # None -> auto (True unless on real TPU)

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}")
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype))

    def parallel(
        self,
        outputs: Sequence[str],
        radius: int = 1,
        tile: Sequence[int] | None = None,
        vmem_budget: int = _stencil.DEFAULT_VMEM_BUDGET,
        rotations: Mapping[str, str] | None = None,
    ) -> Callable[[Callable], "StencilKernel"]:
        """``rotations`` maps each output field to the input field it becomes
        on the next time step (e.g. ``{"T2": "T"}``) — required for the
        temporally-blocked ``run_steps(k>1)`` path."""
        def deco(fn: Callable) -> StencilKernel:
            return StencilKernel(self, fn, tuple(outputs), radius, tile,
                                 vmem_budget, rotations)

        return deco


def init_parallel_stencil(
    backend: str = "jnp", dtype: Any = jnp.float32, ndims: int = 3,
    interpret: bool | None = None,
) -> ParallelStencil:
    return ParallelStencil(backend=backend, dtype=dtype, ndims=ndims, interpret=interpret)


class StencilKernel:
    """A compiled-on-first-use, shape-polymorphic stencil kernel."""

    def __init__(self, ps: ParallelStencil, fn: Callable, outputs: tuple[str, ...],
                 radius: int, tile, vmem_budget: int,
                 rotations: Mapping[str, str] | None = None):
        self.ps = ps
        self.fn = fn
        self.outputs = outputs
        self.radius = radius
        self.tile = tile
        self.vmem_budget = vmem_budget
        self.rotations = dict(rotations) if rotations else None
        self._cache: dict = {}
        functools.update_wrapper(self, fn)

    # -- argument classification ------------------------------------------
    def _split(self, kwargs: Mapping[str, Any]):
        fields, scalars = {}, {}
        for name, v in kwargs.items():
            if hasattr(v, "ndim") and getattr(v, "ndim", 0) == self.ps.ndims:
                fields[name] = v
            else:
                scalars[name] = v
        if not fields:
            raise ValueError("no field arguments found")
        shapes = {np.shape(v) for v in fields.values()}
        if len(shapes) != 1:
            raise ValueError(f"fields must share one shape, got {shapes}")
        for o in self.outputs:
            if o not in fields:
                raise ValueError(f"output {o!r} is not a field argument")
        return fields, scalars, shapes.pop()

    # -- backends -----------------------------------------------------------
    def _run_jnp(self, fields, scalars):
        updates = self.fn(**fields, **scalars)
        r = self.radius
        inner = tuple(slice(r, -r) for _ in range(self.ps.ndims))
        return {
            name: fields[name].at[inner].set(updates[name].astype(self.ps.dtype))
            for name in self.outputs
        }

    def _run_pallas(self, fields, scalars, shape, nsteps: int = 1):
        key = (shape, tuple(sorted(fields)), tuple(sorted(scalars)), nsteps)
        run = self._cache.get(key)
        if run is None:
            field_names = tuple(fields)
            scalar_names = tuple(scalars)

            def update(fdict, sdict):
                return self.fn(**fdict, **sdict)

            run = _stencil.build_stencil_call(
                update,
                field_names=field_names,
                out_names=self.outputs,
                scalar_names=scalar_names,
                shape=shape,
                radius=self.radius,
                dtype=self.ps.dtype,
                tile=self.tile,
                vmem_budget=self.vmem_budget,
                interpret=self.ps.interpret,
                nsteps=nsteps,
                rotations=self.rotations,
            )
            self._cache[key] = run
        return run(fields, scalars)

    def __call__(self, **kwargs):
        fields, scalars, shape = self._split(kwargs)
        if self.ps.backend == "pallas":
            outs = self._run_pallas(fields, scalars, shape)
        else:
            outs = self._run_jnp(fields, scalars)
        if len(self.outputs) == 1:
            return outs[self.outputs[0]]
        return outs

    def run_steps(self, nsteps: int, **kwargs):
        """Advance ``nsteps`` fused time steps; returns the *final* outputs
        (same structure as ``__call__``).

        The pallas backend runs one temporally-blocked kernel launch
        (``k*radius`` halo windows, k in-kernel sweeps — each field crosses
        HBM once per k steps). The jnp backend realizes the identical
        semantics as k unrolled single steps with the ``rotations``
        double-buffer rotation; under ``jax.jit`` XLA fuses the chain and
        elides the intermediate buffers. Both are bitwise-consistent with
        k sequential ``__call__``s when the rotation buffers agree on their
        boundary rings.
        """
        nsteps = int(nsteps)
        if nsteps < 1:
            raise ValueError(f"nsteps must be >= 1, got {nsteps}")
        if nsteps == 1:
            return self(**kwargs)
        if not self.rotations or set(self.outputs) - set(self.rotations):
            raise ValueError(
                "run_steps(nsteps>1) requires rotations covering every output "
                "(pass rotations={'T2': 'T'}-style mapping to @parallel)"
            )
        fields, scalars, shape = self._split(kwargs)
        if self.ps.backend == "pallas":
            outs = self._run_pallas(fields, scalars, shape, nsteps)
        else:
            # True double-buffer rotation, unrolled: sweep s scatters into
            # the stale buffer of the (out, target) pair, which is dead two
            # sweeps later — under jit XLA turns those scatters into
            # in-place updates instead of per-launch copies.
            cur = dict(fields)
            for s in range(nsteps):
                outs = self._run_jnp(cur, scalars)
                if s < nsteps - 1:
                    for o, tgt in self.rotations.items():
                        cur[o], cur[tgt] = cur[tgt], outs[o]
        if len(self.outputs) == 1:
            return outs[self.outputs[0]]
        return outs

    @property
    def launch_info(self) -> dict:
        """Derived launch parameters of compiled instances (for inspection)."""
        return {
            k: {"grid": v.grid, "block": v.block, "window_bytes": v.window_bytes}
            for k, v in self._cache.items()
        }
