"""`@parallel` — single-source xPU stencil kernels (the paper's C1/C2/C3).

Usage, mirroring Fig. 1 of the paper::

    from repro.core import parallel as P
    from repro.core.fd import fd3d as fd

    ps = P.init_parallel_stencil(backend="pallas", dtype="float32", ndims=3)

    @ps.parallel(outputs=("T2",))
    def step(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx**2 + fd.d2_yi(T) * _dy**2 + fd.d2_zi(T) * _dz**2))}

    T2 = step(T2=T2, T=T, Ci=Ci, lam=lam, dt=dt, _dx=_dx, _dy=_dy, _dz=_dz)

The same kernel source runs on every backend (the xPU property):

  * ``backend="jnp"``    — the update is traced on full arrays and scattered
    into the interior; XLA fuses the chain. This doubles as the paper's
    "array programming" comparison baseline when called op-by-op unjitted.
  * ``backend="pallas"`` — the update is traced on halo-extended VMEM
    windows inside a fused Pallas TPU kernel with derived launch parameters
    (kernels/stencil.py). On non-TPU hosts it validates via interpret mode.

Arguments are classified by value: arrays of the kernel's dimensionality
are *fields*, everything else is a *scalar*. Every name in ``outputs``
must be a field argument; its previous contents provide the boundary
values (the paper's ``@inn(T2) = ...`` semantics).

Footprint inference (the stencil IR): before anything runs, the update
function is traced ONCE with symbolic window objects (``repro.ir``) that
implement the same relative-slice protocol as the ``fd`` operators. The
trace yields per-field, per-axis halo depths — ``radius`` no longer needs
declaring. A declared ``radius`` is kept as a cross-check: a mismatch
against the inferred footprint raises a pointed ``ValueError``; if the
update uses constructs the tracer cannot analyze (``jnp.*`` calls,
integer indexing), a declared ``radius`` selects the legacy symmetric
geometry instead, and an undeclared one reports why inference failed.

Boundary conditions: ``bc={"T2": BoundaryCondition("neumann0"), ...}``
(or bare kind strings) declares each output's condition, realized by the
engine itself — inside the fused Pallas launch (dirichlet/neumann0, also
between the sweeps of ``run_steps(k)``) or as a face-slab scatter fused
into the surrounding jit (periodic) — bitwise-equal to applying the
``core.boundary`` post-pass after every step.

Coupled systems: ``outputs`` may name several fields — the whole coupled
update runs as ONE fused Pallas launch. Fields may be staggered: a field
up to the footprint band shorter than the (per-axis maximal) base shape
lives on cell faces, e.g. the Darcy flux ``qx`` of shape ``(nx-1, ny)``
next to cell-centered ``phi``/``Pe`` of shape ``(nx, ny)``. Per-output
write semantics follow the shape of the returned update along each axis:
a symmetric interior margin writes the interior (``@inn``, boundary ring
preserved), full-field extent writes everything (``@all`` — mandatory on
staggered axes). See kernels/stencil.py for the window geometry.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import stencil as _stencil
from .. import ir as _ir

_BACKENDS = ("jnp", "pallas")


def _bf16_load_f32(x):
    """bf16 -> f32 as integer bit movement (widen + shift): exact, and
    — unlike the ``convert`` HLO, which LLVM scalarizes to a libcall on
    CPUs without native bf16 — it vectorizes inside fused loops."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(u << 16, jnp.float32)


def _f32_store_bf16(x):
    """f32 -> bf16 round-to-nearest-even as integer bit arithmetic.
    Bit-identical to ``astype(bfloat16)`` for finite values and Inf
    (ties-to-even via the odd-bit bias); quiet-NaN payloads survive,
    signaling NaNs with sub-0x8000 payloads are not preserved."""
    u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    bias = jnp.uint32(0x7FFF) + ((u >> 16) & jnp.uint32(1))
    return jax.lax.bitcast_convert_type(
        ((u + bias) >> 16).astype(jnp.uint16), jnp.bfloat16)


@dataclasses.dataclass(frozen=True)
class ParallelStencil:
    """Backend/dtype/ndims context (the paper's ``@init_parallel_stencil``).

    ``dtype`` is the *storage* dtype — what fields occupy in HBM and what
    every kernel call returns. ``compute_dtype`` (default: f32 for
    sub-f32 float storage, else the storage dtype itself — see
    ``kernels.stencil.default_compute_dtype``) is what the stencil
    arithmetic runs at: fields are cast up on load and back down on
    store, on both backends, so bf16/f16 storage halves bytes moved
    while derivatives keep f32 precision."""

    backend: str = "jnp"
    dtype: Any = jnp.float32
    ndims: int = 3
    interpret: bool | None = None  # None -> auto (True unless on real TPU)
    compute_dtype: Any = None      # None -> default_compute_dtype(dtype)

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}")
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype))
        cd = self.compute_dtype
        if cd is None:
            cd = _stencil.default_compute_dtype(self.dtype)
        object.__setattr__(self, "compute_dtype", jnp.dtype(cd))

    @property
    def acc_dtype(self) -> jnp.dtype:
        """Reduction-accumulation dtype (never narrower than f32)."""
        return _stencil.accum_dtype(self.compute_dtype)

    def parallel(
        self,
        outputs: Sequence[str],
        radius: int | None = None,
        tile: Sequence[int] | None = None,
        vmem_budget: int = _stencil.DEFAULT_VMEM_BUDGET,
        rotations: Mapping[str, str] | None = None,
        bc: Mapping[str, Any] | None = None,
        march_axis: int | None = None,
        reductions: Mapping[str, Any] | None = None,
    ) -> Callable[[Callable], "StencilKernel"]:
        """``radius`` is optional: the stencil IR infers per-field,
        per-axis footprints from the update function itself; declaring it
        adds a cross-check (ValueError on mismatch) and a fallback
        geometry for untraceable updates. ``rotations`` maps each output
        field to the input field it becomes on the next time step (e.g.
        ``{"T2": "T"}``) — required for the temporally-blocked
        ``run_steps(k>1)`` path. ``bc`` declares per-output boundary
        conditions fused into the engine's step. ``march_axis`` turns one
        grid axis into a sequential *streaming* dimension: the pallas
        backend slides per-field VMEM plane queues along it instead of
        refetching overlapping halo windows, the jnp backend realizes the
        same marching order as a scan over plane slabs (cache-resident
        working set). Streamed results equal the all-parallel path.

        ``reductions`` declares named in-launch reduction epilogues
        (``{"err": "max_abs_diff(T2, T)"}``-style, or ``ir.Reduction``
        objects): the kernel call then returns ``(outputs, {name:
        scalar})`` with the reductions folded inside the same launch as
        the update — no second whole-array pass, no host sync (the
        scalars stay on device; ``core.iterate.solve_until`` consumes
        them inside a ``lax.while_loop``). Reductions reassociate:
        cross-program comparisons (jnp vs pallas, fused vs post-pass)
        are ``allclose``, never bitwise."""
        if march_axis is not None and not 0 <= int(march_axis) < self.ndims:
            raise ValueError(
                f"march_axis {march_axis} out of range for ndims={self.ndims}")

        def deco(fn: Callable) -> StencilKernel:
            return StencilKernel(self, fn, tuple(outputs), radius, tile,
                                 vmem_budget, rotations, bc, march_axis,
                                 reductions)

        return deco


def init_parallel_stencil(
    backend: str = "jnp", dtype: Any = jnp.float32, ndims: int = 3,
    interpret: bool | None = None, compute_dtype: Any = None,
) -> ParallelStencil:
    return ParallelStencil(backend=backend, dtype=dtype, ndims=ndims,
                           interpret=interpret, compute_dtype=compute_dtype)


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """Resolved launch geometry of one kernel instance (per field-shape
    set): the traced IR (None when the legacy declared-radius fallback is
    active), the staggering band, and the per-axis window halo."""

    ir: _ir.StencilIR | None
    band: int                                  # staggering band radius
    halos: tuple[tuple[int, int], ...] | None  # per-axis (lo, hi) or None

    @property
    def inferred(self) -> bool:
        return self.ir is not None


class StencilKernel:
    """A compiled-on-first-use, shape-polymorphic stencil kernel."""

    def __init__(self, ps: ParallelStencil, fn: Callable, outputs: tuple[str, ...],
                 radius: int | None, tile, vmem_budget: int,
                 rotations: Mapping[str, str] | None = None,
                 bc: Mapping[str, Any] | None = None,
                 march_axis: int | None = None,
                 reductions: Mapping[str, Any] | None = None):
        self.ps = ps
        self.fn = fn
        self.outputs = outputs
        self.radius = radius
        self.tile = tile
        self.vmem_budget = vmem_budget
        self.rotations = dict(rotations) if rotations else None
        self.bc = _ir.bc.normalize_bcs(bc, outputs, ps.ndims)
        self.march_axis = None if march_axis is None else int(march_axis)
        self.reductions = _ir.normalize_reductions(reductions)
        if self.reductions and any(c.kind == "periodic"
                                   for c in self.bc.values()):
            raise ValueError(
                "fused reductions cannot be declared next to a periodic "
                "boundary condition: the wrap scatter runs after the "
                "launch, so the in-launch fold would see pre-wrap face "
                "values"
            )
        self._cache: dict = {}
        self._geom_cache: dict = {}
        self._march_variants: dict = {}
        self._red_variants: dict = {}
        functools.update_wrapper(self, fn)

    def marched(self, march_axis: int | None) -> "StencilKernel":
        """A variant of this kernel streaming along ``march_axis``
        (``None`` returns the all-parallel variant). Variants are
        memoized on the parent so repeated calls — e.g. the distributed
        overlap path marching its interior every step — reuse one
        compile cache."""
        if march_axis is not None and not 0 <= int(march_axis) < self.ps.ndims:
            raise ValueError(
                f"march_axis {march_axis} out of range for "
                f"ndims={self.ps.ndims}")
        if march_axis == self.march_axis:
            return self
        v = self._march_variants.get(march_axis)
        if v is None:
            v = StencilKernel(self.ps, self.fn, self.outputs, self.radius,
                              self.tile, self.vmem_budget, self.rotations,
                              self.bc, march_axis, self.reductions)
            self._march_variants[march_axis] = v
        return v

    def with_reductions(self, reductions: Mapping[str, Any] | None
                        ) -> "StencilKernel":
        """A variant of this kernel with a different fused-reduction set
        (``None``/``{}`` strips them — the plain step a convergence
        driver runs between checks). Memoized on the parent so the
        checked and unchecked variants each compile once."""
        reds = _ir.normalize_reductions(reductions)
        if reds == self.reductions:
            return self
        key = tuple(sorted(reds.items()))
        v = self._red_variants.get(key)
        if v is None:
            v = StencilKernel(self.ps, self.fn, self.outputs, self.radius,
                              self.tile, self.vmem_budget, self.rotations,
                              self.bc, self.march_axis, reds)
            self._red_variants[key] = v
        return v

    def apply_reductions(self, outs: Mapping[str, Any],
                         fields: Mapping[str, Any]) -> dict[str, Any]:
        """The post-pass reference realization of this kernel's
        reductions: whole-array folds over the final outputs (``outs``)
        and the pre-step fields — exactly what a separate norm pass
        computes. The fused epilogue is tested ``allclose`` against this
        (bitwise only holds within one compiled program)."""
        reds = {}
        acc = self.ps.acc_dtype
        for name, r in self.reductions.items():
            # lift operands to the accumulation dtype first: bf16 storage
            # must not fold a 256^3 sum in bf16 (it plateaus after ~256
            # increments and the convergence signal is gone)
            ops = [(outs[op] if op in outs else fields[op]).astype(acc)
                   for op in r.operands]
            reds[name] = r.fold(r.map_element(*ops))
        return reds

    # -- argument classification ------------------------------------------
    def _split(self, kwargs: Mapping[str, Any]):
        fields, scalars = {}, {}
        for name, v in kwargs.items():
            if hasattr(v, "ndim") and getattr(v, "ndim", 0) == self.ps.ndims:
                fields[name] = v
            else:
                scalars[name] = v
        if not fields:
            raise ValueError("no field arguments found")
        # Fields live at the context's storage dtype: callers may hand in
        # f32 (or f64 host) arrays to a bf16-storage kernel and get the
        # same carry dtype a chained solve would — cast once at the rim
        # (a no-op asarray for device arrays already at storage dtype).
        fields = {n: jnp.asarray(v, self.ps.dtype)
                  for n, v in fields.items()}
        shapes = {n: tuple(np.shape(v)) for n, v in fields.items()}
        base = tuple(
            max(s[a] for s in shapes.values()) for a in range(self.ps.ndims)
        )
        for o in self.outputs:
            if o not in fields:
                raise ValueError(f"output {o!r} is not a field argument")
        return fields, scalars, base, shapes

    # -- footprint inference ------------------------------------------------
    def _geometry(self, base, shapes: Mapping[str, tuple],
                  scalar_names: Sequence[str]) -> KernelGeometry:
        """Trace the update once per field-shape set; derive and validate
        the launch geometry (footprint halos, staggering band, bc fit)."""
        key = (base, tuple(sorted(shapes.items())), tuple(sorted(scalar_names)))
        geom = self._geom_cache.get(key)
        if geom is not None:
            return geom

        def update(fdict, sdict):
            return self.fn(**fdict, **sdict)

        try:
            ir = _ir.trace_stencil(update, shapes, self.outputs, scalar_names,
                                   reductions=self.reductions)
        except _ir.TraceError as e:
            if self.radius is None:
                raise ValueError(
                    f"footprint inference failed for kernel "
                    f"{getattr(self.fn, '__name__', '?')!r} and no radius "
                    f"was declared — declare radius= on @parallel to use "
                    f"the legacy symmetric geometry. Trace error: {e}"
                ) from e
            ir = None
            # The legacy fallback skips the trace, so the reduction
            # operands must be validated here instead.
            for name, r in self.reductions.items():
                for op in r.operands:
                    if op not in shapes:
                        raise ValueError(
                            f"reduction {name!r} = {r.describe()} reads "
                            f"{op!r}, which is not a field of this kernel"
                        )
                    if any(b - s for b, s in zip(base, shapes[op])):
                        raise ValueError(
                            f"reduction {name!r} = {r.describe()} reads "
                            f"staggered field {op!r}; reduction operands "
                            "must be collocated"
                        )

        if ir is not None and self.radius is not None \
                and ir.inferred_radius != self.radius:
            raise ValueError(
                f"declared radius={self.radius} does not match the inferred "
                f"footprint of kernel {getattr(self.fn, '__name__', '?')!r}: "
                f"per-axis window halo {ir.halo} and write rings "
                f"{tuple(ir.write_rings.values())} imply radius "
                f"{ir.inferred_radius} (drop radius= to use the inferred "
                "geometry, or fix the declaration)"
            )

        band = self.radius if self.radius is not None \
            else max(ir.inferred_radius, 1)
        for n, s in shapes.items():
            off = tuple(b - x for b, x in zip(base, s))
            if any(o < 0 or o > band for o in off):
                raise ValueError(
                    f"field {n!r} shape {s} is inconsistent with the coupled "
                    f"system's base shape {base}: per-axis offsets {off} "
                    f"exceed the staggering band [0, radius={band}] — fields "
                    "of one system must agree up to face/cell staggering"
                )
        # bc face depths must fit the actual field extents
        _ir.bc.normalize_bcs(self.bc, self.outputs, self.ps.ndims,
                             field_shapes=shapes)
        geom = KernelGeometry(ir=ir, band=band,
                              halos=None if ir is None else ir.halo)
        self._geom_cache[key] = geom
        return geom

    def stencil_ir(self, **kwargs) -> _ir.StencilIR:
        """The kernel's traced IR for a given field set. Accepts the same
        keyword arguments as a call — arrays, or bare shape tuples for
        the fields (scalars may be omitted or given any value)."""
        shapes, scalar_names = {}, []
        for name, v in kwargs.items():
            if isinstance(v, (tuple, list)) and all(
                    isinstance(x, (int, np.integer)) for x in v):
                if len(v) == self.ps.ndims:
                    shapes[name] = tuple(int(x) for x in v)
                else:
                    scalar_names.append(name)
            elif hasattr(v, "ndim") and getattr(v, "ndim", 0) == self.ps.ndims:
                shapes[name] = tuple(np.shape(v))
            else:
                scalar_names.append(name)
        if not shapes:
            raise ValueError("no field shapes given")
        base = tuple(max(s[a] for s in shapes.values())
                     for a in range(self.ps.ndims))
        geom = self._geometry(base, shapes, tuple(scalar_names))
        if geom.ir is None:
            raise ValueError(
                "kernel is running on the legacy declared-radius fallback; "
                "no IR is available"
            )
        return geom.ir

    def cost_model(self, **kwargs) -> _ir.StencilCostModel:
        """Analytic flop/byte cost model for a given field set. Byte
        counts use the *storage* itemsize (what actually crosses HBM
        under mixed precision); reduction partials are accounted at the
        accumulation width."""
        ir = self.stencil_ir(**kwargs)
        isz = self.ps.dtype.itemsize
        return _ir.StencilCostModel.from_ir(
            ir, isz,
            field_itemsizes=tuple(isz for _ in ir.field_shapes),
            partials_itemsize=self.ps.acc_dtype.itemsize)

    # -- backends -----------------------------------------------------------
    # Every backend runner returns ``(outs, reds)`` — ``reds`` is None for
    # kernels without declared reductions. The jnp realizations fold the
    # reductions inline (whole-array jnp ops in the SAME jit trace as the
    # update, so XLA fuses the check into the step instead of paying a
    # second HBM pass); the pallas realization folds per-tile partials
    # inside the launch itself.
    def _compute_fields(self, fields):
        """Storage -> compute cast on load (no-op when the dtypes agree):
        the jnp-backend twin of the pallas kernel's in-window cast."""
        cd = self.ps.compute_dtype
        if cd == self.ps.dtype:
            return fields
        if self._bittrick:
            return {n: _bf16_load_f32(v) for n, v in fields.items()}
        return {n: v.astype(cd) for n, v in fields.items()}

    @property
    def _bittrick(self):
        """bf16 storage with f32 compute takes the integer-bit-twiddle
        conversion path: LLVM has no vector lowering for bf16<->f32
        ``convert`` on most CPUs (it emits a per-element libcall once
        XLA's float normalization injects converts mid-loop), but the
        same conversion written as shift/add on uint16/uint32 words
        vectorizes like any integer code. The bit path IS round-to-
        nearest-even, so results are identical to ``astype``."""
        return (self.ps.dtype == jnp.bfloat16
                and self.ps.compute_dtype == jnp.float32)

    @staticmethod
    def _opaque_true(v):
        """A data-dependent, always-true predicate XLA cannot fold away
        (the popcount of any machine word is at most 64). Used to pin a
        computation boundary via ``lax.cond`` — see
        :meth:`_fenced_updates`."""
        if v.dtype.itemsize == 8:
            bits = jax.lax.bitcast_convert_type(
                v.ravel()[0], jnp.uint32)[0]
        else:
            bits = jax.lax.bitcast_convert_type(
                v.ravel()[0],
                jnp.uint16 if v.dtype.itemsize == 2 else jnp.uint32)
        return jax.lax.population_count(
            bits.astype(jnp.uint32)) <= jnp.uint32(64)

    def _fenced_updates(self, fields, scalars):
        """Run ``self.fn`` (cast to compute dtype on load, back to
        storage on store) behind a fusion fence, for sub-f32 storage.

        XLA:CPU loop-fuses the storage-dtype boundary scatter into the
        update computation, producing one mega-loop in which every
        narrow-float load/store converts element-wise — 2-3x slower
        than memory bandwidth. ``optimization_barrier`` is expanded
        away before fusion runs, so the only reliable fence is a
        computation boundary: a ``lax.cond`` whose predicate is
        data-dependent (always true at runtime, never constant-foldable,
        so the conditional cannot be inlined). Only the *fields* enter
        the branch — keeping the output arrays out of the conditional
        avoids full-array copy insertion around it. f32 storage skips
        the fence: there the single fused loop IS the fast path."""
        names = list(fields)

        def compute(vals):
            ups = self.fn(**self._compute_fields(dict(zip(names, vals))),
                          **scalars)
            return tuple(self._store(ups[o]) for o in self.outputs)

        vals = tuple(fields.values())
        shapes = jax.eval_shape(compute, vals)
        updates = jax.lax.cond(
            self._opaque_true(vals[0]), compute,
            lambda _: tuple(jnp.zeros(s.shape, s.dtype) for s in shapes),
            vals)
        return dict(zip(self.outputs, updates))

    @staticmethod
    def _dus_bits(prev, idx, upd):
        """Interior scatter as a raw ``dynamic_update_slice`` on the
        bit-identical unsigned-int view: no oob-guard select, nothing
        for float normalization to rewrite."""
        starts = tuple(0 if s.start is None else int(s.start) for s in idx)
        uint = jnp.dtype(f"uint{8 * upd.dtype.itemsize}")
        p = jax.lax.bitcast_convert_type(prev, uint)
        u = jax.lax.bitcast_convert_type(upd, uint)
        return jax.lax.bitcast_convert_type(
            jax.lax.dynamic_update_slice(p, u, starts), prev.dtype)

    def _store(self, upd):
        """Compute -> storage cast on store, the inverse of
        :meth:`_compute_fields` (no-op when the dtypes agree)."""
        if upd.dtype == self.ps.dtype:
            return upd
        if self._bittrick and upd.dtype == jnp.float32:
            return _f32_store_bf16(upd)
        return upd.astype(self.ps.dtype)

    def _run_jnp(self, fields, scalars, base, geom: KernelGeometry):
        mixed = self.ps.compute_dtype != self.ps.dtype
        if mixed:
            # Sub-f32 storage: fence the update computation away from
            # the boundary scatter (see _fenced_updates — one fused
            # loop with a DUS/pad/concat root drops out of XLA:CPU's
            # vectorized path and runs 1.4-2x slower than the two-pass).
            updates = self._fenced_updates(fields, scalars)
        else:
            updates = self.fn(**fields, **scalars)
        ring = self.radius if geom.ir is None else None
        out = {}
        for name in self.outputs:
            prev = fields[name]
            upd = self._store(updates[name])
            # Per-axis write semantics from the update's shape — the SAME
            # derivation the pallas backend applies to windows (including
            # the staggered-axes-must-be-`all` rule), so a kernel that
            # traces on one backend traces on both.
            off = tuple(b - s for b, s in zip(base, prev.shape))
            modes, rings = _stencil.write_geometry(
                upd.shape, prev.shape, off, name, ring)
            idx = tuple(
                slice(None) if m == "all" else slice(w, prev.shape[a] - w)
                for a, (m, w) in enumerate(zip(modes, rings))
            )
            if mixed:
                # Guard-free DUS on the bit-identical unsigned-int view:
                # jnp's .at[].set would add an oob-guard select that XLA
                # float-normalizes into convert/f32-select/convert loops
                # over the FULL narrow-float array.
                res = self._dus_bits(prev, idx, upd)
            else:
                res = prev.at[idx].set(upd)
            cond = self.bc.get(name)
            if cond is not None:
                res = cond.apply(res)
            out[name] = res
        reds = (self.apply_reductions(out, fields)
                if self.reductions else None)
        return out, reds

    def _march_write_geometry(self, fields, scalars, base, geom):
        """Per-output (modes, rings, off) from an abstract trace (no
        compute), plus the staggered-march validation shared with the
        pallas path."""
        march = self.march_axis
        upd_shapes = jax.eval_shape(
            lambda f, s: self.fn(**f, **s), dict(fields), dict(scalars))
        ring_pin = self.radius if geom.ir is None else None
        out = {}
        for o in self.outputs:
            prev_shape = tuple(fields[o].shape)
            off = tuple(b - s for b, s in zip(base, prev_shape))
            modes, rings = _stencil.write_geometry(
                tuple(upd_shapes[o].shape), prev_shape, off, o, ring_pin)
            out[o] = (modes, rings, off)
        for n, v in fields.items():
            if base[march] - v.shape[march]:
                raise ValueError(
                    f"march_axis {march} points at a staggered axis: field "
                    f"{n!r} has offset {base[march] - v.shape[march]} there "
                    "— streaming slides collocated planes; stagger a "
                    "non-marching axis or drop march_axis"
                )
        return out

    def _run_jnp_march(self, fields, scalars, base, geom: KernelGeometry):
        """Marching realization of the jnp backend: a ``lax.scan`` slides
        plane slabs along ``march_axis`` in block steps, so the working
        set per step is a few planes per field (cache-resident — the CPU
        analogue of the pallas path's VMEM plane queue) instead of the
        whole arrays. Results equal :meth:`_run_jnp` (1-ulp across the
        two separately compiled programs)."""
        march = self.march_axis
        nd = self.ps.ndims
        geometry = self._march_write_geometry(fields, scalars, base, geom)
        n_march = base[march]
        halos = geom.halos if geom.halos is not None \
            else ((self.radius, self.radius),) * nd
        lo_m, hi_m = halos[march]
        ring_max = max(rings[march] for _, rings, _ in geometry.values())
        e_lo, e_hi = max(lo_m, ring_max), max(hi_m, ring_max)
        bm = max((d for d in range(1, min(4, n_march) + 1)
                  if n_march % d == 0), default=1)
        slab = bm + e_lo + e_hi
        if slab > n_march:
            # march extent smaller than one slab: marching degenerates —
            # run the all-parallel realization (identical semantics).
            return self._run_jnp(fields, scalars, base, geom)
        nb = n_march // bm

        def block_at(i):
            sc = jnp.clip(i * bm - e_lo, 0, n_march - slab)
            slabs = {n: jax.lax.dynamic_slice_in_dim(v, sc, slab, axis=march)
                     for n, v in fields.items()}
            updates = self.fn(**self._compute_fields(slabs), **scalars)
            outs = []
            for o in self.outputs:
                modes, rings, off = geometry[o]
                upd = self._store(updates[o])
                w_m = rings[march]
                # Update index u holds the update of global plane
                # sc + u + w_m; block positions g in [i*bm, i*bm + bm)
                # live at u = g - sc - w_m. Out-of-range u (zero pad)
                # only lands on march-ring planes, masked below.
                # Tight placement pad: slice start (i*bm - sc) ranges over
                # [0, e_lo + e_hi] on an update of extent
                # bm + e_lo + e_hi - 2*w_m, so w_m zeros per side cover
                # every clamped position (zeros land only on ring planes,
                # blended below).
                pad = [(0, 0)] * nd
                pad[march] = (w_m, w_m)
                blk = jax.lax.dynamic_slice_in_dim(
                    jnp.pad(upd, pad) if w_m else upd, i * bm - sc, bm,
                    axis=march)
                prev = jax.lax.dynamic_slice_in_dim(
                    fields[o], i * bm, bm, axis=march)
                # Wrap each `inn` axis with the prev block's thin boundary
                # strips (concat assembly — the one block-sized
                # materialization per output; a copy-then-scatter or a
                # post-scan patch both cost extra whole-array passes):
                # when wrapping axis a, axes already wrapped are at full
                # extent, axes still pending stay at their interior
                # extents.
                done = set()
                for a in range(nd - 1, -1, -1):
                    if a == march or modes[a] == "all" or not rings[a]:
                        done.add(a)
                        continue
                    w = rings[a]

                    def strip(side, a=a, w=w, done=frozenset(done)):
                        idx = []
                        for b_ax in range(nd):
                            n_b = prev.shape[b_ax]
                            if b_ax == a:
                                idx.append(slice(0, w) if side == 0
                                           else slice(n_b - w, n_b))
                            elif b_ax in done or b_ax == march:
                                idx.append(slice(None))
                            else:
                                wb = rings[b_ax]
                                idx.append(slice(wb, n_b - wb))
                        return prev[tuple(idx)]

                    blk = jnp.concatenate([strip(0), blk, strip(1)], axis=a)
                    done.add(a)
                if modes[march] == "inn" and w_m:
                    g = i * bm + jnp.arange(bm)
                    keep = (g < w_m) | (g >= n_march - w_m)
                    keep = keep.reshape(tuple(bm if a == march else 1
                                              for a in range(nd)))
                    blk = jnp.where(keep, prev, blk)
                outs.append(blk)
            return tuple(outs)

        _, stacked = jax.lax.scan(lambda c, i: (c, block_at(i)), 0,
                                  jnp.arange(nb))
        out = {}
        for o, ys in zip(self.outputs, stacked):
            arr = jnp.moveaxis(ys, 0, march)
            arr = arr.reshape(fields[o].shape)
            cond = self.bc.get(o)
            if cond is not None:
                arr = cond.apply(arr)
            out[o] = arr
        reds = (self.apply_reductions(out, fields)
                if self.reductions else None)
        return out, reds

    def _run_pallas(self, fields, scalars, base, shapes,
                    geom: KernelGeometry, nsteps: int = 1):
        key = (base, tuple(sorted(shapes.items())), tuple(sorted(scalars)),
               nsteps, self.march_axis)
        run = self._cache.get(key)
        if run is None:
            field_names = tuple(fields)
            scalar_names = tuple(scalars)

            def update(fdict, sdict):
                return self.fn(**fdict, **sdict)

            run = _stencil.build_stencil_call(
                update,
                field_names=field_names,
                out_names=self.outputs,
                scalar_names=scalar_names,
                shape=base,
                radius=geom.band,
                dtype=self.ps.dtype,
                tile=self.tile,
                vmem_budget=self.vmem_budget,
                compute_dtype=self.ps.compute_dtype,
                interpret=self.ps.interpret,
                nsteps=nsteps,
                rotations=self.rotations,
                field_shapes=shapes,
                halos=geom.halos,
                bc=self.bc,
                march_axis=self.march_axis,
                write_rings=None if geom.ir is None else tuple(
                    max(rings[a] for rings in geom.ir.write_rings.values())
                    for a in range(self.ps.ndims)
                ),
                reductions=self.reductions,
            )
            self._cache[key] = run
        res = run(fields, scalars)
        return res if self.reductions else (res, None)

    def __call__(self, **kwargs):
        fields, scalars, base, shapes = self._split(kwargs)
        geom = self._geometry(base, shapes, tuple(scalars))
        if self.ps.backend == "pallas":
            outs, reds = self._run_pallas(fields, scalars, base, shapes, geom)
        elif self.march_axis is not None:
            outs, reds = self._run_jnp_march(fields, scalars, base, geom)
        else:
            outs, reds = self._run_jnp(fields, scalars, base, geom)
        res = outs[self.outputs[0]] if len(self.outputs) == 1 else outs
        return (res, reds) if self.reductions else res

    def _check_rotations(self):
        if not self.rotations or set(self.outputs) - set(self.rotations):
            raise ValueError(
                "run_steps(nsteps>1) requires rotations covering every output "
                "(pass rotations={'T2': 'T'}-style mapping to @parallel)"
            )

    def run_steps(self, nsteps: int, **kwargs):
        """Advance ``nsteps`` fused time steps; returns the *final* outputs
        (same structure as ``__call__``).

        The pallas backend runs one temporally-blocked kernel launch
        (k stacked halo margins, k in-kernel sweeps — each field crosses
        HBM once per k steps), with declared boundary conditions applied
        between sweeps exactly like the post-pass between sequential
        steps. The jnp backend realizes the identical semantics as k
        unrolled single steps with the ``rotations`` double-buffer
        rotation; under ``jax.jit`` XLA fuses the chain and elides the
        intermediate buffers. Both are bitwise-consistent with k
        sequential ``__call__``s when the rotation buffers agree on their
        boundary rings.

        Periodic conditions wrap across the whole domain and cannot run
        inside local windows; the pallas path then falls back to k
        sequential fused launches (bitwise-identical, k HBM round trips).
        """
        nsteps = int(nsteps)
        if nsteps < 1:
            raise ValueError(f"nsteps must be >= 1, got {nsteps}")
        if nsteps == 1:
            return self(**kwargs)
        self._check_rotations()
        fields, scalars, base, shapes = self._split(kwargs)
        geom = self._geometry(base, shapes, tuple(scalars))
        periodic = any(c.kind == "periodic" for c in self.bc.values())
        if self.ps.backend == "pallas" and not periodic:
            outs, reds = self._run_pallas(fields, scalars, base, shapes,
                                          geom, nsteps)
        else:
            # True double-buffer rotation, unrolled: sweep s scatters into
            # the stale buffer of the (out, target) pair, which is dead two
            # sweeps later — under jit XLA turns those scatters into
            # in-place updates instead of per-launch copies. (Also the
            # pallas realization when a periodic bc forbids in-window
            # temporal blocking.) A marching jnp kernel unrolls marched
            # single steps — each sweep streams its slabs in order.
            if self.ps.backend == "jnp":
                step = (self._run_jnp_march if self.march_axis is not None
                        else self._run_jnp)
            else:
                step = lambda f, s, b, g: self._run_pallas(f, s, b,  # noqa: E731
                                                           shapes, g)
            cur = dict(fields)
            for s in range(nsteps):
                # Intermediate sweeps' reductions are dead values — XLA's
                # DCE drops them under jit; only the final sweep's check
                # (the k-step value, matching the fused launch) survives.
                outs, reds = step(cur, scalars, base, geom)
                if s < nsteps - 1:
                    for o, tgt in self.rotations.items():
                        cur[o], cur[tgt] = cur[tgt], outs[o]
        res = outs[self.outputs[0]] if len(self.outputs) == 1 else outs
        return (res, reds) if self.reductions else res

    @property
    def launch_info(self) -> dict:
        """Derived launch parameters of compiled instances (for inspection)."""
        return {
            k: {"grid": v.grid, "block": v.block, "window_bytes": v.window_bytes}
            for k, v in self._cache.items()
        }
