"""`@parallel` — single-source xPU stencil kernels (the paper's C1/C2/C3).

Usage, mirroring Fig. 1 of the paper::

    from repro.core import parallel as P
    from repro.core.fd import fd3d as fd

    ps = P.init_parallel_stencil(backend="pallas", dtype="float32", ndims=3)

    @ps.parallel(outputs=("T2",))
    def step(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx**2 + fd.d2_yi(T) * _dy**2 + fd.d2_zi(T) * _dz**2))}

    T2 = step(T2=T2, T=T, Ci=Ci, lam=lam, dt=dt, _dx=_dx, _dy=_dy, _dz=_dz)

The same kernel source runs on every backend (the xPU property):

  * ``backend="jnp"``    — the update is traced on full arrays and scattered
    into the interior; XLA fuses the chain. This doubles as the paper's
    "array programming" comparison baseline when called op-by-op unjitted.
  * ``backend="pallas"`` — the update is traced on halo-extended VMEM
    windows inside a fused Pallas TPU kernel with derived launch parameters
    (kernels/stencil.py). On non-TPU hosts it validates via interpret mode.

Arguments are classified by value: arrays of the kernel's dimensionality
are *fields*, everything else is a *scalar*. Every name in ``outputs``
must be a field argument; its previous contents provide the boundary
values (the paper's ``@inn(T2) = ...`` semantics).

Footprint inference (the stencil IR): before anything runs, the update
function is traced ONCE with symbolic window objects (``repro.ir``) that
implement the same relative-slice protocol as the ``fd`` operators. The
trace yields per-field, per-axis halo depths — ``radius`` no longer needs
declaring. A declared ``radius`` is kept as a cross-check: a mismatch
against the inferred footprint raises a pointed ``ValueError``; if the
update uses constructs the tracer cannot analyze (``jnp.*`` calls,
integer indexing), a declared ``radius`` selects the legacy symmetric
geometry instead, and an undeclared one reports why inference failed.

Boundary conditions: ``bc={"T2": BoundaryCondition("neumann0"), ...}``
(or bare kind strings) declares each output's condition, realized by the
engine itself — inside the fused Pallas launch (dirichlet/neumann0, also
between the sweeps of ``run_steps(k)``) or as a face-slab scatter fused
into the surrounding jit (periodic) — bitwise-equal to applying the
``core.boundary`` post-pass after every step.

Coupled systems: ``outputs`` may name several fields — the whole coupled
update runs as ONE fused Pallas launch. Fields may be staggered: a field
up to the footprint band shorter than the (per-axis maximal) base shape
lives on cell faces, e.g. the Darcy flux ``qx`` of shape ``(nx-1, ny)``
next to cell-centered ``phi``/``Pe`` of shape ``(nx, ny)``. Per-output
write semantics follow the shape of the returned update along each axis:
a symmetric interior margin writes the interior (``@inn``, boundary ring
preserved), full-field extent writes everything (``@all`` — mandatory on
staggered axes). See kernels/stencil.py for the window geometry.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import stencil as _stencil
from .. import ir as _ir

_BACKENDS = ("jnp", "pallas")


@dataclasses.dataclass(frozen=True)
class ParallelStencil:
    """Backend/dtype/ndims context (the paper's ``@init_parallel_stencil``)."""

    backend: str = "jnp"
    dtype: Any = jnp.float32
    ndims: int = 3
    interpret: bool | None = None  # None -> auto (True unless on real TPU)

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}")
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype))

    def parallel(
        self,
        outputs: Sequence[str],
        radius: int | None = None,
        tile: Sequence[int] | None = None,
        vmem_budget: int = _stencil.DEFAULT_VMEM_BUDGET,
        rotations: Mapping[str, str] | None = None,
        bc: Mapping[str, Any] | None = None,
    ) -> Callable[[Callable], "StencilKernel"]:
        """``radius`` is optional: the stencil IR infers per-field,
        per-axis footprints from the update function itself; declaring it
        adds a cross-check (ValueError on mismatch) and a fallback
        geometry for untraceable updates. ``rotations`` maps each output
        field to the input field it becomes on the next time step (e.g.
        ``{"T2": "T"}``) — required for the temporally-blocked
        ``run_steps(k>1)`` path. ``bc`` declares per-output boundary
        conditions fused into the engine's step."""
        def deco(fn: Callable) -> StencilKernel:
            return StencilKernel(self, fn, tuple(outputs), radius, tile,
                                 vmem_budget, rotations, bc)

        return deco


def init_parallel_stencil(
    backend: str = "jnp", dtype: Any = jnp.float32, ndims: int = 3,
    interpret: bool | None = None,
) -> ParallelStencil:
    return ParallelStencil(backend=backend, dtype=dtype, ndims=ndims, interpret=interpret)


@dataclasses.dataclass(frozen=True)
class KernelGeometry:
    """Resolved launch geometry of one kernel instance (per field-shape
    set): the traced IR (None when the legacy declared-radius fallback is
    active), the staggering band, and the per-axis window halo."""

    ir: _ir.StencilIR | None
    band: int                                  # staggering band radius
    halos: tuple[tuple[int, int], ...] | None  # per-axis (lo, hi) or None

    @property
    def inferred(self) -> bool:
        return self.ir is not None


class StencilKernel:
    """A compiled-on-first-use, shape-polymorphic stencil kernel."""

    def __init__(self, ps: ParallelStencil, fn: Callable, outputs: tuple[str, ...],
                 radius: int | None, tile, vmem_budget: int,
                 rotations: Mapping[str, str] | None = None,
                 bc: Mapping[str, Any] | None = None):
        self.ps = ps
        self.fn = fn
        self.outputs = outputs
        self.radius = radius
        self.tile = tile
        self.vmem_budget = vmem_budget
        self.rotations = dict(rotations) if rotations else None
        self.bc = _ir.bc.normalize_bcs(bc, outputs, ps.ndims)
        self._cache: dict = {}
        self._geom_cache: dict = {}
        functools.update_wrapper(self, fn)

    # -- argument classification ------------------------------------------
    def _split(self, kwargs: Mapping[str, Any]):
        fields, scalars = {}, {}
        for name, v in kwargs.items():
            if hasattr(v, "ndim") and getattr(v, "ndim", 0) == self.ps.ndims:
                fields[name] = v
            else:
                scalars[name] = v
        if not fields:
            raise ValueError("no field arguments found")
        shapes = {n: tuple(np.shape(v)) for n, v in fields.items()}
        base = tuple(
            max(s[a] for s in shapes.values()) for a in range(self.ps.ndims)
        )
        for o in self.outputs:
            if o not in fields:
                raise ValueError(f"output {o!r} is not a field argument")
        return fields, scalars, base, shapes

    # -- footprint inference ------------------------------------------------
    def _geometry(self, base, shapes: Mapping[str, tuple],
                  scalar_names: Sequence[str]) -> KernelGeometry:
        """Trace the update once per field-shape set; derive and validate
        the launch geometry (footprint halos, staggering band, bc fit)."""
        key = (base, tuple(sorted(shapes.items())), tuple(sorted(scalar_names)))
        geom = self._geom_cache.get(key)
        if geom is not None:
            return geom

        def update(fdict, sdict):
            return self.fn(**fdict, **sdict)

        try:
            ir = _ir.trace_stencil(update, shapes, self.outputs, scalar_names)
        except _ir.TraceError as e:
            if self.radius is None:
                raise ValueError(
                    f"footprint inference failed for kernel "
                    f"{getattr(self.fn, '__name__', '?')!r} and no radius "
                    f"was declared — declare radius= on @parallel to use "
                    f"the legacy symmetric geometry. Trace error: {e}"
                ) from e
            ir = None

        if ir is not None and self.radius is not None \
                and ir.inferred_radius != self.radius:
            raise ValueError(
                f"declared radius={self.radius} does not match the inferred "
                f"footprint of kernel {getattr(self.fn, '__name__', '?')!r}: "
                f"per-axis window halo {ir.halo} and write rings "
                f"{tuple(ir.write_rings.values())} imply radius "
                f"{ir.inferred_radius} (drop radius= to use the inferred "
                "geometry, or fix the declaration)"
            )

        band = self.radius if self.radius is not None \
            else max(ir.inferred_radius, 1)
        for n, s in shapes.items():
            off = tuple(b - x for b, x in zip(base, s))
            if any(o < 0 or o > band for o in off):
                raise ValueError(
                    f"field {n!r} shape {s} is inconsistent with the coupled "
                    f"system's base shape {base}: per-axis offsets {off} "
                    f"exceed the staggering band [0, radius={band}] — fields "
                    "of one system must agree up to face/cell staggering"
                )
        # bc face depths must fit the actual field extents
        _ir.bc.normalize_bcs(self.bc, self.outputs, self.ps.ndims,
                             field_shapes=shapes)
        geom = KernelGeometry(ir=ir, band=band,
                              halos=None if ir is None else ir.halo)
        self._geom_cache[key] = geom
        return geom

    def stencil_ir(self, **kwargs) -> _ir.StencilIR:
        """The kernel's traced IR for a given field set. Accepts the same
        keyword arguments as a call — arrays, or bare shape tuples for
        the fields (scalars may be omitted or given any value)."""
        shapes, scalar_names = {}, []
        for name, v in kwargs.items():
            if isinstance(v, (tuple, list)) and all(
                    isinstance(x, (int, np.integer)) for x in v):
                if len(v) == self.ps.ndims:
                    shapes[name] = tuple(int(x) for x in v)
                else:
                    scalar_names.append(name)
            elif hasattr(v, "ndim") and getattr(v, "ndim", 0) == self.ps.ndims:
                shapes[name] = tuple(np.shape(v))
            else:
                scalar_names.append(name)
        if not shapes:
            raise ValueError("no field shapes given")
        base = tuple(max(s[a] for s in shapes.values())
                     for a in range(self.ps.ndims))
        geom = self._geometry(base, shapes, tuple(scalar_names))
        if geom.ir is None:
            raise ValueError(
                "kernel is running on the legacy declared-radius fallback; "
                "no IR is available"
            )
        return geom.ir

    def cost_model(self, **kwargs) -> _ir.StencilCostModel:
        """Analytic flop/byte cost model for a given field set."""
        return _ir.StencilCostModel.from_ir(self.stencil_ir(**kwargs),
                                            self.ps.dtype.itemsize)

    # -- backends -----------------------------------------------------------
    def _run_jnp(self, fields, scalars, base, geom: KernelGeometry):
        updates = self.fn(**fields, **scalars)
        ring = self.radius if geom.ir is None else None
        out = {}
        for name in self.outputs:
            prev = fields[name]
            upd = updates[name].astype(self.ps.dtype)
            # Per-axis write semantics from the update's shape — the SAME
            # derivation the pallas backend applies to windows (including
            # the staggered-axes-must-be-`all` rule), so a kernel that
            # traces on one backend traces on both.
            off = tuple(b - s for b, s in zip(base, prev.shape))
            modes, rings = _stencil.write_geometry(
                upd.shape, prev.shape, off, name, ring)
            idx = tuple(
                slice(None) if m == "all" else slice(w, prev.shape[a] - w)
                for a, (m, w) in enumerate(zip(modes, rings))
            )
            res = prev.at[idx].set(upd)
            cond = self.bc.get(name)
            if cond is not None:
                res = cond.apply(res)
            out[name] = res
        return out

    def _run_pallas(self, fields, scalars, base, shapes,
                    geom: KernelGeometry, nsteps: int = 1):
        key = (base, tuple(sorted(shapes.items())), tuple(sorted(scalars)),
               nsteps)
        run = self._cache.get(key)
        if run is None:
            field_names = tuple(fields)
            scalar_names = tuple(scalars)

            def update(fdict, sdict):
                return self.fn(**fdict, **sdict)

            run = _stencil.build_stencil_call(
                update,
                field_names=field_names,
                out_names=self.outputs,
                scalar_names=scalar_names,
                shape=base,
                radius=geom.band,
                dtype=self.ps.dtype,
                tile=self.tile,
                vmem_budget=self.vmem_budget,
                interpret=self.ps.interpret,
                nsteps=nsteps,
                rotations=self.rotations,
                field_shapes=shapes,
                halos=geom.halos,
                bc=self.bc,
            )
            self._cache[key] = run
        return run(fields, scalars)

    def __call__(self, **kwargs):
        fields, scalars, base, shapes = self._split(kwargs)
        geom = self._geometry(base, shapes, tuple(scalars))
        if self.ps.backend == "pallas":
            outs = self._run_pallas(fields, scalars, base, shapes, geom)
        else:
            outs = self._run_jnp(fields, scalars, base, geom)
        if len(self.outputs) == 1:
            return outs[self.outputs[0]]
        return outs

    def _check_rotations(self):
        if not self.rotations or set(self.outputs) - set(self.rotations):
            raise ValueError(
                "run_steps(nsteps>1) requires rotations covering every output "
                "(pass rotations={'T2': 'T'}-style mapping to @parallel)"
            )

    def run_steps(self, nsteps: int, **kwargs):
        """Advance ``nsteps`` fused time steps; returns the *final* outputs
        (same structure as ``__call__``).

        The pallas backend runs one temporally-blocked kernel launch
        (k stacked halo margins, k in-kernel sweeps — each field crosses
        HBM once per k steps), with declared boundary conditions applied
        between sweeps exactly like the post-pass between sequential
        steps. The jnp backend realizes the identical semantics as k
        unrolled single steps with the ``rotations`` double-buffer
        rotation; under ``jax.jit`` XLA fuses the chain and elides the
        intermediate buffers. Both are bitwise-consistent with k
        sequential ``__call__``s when the rotation buffers agree on their
        boundary rings.

        Periodic conditions wrap across the whole domain and cannot run
        inside local windows; the pallas path then falls back to k
        sequential fused launches (bitwise-identical, k HBM round trips).
        """
        nsteps = int(nsteps)
        if nsteps < 1:
            raise ValueError(f"nsteps must be >= 1, got {nsteps}")
        if nsteps == 1:
            return self(**kwargs)
        self._check_rotations()
        fields, scalars, base, shapes = self._split(kwargs)
        geom = self._geometry(base, shapes, tuple(scalars))
        periodic = any(c.kind == "periodic" for c in self.bc.values())
        if self.ps.backend == "pallas" and not periodic:
            outs = self._run_pallas(fields, scalars, base, shapes, geom,
                                    nsteps)
        else:
            # True double-buffer rotation, unrolled: sweep s scatters into
            # the stale buffer of the (out, target) pair, which is dead two
            # sweeps later — under jit XLA turns those scatters into
            # in-place updates instead of per-launch copies. (Also the
            # pallas realization when a periodic bc forbids in-window
            # temporal blocking.)
            step = (self._run_jnp if self.ps.backend == "jnp"
                    else lambda f, s, b, g: self._run_pallas(f, s, b,
                                                             shapes, g))
            cur = dict(fields)
            for s in range(nsteps):
                outs = step(cur, scalars, base, geom)
                if s < nsteps - 1:
                    for o, tgt in self.rotations.items():
                        cur[o], cur[tgt] = cur[tgt], outs[o]
        if len(self.outputs) == 1:
            return outs[self.outputs[0]]
        return outs

    @property
    def launch_info(self) -> dict:
        """Derived launch parameters of compiled instances (for inspection)."""
        return {
            k: {"grid": v.grid, "block": v.block, "window_bytes": v.window_bytes}
            for k, v in self._cache.items()
        }
