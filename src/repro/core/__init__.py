"""Core stencil engine: the paper's contribution as a composable JAX module."""
from .grid import Grid
from .fields import FieldSet, VectorField
from .fd import fd1d, fd2d, fd3d
from .parallel import ParallelStencil, StencilKernel, init_parallel_stencil
from .iterate import Checkpointing, SolveResult, make_solver, solve_until
from . import boundary, teff

__all__ = [
    "Grid", "FieldSet", "VectorField", "fd1d", "fd2d", "fd3d",
    "ParallelStencil", "StencilKernel", "init_parallel_stencil",
    "Checkpointing", "SolveResult", "make_solver", "solve_until",
    "boundary", "teff",
]
