"""Declarative field allocation (the paper's ``@ones``/``@zeros`` macros, C5).

ParallelStencil's allocation macros are *declarative*: the user states what
logical field they need and the framework chooses device placement and data
layout. Here :class:`FieldSet` plays that role:

  * scalars fields are dense arrays of the grid shape, placed on the target
    device / sharded with the given :class:`jax.sharding.Sharding`;
  * logical vector/tensor fields (arrays-of-structs in the paper's wording)
    are allocated either as **SoA** (a tuple of component arrays — the TPU
    friendly layout, minor dims stay 128-lane aligned) or **AoS** (one array
    with a trailing component axis), selected per FieldSet or per field.

Everything returns ordinary ``jax.Array``s, so fields compose with the rest
of JAX (pjit, shard_map, pallas) with no wrapper types in hot paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .grid import Grid


def _place(x: jax.Array, sharding) -> jax.Array:
    if sharding is not None:
        return jax.device_put(x, sharding)
    return x


@dataclasses.dataclass
class VectorField:
    """A logical array-of-structs field with a chosen memory layout."""

    components: tuple[jax.Array, ...] | jax.Array
    layout: str  # "soa" | "aos"

    def __getitem__(self, i: int) -> jax.Array:
        if self.layout == "soa":
            return self.components[i]
        return self.components[..., i]

    @property
    def ncomp(self) -> int:
        if self.layout == "soa":
            return len(self.components)
        return self.components.shape[-1]

    def as_soa(self) -> "VectorField":
        if self.layout == "soa":
            return self
        comps = tuple(self.components[..., i] for i in range(self.ncomp))
        return VectorField(comps, "soa")

    def as_aos(self) -> "VectorField":
        if self.layout == "aos":
            return self
        return VectorField(jnp.stack(self.components, axis=-1), "aos")

    def map(self, fn: Callable[[jax.Array], jax.Array]) -> "VectorField":
        if self.layout == "soa":
            return VectorField(tuple(fn(c) for c in self.components), "soa")
        return VectorField(fn(self.components), "aos")


class FieldSet:
    """Declarative allocator bound to a grid, dtype, layout and placement."""

    def __init__(
        self,
        grid: Grid | Sequence[int],
        dtype: Any = jnp.float32,
        layout: str = "soa",
        sharding=None,
    ):
        if not isinstance(grid, Grid):
            grid = Grid(tuple(grid))
        if layout not in ("soa", "aos"):
            raise ValueError(f"layout must be 'soa' or 'aos', got {layout!r}")
        self.grid = grid
        self.dtype = jnp.dtype(dtype)
        self.layout = layout
        self.sharding = sharding
        self._registry: dict[str, Any] = {}

    # -- scalar fields ------------------------------------------------------
    def zeros(self, name: str | None = None) -> jax.Array:
        return self._scalar(name, jnp.zeros(self.grid.shape, self.dtype))

    def ones(self, name: str | None = None) -> jax.Array:
        return self._scalar(name, jnp.ones(self.grid.shape, self.dtype))

    def full(self, value, name: str | None = None) -> jax.Array:
        return self._scalar(name, jnp.full(self.grid.shape, value, self.dtype))

    def rand(self, key: jax.Array, name: str | None = None) -> jax.Array:
        return self._scalar(name, jax.random.uniform(key, self.grid.shape, self.dtype))

    def from_fn(self, fn: Callable[..., jax.Array], name: str | None = None) -> jax.Array:
        """Initialize from a function of the physical coordinates."""
        xs = self.grid.meshgrid(self.dtype)
        return self._scalar(name, fn(*xs).astype(self.dtype))

    def _scalar(self, name, arr) -> jax.Array:
        arr = _place(arr, self.sharding)
        if name:
            self._registry[name] = arr
        return arr

    # -- vector / struct fields ----------------------------------------------
    def vector(
        self, ncomp: int, init=0.0, name: str | None = None, layout: str | None = None
    ) -> VectorField:
        layout = layout or self.layout
        if layout == "soa":
            comps = tuple(
                _place(jnp.full(self.grid.shape, init, self.dtype), self.sharding)
                for _ in range(ncomp)
            )
            vf = VectorField(comps, "soa")
        else:
            arr = jnp.full((*self.grid.shape, ncomp), init, self.dtype)
            vf = VectorField(_place(arr, self.sharding), "aos")
        if name:
            self._registry[name] = vf
        return vf

    # -- bookkeeping ----------------------------------------------------------
    def __getitem__(self, name: str):
        return self._registry[name]

    def names(self) -> list[str]:
        return list(self._registry)

    def nbytes(self) -> int:
        total = 0
        for v in self._registry.values():
            if isinstance(v, VectorField):
                arrs = v.components if v.layout == "soa" else (v.components,)
                total += sum(int(a.size) * a.dtype.itemsize for a in arrs)
            else:
                total += int(v.size) * v.dtype.itemsize
        return total
