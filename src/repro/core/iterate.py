"""Device-resident convergence-driven iteration (``solve_until``).

The paper's pseudo-transient solvers iterate until ``err = max|dT|``
drops under a tolerance. The classic host loop

    while err > tol: err = float(norm(step(...)))   # host sync per check

serializes the step stream on a device->host transfer every check. With
the engine's fused reduction epilogues the error is a device scalar that
costs no extra HBM pass — so the WHOLE iteration can live on device: a
``lax.while_loop`` whose body advances ``check_every`` steps (the first
``m-1`` through the reduction-free kernel variant, the last through the
checked one), rotates the double buffers in place (the carry is donated
— XLA updates the field buffers without copies), and whose condition
reads the fused error scalar. Zero host transfers from the first step to
convergence; one compiled program regardless of iteration count.

``until="below"`` runs while ``err > tol`` (convergence: stop once the
residual drops under tol); ``until="above"`` runs while ``err <= tol``
(drift guard: stop once a conserved-quantity error exceeds tol).

Caveat: a ``while_loop`` has data-dependent trip count, so the program
cannot be reverse-differentiated and steps are taken in multiples of
``check_every`` (``iters`` may overshoot ``max_iters`` by at most
``check_every - 1``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

__all__ = ["SolveResult", "make_solver", "solve_until"]


@dataclasses.dataclass
class SolveResult:
    """Final state of a convergence-driven solve. Everything is a device
    value — reading ``.err``/``.iters`` as Python numbers is the caller's
    (single, final) host sync."""

    fields: dict[str, jax.Array]   # all field buffers, rotated in place
    reds: dict[str, jax.Array]     # the last check's fused reductions
    err: jax.Array                 # last error scalar (float32)
    iters: jax.Array               # steps taken (int32)

    def output(self, kernel) -> Any:
        """The solver's answer: the rotation target of each output holds
        the newest value after the final in-loop rotation."""
        tgts = {o: self.fields[t] for o, t in kernel.rotations.items()}
        if len(kernel.outputs) == 1:
            return tgts[kernel.outputs[0]]
        return tgts


def _resolve_error(kernel, error) -> Callable[[Mapping[str, Any]], Any]:
    if error is None:
        if len(kernel.reductions) != 1:
            raise ValueError(
                f"kernel declares reductions {tuple(kernel.reductions)}; "
                "pass error=<name> (or a callable over the reduction dict) "
                "to pick the convergence scalar"
            )
        error = next(iter(kernel.reductions))
    if isinstance(error, str):
        if error not in kernel.reductions:
            raise ValueError(
                f"error={error!r} is not a declared reduction "
                f"(have {tuple(kernel.reductions)})"
            )
        name = error
        return lambda reds: reds[name]
    return error


def make_solver(
    kernel,
    scalars: Mapping[str, Any] | None = None,
    *,
    check_every: int = 1,
    error: str | Callable | None = None,
    until: str = "below",
):
    """Build the un-jitted driver ``solver(fields, tol, max_iters) ->
    (fields, reds, err, iters)`` for :func:`solve_until`.

    Exposed separately so callers (and the zero-host-sync test) can
    inspect the traced program: ``jax.make_jaxpr(solver)(...)`` is ONE
    ``while`` — no transfers, no callbacks between checks.
    """
    if not kernel.reductions:
        raise ValueError(
            "solve_until needs a kernel with fused reductions "
            "(declare reductions={'err': 'max_abs_diff(T2, T)'}-style on "
            "@parallel)"
        )
    rot = kernel.rotations
    if not rot or set(kernel.outputs) - set(rot):
        raise ValueError(
            "solve_until rotates double buffers between steps and needs "
            "rotations covering every output (pass rotations={'T2': 'T'}-"
            "style mapping to @parallel)"
        )
    check_every = int(check_every)
    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    if until not in ("below", "above"):
        raise ValueError(f"until must be 'below' or 'above', got {until!r}")
    err_fn = _resolve_error(kernel, error)
    scalars = dict(scalars or {})
    plain = kernel.with_reductions(None)
    single = len(kernel.outputs) == 1

    def as_dict(res):
        return {kernel.outputs[0]: res} if single else dict(res)

    def rotate(cur, outs):
        cur = dict(cur)
        for o, tgt in rot.items():
            cur[o], cur[tgt] = cur[tgt], outs[o]
        return cur

    def solver(fields, tol, max_iters):
        tol = jnp.asarray(tol, jnp.float32)
        max_iters = jnp.asarray(max_iters, jnp.int32)
        cur0 = dict(fields)
        reds0 = {n: jnp.zeros((), jnp.float32) for n in kernel.reductions}
        err0 = jnp.float32(jnp.inf if until == "below" else -jnp.inf)

        def cond(state):
            _, _, err, it = state
            keep = err > tol if until == "below" else err <= tol
            return keep & (it < max_iters)

        def body(state):
            cur, _, _, it = state
            for _ in range(check_every - 1):
                cur = rotate(cur, as_dict(plain(**cur, **scalars)))
            outs, reds = kernel(**cur, **scalars)
            cur = rotate(cur, as_dict(outs))
            reds = {n: jnp.asarray(v, jnp.float32) for n, v in reds.items()}
            err = jnp.asarray(err_fn(reds), jnp.float32)
            return cur, reds, err, it + check_every

        return jax.lax.while_loop(cond, body, (cur0, reds0, err0,
                                               jnp.int32(0)))

    return solver


def solve_until(
    kernel,
    fields: Mapping[str, Any],
    scalars: Mapping[str, Any] | None = None,
    *,
    tol: float,
    max_iters: int,
    check_every: int = 1,
    error: str | Callable | None = None,
    until: str = "below",
) -> SolveResult:
    """Iterate ``kernel`` on device until its fused error scalar crosses
    ``tol`` (or ``max_iters`` steps), checking every ``check_every``
    steps — zero host transfers between checks.

    ``kernel`` is a :class:`~repro.core.parallel.StencilKernel` with
    ``reductions=`` and ``rotations=`` declared. ``fields`` maps every
    field argument to its initial array; ``scalars`` the non-field
    arguments. ``error`` picks the convergence scalar: a reduction name
    (default: the single declared reduction) or a callable over the
    reduction dict (e.g. a relative-drift formula); it must be cheap —
    it runs inside the loop condition's body on device.
    """
    solver = jax.jit(make_solver(kernel, scalars, check_every=check_every,
                                 error=error, until=until))
    cur, reds, err, iters = solver(dict(fields), tol, max_iters)
    return SolveResult(fields=cur, reds=reds, err=err, iters=iters)
