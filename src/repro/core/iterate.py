"""Device-resident convergence-driven iteration (``solve_until``).

The paper's pseudo-transient solvers iterate until ``err = max|dT|``
drops under a tolerance. The classic host loop

    while err > tol: err = float(norm(step(...)))   # host sync per check

serializes the step stream on a device->host transfer every check. With
the engine's fused reduction epilogues the error is a device scalar that
costs no extra HBM pass — so the WHOLE iteration can live on device: a
``lax.while_loop`` whose body advances ``check_every`` steps (the first
``m-1`` through the reduction-free kernel variant, the last through the
checked one), rotates the double buffers in place (the carry is donated
— XLA updates the field buffers without copies), and whose condition
reads the fused error scalar. Zero host transfers from the first step to
convergence; one compiled program regardless of iteration count.

``until="below"`` runs while ``err > tol`` (convergence: stop once the
residual drops under tol); ``until="above"`` runs while ``err <= tol``
(drift guard: stop once a conserved-quantity error exceeds tol).

Caveat: a ``while_loop`` has data-dependent trip count, so the program
cannot be reverse-differentiated and steps are taken in multiples of
``check_every`` (``iters`` may overshoot ``max_iters`` by at most
``check_every - 1``).

Fault tolerance (``checkpoint=``): the paper's headline workloads run
for days, and at that scale runs die to preemption, not math. The
checkpointing driver chunks the same jitted ``while_loop`` at
reduction-check boundaries — each chunk is ``save_every`` checks — and
hands the double-buffer carry (field buffers + iteration counter +
error scalar + last reductions) to an async
:class:`~repro.checkpoint.manager.CheckpointManager` between chunks.
The loop only stalls for the device->host copy; the filesystem write
runs behind the next chunk. Checkpoints are atomic (``step_X.tmp`` +
``os.replace`` + ``LATEST`` swap) with keep-k retention, and a killed
run resumes from ``LATEST`` bit-identically to the uninterrupted run
on the same machine (per-step math never sees the chunk boundary; only
cross-mesh/cross-program comparisons degrade to allclose — reductions
reassociate).

Telemetry (``telemetry=`` / ``REPRO_TELEMETRY=1``): the solve is the
subsystem's flagship instrumentation site, and it obeys the zero-host-
sync rule — device-derived metrics (step counts, the error trajectory,
reduction values) are harvested ONLY at host syncs that already exist:
the chunk boundary of the checkpointing driver (which reads ``iters`` /
``err`` anyway) and the final carry of the plain path. The traced
program is identical with telemetry on or off; the disabled path costs
one attribute check.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Any, Callable, Mapping, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import telemetry as _telemetry
from ..telemetry import attrib as _attrib

__all__ = [
    "Checkpointing", "SolveResult", "make_solver", "solve_until",
    "BatchCarry", "BatchedSolveResult", "make_batched_solver", "solve_batch",
]

# jitted-solver reuse across solve_until calls: make_solver builds a new
# closure per call, so a bare jax.jit would retrace AND recompile every
# solve of the same kernel — death by compile for iterative callers (and
# it would bury the telemetry-overhead measurement under compile noise).
# Keyed weakly on the kernel; entries hold strong refs to any jax.Array
# scalars so their id()s can't be recycled under the key.
_SOLVER_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _scalars_cache_key(scalars) -> Optional[tuple]:
    """A hashable identity for a scalars dict (the values are baked into
    the traced program as constants): plain Python numbers key by value,
    immutable jax arrays by object identity. Anything else (e.g. a
    mutable numpy buffer) returns None — no caching for that call."""
    items = []
    for k in sorted(scalars or {}):
        v = scalars[k]
        if isinstance(v, (bool, int, float)):
            items.append((k, type(v).__name__, v))
        elif isinstance(v, jax.Array):
            items.append((k, "jax", id(v)))
        else:
            return None
    return tuple(items)


def _jitted_solver(kernel, scalars, *, check_every, error, until):
    """The jitted driver for (kernel, scalars, policy), memoized."""
    def build():
        return jax.jit(make_solver(kernel, scalars, check_every=check_every,
                                   error=error, until=until))

    skey = _scalars_cache_key(scalars)
    if skey is None:
        return build()
    err_key = error if (error is None or isinstance(error, str)) \
        else id(error)
    key = (int(check_every), err_key, until, skey)
    try:
        cache = _SOLVER_CACHE.setdefault(kernel, {})
    except TypeError:                      # kernel not weak-referenceable
        return build()
    if key not in cache:
        keep = [v for v in (scalars or {}).values()
                if isinstance(v, jax.Array)]
        cache[key] = (build(), keep)
    return cache[key][0]


@dataclasses.dataclass
class Checkpointing:
    """Checkpoint policy for :func:`solve_until`.

    ``path`` is the checkpoint root directory (or an existing
    :class:`~repro.checkpoint.manager.CheckpointManager`). ``save_every``
    counts reduction CHECKS between saves — the snapshot piggybacks on a
    check boundary, so it never costs an extra HBM pass; per-step
    overhead is the device->host copy amortized over
    ``save_every * check_every`` steps. ``resume=True`` restores from
    ``LATEST`` when one exists (a fresh directory starts from the given
    initial fields). ``blocking=False`` writes on a background thread.
    ``monitor`` (a :class:`~repro.distributed.fault.StepMonitor`) bumps
    a heartbeat file per chunk and raises
    :class:`~repro.distributed.fault.RankFailure` when a peer's
    heartbeat goes stale."""

    path: Union[str, Any]          # root dir or CheckpointManager
    save_every: int = 1            # checks between saves
    keep: int = 3
    resume: bool = True
    blocking: bool = False
    monitor: Optional[Any] = None  # fault.StepMonitor

    def manager(self):
        from ..checkpoint import CheckpointManager

        if isinstance(self.path, str):
            return CheckpointManager(self.path, keep=self.keep)
        return self.path


@dataclasses.dataclass
class SolveResult:
    """Final state of a convergence-driven solve. Everything is a device
    value — reading ``.err``/``.iters`` as Python numbers is the caller's
    (single, final) host sync."""

    fields: dict[str, jax.Array]   # all field buffers, rotated in place
    reds: dict[str, jax.Array]     # the last check's fused reductions
    err: jax.Array                 # last error scalar (float32)
    iters: jax.Array               # steps taken (int32)
    resumed_from: Optional[int] = None   # checkpoint step a resume started at
    saved_steps: tuple[int, ...] = ()    # steps checkpointed this run
    # per-rank EWMA step stats from the run's StepMonitor (own rank plus
    # every peer heartbeat), {rank: {"ewma_s", "last_s", "n"}} — None when
    # the solve ran without a monitor
    step_stats: Optional[dict[int, dict[str, float]]] = None

    def output(self, kernel) -> Any:
        """The solver's answer: the rotation target of each output holds
        the newest value after the final in-loop rotation."""
        tgts = {o: self.fields[t] for o, t in kernel.rotations.items()}
        if len(kernel.outputs) == 1:
            return tgts[kernel.outputs[0]]
        return tgts


def _resolve_error(kernel, error) -> Callable[[Mapping[str, Any]], Any]:
    if error is None:
        if len(kernel.reductions) != 1:
            raise ValueError(
                f"kernel declares reductions {tuple(kernel.reductions)}; "
                "pass error=<name> (or a callable over the reduction dict) "
                "to pick the convergence scalar"
            )
        error = next(iter(kernel.reductions))
    if isinstance(error, str):
        if error not in kernel.reductions:
            raise ValueError(
                f"error={error!r} is not a declared reduction "
                f"(have {tuple(kernel.reductions)})"
            )
        name = error
        return lambda reds: reds[name]
    return error


def make_solver(
    kernel,
    scalars: Mapping[str, Any] | None = None,
    *,
    check_every: int = 1,
    error: str | Callable | None = None,
    until: str = "below",
):
    """Build the un-jitted driver ``solver(fields, tol, max_iters) ->
    (fields, reds, err, iters)`` for :func:`solve_until`.

    Exposed separately so callers (and the zero-host-sync test) can
    inspect the traced program: ``jax.make_jaxpr(solver)(...)`` is ONE
    ``while`` — no transfers, no callbacks between checks.
    """
    if not kernel.reductions:
        raise ValueError(
            "solve_until needs a kernel with fused reductions "
            "(declare reductions={'err': 'max_abs_diff(T2, T)'}-style on "
            "@parallel)"
        )
    rot = kernel.rotations
    if not rot or set(kernel.outputs) - set(rot):
        raise ValueError(
            "solve_until rotates double buffers between steps and needs "
            "rotations covering every output (pass rotations={'T2': 'T'}-"
            "style mapping to @parallel)"
        )
    check_every = int(check_every)
    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    if until not in ("below", "above"):
        raise ValueError(f"until must be 'below' or 'above', got {until!r}")
    err_fn = _resolve_error(kernel, error)
    scalars = dict(scalars or {})
    plain = kernel.with_reductions(None)
    single = len(kernel.outputs) == 1

    def as_dict(res):
        return {kernel.outputs[0]: res} if single else dict(res)

    def rotate(cur, outs):
        cur = dict(cur)
        for o, tgt in rot.items():
            cur[o], cur[tgt] = cur[tgt], outs[o]
        return cur

    def solver(fields, tol, max_iters):
        tol = jnp.asarray(tol, jnp.float32)
        max_iters = jnp.asarray(max_iters, jnp.int32)
        # Carry fields at the kernel's STORAGE dtype: a bf16-storage
        # kernel returns bf16 buffers, so f32 initial fields would make
        # the while_loop carry type-unstable after the first rotation.
        st = kernel.ps.dtype
        cur0 = {n: jnp.asarray(v, st) for n, v in fields.items()}
        reds0 = {n: jnp.zeros((), jnp.float32) for n in kernel.reductions}
        err0 = jnp.float32(jnp.inf if until == "below" else -jnp.inf)

        def cond(state):
            _, _, err, it = state
            keep = err > tol if until == "below" else err <= tol
            return keep & (it < max_iters)

        def body(state):
            cur, _, _, it = state
            for _ in range(check_every - 1):
                cur = rotate(cur, as_dict(plain(**cur, **scalars)))
            outs, reds = kernel(**cur, **scalars)
            cur = rotate(cur, as_dict(outs))
            reds = {n: jnp.asarray(v, jnp.float32) for n, v in reds.items()}
            err = jnp.asarray(err_fn(reds), jnp.float32)
            return cur, reds, err, it + check_every

        return jax.lax.while_loop(cond, body, (cur0, reds0, err0,
                                               jnp.int32(0)))

    return solver


def _crossed(err: float, tol: float, until: str) -> bool:
    """Host-side mirror of the while_loop's stop test."""
    return err <= tol if until == "below" else err > tol


def _kernel_label(kernel) -> str:
    return getattr(kernel.fn, "__name__", "kernel")


def _roofline(col, kernel, fields, scalars, per_step_s, check_every):
    """Best-effort roofline-gap attribution for an instrumented solve:
    pair measured per-step seconds with the kernel's IR cost model.
    Kernels whose update cannot be IR-traced just skip the record."""
    cost = _cost_model_cached(kernel, fields, scalars)
    if cost is None:
        return
    _attrib.attribute(col, _kernel_label(kernel), per_step_s, cost,
                      check_every=int(check_every), fused_checks=True)


# the IR cost model depends only on field shapes/dtypes and the scalar
# values, all of which are fixed across repeat solves — memoize it so
# per-solve attribution is float math + record appends, not a re-trace
_COST_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _cost_model_cached(kernel, fields, scalars):
    def build():
        try:
            return kernel.cost_model(**fields, **(scalars or {}))
        except Exception:
            return None

    skey = _scalars_cache_key(scalars)
    if skey is None:
        return build()
    fkey = tuple(sorted((n, tuple(getattr(v, "shape", ())),
                         str(getattr(v, "dtype", type(v).__name__)))
                        for n, v in fields.items()))
    key = (fkey, skey)
    try:
        cache = _COST_CACHE.setdefault(kernel, {})
    except TypeError:
        return build()
    if key not in cache:
        cache[key] = build()
    return cache[key]


def _solve_checkpointed(
    kernel, fields, scalars, *, tol, max_iters, check_every, error, until,
    ckpt: Checkpointing, col=_telemetry.NULL,
) -> SolveResult:
    """The chunked driver behind ``solve_until(checkpoint=...)``.

    Each chunk is the SAME jitted while_loop as the plain path, capped
    at ``save_every`` checks — per-step math never sees the chunk
    boundary, so a run killed between chunks resumes from ``LATEST``
    bit-identically to the uninterrupted run. Between chunks the carry
    is handed to the (async) checkpoint writer and the FaultPlan /
    heartbeat hooks fire; those are the run's only host syncs."""
    from ..distributed import fault

    mgr = ckpt.manager()
    save_every = int(ckpt.save_every)
    if save_every < 1:
        raise ValueError(f"save_every must be >= 1, got {save_every}")
    solver = _jitted_solver(kernel, scalars, check_every=check_every,
                            error=error, until=until)
    block = save_every * check_every
    # storage-dtype carry (same rationale as make_solver): resume-vs-
    # fresh stay bitwise because checkpoints then hold storage dtype too
    cur = {n: jnp.asarray(v, kernel.ps.dtype) for n, v in fields.items()}
    reds = {n: jnp.zeros((), jnp.float32) for n in kernel.reductions}
    err = jnp.float32(jnp.inf if until == "below" else -jnp.inf)
    done, resumed_from = 0, None

    if ckpt.resume and mgr.latest_step() is not None:
        like = {"fields": cur, "reds": reds, "err": err}
        tree, extra = mgr.restore(like)
        cur, reds, err = tree["fields"], tree["reds"], tree["err"]
        done = int(extra.get("iters", extra["step"]))
        resumed_from = done
        if col.enabled:
            ev = {"step": done, "err": float(err)}
            if extra.get("skipped_corrupt"):
                # torn steps the fallback walked past (step, reason)
                ev["skipped_corrupt"] = [s for s, _ in
                                         extra["skipped_corrupt"]]
            col.event("solve.resume", **ev)

    plan = fault.FaultPlan.active()
    monitor = ckpt.monitor
    saved: list[int] = []
    chunks: list[tuple[float, int]] = []   # (device seconds, steps) per chunk
    converged = done > 0 and _crossed(float(err), tol, until)
    while not converged and done < max_iters:
        take = min(block, max_iters - done)
        w0 = time.time()
        t0 = time.perf_counter()
        cur, reds, err, it = solver(cur, tol, take)
        n = int(it)                      # chunk-boundary host sync
        dt = time.perf_counter() - t0
        done += n
        converged = _crossed(float(err), tol, until)
        chunks.append((dt, n))
        if col.enabled:
            # harvest ONLY what this boundary already syncs: iters + err
            # (+ the reduction scalars the checkpoint ships anyway)
            per = dt / max(n, 1)
            col.span_end("solve.chunk", w0, dt,
                         {"steps": n, "iters": done, "err": float(err),
                          "per_step_s": per, "cold": len(chunks) == 1})
            col.count("solve.steps", n)
            col.event("solve.trajectory", iters=done, err=float(err),
                      per_step_s=per,
                      reds={k: float(v) for k, v in reds.items()})
        if monitor is not None:
            monitor.record(done, dt / max(n, 1))
            health = monitor.check_peers()
            if health["dead"]:
                mgr.wait()
                raise fault.RankFailure(health["dead"])
        # async: stalls only for the device->host snapshot; the write
        # overlaps the next chunk's device work
        mgr.save(done, {"fields": cur, "reds": reds, "err": err},
                 blocking=ckpt.blocking,
                 extra={"iters": done, "err": float(err), "tol": float(tol),
                        "check_every": int(check_every),
                        "save_every": save_every, "until": until,
                        "converged": converged})
        saved.append(done)
        if plan is not None:
            plan.on_step(done)   # a kill lands between save and next chunk
    mgr.wait()                           # surface async write failures
    stats = monitor.snapshot() if monitor is not None else None
    if col.enabled:
        col.gauge("solve.iters", done)
        col.gauge("solve.err", float(err))
        # per-step seconds for the roofline gap: warm chunks only (the
        # first chunk pays trace+compile) unless the run was one chunk
        warm = chunks[1:] if len(chunks) > 1 else chunks
        steps = sum(n for _, n in warm)
        if steps:
            _roofline(col, kernel, cur, scalars,
                      sum(dt for dt, _ in warm) / steps, check_every)
    return SolveResult(fields=cur, reds=reds, err=err,
                       iters=jnp.int32(done), resumed_from=resumed_from,
                       saved_steps=tuple(saved), step_stats=stats)


def solve_until(
    kernel,
    fields: Mapping[str, Any],
    scalars: Mapping[str, Any] | None = None,
    *,
    tol: float,
    max_iters: int,
    check_every: int = 1,
    error: str | Callable | None = None,
    until: str = "below",
    checkpoint: Union[Checkpointing, str, None] = None,
    telemetry: Any = None,
) -> SolveResult:
    """Iterate ``kernel`` on device until its fused error scalar crosses
    ``tol`` (or ``max_iters`` steps), checking every ``check_every``
    steps — zero host transfers between checks.

    ``kernel`` is a :class:`~repro.core.parallel.StencilKernel` with
    ``reductions=`` and ``rotations=`` declared. ``fields`` maps every
    field argument to its initial array; ``scalars`` the non-field
    arguments. ``error`` picks the convergence scalar: a reduction name
    (default: the single declared reduction) or a callable over the
    reduction dict (e.g. a relative-drift formula); it must be cheap —
    it runs inside the loop condition's body on device.

    ``checkpoint`` (a directory path or :class:`Checkpointing`) makes
    the solve survivable: the loop is chunked at check boundaries, the
    carry is checkpointed asynchronously every ``save_every`` checks,
    and an interrupted run restarted with the same arguments resumes
    from the last atomic checkpoint (see :class:`Checkpointing`).

    ``telemetry`` selects a collector: ``None`` inherits the process
    singleton (env ``REPRO_TELEMETRY``), ``False`` forces it off,
    ``True``/a ``Collector`` forces it on. With telemetry off this
    function is byte-identical to the uninstrumented solve; with it on,
    device metrics are read only at already-existing host syncs (chunk
    boundaries / the final carry) — never inside the while_loop.
    """
    col = _telemetry.resolve(telemetry)
    if checkpoint is not None:
        if isinstance(checkpoint, str):
            checkpoint = Checkpointing(checkpoint)
        return _solve_checkpointed(
            kernel, dict(fields), scalars, tol=tol, max_iters=max_iters,
            check_every=check_every, error=error, until=until,
            ckpt=checkpoint, col=col)
    solver = _jitted_solver(kernel, scalars, check_every=check_every,
                            error=error, until=until)
    if not col.enabled:
        cur, reds, err, iters = solver(dict(fields), tol, max_iters)
        return SolveResult(fields=cur, reds=reds, err=err, iters=iters)
    # Instrumented plain path: same cached jitted solver as the disabled
    # path (identical dispatch cost), with cold calls — the ones that
    # paid trace+compile inside the timed window — detected via the jit
    # cache size and excluded from roofline attribution so the gap
    # reflects execution, not compilation.
    size_fn = getattr(solver, "_cache_size", None)
    before = size_fn() if size_fn is not None else None
    w0 = time.time()
    t0 = time.perf_counter()
    cur, reds, err, iters = solver(dict(fields), tol, max_iters)
    it = int(jax.block_until_ready(iters))   # final-carry harvest
    dt = time.perf_counter() - t0
    cold = (size_fn() > before) if size_fn is not None else False
    col.span_end("solve_until", w0, dt,
                 {"kernel": _kernel_label(kernel), "iters": it,
                  "err": float(err), "check_every": int(check_every),
                  "cold": cold})
    col.count("solve.steps", it)
    col.gauge("solve.iters", it)
    col.gauge("solve.err", float(err))
    if it and not cold:
        _roofline(col, kernel, cur, scalars, dt / it, check_every)
    return SolveResult(fields=cur, reds=reds, err=err, iters=iters)


# ---------------------------------------------------------------------------
# batch-axis solves: many independent samples through one device loop
# ---------------------------------------------------------------------------
#
# The serving scenario ("millions of users") is many SMALL independent
# solves — per-request scalars and initial conditions on a common grid —
# not one giant grid. A batched solver stacks them on a leading sample
# axis and advances the whole ensemble inside ONE jitted lax.while_loop:
# the per-sample step is the kernel's single-source jnp realization under
# jax.vmap (XLA fuses the batch axis like any other — on small grids the
# stacked step also uses the machine far better than B undersized
# launches), per-sample fused reductions come back as (B,) vectors, and a
# per-sample ACTIVE mask freezes finished samples — a converged, bad, or
# out-of-budget sample's buffers stop changing bitwise while stragglers
# continue — which is exactly the masking that lets a serving layer
# refill finished slots between chunks (continuous batching).
#
# Numerical health rides in the same loop: a `finite` reduction epilogue
# over the first output turns NaN/Inf into a per-sample indicator at
# check boundaries with zero extra HBM passes or host syncs; the loop
# retires poisoned samples (quarantine) instead of letting one diverging
# request wedge the batch (a NaN error would otherwise compare False
# against tol and masquerade as converged).


GUARD_NAME = "__finite"   # reserved reduction name for the health guard


@dataclasses.dataclass
class BatchCarry:
    """The device-resident state of a batched solve: every leaf carries a
    leading sample axis of extent B. Chunked drivers thread this through
    repeated jitted calls; all leaves are device values."""

    fields: dict[str, jax.Array]   # {name: (B, *grid)} double buffers
    reds: dict[str, jax.Array]     # {name: (B,)} last check's reductions
    err: jax.Array                 # (B,) f32 last error (±inf before first)
    steps: jax.Array               # (B,) i32 per-sample steps taken
    active: jax.Array              # (B,) bool — still iterating
    converged: jax.Array           # (B,) bool — crossed its own tol
    bad: jax.Array                 # (B,) bool — non-finite detected

    def tuple(self):
        return (self.fields, self.reds, self.err, self.steps, self.active,
                self.converged, self.bad)

    @classmethod
    def from_tuple(cls, t):
        return cls(*t)


@dataclasses.dataclass
class BatchedSolveResult:
    """Final state of :func:`solve_batch` (leading sample axis B).

    ``converged[b]`` — sample crossed its own tol; ``bad[b]`` — the
    finite guard tripped (NaN/Inf detected at a check boundary; the
    sample's buffers hold the detecting check's state and may contain
    non-finite values — consumers report the quarantine, not the
    payload); ``expired[b]`` — neither: the sample ran out of its step
    budget."""

    fields: dict[str, jax.Array]
    reds: dict[str, jax.Array]
    err: jax.Array
    iters: jax.Array
    converged: jax.Array
    bad: jax.Array

    @property
    def expired(self) -> jax.Array:
        return ~(self.converged | self.bad)

    def output(self, kernel) -> Any:
        tgts = {o: self.fields[t] for o, t in kernel.rotations.items()}
        if len(kernel.outputs) == 1:
            return tgts[kernel.outputs[0]]
        return tgts


def batchable_kernel(kernel):
    """The kernel variant a batched solve vmaps: the single-source update
    through the jnp (XLA-fused) realization, marching disabled (the
    sample axis is the parallel axis that feeds the machine; plane
    streaming inside a vmap adds nothing on bucket-sized grids). A
    pallas-backend kernel is re-bound to the jnp backend — same update
    fn, outputs, rotations, bcs and reductions, so results agree to
    reassociation (the paper's xPU single-source property is what makes
    this a one-liner)."""
    ps = kernel.ps
    if ps.backend == "jnp" and kernel.march_axis is None:
        return kernel
    from .parallel import StencilKernel

    ps2 = dataclasses.replace(ps, backend="jnp") if ps.backend != "jnp" \
        else ps
    return StencilKernel(ps2, kernel.fn, kernel.outputs, kernel.radius,
                         kernel.tile, kernel.vmem_budget, kernel.rotations,
                         kernel.bc, None, kernel.reductions)


def make_batched_solver(
    kernel,
    *,
    check_every: int = 1,
    error: str | Callable | None = None,
    until: str = "below",
    guard: bool = True,
):
    """Build the un-jitted batched driver
    ``solver(carry, scalars, tol, budget, max_steps) -> carry``.

    ``carry`` is a :class:`BatchCarry` tuple (see :meth:`BatchCarry.tuple`),
    ``scalars`` maps every scalar argument to a ``(B,)`` vector (each
    sample runs its own parameters), ``tol`` is a ``(B,)`` per-sample
    tolerance, ``budget`` a ``(B,)`` per-sample step cap (a deadline
    expressed in steps), and ``max_steps`` bounds this CALL — the loop
    exits when every sample is inactive or ``max_steps`` more steps have
    run, whichever first (chunked serving drivers pass their chunk size;
    :func:`solve_batch` passes the full budget).

    Semantics per check boundary (every ``check_every`` steps):

    * every ACTIVE sample advances; frozen samples are carried through
      ``jnp.where`` untouched (bitwise);
    * the per-sample fused error is compared against the sample's own
      tol (``until`` as in :func:`solve_until`);
    * with ``guard=True`` a ``finite`` reduction epilogue over the first
      output retires samples that went NaN/Inf (``bad``) the moment a
      check detects them, and a NaN error can never masquerade as
      convergence (the guard indicator is NaN-free by construction and
      takes precedence over the tol test);
    * a sample whose ``steps`` reached its budget goes inactive without
      ``converged`` or ``bad`` (the caller reads that as expiry).
    """
    if not kernel.reductions:
        raise ValueError(
            "batched solves need a kernel with fused reductions "
            "(declare reductions={'err': 'max_abs_diff(T2, T)'}-style on "
            "@parallel)"
        )
    err_fn = _resolve_error(kernel, error)   # against the DECLARED set
    kernel = batchable_kernel(kernel)
    rot = kernel.rotations
    if not rot or set(kernel.outputs) - set(rot):
        raise ValueError(
            "batched solves rotate double buffers between steps and need "
            "rotations covering every output (pass rotations={'T2': 'T'}-"
            "style mapping to @parallel)"
        )
    check_every = int(check_every)
    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    if until not in ("below", "above"):
        raise ValueError(f"until must be 'below' or 'above', got {until!r}")
    plain = kernel.with_reductions(None)
    if guard:
        from ..ir import Reduction

        if GUARD_NAME in kernel.reductions:
            raise ValueError(f"reduction name {GUARD_NAME!r} is reserved "
                             "for the batched health guard")
        checked = kernel.with_reductions(
            dict(kernel.reductions,
                 **{GUARD_NAME: Reduction("finite", kernel.outputs[0])}))
    else:
        checked = kernel
    single = len(kernel.outputs) == 1
    red_names = tuple(kernel.reductions)

    def as_dict(res):
        return {kernel.outputs[0]: res} if single else dict(res)

    def rotate(cur, outs):
        cur = dict(cur)
        for o, tgt in rot.items():
            cur[o], cur[tgt] = cur[tgt], outs[o]
        return cur

    def sample_step(f, s):
        """One sample's check block: m-1 plain steps + 1 checked step."""
        cur = f
        for _ in range(check_every - 1):
            cur = rotate(cur, as_dict(plain(**cur, **s)))
        outs, reds = checked(**cur, **s)
        cur = rotate(cur, as_dict(outs))
        return cur, {n: jnp.asarray(v, jnp.float32)
                     for n, v in reds.items()}

    def solver(carry, scalars, tol, budget, max_steps):
        cur, reds, err, steps, active, converged, bad = carry
        tol = jnp.asarray(tol, jnp.float32)
        budget = jnp.asarray(budget, jnp.int32)
        max_steps = jnp.asarray(max_steps, jnp.int32)

        def cond(state):
            (_, _, _, _, active, _, _), t = state
            return jnp.any(active) & (t < max_steps)

        def body(state):
            (cur, reds, err, steps, active, converged, bad), t = state
            new_cur, new_reds = jax.vmap(sample_step)(cur, scalars)
            new_err = jnp.asarray(
                jax.vmap(lambda r: err_fn(
                    {n: r[n] for n in red_names}))(new_reds), jnp.float32)
            if guard:
                nonfin = (new_reds[GUARD_NAME] > 0) | ~jnp.isfinite(new_err)
            else:
                nonfin = ~jnp.isfinite(new_err)

            def freeze(new, old):
                keep = active.reshape(active.shape + (1,) * (new.ndim - 1))
                return jnp.where(keep, new, old)

            cur = {n: freeze(new_cur[n], cur[n]) for n in cur}
            reds = {n: jnp.where(active, new_reds[n], reds[n])
                    for n in red_names}
            err = jnp.where(active, new_err, err)
            steps = steps + jnp.where(active, check_every, 0)
            newly_bad = active & nonfin
            crossed = (err <= tol) if until == "below" else (err > tol)
            newly_conv = active & ~newly_bad & crossed
            bad = bad | newly_bad
            converged = converged | newly_conv
            active = active & ~newly_bad & ~newly_conv & (steps < budget)
            return ((cur, reds, err, steps, active, converged, bad),
                    t + check_every)

        state = ((cur, reds, err, steps, active, converged, bad),
                 jnp.int32(0))
        final, _ = jax.lax.while_loop(cond, body, state)
        return final

    return solver


# batched jitted solvers, memoized exactly like _SOLVER_CACHE (the key
# adds the batch extent + field shapes: the closure itself is shape-
# polymorphic, but one jit per (kernel, policy) signature suffices)
_BATCH_SOLVER_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def jitted_batched_solver(kernel, *, check_every=1, error=None,
                          until="below", guard=True):
    """The jitted driver for (kernel, policy), memoized on the kernel."""
    err_key = error if (error is None or isinstance(error, str)) \
        else id(error)
    key = (int(check_every), err_key, until, bool(guard))
    try:
        cache = _BATCH_SOLVER_CACHE.setdefault(kernel, {})
    except TypeError:
        cache = None
    if cache is not None and key in cache:
        return cache[key]
    solver = jax.jit(
        make_batched_solver(kernel, check_every=check_every, error=error,
                            until=until, guard=guard),
        static_argnums=())
    if cache is not None:
        cache[key] = solver
    return solver


def init_batch_carry(kernel, fields: Mapping[str, Any],
                     until: str = "below",
                     active: Any = None) -> BatchCarry:
    """A fresh :class:`BatchCarry` from stacked initial fields
    ``{name: (B, *grid)}`` (cast to the kernel's storage dtype).
    ``active`` preselects live samples (default: all)."""
    st = kernel.ps.dtype
    cur = {n: jnp.asarray(v, st) for n, v in fields.items()}
    b = next(iter(cur.values())).shape[0]
    for n, v in cur.items():
        if v.shape[0] != b:
            raise ValueError(
                f"field {n!r} has batch extent {v.shape[0]} != {b}; all "
                "stacked fields must share the leading sample axis")
    err0 = jnp.full((b,), jnp.inf if until == "below" else -jnp.inf,
                    jnp.float32)
    active = (jnp.ones((b,), bool) if active is None
              else jnp.asarray(active, bool))
    return BatchCarry(
        fields=cur,
        reds={n: jnp.zeros((b,), jnp.float32) for n in kernel.reductions},
        err=err0,
        steps=jnp.zeros((b,), jnp.int32),
        active=active,
        converged=jnp.zeros((b,), bool),
        bad=jnp.zeros((b,), bool),
    )


def solve_batch(
    kernel,
    fields: Mapping[str, Any],
    scalars: Mapping[str, Any] | None = None,
    *,
    tol: Any,
    max_iters: Any,
    check_every: int = 1,
    error: str | Callable | None = None,
    until: str = "below",
    guard: bool = True,
) -> BatchedSolveResult:
    """Solve B independent samples to their own convergence in ONE jitted
    device loop (see :func:`make_batched_solver` for the semantics).

    ``fields`` maps every field argument to a stacked ``(B, *grid)``
    array; ``scalars`` maps every scalar argument to a ``(B,)`` vector or
    a python number (broadcast to all samples). ``tol`` and ``max_iters``
    are likewise per-sample vectors or broadcast scalars. The loop runs
    until every sample converged, tripped the finite guard, or exhausted
    its own ``max_iters`` — finished samples freeze bitwise while
    stragglers continue."""
    carry = init_batch_carry(kernel, fields, until=until)
    b = carry.err.shape[0]
    scal = {n: jnp.broadcast_to(jnp.asarray(v), (b,))
            for n, v in (scalars or {}).items()}
    tolv = jnp.broadcast_to(jnp.asarray(tol, jnp.float32), (b,))
    budget = jnp.broadcast_to(jnp.asarray(max_iters, jnp.int32), (b,))
    solver = jitted_batched_solver(kernel, check_every=check_every,
                                   error=error, until=until, guard=guard)
    # cap = the largest per-sample budget, rounded up to a whole check
    cap = int(np.ceil(int(np.max(np.asarray(budget))) / check_every)
              ) * check_every
    final = solver(carry.tuple(), scal, tolv, budget, cap)
    out = BatchCarry.from_tuple(final)
    return BatchedSolveResult(fields=out.fields, reds=out.reds, err=out.err,
                              iters=out.steps, converged=out.converged,
                              bad=out.bad)
