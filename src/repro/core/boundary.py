"""Boundary conditions for stencil fields.

Functional counterparts of the boundary handling a ParallelStencil user
writes as small ``@parallel_indices`` kernels. Each function returns a new
array with the requested condition applied on the given faces.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp


def check_depth(shape: Sequence[int], kind: str, axes: Sequence[int],
                depth: int) -> None:
    """Validate that every requested face fits the array.

    ``dirichlet`` needs two disjoint ``depth``-cell faces per axis
    (extent >= 2*depth); ``neumann0``/``periodic`` additionally need their
    source layers to be interior cells disjoint from both faces
    (extent >= 3*depth). Raises a pointed ``ValueError`` otherwise —
    silently aliasing faces and sources is never what the user meant.
    """
    if depth < 1:
        raise ValueError(f"boundary depth must be >= 1, got {depth}")
    need = 2 * depth if kind == "dirichlet" else 3 * depth
    for ax in axes:
        n = shape[ax]
        if n < need:
            raise ValueError(
                f"axis {ax} of extent {n} is smaller than the {depth}-deep "
                f"{kind} faces require (need >= {need}: two {depth}-cell "
                "faces" + ("" if kind == "dirichlet"
                           else f" plus interior source layers") + ")"
            )


def _face(ndim: int, axis: int, side: int, depth: int = 1):
    sl = [slice(None)] * ndim
    sl[axis] = slice(0, depth) if side == 0 else slice(-depth, None)
    return tuple(sl)


def _inner_face(ndim: int, axis: int, side: int, depth: int = 1):
    sl = [slice(None)] * ndim
    sl[axis] = slice(depth, 2 * depth) if side == 0 else slice(-2 * depth, -depth)
    return tuple(sl)


def dirichlet(A: jnp.ndarray, value, axes: Sequence[int] | None = None, depth: int = 1):
    """Fix boundary faces to ``value`` (scalar or broadcastable)."""
    axes = tuple(range(A.ndim) if axes is None else axes)
    check_depth(A.shape, "dirichlet", axes, depth)
    for ax in axes:
        for side in (0, 1):
            A = A.at[_face(A.ndim, ax, side, depth)].set(value)
    return A


def neumann0(A: jnp.ndarray, axes: Sequence[int] | None = None, depth: int = 1):
    """Zero-flux: copy the first interior layer onto the boundary layer."""
    axes = tuple(range(A.ndim) if axes is None else axes)
    check_depth(A.shape, "neumann0", axes, depth)
    for ax in axes:
        for side in (0, 1):
            A = A.at[_face(A.ndim, ax, side, depth)].set(
                A[_inner_face(A.ndim, ax, side, depth)]
            )
    return A


def periodic(A: jnp.ndarray, axes: Sequence[int] | None = None, depth: int = 1):
    """Wrap: boundary layers mirror the opposite interior layers."""
    axes = tuple(range(A.ndim) if axes is None else axes)
    check_depth(A.shape, "periodic", axes, depth)
    for ax in axes:
        n = A.shape[ax]
        lo_src = [slice(None)] * A.ndim
        hi_src = [slice(None)] * A.ndim
        lo_src[ax] = slice(n - 2 * depth, n - depth)  # far interior -> low ghost
        hi_src[ax] = slice(depth, 2 * depth)  # near interior -> high ghost
        A = A.at[_face(A.ndim, ax, 0, depth)].set(A[tuple(lo_src)])
        A = A.at[_face(A.ndim, ax, 1, depth)].set(A[tuple(hi_src)])
    return A
