"""Math-close finite-difference operators (ParallelStencil.FiniteDifferences{1,2,3}D).

These are the JAX analogues of the paper's macros (``@inn``, ``@d2_xi``,
``@av`` ...). They are *relative* slice expressions, so the very same kernel
source works on

  * full arrays (the ``jnp`` / array-programming backend), and
  * halo-extended VMEM windows inside a Pallas kernel body (the ``pallas``
    backend),

which is how the single-source xPU property of ParallelStencil is realized
here (DESIGN.md C1/C2).

Naming follows ParallelStencil:
  ``*_a``  operate over the full extent of the differentiated axis,
  ``*_i``  additionally restrict all *other* axes to their interior,
  ``inn``  selects the interior in all axes.

All operators reduce the differentiated axis length by their stencil width;
combined with ``inn``-style selection the results of e.g. ``d2_xi``,
``d2_yi``, ``d2_zi`` share one common shape — exactly the interior.
"""
from __future__ import annotations

import numpy as np

__all__ = ["fd1d", "fd2d", "fd3d", "FiniteDifferences"]


def _s(ndim: int, axis: int, sl: slice, other: slice) -> tuple[slice, ...]:
    return tuple(sl if a == axis else other for a in range(ndim))


_FULL = slice(None)
_INN = slice(1, -1)


class FiniteDifferences:
    """Finite-difference operator namespace for a fixed dimensionality.

    Instantiated once per ndim below (``fd1d``, ``fd2d``, ``fd3d``); all
    methods are static-like (take the array as first argument).
    """

    def __init__(self, ndim: int):
        self.ndim = ndim
        ax_names = "xyz"[:ndim]
        # Generate the full ParallelStencil-style API surface: d_xa, d_xi,
        # d2_xa, d2_xi, av_xa, av_xi, ... per axis.
        for axis, name in enumerate(ax_names):
            setattr(self, f"d_{name}a", self._make(self._d, axis, inner_other=False))
            setattr(self, f"d_{name}i", self._make(self._d, axis, inner_other=True))
            setattr(self, f"d2_{name}a", self._make(self._d2, axis, inner_other=False))
            setattr(self, f"d2_{name}i", self._make(self._d2, axis, inner_other=True))
            setattr(self, f"av_{name}a", self._make(self._av, axis, inner_other=False))
            setattr(self, f"av_{name}i", self._make(self._av, axis, inner_other=True))

    # -- primitive stencils ------------------------------------------------
    def _d(self, A, axis, other):
        n = self.ndim
        return A[_s(n, axis, slice(1, None), other)] - A[_s(n, axis, slice(None, -1), other)]

    def _d2(self, A, axis, other):
        n = self.ndim
        return (
            A[_s(n, axis, slice(2, None), other)]
            - 2.0 * A[_s(n, axis, _INN, other)]
            + A[_s(n, axis, slice(None, -2), other)]
        )

    def _av(self, A, axis, other):
        n = self.ndim
        return 0.5 * (
            A[_s(n, axis, slice(1, None), other)] + A[_s(n, axis, slice(None, -1), other)]
        )

    def _make(self, op, axis, inner_other):
        other = _INN if inner_other else _FULL
        def f(A):
            return op(A, axis, other)
        f.__name__ = f"{op.__name__}_ax{axis}_{'i' if inner_other else 'a'}"
        return f

    # -- interior / neighborhood ops ---------------------------------------
    def inn(self, A):
        """Interior of A in every axis (the paper's ``@inn``)."""
        return A[(_INN,) * self.ndim]

    def av(self, A):
        """Average over the 2^ndim cell corners (the paper's ``@av``)."""
        out = A
        for axis in range(self.ndim):
            out = 0.5 * (
                out[_s(self.ndim, axis, slice(1, None), _FULL)]
                + out[_s(self.ndim, axis, slice(None, -1), _FULL)]
            )
        return out

    def maxloc(self, A):
        """Maximum over the 3^ndim neighborhood, evaluated on the interior
        (the paper/package's ``@maxloc``)."""
        import jax.numpy as jnp

        n = self.ndim
        out = None
        for offs in np.ndindex(*(3,) * n):
            sl = tuple(slice(o, None if o == 2 else o - 2) for o in offs)
            v = A[sl]
            out = v if out is None else jnp.maximum(out, v)
        return out

    def laplacian(self, A, inv_spacing):
        """Sum of second differences on the interior, scaled by 1/d^2."""
        names = "xyz"[: self.ndim]
        total = 0.0
        for axis, nm in enumerate(names):
            total = total + getattr(self, f"d2_{nm}i")(A) * inv_spacing[axis] ** 2
        return total


fd1d = FiniteDifferences(1)
fd2d = FiniteDifferences(2)
fd3d = FiniteDifferences(3)
