"""T_eff — the paper's effective-memory-throughput performance model (C7).

    T_eff = A_eff / t,     A_eff = n_IO * n_gridpoints * sizeof(eltype)

where ``n_IO`` counts the arrays that *must* be read or written once per
time step under perfect reuse (for the 3-D diffusion solver of Fig. 1:
read T and Ci, write T2 -> n_IO = 3; the paper's canonical definition in
Räss et al. 2022 [5] uses reads+writes of fields that change every step,
i.e. A_eff = (2 * n_rw + n_r) * V; we expose both and use the explicit
read/write counts everywhere).

The fraction T_eff / T_peak is the memory-roofline fraction this repo
reports as its §Perf score (the paper reaches 0.88 on P100 / 0.93 on A100).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Sequence

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_bw: float  # bytes/s, HBM/DRAM
    peak_flops: float  # FLOP/s at the relevant precision
    link_bw: float = 0.0  # bytes/s per ICI/NVLink link (for collective roofline)
    hbm_bytes: float = 0.0

    @property
    def ridge_intensity(self) -> float:
        return self.peak_flops / self.peak_bw


# Hardware constants. TPU numbers are the task-specified v5e targets; the
# GPU entries reproduce the paper's Fig. 2 reference hardware.
TPU_V5E = HardwareSpec("TPU v5e", peak_bw=819e9, peak_flops=197e12, link_bw=50e9,
                       hbm_bytes=16e9)
A100_SXM4 = HardwareSpec("NVIDIA A100 SXM4", peak_bw=1355e9, peak_flops=312e12,
                         link_bw=300e9, hbm_bytes=40e9)
P100_PCIE = HardwareSpec("NVIDIA P100 PCIe", peak_bw=561e9, peak_flops=18.7e12,
                         link_bw=16e9, hbm_bytes=16e9)


def a_eff(n_points: int, n_read: int, n_write: int, itemsize: int) -> int:
    """Effective bytes moved per step: each counted field crosses HBM once."""
    return (n_read + n_write) * n_points * itemsize


def a_eff_blocked(n_points: int, n_read: int, n_write: int, itemsize: int,
                  nsteps: int = 1) -> float:
    """Ideal per-step HBM traffic under k-step temporal blocking.

    A k-fused launch moves each counted field across HBM once per *k* steps,
    so the per-step effective volume divides by k. T_eff computed against
    this volume can exceed the single-sweep memory roofline — that is the
    point of temporal blocking. ``nsteps=1`` degenerates to :func:`a_eff`.
    """
    return a_eff(n_points, n_read, n_write, itemsize) / max(int(nsteps), 1)


def window_overlap_factor(block, halo, nsteps: int = 1,
                          march_axis: int | None = None) -> float:
    """Read-amplification of a tiled launch vs ideal once-per-sweep
    streaming: ``prod_a (b_a + k*(lo_a + hi_a)) / b_a`` over the axes
    whose windows overlap. The all-parallel launch refetches along every
    axis; a streamed launch (``march_axis``) carries its march-axis halo
    planes in on-chip scratch, so that axis drops out of the product —
    which is exactly the traffic the marching mode saves."""
    k = max(int(nsteps), 1)
    block = tuple(int(b) for b in block)
    if isinstance(halo, int):
        halo = ((halo, halo),) * len(block)
    f = 1.0
    for a, (b, (lo, hi)) in enumerate(zip(block, halo)):
        if march_axis is not None and a == march_axis:
            continue
        f *= (b + k * (lo + hi)) / b
    return f


def a_eff_streamed(n_points: int, n_read: int, n_write: int, itemsize: int,
                   nsteps: int = 1, overlap: float = 1.0) -> float:
    """Per-step HBM traffic of a streamed (marching) launch: each read
    field is fetched ~once per sweep times the residual window-overlap
    factor of the non-marching axes (``window_overlap_factor`` with the
    march axis excluded; 1.0 = perfect reuse), writes stream out once,
    and a k-fused launch amortizes both over k steps. The refetched
    all-parallel traffic is the same formula with the full overlap
    factor — the difference is what ``march_axis=`` eliminates."""
    return ((n_read * overlap + n_write) * n_points * itemsize
            / max(int(nsteps), 1))


def halo_compute_overhead(block, radius: int, nsteps: int) -> float:
    """Fraction of *redundant* gridpoint-updates a k-fused launch performs
    relative to k ideal sweeps over the block.

    Sweep s of a temporally-blocked kernel updates the block extended by
    ``(k-1-s)*radius`` cells per side (the shrinking halo cone), so blocks
    recompute their neighbors' edge cells. When this ratio grows faster
    than the k-fold A_eff saving, larger k stops paying off — that is the
    classic temporal-blocking trade-off (redundant work vs traffic).
    """
    k = max(int(nsteps), 1)
    block = tuple(int(b) for b in block)
    ideal = k * math.prod(block)
    total = sum(
        math.prod(b + 2 * (k - 1 - s) * radius for b in block) for s in range(k)
    )
    return total / ideal - 1.0


def a_eff_checked(a_eff_step: float, check_bytes: float,
                  check_every: int = 1, fused: bool = True) -> float:
    """Per-step ideal HBM traffic of an iterative solver that checks
    convergence every ``check_every`` steps.

    ``fused=True`` is the in-launch reduction epilogue: the check folds
    over data already in flight, so the only extra traffic is the
    per-tile partials write (rounded to zero here — O(n_blocks) scalars).
    ``fused=False`` is the separate norm pass: ``check_bytes`` (each
    operand field re-read once — e.g. ``ir.check_io_bytes``) lands on
    every check step and is amortized over the cadence. Keeping both in
    the T_eff table is what makes check traffic visible instead of
    silently inflating the "compute" time of check steps."""
    m = max(int(check_every), 1)
    extra = 0.0 if fused else check_bytes / m
    return a_eff_step + extra


def io_counts_from_ir(ir) -> tuple[int, int]:
    """(n_read, n_write) derived from a traced ``repro.ir.StencilIR``
    instead of hand-counting which fields cross HBM — the IR knows which
    arguments the update actually reads."""
    return ir.io_counts()


def a_eff_from_ir(ir, itemsize: int, nsteps: int = 1,
                  field_itemsizes=None) -> float:
    """A_eff derived from the stencil IR: exact per-field byte volumes
    (staggered fields at their own extents; mixed-precision fields at
    their own storage width via ``field_itemsizes``, a ``{field:
    itemsize}`` mapping), divided by the temporal-blocking depth.
    Replaces hand-supplied ``n_read``/``n_write`` for any kernel built
    through ``@parallel``."""
    return (ir.io_bytes(itemsize, field_itemsizes=field_itemsizes)
            / max(int(nsteps), 1))


def t_eff(a_eff_bytes: float, seconds: float) -> float:
    """Effective throughput in bytes/s."""
    return a_eff_bytes / seconds


def fraction(throughput: float, hw: HardwareSpec) -> float:
    return throughput / hw.peak_bw


@dataclasses.dataclass
class Measurement:
    median_s: float
    ci95_s: tuple[float, float]
    samples_s: list[float]

    def t_eff(self, a_eff_bytes: float) -> float:
        return t_eff(a_eff_bytes, self.median_s)

    # Jitter percentiles over the raw per-iteration samples: the median
    # alone hides straggling iterations (GC pauses, a noisy neighbor, a
    # slow link), which is exactly what a perf trajectory wants to catch.
    @property
    def mean_s(self) -> float:
        return float(np.mean(self.samples_s))

    @property
    def p50_s(self) -> float:
        return float(np.percentile(self.samples_s, 50))

    @property
    def p90_s(self) -> float:
        return float(np.percentile(self.samples_s, 90))

    @property
    def max_s(self) -> float:
        return float(max(self.samples_s))

    def percentiles(self) -> dict[str, float]:
        """{"mean_s", "p50_s", "p90_s", "max_s"} — the jitter summary
        bench rows embed next to the median."""
        return {"mean_s": self.mean_s, "p50_s": self.p50_s,
                "p90_s": self.p90_s, "max_s": self.max_s}


def measure(fn: Callable[[], object], iters: int = 20, warmup: int = 3,
            inner: int = 1) -> Measurement:
    """Median wall time with a bootstrap 95% CI (paper Fig. 2 methodology:
    medians of 20 samples with confidence interval). The returned
    :class:`Measurement` also exposes p50/p90/max per-iteration jitter
    percentiles over the raw samples."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn()
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / inner)
    med = float(np.median(samples))
    rng = np.random.RandomState(0)
    boots = [float(np.median(rng.choice(samples, size=len(samples)))) for _ in range(200)]
    lo, hi = float(np.percentile(boots, 2.5)), float(np.percentile(boots, 97.5))
    return Measurement(med, (lo, hi), samples)


def measure_host_bandwidth(nbytes: int = 1 << 28) -> float:
    """Rough STREAM-copy estimate of this host's achievable memory bandwidth,
    used as T_peak for the CPU rows of the Fig. 2 reproduction."""
    a = np.ones(nbytes // 8, dtype=np.float64)
    b = np.empty_like(a)
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        np.copyto(b, a)
    dt = (time.perf_counter() - t0) / reps
    return 2 * a.nbytes / dt  # read + write
