"""Version-compat shims for the jax API surface this repo depends on.

The repo targets current jax but must run on the 0.4.x line too (the
pinned toolchain of some hosts). Everything version-sensitive funnels
through here:

  * ``shard_map`` — moved from ``jax.experimental.shard_map`` to top-level
    ``jax.shard_map``; the replication-check kwarg was renamed
    ``check_rep`` -> ``check_vma`` along the way.
  * ``axis_size`` — ``jax.lax.axis_size`` only exists on newer jax; 0.4.x
    exposes the static size through ``jax.core.axis_frame``.
  * ``tree_flatten_with_path`` — ``jax.tree.flatten_with_path`` on newer
    jax, ``jax.tree_util.tree_flatten_with_path`` on 0.4.x.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.6
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x/0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = inspect.signature(_shard_map).parameters
_CHECK_KW = "check_vma" if "check_vma" in _SM_PARAMS else "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool | None = None,
              **kwargs):
    """jax.shard_map with the replication-check kwarg normalized to the
    new ``check_vma`` spelling on every supported jax version."""
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def tree_flatten_with_path(tree):
    """(key_path, leaf) flattening on every supported jax version."""
    try:
        return jax.tree.flatten_with_path(tree)
    except AttributeError:  # jax <= 0.4.x keeps it in tree_util
        return jax.tree_util.tree_flatten_with_path(tree)


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis, usable in Python control flow
    inside shard_map on every supported jax version."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return frame.size if hasattr(frame, "size") else frame
