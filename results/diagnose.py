"""Per-collective breakdown of a dry-run cell: top wire-byte contributors
with HLO metadata provenance (the §Perf 'profile')."""
import os, sys, re, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro import configs
from repro.models import build, RunConfig
from repro.distributed import sharding as shd
from repro.launch import steps as steps_mod, mesh as mesh_mod, hlo_analysis as ha
from repro.optim import adamw

def compile_cell(arch, shape_name, rules=shd.DEFAULT_RULES, rc=None, save=None):
    cfg = configs.get_arch(arch)
    shape = configs.SHAPES[shape_name]
    if rc is None:
        size = cfg.d_model * cfg.n_layers
        n_micro = 8 if size >= 512*1024 else (4 if size >= 64*1024 else 1)
        rc = RunConfig(n_microbatch=n_micro)
    model = build(cfg, rc)
    mesh = mesh_mod.make_production_mesh()
    if shape.mode == "train":
        b = steps_mod.make_train_step(model, mesh, rules, adamw.AdamWConfig(),
                                      shape.seq_len, shape.global_batch, n_micro=rc.n_microbatch)
    elif shape.mode == "prefill":
        b = steps_mod.make_prefill_step(model, mesh, rules, shape.seq_len, shape.global_batch)
    else:
        b = steps_mod.make_decode_step(model, mesh, rules, shape.seq_len, shape.global_batch)
    with mesh:
        comp = jax.jit(b.fn, in_shardings=b.in_shardings, out_shardings=b.out_shardings,
                       donate_argnums=b.donate_argnums).lower(*b.abstract_inputs).compile()
    t = comp.as_text()
    if save:
        open(save, "w").write(t)
    return t, comp

def diagnose(text, topk=12):
    mc = ha.ModuleCost(text)
    total = mc.cost()
    # per-collective attribution with trip multipliers: walk again recording
    rows = []
    trips = {}
    def walk(comp_name, mult):
        comp = mc.comps.get(comp_name)
        if comp is None: return
        key = ("__visited__", comp_name, mult)
        for i in comp.instrs:
            if i.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", i.rest)
                mt = ha._TRIP_CFG.search(i.rest)
                trip = int(mt.group(1)) if mt else 1
                if mb: walk(mb.group(1), mult*trip)
            elif i.op in ("call", "conditional", "fusion"):
                for m in ha._CALLS.finditer(i.rest):
                    for nm in m.group(1).split(","):
                        walk(nm.strip().lstrip("%"), mult)
            if i.op in ha.COLLECTIVES and not i.op.endswith("-done"):
                w = ha._coll_wire(i) * mult
                md = re.search(r'op_name="([^"]*)"', i.rest)
                rows.append((w, i.op, i.shape_str[:60], (md.group(1) if md else "")[:90]))
    entry = mc.entry.name
    walk(entry, 1)
    rows.sort(reverse=True)
    print(f"total flops {total.flops:.3e} bytes {total.bytes:.3e} wire {total.coll_wire:.3e}")
    agg = {}
    for w, op, sh, name in rows:
        key = (op, name.split("/")[-1][:40] if name else sh)
        agg[key] = agg.get(key, 0) + w
    for (op, key), w in sorted(agg.items(), key=lambda kv: -kv[1])[:topk]:
        print(f"  {w:12.3e}  {op:20s} {key}")
    return total

if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    t, comp = compile_cell(arch, shape, save=f"results/hlo_{arch}_{shape}_diag.txt")
    diagnose(t)
