import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, re, collections, time
from repro import configs
from repro.models import build, RunConfig
from repro.distributed import sharding as shd
from repro.launch import steps as steps_mod, mesh as mesh_mod, hlo_analysis
from repro.launch import roofline as rf
from repro.optim import adamw

def probe(arch, shape_name):
    cfg = configs.get_arch(arch)
    shape = configs.SHAPES[shape_name]
    rc = RunConfig()
    model = build(cfg, rc)
    mesh = mesh_mod.make_production_mesh()
    t0=time.time()
    if shape.mode == "train":
        b = steps_mod.make_train_step(model, mesh, shd.DEFAULT_RULES, adamw.AdamWConfig(), shape.seq_len, shape.global_batch)
        mf = rf.model_flops_train(cfg, shape.seq_len, shape.global_batch)
    elif shape.mode == "prefill":
        b = steps_mod.make_prefill_step(model, mesh, shd.DEFAULT_RULES, shape.seq_len, shape.global_batch)
        mf = rf.model_flops_prefill(cfg, shape.seq_len, shape.global_batch)
    else:
        b = steps_mod.make_decode_step(model, mesh, shd.DEFAULT_RULES, shape.seq_len, shape.global_batch)
        mf = rf.model_flops_decode(cfg, shape.global_batch)
    with mesh:
        comp = jax.jit(b.fn, in_shardings=b.in_shardings, out_shardings=b.out_shardings,
                       donate_argnums=b.donate_argnums).lower(*b.abstract_inputs).compile()
    t = comp.as_text()
    ops_h = collections.Counter(m.group(1) for m in re.finditer(r"=\s*(?:\([^=]*?\)|[\w\[\],{}]+?)\s+([\w\-]+)\(", t))
    mc = hlo_analysis.ModuleCost(t).cost()
    mem = comp.memory_analysis()
    print(f"== {arch}/{shape_name}: compile {time.time()-t0:.0f}s")
    print("   temp GiB:", getattr(mem, "temp_size_in_bytes", 0)/2**30)
    print("   dot:", ops_h.get("dot",0), "custom-call:", ops_h.get("custom-call",0), "while:", ops_h.get("while",0))
    for cc in set(re.findall(r'custom_call_target="([^"]+)"', t)): print("   cc target:", cc)
    print(f"   analyzer flops/dev {mc.flops:.3e} want~{mf/256:.3e} bytes {mc.bytes:.3e} wire {mc.coll_wire:.3e}")
    with open(f"/root/repo/results/hlo_{arch}_{shape_name}.txt", "w") as f:
        f.write(t)

probe("qwen2-72b", "train_4k")
probe("moonshot-v1-16b-a3b", "decode_32k")
probe("mamba2-130m", "train_4k")
