import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, time, traceback
from repro.launch.dryrun import run_cell
CELLS = [
    ("qwen2-72b", "train_4k", False),
    ("mixtral-8x7b", "train_4k", False),
    ("moonshot-v1-16b-a3b", "decode_32k", False),
    ("seamless-m4t-medium", "prefill_32k", False),
    ("seamless-m4t-medium", "decode_32k", False),
    ("phi-3-vision-4.2b", "train_4k", False),
    ("zamba2-1.2b", "long_500k", False),
    ("mixtral-8x7b", "long_500k", False),
    ("mamba2-130m", "train_4k", True),
]
for arch, shape, mp in CELLS:
    t0 = time.time()
    try:
        rec = run_cell(arch, shape, mp)
        r = rec.get("roofline", {})
        print(f"OK {arch}/{shape}/{'multi' if mp else 'single'}: compile={rec['compile_s']}s "
              f"dom={r.get('dominant')} tc={r.get('t_compute'):.4g} tm={r.get('t_memory'):.4g} "
              f"tl={r.get('t_collective'):.4g} useful={r.get('useful_ratio'):.3f} "
              f"temp={rec['memory'].get('temp_size_in_bytes',0)/2**30:.2f}GiB", flush=True)
    except Exception as e:
        print(f"FAIL {arch}/{shape}/{mp}: {e!r}", flush=True)
        traceback.print_exc()
