"""Hillclimb driver: hypothesis -> change -> re-lower -> measure, for the
three selected cells. Each experiment writes a JSON record; the narrative
goes to EXPERIMENTS.md §Perf."""
import os, sys, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
sys.path.insert(0, "results")
from diagnose import compile_cell, diagnose
from repro.distributed import sharding as shd
from repro.models import RunConfig
from repro.launch import hlo_analysis as ha, roofline as rf
from repro import configs

def measure(arch, shape, tag, rules=shd.DEFAULT_RULES, rc=None):
    t0 = time.time()
    text, comp = compile_cell(arch, shape, rules=rules, rc=rc)
    mc = ha.ModuleCost(text).cost()
    mem = comp.memory_analysis()
    cfg = configs.get_arch(arch)
    sh = configs.SHAPES[shape]
    if sh.mode == "train":
        mf = rf.model_flops_train(cfg, sh.seq_len, sh.global_batch) / 256
    elif sh.mode == "prefill":
        mf = rf.model_flops_prefill(cfg, sh.seq_len, sh.global_batch) / 256
    else:
        mf = rf.model_flops_decode(cfg, sh.global_batch) / 256
    rec = {
        "arch": arch, "shape": shape, "tag": tag,
        "flops": mc.flops, "bytes_hlo": mc.bytes, "wire": mc.coll_wire,
        "t_compute": mc.flops / rf.PEAK_FLOPS,
        "t_collective": mc.coll_wire / rf.LINK_BW,
        "useful_ratio": mf / mc.flops if mc.flops else 0,
        "temp_gib": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
        "compile_s": round(time.time() - t0, 1),
    }
    fn = f"results/hc_{arch}_{shape}_{tag}.json"
    json.dump(rec, open(fn, "w"), indent=1)
    print(f"[{tag}] {arch}/{shape}: tc={rec['t_compute']:.3f}s tl={rec['t_collective']:.3f}s "
          f"useful={rec['useful_ratio']:.2f} temp={rec['temp_gib']:.1f}GiB "
          f"(compile {rec['compile_s']}s)", flush=True)
    return rec

if __name__ == "__main__":
    which = sys.argv[1]
    if which == "mamba_naive":
        # paper-faithful naive baseline (pure DP, replicated weights)
        measure("mamba2-130m", "train_4k", "naive", rules=shd.NAIVE_RULES)
    elif which == "mamba_h1":
        # H1: spend the idle/indivisible model axis on batch DP for pure-SSM
        rules = shd.ShardRules(batch=("pod", "data", "model"), fsdp="data",
                               tensor=None, seq=None, seq_act=None)
        measure("mamba2-130m", "train_4k", "h1_batch_over_model", rules=rules)
    elif which == "mamba_h2":
        # H2: same + FSDP over both axes (ZeRO across all 256 devices)
        rules = shd.ShardRules(batch=("pod", "data", "model"), fsdp="data",
                               tensor=None, seq=None, seq_act=None)
        measure("mamba2-130m", "train_4k", "h2_bigger_chunks",
                rules=rules, rc=RunConfig(n_microbatch=1, ssd_impl="chunked"))
    elif which == "mixtral_naive":
        measure("mixtral-8x7b", "train_4k", "naive", rules=shd.NAIVE_RULES)
    elif which == "mixtral_base":
        measure("mixtral-8x7b", "train_4k", "base")
    elif which == "mixtral_h1":
        # H1: EP over 8 of the axis impossible; instead batch over model too
        # for the attention part is illegal w/ tensor; try seq_act=None to
        # remove per-block gather/scatter pairs
        rules = shd.ShardRules(seq_act=None)
        measure("mixtral-8x7b", "train_4k", "h1_no_seqact", rules=rules)
    elif which == "qwen_base":
        measure("qwen2-72b", "train_4k", "base")
    elif which == "qwen_naive":
        measure("qwen2-72b", "train_4k", "naive", rules=shd.NAIVE_RULES)
    elif which == "qwen_h1":
        measure("qwen2-72b", "train_4k", "h1_remat_dots",
                rc=RunConfig(n_microbatch=8, remat_policy="dots"))
    elif which == "qwen_h2":
        measure("qwen2-72b", "train_4k", "h2_remat_dots_micro4",
                rc=RunConfig(n_microbatch=4, remat_policy="dots"))
    elif which == "mamba_base":
        measure("mamba2-130m", "train_4k", "base")

def diag(arch, shape, rules=shd.DEFAULT_RULES, rc=None):
    text, comp = compile_cell(arch, shape, rules=rules, rc=rc)
    diagnose(text)

# appended variants
if __name__ == "__main__" and sys.argv[1] == "qwen_h2sp":
    measure("qwen2-72b", "train_4k", "h2_sp_boundary")
if __name__ == "__main__" and sys.argv[1] == "mixtral_h2sp":
    measure("mixtral-8x7b", "train_4k", "h2_sp_boundary")
if __name__ == "__main__" and sys.argv[1] == "qwen_h3":
    measure("qwen2-72b", "train_4k", "h3_sp_and_dots",
            rc=RunConfig(n_microbatch=8, remat_policy="dots"))
if __name__ == "__main__" and sys.argv[1] == "qwen_h4":
    measure("qwen2-72b", "train_4k", "h4_no_seqact_micro8",
            rules=shd.ShardRules(seq_act=None), rc=RunConfig(n_microbatch=8))
if __name__ == "__main__" and sys.argv[1] == "mixtral_h3":
    measure("mixtral-8x7b", "train_4k", "h3_no_seqact",
            rules=shd.ShardRules(seq_act=None), rc=RunConfig(n_microbatch=4))
if __name__ == "__main__" and sys.argv[1] == "qwen_h5":
    measure("qwen2-72b", "train_4k", "h5_no_seqact_micro16",
            rules=shd.ShardRules(seq_act=None), rc=RunConfig(n_microbatch=16))
