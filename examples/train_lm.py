"""End-to-end training driver: mamba2-130m (a real ~130M-param config) on
the synthetic token stream, with checkpointing + resume.

    PYTHONPATH=src python examples/train_lm.py --steps 300          # full 130M
    PYTHONPATH=src python examples/train_lm.py --quick --steps 50   # reduced

The full model at seq 128 / batch 4 is CPU-runnable (~10 s/step); on the
production mesh this is exactly what launch/dryrun.py compiles at
train_4k scale.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.train import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    loop = TrainLoopConfig(steps=args.steps, seq_len=args.seq_len,
                           global_batch=args.global_batch,
                           ckpt_dir=args.ckpt_dir, resume=args.resume,
                           ckpt_every=max(args.steps // 4, 10), log_every=5)
    _, _, hist = train("mamba2-130m", loop, smoke=args.quick)
    print(f"loss: {hist[0]:.4f} -> {hist[-1]:.4f} over {len(hist)} steps")
    assert hist[-1] < hist[0], "loss must decrease"


if __name__ == "__main__":
    main()
