"""Gross-Pitaevskii quantum-fluid solver (paper §4 cites this application).

  i dpsi/dt = [ -1/2 lap + V(x) + g |psi|^2 ] psi

Explicit leapfrog on (re, im) — two coupled stencil fields through the same
@parallel engine as the diffusion solver; mass (integral |psi|^2) is the
conservation diagnostic.

    PYTHONPATH=src python examples/gross_pitaevskii.py [--n 48] [--nt 200]
"""
import argparse
import sys

import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core import Grid, FieldSet, fd3d as fd, init_parallel_stencil


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--nt", type=int, default=200)
    ap.add_argument("--g", type=float, default=0.5, help="interaction")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    args = ap.parse_args()

    grid = Grid((args.n,) * 3, (8.0, 8.0, 8.0))
    fs = FieldSet(grid)
    xs = grid.meshgrid()
    c = [l / 2 for l in grid.length]
    r2 = sum((x - ci) ** 2 for x, ci in zip(xs, c))
    V = 0.05 * r2                                  # harmonic trap
    re = jnp.exp(-r2 / 4.0)                        # ground-state-ish blob
    im = fs.zeros()
    norm = jnp.sqrt(jnp.sum(re ** 2 + im ** 2))
    re = re / norm

    inv2 = tuple(1.0 / d ** 2 for d in grid.spacing)
    dt = 0.2 * min(grid.spacing) ** 2              # explicit stability
    ps = init_parallel_stencil(backend=args.backend, ndims=3)

    def H(f, re, im, V, g, _dx2, _dy2, _dz2):
        """(-1/2 lap + V + g|psi|^2) f, on the interior."""
        lap = (fd.d2_xi(f) * _dx2 + fd.d2_yi(f) * _dy2 + fd.d2_zi(f) * _dz2)
        dens = fd.inn(re) ** 2 + fd.inn(im) ** 2
        return -0.5 * lap + (fd.inn(V) + g * dens) * fd.inn(f)

    # symplectic (staggered) Euler: re with current im, im with NEW re —
    # the leapfrog that keeps the Schroedinger flow norm-stable.
    @ps.parallel(outputs=("re2",))
    def step_re(re2, re, im, V, g, dt, _dx2, _dy2, _dz2):
        return {"re2": fd.inn(re) + dt * H(im, re, im, V, g, _dx2, _dy2, _dz2)}

    @ps.parallel(outputs=("im2",))
    def step_im(im2, re, im, V, g, dt, _dx2, _dy2, _dz2):
        return {"im2": fd.inn(im) - dt * H(re, re, im, V, g, _dx2, _dy2, _dz2)}

    mass0 = float(jnp.sum(re ** 2 + im ** 2))
    sc = dict(V=V, g=args.g, dt=dt, _dx2=inv2[0], _dy2=inv2[1], _dz2=inv2[2])
    for it in range(args.nt):
        re = step_re(re2=re, re=re, im=im, **sc)
        im = step_im(im2=im, re=re, im=im, **sc)
    mass = float(jnp.sum(re ** 2 + im ** 2))
    drift = abs(mass - mass0) / mass0
    print(f"GP: {args.nt} steps on {grid.shape} [{args.backend}] "
          f"mass drift {drift:.2e} (explicit scheme, O(dt^2) per step)")
    assert drift < 0.05, "mass not conserved — numerical instability"


if __name__ == "__main__":
    main()
