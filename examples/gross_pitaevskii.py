"""Gross-Pitaevskii quantum-fluid solver (paper §4 cites this application).

  i dpsi/dt = [ -1/2 lap + V(x) + g |psi|^2 ] psi

Explicit symplectic (staggered) Euler on (re, im) — re with the current
im, im with the NEW re: the leapfrog that keeps the Schroedinger flow
norm-stable. Mass (integral |psi|^2) is the conservation diagnostic.

Two formulations through the same ``@parallel`` engine:

  * ``fused=True`` (default): ONE coupled radius-2 launch per step. The
    kernel computes ``re1`` (the new re on the once-shrunk frame) and
    then ``im``'s update from ``re1`` *inside the same window* — the
    whole coupled system crosses HBM once per step, and the
    ``{re2: re, im2: im}`` rotation supports ``run_steps`` temporal
    blocking (k coupled steps per launch).
  * ``fused=False``: the seed's two radius-1 launches (re then im).

The fused coupled kernel declares no ``radius``: the engine's stencil IR
infers the radius-2 footprint from the two-frame symplectic update
itself. ``--bc`` declares per-output boundary conditions fused into the
engine step (default: the seed's frozen boundary ring).

Drift-guard mode (``--tol``): the fused kernel gains ``sum_sq(re2)`` /
``sum_sq(im2)`` reduction epilogues — the mass integral folds inside the
same launch as the update — and ``core.iterate.solve_until(until=
"above")`` iterates on device until the relative mass drift EXCEEDS the
tolerance (numerical instability tripwire) or ``--nt`` steps complete,
with zero host syncs between checks.

    PYTHONPATH=src python examples/gross_pitaevskii.py [--n 48] [--nt 200]
        [--backend jnp|pallas] [--two-launch]
        [--bc none|neumann|dirichlet|periodic]
        [--tol 1e-3] [--check-every 10]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import Grid, fd3d as fd, init_parallel_stencil, iterate
from repro.ir import BoundaryCondition


@dataclasses.dataclass(frozen=True)
class GPConfig:
    n: int = 48
    nt: int = 200
    g: float = 0.5             # interaction strength
    backend: str = "jnp"
    fused: bool = True
    bc: str = "none"           # none | neumann | dirichlet | periodic
    interpret: bool | None = None
    tol: float | None = None   # mass-drift tripwire (None: fixed nt)
    check_every: int = 10      # drift cadence in --tol mode
    checkpoint_dir: str | None = None
    save_every: int = 10       # checkpoint cadence, in checks
    resume: bool = True


def boundary_conditions(cfg: GPConfig) -> dict | None:
    """Per-output BC specs for (re2, im2). ``none`` keeps the seed's
    behavior: the boundary ring of the trap stays frozen at its initial
    (exponentially small) values."""
    if cfg.bc == "none":
        return None
    kinds = {"neumann": lambda: BoundaryCondition("neumann0"),
             "dirichlet": lambda: BoundaryCondition("dirichlet", value=0.0),
             "periodic": lambda: BoundaryCondition("periodic")}
    if cfg.bc not in kinds:
        raise ValueError(f"unknown bc {cfg.bc!r}")
    return {"re2": kinds[cfg.bc](), "im2": kinds[cfg.bc]()}


def make_grid(cfg: GPConfig) -> Grid:
    return Grid((cfg.n,) * 3, (8.0, 8.0, 8.0))


def init_state(cfg: GPConfig):
    """Normalized ground-state-ish blob in a harmonic trap."""
    grid = make_grid(cfg)
    xs = grid.meshgrid()
    c = [l / 2 for l in grid.length]
    r2 = sum((x - ci) ** 2 for x, ci in zip(xs, c))
    V = 0.05 * r2
    re = jnp.exp(-r2 / 4.0)
    im = jnp.zeros_like(re)
    norm = jnp.sqrt(jnp.sum(re ** 2 + im ** 2))
    return grid, re / norm, im, V


def _H(f, re, im, V, g, _dx2, _dy2, _dz2):
    """(-1/2 lap + V + g|psi|^2) f, one frame inward (consumes radius 1)."""
    lap = fd.d2_xi(f) * _dx2 + fd.d2_yi(f) * _dy2 + fd.d2_zi(f) * _dz2
    dens = fd.inn(re) ** 2 + fd.inn(im) ** 2
    return -0.5 * lap + (fd.inn(V) + g * dens) * fd.inn(f)


def make_step(grid: Grid, cfg: GPConfig):
    """Build ``step(re, im, dt) -> (re, im)``; ``step.kernels`` exposes the
    underlying StencilKernel(s) (fused variant supports ``run_steps``)."""
    ps = init_parallel_stencil(backend=cfg.backend, ndims=3,
                               interpret=cfg.interpret)
    bc = boundary_conditions(cfg)

    if cfg.fused:
        # radius omitted: the IR infers the coupled two-frame update's
        # radius-2 footprint from the kernel source.
        @ps.parallel(outputs=("re2", "im2"), bc=bc,
                     rotations={"re2": "re", "im2": "im"})
        def update(re2, im2, re, im, V, g, dt, _dx2, _dy2, _dz2):
            # frame 1: new re everywhere im's stencil will need it
            re1 = fd.inn(re) + dt * _H(im, re, im, V, g, _dx2, _dy2, _dz2)
            im1, V1 = fd.inn(im), fd.inn(V)
            # frame 2: im update from the NEW re (symplectic order)
            return {"re2": fd.inn(re1),
                    "im2": fd.inn(im1)
                           - dt * _H(re1, re1, im1, V1, g, _dx2, _dy2, _dz2)}

        kernels = (update,)

        def raw_step(re, im, V, g, dt, inv2):
            out = update(re2=re, im2=im, re=re, im=im, V=V, g=g, dt=dt,
                         _dx2=inv2[0], _dy2=inv2[1], _dz2=inv2[2])
            return out["re2"], out["im2"]
    else:
        bc_re = None if bc is None else {"re2": bc["re2"]}
        bc_im = None if bc is None else {"im2": bc["im2"]}

        @ps.parallel(outputs=("re2",), bc=bc_re)
        def step_re(re2, re, im, V, g, dt, _dx2, _dy2, _dz2):
            return {"re2": fd.inn(re)
                           + dt * _H(im, re, im, V, g, _dx2, _dy2, _dz2)}

        @ps.parallel(outputs=("im2",), bc=bc_im)
        def step_im(im2, re, im, V, g, dt, _dx2, _dy2, _dz2):
            return {"im2": fd.inn(im)
                           - dt * _H(re, re, im, V, g, _dx2, _dy2, _dz2)}

        kernels = (step_re, step_im)

        def raw_step(re, im, V, g, dt, inv2):
            sc = dict(V=V, g=g, dt=dt, _dx2=inv2[0], _dy2=inv2[1],
                      _dz2=inv2[2])
            re = step_re(re2=re, re=re, im=im, **sc)
            im = step_im(im2=im, re=re, im=im, **sc)
            return re, im

    inv2 = tuple(1.0 / d ** 2 for d in grid.spacing)

    def step(re, im, dt, V):
        return raw_step(re, im, V, cfg.g, dt, inv2)

    step.kernels = kernels
    return step


def timestep(grid: Grid) -> float:
    return 0.2 * min(grid.spacing) ** 2   # explicit stability


def solve_guarded(cfg: GPConfig) -> dict:
    """Device-resident drift-guarded run: the mass integral rides the
    fused launch as ``sum_sq`` epilogues and ``solve_until(until=
    "above")`` stops the on-device loop the moment the relative drift
    exceeds ``cfg.tol`` (instability tripwire) — or after ``cfg.nt``
    steps, whichever first. Zero host syncs between checks."""
    if not cfg.fused:
        raise ValueError(
            "--tol drives the fused coupled kernel; the two-launch scheme "
            "has no single launch to attach the mass epilogue to — drop "
            "--two-launch"
        )
    if cfg.bc == "periodic":
        raise ValueError(
            "--tol needs the fused mass epilogue, which cannot ride a "
            "periodic-bc launch (the wrap scatter runs after it)"
        )
    grid, re, im, V = init_state(cfg)
    dt = timestep(grid)
    kern = make_step(grid, cfg).kernels[0]
    rkern = kern.with_reductions({"m_re": "sum_sq(re2)",
                                  "m_im": "sum_sq(im2)"})
    mass0 = float(jnp.sum(re ** 2 + im ** 2))
    inv2 = tuple(1.0 / d ** 2 for d in grid.spacing)

    def drift_of(reds):
        return jnp.abs((reds["m_re"] + reds["m_im"]) - mass0) / mass0

    ckpt = None
    if cfg.checkpoint_dir is not None:
        ckpt = iterate.Checkpointing(cfg.checkpoint_dir,
                                     save_every=cfg.save_every,
                                     resume=cfg.resume)
    res = iterate.solve_until(
        rkern, dict(re2=re, im2=im, re=re, im=im, V=V),
        dict(g=cfg.g, dt=dt, _dx2=inv2[0], _dy2=inv2[1], _dz2=inv2[2]),
        tol=cfg.tol, max_iters=cfg.nt, check_every=cfg.check_every,
        error=drift_of, until="above", checkpoint=ckpt)
    if res.resumed_from is not None:
        print(f"GP: resumed from checkpoint step {res.resumed_from} "
              f"in {cfg.checkpoint_dir}")
    re, im = res.fields["re"], res.fields["im"]
    mass = float(res.reds["m_re"] + res.reds["m_im"])
    return {"grid": grid, "re": re, "im": im, "V": V,
            "mass0": mass0, "mass": mass, "drift": float(res.err),
            "iters": int(res.iters),
            "tripped": bool(res.err > cfg.tol)}


def solve(cfg: GPConfig = GPConfig()) -> dict:
    if cfg.tol is not None:
        return solve_guarded(cfg)
    grid, re, im, V = init_state(cfg)
    dt = timestep(grid)
    step = jax.jit(make_step(grid, cfg))
    mass0 = float(jnp.sum(re ** 2 + im ** 2))
    for _ in range(cfg.nt):
        re, im = step(re, im, dt, V)
    mass = float(jnp.sum(re ** 2 + im ** 2))
    drift = abs(mass - mass0) / mass0
    return {"grid": grid, "re": re, "im": im, "V": V,
            "mass0": mass0, "mass": mass, "drift": drift,
            "iters": cfg.nt}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--nt", type=int, default=200)
    ap.add_argument("--g", type=float, default=0.5, help="interaction")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--two-launch", action="store_true",
                    help="seed scheme: two radius-1 launches per step")
    ap.add_argument("--bc", default="none",
                    choices=["none", "neumann", "dirichlet", "periodic"],
                    help="boundary condition fused into the engine step")
    ap.add_argument("--tol", type=float, default=None,
                    help="mass-drift tripwire: iterate on device until the "
                         "relative drift exceeds tol (fused sum_sq checks, "
                         "zero host syncs); --nt becomes the step cap")
    ap.add_argument("--check-every", type=int, default=10,
                    help="drift cadence (steps per check) in --tol mode")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for atomic async checkpoints of the "
                         "--tol guarded run (restartable: see --resume)")
    ap.add_argument("--save-every", type=int, default=10,
                    help="checkpoint cadence in CHECKS (default 10)")
    ap.add_argument("--resume", dest="resume", action="store_true",
                    default=True,
                    help="resume from the LATEST checkpoint (default)")
    ap.add_argument("--no-resume", dest="resume", action="store_false",
                    help="ignore existing checkpoints; start fresh")
    args = ap.parse_args(argv)
    if args.checkpoint_dir is not None and args.tol is None:
        ap.error("--checkpoint-dir requires --tol (checkpoints ride the "
                 "drift-guarded solve loop)")
    cfg = GPConfig(n=args.n, nt=args.nt, g=args.g, backend=args.backend,
                   fused=not args.two_launch, bc=args.bc, tol=args.tol,
                   check_every=args.check_every,
                   checkpoint_dir=args.checkpoint_dir,
                   save_every=args.save_every, resume=args.resume)
    r = solve(cfg)
    print(f"GP: {r['iters']} steps on {r['grid'].shape} [{cfg.backend}"
          f"{'/fused' if cfg.fused else '/two-launch'}] "
          f"mass drift {r['drift']:.2e} (explicit scheme, O(dt^2) per step)")
    if cfg.tol is not None:
        status = ("TRIPPED: drift crossed tol — instability caught on "
                  "device" if r["tripped"] else "drift stayed under tol")
        print(f"GP drift guard: {status} after {r['iters']} steps "
              f"(tol={cfg.tol:g})")
    else:
        assert r["drift"] < 0.05, "mass not conserved — numerical instability"


if __name__ == "__main__":
    main()
