"""Quickstart: the paper's Fig. 1 — 3-D heat diffusion, math-close notation.

    PYTHONPATH=src python examples/quickstart.py [--n 64] [--nt 50] \
        [--backend pallas|jnp]

One kernel source runs on every backend (the xPU property): `pallas` is the
TPU kernel (interpret-mode on CPU), `jnp` is the XLA-fused path.
"""
import argparse
import sys

import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core import Grid, FieldSet, fd3d as fd, init_parallel_stencil, \
    solve_until
from repro.core.teff import a_eff, measure, t_eff
from repro.data.physics import gaussian_hotspot


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--nt", type=int, default=50)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    args = ap.parse_args()

    # Physics (paper Fig. 1 lines 14-18)
    lam, c0 = 1.0, 2.0
    grid = Grid((args.n,) * 3, (1.0, 1.0, 1.0))

    # Initial conditions (lines 27-31)
    fs = FieldSet(grid)
    T = fs.from_fn(lambda x, y, z: 1.7 + gaussian_hotspot(grid) * 0)
    T = T + gaussian_hotspot(grid, amplitude=1.0, width=0.1)
    T2 = T.copy()
    Ci = fs.ones() / c0

    # Time step (line 33)
    dt = grid.stable_diffusion_dt(lam / c0)
    _dx, _dy, _dz = grid.inv_spacing

    ps = init_parallel_stencil(backend=args.backend, dtype="float32", ndims=3)

    # the paper's @parallel macro (line 5); rotations name the T2->T
    # double buffer so fused multi-step / convergence drivers can rotate
    @ps.parallel(outputs=("T2",), rotations={"T2": "T"})
    def step(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx ** 2 + fd.d2_yi(T) * _dy ** 2 +
            fd.d2_zi(T) * _dz ** 2))}

    # Time loop (lines 34-37)
    for it in range(args.nt):
        T2 = step(T2=T2, T=T, Ci=Ci, lam=lam, dt=dt, _dx=_dx, _dy=_dy, _dz=_dz)
        T, T2 = T2, T

    print(f"done: {args.nt} steps on {grid.shape} [{args.backend}] "
          f"T in [{float(T.min()):.4f}, {float(T.max()):.4f}]")

    # T_eff (paper's metric): 2 reads + 1 write per step
    m = measure(lambda: step(T2=T2, T=T, Ci=Ci, lam=lam, dt=dt,
                             _dx=_dx, _dy=_dy, _dz=_dz), iters=5, warmup=2)
    A = a_eff(grid.n_points, 2, 1, 4)
    print(f"T_eff = {t_eff(A, m.median_s)/1e9:.2f} GB/s "
          f"(median {m.median_s*1e3:.2f} ms)")

    # Convergence-driven: the SAME kernel with a fused error epilogue —
    # max|T2-T| folds inside the launch (no second pass) and the whole
    # iteration runs on device in one lax.while_loop (no host syncs).
    conv = step.with_reductions({"err": "max_abs_diff(T2, T)"})
    res = solve_until(conv, dict(T2=T2, T=T, Ci=Ci),
                      dict(lam=lam, dt=dt, _dx=_dx, _dy=_dy, _dz=_dz),
                      tol=1e-7, max_iters=10 * args.nt, check_every=10)
    print(f"solve_until: steady in {int(res.iters)} steps "
          f"(max|dT| = {float(res.err):.2e})")


if __name__ == "__main__":
    main()
