"""Batched serving demo: prefill + jitted single-token decode loop.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m \
        --batch 4 --prompt-len 64 --gen-len 64 [--quick]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import ServeConfig, serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    gen, stats = serve(args.arch,
                       ServeConfig(batch=args.batch, prompt_len=args.prompt_len,
                                   gen_len=args.gen_len,
                                   temperature=args.temperature),
                       smoke=args.quick)
    print(f"generated {gen.shape} tokens; {stats['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
