"""Example solvers (importable modules with thin CLIs).

Each solver exposes ``make_step``/``solve`` so tests and benchmarks can
drive the exact physics the CLI runs; ``python examples/<name>.py`` stays
the demo entry point (with ``PYTHONPATH=src``).
"""
