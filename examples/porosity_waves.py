"""Reactive porosity waves — the paper §3's second translated solver family.

Pseudo-transient two-field compaction model (Raess et al. 2022 [5], 2-D):

    q         = -k(phi) (grad(Pe) - rho_g)      Darcy flux (staggered)
    dPe/dtau  = -(div q + Pe/eta)               effective pressure
    dphi/dtau = -(1 - phi) Pe/eta               porosity

A buoyant porosity anomaly focuses into an ascending wave. The coupled
(phi, Pe) update runs as ONE fused stencil launch through ``@parallel``
on either backend; staggered-grid fluxes use the ``d_xa``/``av_xa``
operators. Two equivalent formulations are provided:

  * ``flux_split=False`` (default): the face fluxes are intermediates
    inside the single coupled kernel — one launch per time step.
  * ``flux_split=True``: the fluxes are explicit *face-centered fields*
    (``qx``: (nx-1, ny), ``qy``: (nx, ny-1)) produced by a staggered
    ``@all``-write kernel and consumed, mixed-shape, by the cell update —
    the two-launch scheme that exercises the engine's staggered-field
    support end-to-end. Both produce identical physics.

Stencil geometry is *inferred*: no ``radius`` is declared anywhere — the
engine traces the update once and derives the (phi, Pe) footprint and
the staggered flux offsets itself. Boundary conditions are declared per
output (``--bc``) and fused into the engine's step (bitwise-equal to the
seed's explicit ``neumann0`` post-pass).

Convergence-driven mode (``--tol``): the pseudo-transient iteration runs
to *steady state* instead of a fixed step count — the coupled kernel
gains a fused ``max_abs_diff(Pe2, Pe)`` reduction epilogue (the residual
folds inside the same launch as the update; no separate norm pass) and
``core.iterate.solve_until`` drives the loop on device with a
``lax.while_loop``: zero host syncs between checks, ``--nt`` becomes the
iteration cap.

    PYTHONPATH=src python examples/porosity_waves.py [--n 128] [--nt 500]
        [--backend jnp|pallas] [--flux-split]
        [--bc neumann|dirichlet|periodic]
        [--tol 1e-6] [--check-every 10]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import Grid, fd2d as fd, init_parallel_stencil, iterate
from repro.ir import BoundaryCondition


@dataclasses.dataclass(frozen=True)
class PorosityConfig:
    n: int = 128
    nt: int = 500
    npow: float = 3.0          # permeability exponent, k ~ phi^n
    phi0: float = 0.01         # background porosity
    dphi: float = 0.1          # relative anomaly amplitude
    eta: float = 1.0           # compaction viscosity
    rho_g: float = 30.0        # buoyancy contrast
    backend: str = "jnp"
    dtype: str = "float32"     # field STORAGE dtype; compute stays f32
    flux_split: bool = False
    bc: str = "neumann"        # neumann | dirichlet | periodic | none
    interpret: bool | None = None
    tol: float | None = None   # steady-state residual (None: fixed nt)
    check_every: int = 10      # residual cadence in --tol mode
    checkpoint_dir: str | None = None  # survivable --tol solves
    save_every: int = 10       # checks between checkpoints
    resume: bool = True        # restore from LATEST when present


def boundary_conditions(cfg: PorosityConfig) -> dict | None:
    """Per-output BC specs routed through the engine's fused path.

    ``neumann`` reproduces the seed's zero-flux post-pass; ``dirichlet``
    pins the faces to the far-field state (phi0, zero overpressure);
    ``none`` freezes the initial boundary ring (raw ``@inn`` semantics,
    the reference the parity tests post-process by hand).
    """
    if cfg.bc == "none":
        return None
    if cfg.bc == "neumann":
        return {"phi2": BoundaryCondition("neumann0"),
                "Pe2": BoundaryCondition("neumann0")}
    if cfg.bc == "dirichlet":
        return {"phi2": BoundaryCondition("dirichlet", value=cfg.phi0),
                "Pe2": BoundaryCondition("dirichlet", value=0.0)}
    if cfg.bc == "periodic":
        return {"phi2": BoundaryCondition("periodic"),
                "Pe2": BoundaryCondition("periodic")}
    raise ValueError(f"unknown bc {cfg.bc!r}")


def make_grid(cfg: PorosityConfig) -> Grid:
    return Grid((cfg.n, cfg.n), (10.0, 10.0))


def init_state(cfg: PorosityConfig):
    """Gaussian porosity anomaly low in the domain, zero overpressure."""
    grid = make_grid(cfg)
    x, y = grid.meshgrid()
    phi = cfg.phi0 + cfg.dphi * cfg.phi0 * jnp.exp(
        -((x - 5.0) ** 2 + (y - 2.0) ** 2) / 0.5)
    # storage rounding happens once, here — every later step computes in
    # f32 and rounds only on store (see README "Mixed precision")
    phi = phi.astype(jnp.dtype(cfg.dtype))
    Pe = jnp.zeros_like(phi)
    return grid, phi, Pe


def timestep(cfg: PorosityConfig, grid: Grid) -> float:
    dx, dy = grid.spacing
    return 0.1 * min(dx, dy) ** 2 / (cfg.phi0 ** cfg.npow * 4) * cfg.phi0 ** cfg.npow


def make_step(grid: Grid, cfg: PorosityConfig):
    """Build ``step(phi, Pe, dtau) -> (phi, Pe)``.

    The returned callable advances one pseudo-time step: the coupled
    stencil launch(es) followed by zero-flux boundaries. Its ``kernels``
    attribute exposes the underlying :class:`StencilKernel`s (the fused
    variant supports ``run_steps`` temporal blocking with the
    ``{phi2: phi, Pe2: Pe}`` double-buffer rotation).
    """
    dx, dy = grid.spacing
    phi0, npow, eta, rho_g = cfg.phi0, cfg.npow, cfg.eta, cfg.rho_g
    bc = boundary_conditions(cfg)
    ps = init_parallel_stencil(backend=cfg.backend, dtype=cfg.dtype,
                               ndims=2, interpret=cfg.interpret)

    if not cfg.flux_split:
        @ps.parallel(outputs=("phi2", "Pe2"),
                     rotations={"phi2": "phi", "Pe2": "Pe"}, bc=bc)
        def update(phi2, Pe2, phi, Pe, dtau):
            k = (phi / phi0) ** npow
            # staggered Darcy fluxes (x-faces / y-faces), in-kernel
            qx = -fd.av_xa(k) * fd.d_xa(Pe) / dx
            qy = -fd.av_ya(k) * (fd.d_ya(Pe) / dy
                                 - rho_g * (fd.av_ya(phi) - phi0))
            div_q = fd.d_xa(qx[:, 1:-1]) / dx + fd.d_ya(qy[1:-1, :]) / dy
            Pe_new = fd.inn(Pe) + dtau * (-(div_q + fd.inn(Pe) / eta))
            phi_new = fd.inn(phi) + dtau * (-(1.0 - fd.inn(phi)) * Pe_new / eta)
            return {"phi2": phi_new, "Pe2": Pe_new}

        def step(phi, Pe, dtau):
            out = update(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe, dtau=dtau)
            return out["phi2"], out["Pe2"]

        step.kernels = (update,)
        return step

    # Flux-split scheme: explicit face-centered flux fields. `fluxes`
    # writes its staggered outputs at full extent (`@all` semantics);
    # `update` consumes them mixed-shape next to the cell fields.
    @ps.parallel(outputs=("qx", "qy"))
    def fluxes(qx, qy, phi, Pe):
        k = (phi / phi0) ** npow
        return {"qx": -fd.av_xa(k) * fd.d_xa(Pe) / dx,
                "qy": -fd.av_ya(k) * (fd.d_ya(Pe) / dy
                                      - rho_g * (fd.av_ya(phi) - phi0))}

    @ps.parallel(outputs=("phi2", "Pe2"), bc=bc)
    def update(phi2, Pe2, phi, Pe, qx, qy, dtau):
        div_q = fd.d_xa(qx[:, 1:-1]) / dx + fd.d_ya(qy[1:-1, :]) / dy
        Pe_new = fd.inn(Pe) + dtau * (-(div_q + fd.inn(Pe) / eta))
        phi_new = fd.inn(phi) + dtau * (-(1.0 - fd.inn(phi)) * Pe_new / eta)
        return {"phi2": phi_new, "Pe2": Pe_new}

    nx, ny = grid.shape
    qx0 = jnp.zeros((nx - 1, ny), jnp.dtype(cfg.dtype))
    qy0 = jnp.zeros((nx, ny - 1), jnp.dtype(cfg.dtype))

    def step(phi, Pe, dtau):
        q = fluxes(qx=qx0, qy=qy0, phi=phi, Pe=Pe)
        out = update(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe,
                     qx=q["qx"], qy=q["qy"], dtau=dtau)
        return out["phi2"], out["Pe2"]

    step.kernels = (fluxes, update)
    return step


def solve_steady(cfg: PorosityConfig, grid: Grid, phi, Pe) -> tuple:
    """Device-resident steady-state drive from the given initial state:
    iterate the coupled kernel until ``max|Pe2 - Pe| < cfg.tol``
    (checked every ``cfg.check_every`` sweeps through the fused
    reduction epilogue — the residual never costs a second whole-array
    pass or a host round-trip), capped at ``cfg.nt`` sweeps. Returns
    (phi, Pe, iters, err)."""
    if cfg.flux_split:
        raise ValueError(
            "--tol drives the fused coupled kernel; the flux-split scheme "
            "splits the update over two launches and has no single kernel "
            "to attach the residual to — drop --flux-split"
        )
    if cfg.bc == "periodic":
        raise ValueError(
            "--tol needs the fused residual epilogue, which cannot ride a "
            "periodic-bc launch (the wrap scatter runs after it); use "
            "--bc neumann or dirichlet"
        )
    dtau = timestep(cfg, grid)
    kern = make_step(grid, cfg).kernels[0]
    rkern = kern.with_reductions({"err": "max_abs_diff(Pe2, Pe)"})
    ckpt = None
    if cfg.checkpoint_dir is not None:
        # survivable solve: async atomic checkpoints of the carry every
        # save_every checks; a killed run restarted with the same flags
        # resumes from LATEST (see README "Fault tolerance")
        ckpt = iterate.Checkpointing(cfg.checkpoint_dir,
                                     save_every=cfg.save_every,
                                     resume=cfg.resume)
    res = iterate.solve_until(
        rkern, dict(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe), dict(dtau=dtau),
        tol=cfg.tol, max_iters=cfg.nt, check_every=cfg.check_every,
        checkpoint=ckpt)
    if res.resumed_from is not None:
        print(f"porosity wave: resumed from checkpoint step "
              f"{res.resumed_from} in {cfg.checkpoint_dir}")
    # rotation targets hold the newest state after the in-loop rotation
    return res.fields["phi"], res.fields["Pe"], int(res.iters), \
        float(res.err)


def solve(cfg: PorosityConfig = PorosityConfig()) -> dict:
    """Run ``cfg.nt`` pseudo-time steps (or, with ``cfg.tol``, iterate on
    device until steady state); returns fields + diagnostics."""
    iters, err = cfg.nt, None
    grid, phi, Pe = init_state(cfg)
    peak0_y = float(jnp.argmax(jnp.max(phi, axis=0)))
    if cfg.tol is not None:
        phi, Pe, iters, err = solve_steady(cfg, grid, phi, Pe)
    else:
        dtau = timestep(cfg, grid)
        step = jax.jit(make_step(grid, cfg))
        for it in range(cfg.nt):
            phi, Pe = step(phi, Pe, dtau)
            if (it + 1) % 50 == 0 and not bool(jnp.isfinite(phi).all()):
                raise FloatingPointError(f"diverged at step {it}")
    if not bool(jnp.isfinite(phi).all()):
        raise FloatingPointError(f"diverged by step {cfg.nt}")
    dy = grid.spacing[1]
    peak_y = float(jnp.argmax(jnp.max(phi, axis=0)))
    return {
        "grid": grid,
        "phi": phi,
        "Pe": Pe,
        "phi_min": float(phi.min()),
        "phi_max": float(phi.max()),
        "pe_absmax": float(jnp.abs(Pe).max()),
        "peak0_y": peak0_y * dy,
        "peak_y": peak_y * dy,
        "iters": iters,
        "residual": err,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--nt", type=int, default=500)
    ap.add_argument("--npow", type=float, default=3.0, help="k ~ phi^n")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16", "float16"],
                    help="field storage dtype (stencil arithmetic stays "
                         "f32; bf16/f16 halve the bytes every sweep moves)")
    ap.add_argument("--flux-split", action="store_true",
                    help="explicit staggered flux fields (two launches)")
    ap.add_argument("--bc", default="neumann",
                    choices=["neumann", "dirichlet", "periodic"],
                    help="boundary condition fused into the engine step")
    ap.add_argument("--tol", type=float, default=None,
                    help="steady-state residual: iterate on device until "
                         "max|dPe| < tol (fused check, zero host syncs); "
                         "--nt becomes the iteration cap")
    ap.add_argument("--check-every", type=int, default=10,
                    help="residual cadence (steps per check) in --tol mode")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for atomic async checkpoints of the "
                         "--tol solve (restartable: see --resume)")
    ap.add_argument("--save-every", type=int, default=10,
                    help="checkpoint cadence in CHECKS (default 10: one "
                         "save per 10 residual checks)")
    ap.add_argument("--resume", dest="resume", action="store_true",
                    default=True,
                    help="resume from the LATEST checkpoint when one "
                         "exists (default)")
    ap.add_argument("--no-resume", dest="resume", action="store_false",
                    help="ignore existing checkpoints; start fresh")
    args = ap.parse_args(argv)
    if args.checkpoint_dir is not None and args.tol is None:
        ap.error("--checkpoint-dir requires --tol (checkpoints ride the "
                 "convergence-driven solve loop)")
    cfg = PorosityConfig(n=args.n, nt=args.nt, npow=args.npow,
                         backend=args.backend, dtype=args.dtype,
                         flux_split=args.flux_split,
                         bc=args.bc, tol=args.tol,
                         check_every=args.check_every,
                         checkpoint_dir=args.checkpoint_dir,
                         save_every=args.save_every, resume=args.resume)
    r = solve(cfg)
    steps = (f"{r['iters']} steps (tol={cfg.tol:g}, "
             f"residual={r['residual']:.2e})" if cfg.tol is not None
             else f"{cfg.nt} steps")
    print(f"porosity wave: {steps} on {r['grid'].shape} "
          f"[{cfg.backend}{'/flux-split' if cfg.flux_split else ''}"
          f"/bc={cfg.bc}]; "
          f"phi in [{r['phi_min']:.4f}, {r['phi_max']:.4f}]; "
          f"anomaly y: {r['peak0_y']:.2f} -> {r['peak_y']:.2f} (ascending)")


if __name__ == "__main__":
    main()
