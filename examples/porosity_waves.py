"""Reactive porosity waves — the paper §3's second translated solver family.

Pseudo-transient two-field compaction model (Raess et al. 2022 [5], 2-D):

    q         = -k(phi) (grad(Pe) - rho_g)      Darcy flux (staggered)
    dPe/dtau  = -(div q + Pe/eta)               effective pressure
    dphi/dtau = -(1 - phi) Pe/eta               porosity

A buoyant porosity anomaly focuses into an ascending wave. Staggered-grid
fluxes use the d_xa/av_xa operators (the jnp backend supports mixed-shape
staggered fields; pallas path covers collocated kernels — DESIGN.md).

    PYTHONPATH=src python examples/porosity_waves.py [--n 128] [--nt 500]
"""
import argparse
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core import Grid, fd2d as fd
from repro.core.boundary import neumann0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--nt", type=int, default=500)
    ap.add_argument("--npow", type=float, default=3.0, help="k ~ phi^n")
    args = ap.parse_args()

    n = args.n
    grid = Grid((n, n), (10.0, 10.0))
    dx, dy = grid.spacing
    x, y = grid.meshgrid()
    phi0, dphi = 0.01, 0.1
    phi = phi0 + dphi * phi0 * jnp.exp(
        -((x - 5.0) ** 2 + (y - 2.0) ** 2) / 0.5)
    Pe = jnp.zeros_like(phi)
    eta, rho_g = 1.0, 30.0
    dtau = 0.1 * min(dx, dy) ** 2 / (phi0 ** args.npow * 4) * phi0 ** args.npow

    @jax.jit
    def step(phi, Pe):
        k = (phi / phi0) ** args.npow
        # staggered Darcy fluxes (x-faces / y-faces)
        kx = fd.av_xa(k)
        ky = fd.av_ya(k)
        qx = -kx * fd.d_xa(Pe) / dx
        qy = -ky * (fd.d_ya(Pe) / dy - rho_g * (fd.av_ya(phi) - phi0))
        div_q = fd.d_xa(qx[:, 1:-1]) / dx + fd.d_ya(qy[1:-1, :]) / dy
        dPe = -(div_q + fd.inn(Pe) / eta)
        Pe = Pe.at[grid.interior_slice].add(dtau * dPe)
        Pe = neumann0(Pe)
        dphi_ = -(1.0 - fd.inn(phi)) * fd.inn(Pe) / eta
        phi = phi.at[grid.interior_slice].add(dtau * dphi_)
        phi = neumann0(phi)
        return phi, Pe

    peak0_y = float(jnp.argmax(jnp.max(phi, axis=0)))
    for it in range(args.nt):
        phi, Pe = step(phi, Pe)
        if not bool(jnp.isfinite(phi).all()):
            raise SystemExit(f"diverged at step {it}")
    peak_y = float(jnp.argmax(jnp.max(phi, axis=0)))
    print(f"porosity wave: {args.nt} steps on {grid.shape}; "
          f"phi in [{float(phi.min()):.4f}, {float(phi.max()):.4f}]; "
          f"anomaly y: {peak0_y * dy:.2f} -> {peak_y * dy:.2f} (ascending)")


if __name__ == "__main__":
    main()
