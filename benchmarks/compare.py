"""Perf-regression guard over the BENCH_teff_*.json trajectory.

The benchmark records were append-only JSON with no reader; this closes
the loop: the newest record's rows are diffed against the most recent
older record that shares the same row key (``name``, grid size ``n``,
``nsteps``) and a compatible ``_meta.py`` stamp (same jax backend — a
CPU record is never judged against a TPU one), and any per-step-time
regression beyond the threshold fails the run.

    PYTHONPATH=src python benchmarks/compare.py            # scan cwd
    PYTHONPATH=src python benchmarks/compare.py OLD NEW    # explicit pair
    ... [--threshold 0.15] [--dir PATH] [--pattern GLOB]

Records written before the provenance stamp existed (no ``meta`` block)
sort as oldest and are only used as baselines, with a warning. Exit
status: 1 on any regression beyond threshold, else 0 ("no comparable
rows" is a clean pass — a fresh machine has no trajectory yet).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load(path: str) -> dict:
    with open(path) as f:
        d = json.load(f)
    d["_path"] = path
    return d


def row_key(row: dict) -> tuple:
    return (row.get("name"), row.get("n"), row.get("nsteps"))


SKIP_SUBSTRINGS = ("broadcast",)   # unjitted didactic baselines: pure noise


def record_rows(rec: dict) -> dict:
    return {row_key(r): r for r in rec.get("rows", [])
            if "per_step_s" in r
            and not any(s in str(r.get("name")) for s in SKIP_SUBSTRINGS)}


def meta_compatible(old: dict, new: dict) -> tuple[bool, str]:
    mo, mn = old.get("meta"), new.get("meta")
    if mo is None:
        return True, "baseline predates provenance stamps; comparing anyway"
    if mo.get("backend") != (mn or {}).get("backend"):
        return False, (f"backend mismatch ({mo.get('backend')} vs "
                       f"{(mn or {}).get('backend')})")
    ho, hn = mo.get("hostname"), (mn or {}).get("hostname")
    if ho and hn and ho != hn:
        # wall-time deltas across machines are not regressions
        return False, f"different hosts ({ho} vs {hn})"
    note = ""
    if mo.get("jax_version") != (mn or {}).get("jax_version"):
        note = (f"jax {mo.get('jax_version')} -> "
                f"{(mn or {}).get('jax_version')}")
    return True, note


def sort_stamp(rec: dict) -> str:
    # records without a meta block predate the stamp: sort oldest
    return (rec.get("meta") or {}).get("timestamp_utc", "")


def compare(old: dict, new: dict, threshold: float) -> list[str]:
    """Regression lines (empty = pass) for rows shared by two records."""
    ok, note = meta_compatible(old, new)
    if not ok:
        print(f"# skip {old['_path']} vs {new['_path']}: {note}")
        return []
    if note:
        print(f"# note: {note}")
    failures = []
    orows, nrows = record_rows(old), record_rows(new)
    for key in sorted(set(orows) & set(nrows), key=str):
        t_old = float(orows[key]["per_step_s"])
        t_new = float(nrows[key]["per_step_s"])
        ratio = t_new / t_old if t_old else float("inf")
        status = "OK" if ratio <= 1.0 + threshold else "REGRESSION"
        print(f"{status} {key}: {t_old*1e6:.1f}us -> {t_new*1e6:.1f}us "
              f"({ratio:.2f}x)")
        if status != "OK":
            failures.append(f"{key}: {ratio:.2f}x slower "
                            f"({old['_path']} -> {new['_path']})")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="explicit OLD NEW pair; default scans --dir")
    ap.add_argument("--dir", default=".")
    ap.add_argument("--pattern", default="BENCH_teff*.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="allowed per-step slowdown fraction (default 15%%)")
    args = ap.parse_args(argv)

    if args.files:
        if len(args.files) != 2:
            ap.error("pass exactly two files (OLD NEW) or none")
        failures = compare(load(args.files[0]), load(args.files[1]),
                           args.threshold)
    else:
        paths = sorted(glob.glob(os.path.join(args.dir, args.pattern)))
        recs = sorted((load(p) for p in paths), key=sort_stamp)
        if len(recs) < 2:
            print(f"# {len(recs)} record(s) matching {args.pattern!r} in "
                  f"{args.dir!r}: nothing to compare")
            return 0
        newest = recs[-1]
        failures = []
        # walk older records newest-first until one shares a row key
        for old in reversed(recs[:-1]):
            if set(record_rows(old)) & set(record_rows(newest)):
                failures = compare(old, newest, args.threshold)
                break
        else:
            print("# no older record shares a row key with "
                  f"{newest['_path']}: nothing to compare")
    if failures:
        print("\nFAIL: per-step regression beyond "
              f"{args.threshold:.0%}:\n  " + "\n  ".join(failures))
        return 1
    print("# perf trajectory OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
