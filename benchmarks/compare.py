"""Perf-regression guard over the BENCH_*.json trajectories.

The benchmark records were append-only JSON with no reader; this closes
the loop for every record family: within each scanned group
(``BENCH_teff*.json``, ``BENCH_solvers*.json``, ``BENCH_scaling*.json``
and ``BENCH_serve*.json`` by default), the
newest record's rows are diffed against the most recent older record
that shares the same row key and a compatible ``_meta.py`` stamp (same
jax backend — a CPU record is never judged against a TPU one), and any
per-step-time regression beyond the threshold fails the run.

Row keys: teff records key by (``name``, grid size ``n``, ``nsteps``,
storage ``dtype`` — absent on pre-mixed-precision rows, so old baselines
keep matching; the ``BENCH_teff_mixed_*.json`` family rides the same
``BENCH_teff*.json`` glob and is guarded per dtype);
solver records (nested dicts) key by (solver, variant, n) — e.g.
``("porosity", "jnp", 64)``, ``("gp", "fused_k2", 32)``;
serve records (``kind: "serve"``) key by (mode, n, requests, max_batch)
on per-SOLVE seconds — e.g. ``("batched", 16, 16, 8)``. Interpret-mode
``pallas`` solver timings are skipped (correctness-path records, pure
noise), as are the unjitted ``broadcast`` teff baselines.

    PYTHONPATH=src python benchmarks/compare.py            # scan cwd
    PYTHONPATH=src python benchmarks/compare.py OLD NEW    # explicit pair
    ... [--threshold 0.15] [--dir PATH] [--pattern GLOB]

Records written before the provenance stamp existed (no ``meta`` block)
sort as oldest and are only used as baselines, with a warning. Exit
status: 1 on any regression beyond threshold, else 0 ("no comparable
rows" is a clean pass — a fresh machine has no trajectory yet).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


class BenchRecordError(Exception):
    """A bench record could not be read — pointed notice, not a
    traceback (a missing or torn record is an operator message, not a
    crash)."""


def load(path: str) -> dict:
    try:
        with open(path) as f:
            d = json.load(f)
    except OSError as e:
        raise BenchRecordError(f"cannot read bench record {path!r}: "
                               f"{e.strerror or e}") from None
    except json.JSONDecodeError as e:
        raise BenchRecordError(f"bench record {path!r} is not valid JSON "
                               f"({e}) — torn write? delete or re-run the "
                               "benchmark") from None
    if not isinstance(d, dict):
        raise BenchRecordError(f"bench record {path!r} is not a JSON object")
    d["_path"] = path
    return d


def row_key(row: dict) -> tuple:
    return (row.get("name"), row.get("n"), row.get("nsteps"),
            row.get("dtype"))


SKIP_SUBSTRINGS = ("broadcast",)   # unjitted didactic baselines: pure noise


def teff_rows(rec: dict) -> dict:
    return {row_key(r): float(r["per_step_s"])
            for r in rec.get("rows", [])
            if isinstance(r, dict) and "per_step_s" in r
            and not any(s in str(r.get("name")) for s in SKIP_SUBSTRINGS)}


def solver_rows(rec: dict) -> dict:
    """Flatten a BENCH_solvers record (nested per-solver dicts) into
    ``(solver, variant, n) -> per-step microseconds``. Interpret-mode
    pallas timings are excluded: on non-TPU hosts they are correctness-
    path records whose wall time says nothing about the engine."""
    rows: dict = {}
    r = rec.get("rows")
    if not isinstance(r, dict):
        return rows
    for solver, key in (("porosity", "porosity_coupled"),
                        ("gp", "gp_coupled")):
        d = r.get(key)
        if not isinstance(d, dict):
            continue
        n = d.get("n")
        for variant in ("jnp", "two_launch"):
            if f"{variant}_us" in d:
                rows[(solver, variant, n)] = float(d[f"{variant}_us"]) / 1e6
        t = d.get("temporal") or {}
        if "fused_per_step_us" in t:
            k = t.get("nsteps")
            rows[(solver, f"fused_k{k}", n)] = \
                float(t["fused_per_step_us"]) / 1e6
            rows[(solver, f"seq_k{k}", n)] = \
                float(t["sequential_per_step_us"]) / 1e6
        mrow = d.get("march") or {}
        if "jnp_us" in mrow:
            rows[(solver, f"march{mrow.get('axis')}_jnp", n)] = \
                float(mrow["jnp_us"]) / 1e6
    for solver in ("diffusion", "gp"):
        d = r.get(solver) or {}
        if "framework_us" in d:
            rows[(f"{solver}_translation", "framework", 0)] = \
                float(d["framework_us"]) / 1e6
    return rows


def serve_rows(rec: dict) -> dict:
    """Flatten a BENCH_serve record into ``(mode, n, requests,
    max_batch) -> per-solve seconds`` — the serving layer's analogue of
    per-step time, so the same threshold guards it."""
    return {(r.get("name"), r.get("n"), r.get("requests"),
             r.get("max_batch")): float(r["per_solve_s"])
            for r in rec.get("rows", [])
            if isinstance(r, dict) and "per_solve_s" in r}


def record_rows(rec: dict) -> dict:
    """Row-key -> per-step time for any record family (auto-detected:
    serve records carry kind="serve", teff records a rows LIST, solver
    records a rows DICT)."""
    if rec.get("kind") == "serve":
        return serve_rows(rec)
    if isinstance(rec.get("rows"), dict):
        return solver_rows(rec)
    return teff_rows(rec)


def meta_compatible(old: dict, new: dict) -> tuple[bool, str]:
    mo, mn = old.get("meta"), new.get("meta")
    if mo is None:
        return True, "baseline predates provenance stamps; comparing anyway"
    if mo.get("backend") != (mn or {}).get("backend"):
        return False, (f"backend mismatch ({mo.get('backend')} vs "
                       f"{(mn or {}).get('backend')})")
    ho, hn = mo.get("hostname"), (mn or {}).get("hostname")
    if ho and hn and ho != hn:
        # wall-time deltas across machines are not regressions
        return False, f"different hosts ({ho} vs {hn})"
    note = ""
    if mo.get("jax_version") != (mn or {}).get("jax_version"):
        note = (f"jax {mo.get('jax_version')} -> "
                f"{(mn or {}).get('jax_version')}")
    return True, note


def sort_stamp(rec: dict) -> str:
    # records without a meta block predate the stamp: sort oldest
    return (rec.get("meta") or {}).get("timestamp_utc", "")


def compare(old: dict, new: dict, threshold: float,
            keys=None) -> list[str]:
    """Regression lines (empty = pass) for rows shared by two records
    (restricted to ``keys`` when given)."""
    ok, note = meta_compatible(old, new)
    if not ok:
        print(f"# skip {old['_path']} vs {new['_path']}: {note}")
        return []
    if note:
        print(f"# note: {note}")
    failures = []
    orows, nrows = record_rows(old), record_rows(new)
    shared = set(orows) & set(nrows)
    if keys is not None:
        shared &= set(keys)
    for key in sorted(shared, key=str):
        t_old = orows[key]
        t_new = nrows[key]
        ratio = t_new / t_old if t_old else float("inf")
        status = "OK" if ratio <= 1.0 + threshold else "REGRESSION"
        print(f"{status} {key}: {t_old*1e6:.1f}us -> {t_new*1e6:.1f}us "
              f"({ratio:.2f}x)")
        if status != "OK":
            failures.append(f"{key}: {ratio:.2f}x slower "
                            f"({old['_path']} -> {new['_path']})")
    return failures


def scan_group(dirname: str, pattern: str, threshold: float) -> list[str]:
    """Newest-per-ROW-KEY comparison within one record family.

    Every row key is guarded at its newest occurrence against its most
    recent older baseline — so a freshly committed record that happens
    to share no keys with anything (e.g. a checks-only run) cannot
    shadow the rest of the group's trajectory the way a newest-RECORD
    scan would."""
    paths = sorted(glob.glob(os.path.join(dirname, pattern)))
    recs = []
    for p in paths:
        try:
            recs.append(load(p))
        except BenchRecordError as e:
            print(f"# skip: {e}")
    recs.sort(key=sort_stamp)
    if len(recs) < 2:
        print(f"# {len(recs)} readable record(s) matching {pattern!r} in "
              f"{dirname!r}: nothing to compare")
        return []
    failures: list[str] = []
    guarded: set = set()       # keys whose newest occurrence was handled
    compared = 0
    for i in range(len(recs) - 1, 0, -1):
        new = recs[i]
        pending = set(record_rows(new)) - guarded
        for old in reversed(recs[:i]):
            if not pending:
                break
            shared = set(record_rows(old)) & pending
            if not shared:
                continue
            if not meta_compatible(old, new)[0]:
                continue  # keep looking older for a compatible baseline
            failures += compare(old, new, threshold, keys=shared)
            compared += len(shared)
            pending -= shared
        guarded |= set(record_rows(new))
    if not compared:
        print(f"# no record pair matching {pattern!r} shares a row key: "
              "nothing to compare")
    return failures


DEFAULT_PATTERNS = ("BENCH_teff*.json", "BENCH_solvers*.json",
                    "BENCH_scaling*.json", "BENCH_serve*.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="explicit OLD NEW pair; default scans --dir")
    ap.add_argument("--dir", default=".")
    ap.add_argument("--pattern", default=None,
                    help="scan a single glob instead of the default "
                         f"groups {DEFAULT_PATTERNS}")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="allowed per-step slowdown fraction (default 15%%)")
    args = ap.parse_args(argv)

    if args.files:
        if len(args.files) != 2:
            ap.error("pass exactly two files (OLD NEW) or none")
        try:
            failures = compare(load(args.files[0]), load(args.files[1]),
                               args.threshold)
        except BenchRecordError as e:
            print(f"# {e}")
            return 2
    else:
        patterns = ((args.pattern,) if args.pattern is not None
                    else DEFAULT_PATTERNS)
        failures = []
        for pattern in patterns:
            failures += scan_group(args.dir, pattern, args.threshold)
    if failures:
        print("\nFAIL: per-step regression beyond "
              f"{args.threshold:.0%}:\n  " + "\n  ".join(failures))
        return 1
    print("# perf trajectory OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
