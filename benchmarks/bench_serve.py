"""Serving-layer throughput/latency benchmark: batched ensemble solves
through :mod:`repro.serve` vs the naive one-request-one-launch loop.

All requests arrive at t=0 (closed-loop burst): the naive baseline
answers them one ``solve_until`` at a time, so request k's latency
includes the k-1 solves ahead of it; the server packs them into
``max_batch``-wide batches whose per-sample convergence masking keeps
every lane busy (converged samples freeze and free their slot for
refill). Reported per mode: aggregate solves/s and the p50/p99
request-completion latency of the burst. The headline claim — batched
beats one-by-one on solves/s at >= 8 concurrent requests — is what CI's
``--quick`` run re-checks.

Results land in ``BENCH_serve_*.json`` (stamped via ``_meta.py``) and
are guarded by ``benchmarks/compare.py``.

    PYTHONPATH=src python benchmarks/bench_serve.py [--quick]
        [--n 16] [--requests 16] [--max-batch 8] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

import numpy as np

from repro.core import fd3d, init_parallel_stencil
from repro.core import iterate
from repro.serve import ServePolicy, SimulationServer, SolveRequest

from _meta import bench_meta


def diffusion_kernel():
    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"},
                 reductions={"err": "max_abs_diff(T2, T)"})
    def kern(T2, T, dt):
        return {"T2": fd3d.inn(T) + dt * (
            fd3d.d2_xi(T) + fd3d.d2_yi(T) + fd3d.d2_zi(T))}

    return kern


def make_requests(n: int, count: int, tol: float, max_iters: int):
    """``count`` independent ICs/scalars on one grid bucket — a spike of
    varying amplitude and a per-request stable dt."""
    reqs = []
    for i in range(count):
        T = np.zeros((n, n, n), np.float32)
        T[n // 2, n // 2, n // 2] = 1.0 + 0.1 * i
        dt = 0.06 + 0.002 * (i % 5)
        reqs.append(SolveRequest(
            fields={"T": T, "T2": T}, scalars={"dt": dt},
            tol=tol, max_iters=max_iters))
    return reqs


def percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def run_one_by_one(kernel, n, count, tol, max_iters):
    """The naive baseline: a fresh solve_until launch per request."""
    reqs = make_requests(n, count, tol, max_iters)
    # warm the jit outside the timed region, as the server does
    r0 = reqs[0]
    iterate.solve_until(kernel, dict(r0.fields), dict(r0.scalars),
                        tol=tol, max_iters=max_iters, check_every=4)
    lat = []
    t0 = time.perf_counter()
    for r in reqs:
        res = iterate.solve_until(kernel, dict(r.fields), dict(r.scalars),
                                  tol=tol, max_iters=max_iters,
                                  check_every=4)
        np.asarray(res.fields["T"])          # request is done when host-visible
        lat.append(time.perf_counter() - t0)
    wall = time.perf_counter() - t0
    return wall, lat


def run_batched(kernel, n, count, tol, max_iters, max_batch):
    """The serving path: burst-submit, continuous batching drains it."""
    pol = ServePolicy(max_batch=max_batch, chunk_steps=64, check_every=4,
                      collect_window_s=0.005,
                      queue_capacity=max(64, 2 * count))
    with SimulationServer(kernel, pol) as srv:
        # warm the jit (one throwaway request) before the timed burst
        warm = make_requests(n, 1, tol, max_iters)[0]
        srv.solve(warm, timeout=120.0)
        reqs = make_requests(n, count, tol, max_iters)
        t0 = time.perf_counter()
        tickets = [srv.submit(r) for r in reqs]
        lat = []
        for t in tickets:
            t.result(timeout=300.0)
            lat.append(time.perf_counter() - t0)
        wall = time.perf_counter() - t0
    return wall, lat


def bench(n: int, count: int, max_batch: int, tol: float = 1e-5,
          max_iters: int = 500):
    kernel = diffusion_kernel()
    rows = []
    for name, runner in (
            ("one_by_one", lambda: run_one_by_one(
                kernel, n, count, tol, max_iters)),
            ("batched", lambda: run_batched(
                kernel, n, count, tol, max_iters, max_batch))):
        wall, lat = runner()
        rows.append({
            "name": name, "n": n, "requests": count,
            "max_batch": max_batch if name == "batched" else 1,
            "wall_s": wall,
            "solves_per_s": count / wall,
            "per_solve_s": wall / count,
            "p50_s": percentile(lat, 50),
            "p99_s": percentile(lat, 99),
        })
        print(f"{name:12s} n={n} requests={count}: "
              f"{count / wall:7.2f} solves/s  "
              f"p50 {percentile(lat, 50)*1e3:7.1f} ms  "
              f"p99 {percentile(lat, 99)*1e3:7.1f} ms")
    base = next(r for r in rows if r["name"] == "one_by_one")
    bat = next(r for r in rows if r["name"] == "batched")
    speedup = bat["solves_per_s"] / base["solves_per_s"]
    print(f"batched/one_by_one throughput: {speedup:.2f}x")
    return rows, speedup


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small burst for CI: n=12, 8 requests")
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_serve record here")
    args = ap.parse_args(argv)
    if args.quick:
        args.n, args.requests = 12, max(8, args.max_batch)

    rows, speedup = bench(args.n, args.requests, args.max_batch)
    record = {"kind": "serve", "rows": rows,
              "speedup_batched": speedup, "meta": bench_meta()}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {args.json}")
    # the acceptance claim: batching wins at >= 8 concurrent requests
    if args.requests >= 8 and speedup <= 1.0:
        print("FAIL: batched serving did not beat one-request-one-launch")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
