"""Paper Fig. 2 reproduction: T_eff of the 3-D heat diffusion solver.

Rows mirror the paper's comparison:
  * ``kernel``        — the fused stencil step (ParallelStencil analogue):
                        jnp backend under jit (XLA-fused single pass); this
                        is what runs on TPU via the Pallas kernel.
  * ``broadcast``     — "array programming" baseline: the same update as a
                        chain of unfused whole-array ops (op-by-op eager),
                        the paper's CUDA.jl / Julia-broadcast comparison.
  * ``pallas(interp)``— the Pallas TPU kernel in interpret mode (CPU
                        correctness path; wall-time not meaningful, listed
                        for completeness).

T_eff = A_eff / t with A_eff = (1 write + 2 reads) * n * sizeof(f32): T2
written, T and Ci read (the paper's counting for Fig. 1). T_peak for the
CPU rows is a measured STREAM-copy bandwidth; the TPU v5e roofline fraction
is *derived* in EXPERIMENTS.md §Roofline from the dry-run (no TPU here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.diffusion3d import BENCH_128, BENCH_256, Diffusion3DConfig
from repro.core import Grid, teff
from repro.kernels import ops, ref


def _setup(cfg: Diffusion3DConfig):
    g = Grid(cfg.shape, (cfg.lx, cfg.ly, cfg.lz))
    key = jax.random.PRNGKey(0)
    T = jax.random.uniform(key, cfg.shape, jnp.float32) + 1.0
    Ci = jnp.full(cfg.shape, 1.0 / cfg.c0, jnp.float32)
    dt = g.stable_diffusion_dt(cfg.lam / cfg.c0)
    return g, T, Ci, dt


def bench(cfg: Diffusion3DConfig = BENCH_128, iters: int = 20):
    g, T, Ci, dt = _setup(cfg)
    inv = g.inv_spacing
    a_eff = teff.a_eff(g.n_points, n_read=2, n_write=1, itemsize=4)
    host_bw = teff.measure_host_bandwidth()
    rows = []

    # fused kernel (jit)
    step = jax.jit(lambda T2, T: ref.diffusion3d_step(T2, T, Ci, cfg.lam, dt,
                                                      *inv))
    m = teff.measure(lambda: step(T, T), iters=iters)
    rows.append(("kernel_jit", m, a_eff))

    # broadcast baseline: op-by-op, unfused, materializing temporaries
    def broadcast_step(T2, T):
        d2x = (T[2:, 1:-1, 1:-1] - 2 * T[1:-1, 1:-1, 1:-1] + T[:-2, 1:-1, 1:-1])
        d2x = d2x * inv[0] ** 2
        d2y = (T[1:-1, 2:, 1:-1] - 2 * T[1:-1, 1:-1, 1:-1] + T[1:-1, :-2, 1:-1])
        d2y = d2y * inv[1] ** 2
        d2z = (T[1:-1, 1:-1, 2:] - 2 * T[1:-1, 1:-1, 1:-1] + T[1:-1, 1:-1, :-2])
        d2z = d2z * inv[2] ** 2
        lap = d2x + d2y + d2z
        upd = T[1:-1, 1:-1, 1:-1] + dt * (cfg.lam * Ci[1:-1, 1:-1, 1:-1] * lap)
        return T2.at[1:-1, 1:-1, 1:-1].set(upd)

    with jax.disable_jit():
        m = teff.measure(lambda: broadcast_step(T, T), iters=max(iters // 2, 5))
    rows.append(("broadcast_eager", m, a_eff))

    out = []
    for name, m, a in rows:
        t_eff = m.t_eff(a)
        out.append({
            "name": name, "n": cfg.nx,
            "median_s": m.median_s,
            "ci95_s": m.ci95_s,
            "t_eff_GBs": t_eff / 1e9,
            "host_bw_GBs": host_bw / 1e9,
            "frac_of_host_peak": t_eff / host_bw,
        })
    return out


def main(out_rows=None):
    all_rows = []
    for cfg in (BENCH_128, BENCH_256):
        all_rows += bench(cfg)
    speedup = all_rows[0]["t_eff_GBs"] / all_rows[1]["t_eff_GBs"]
    for r in all_rows:
        print(f"teff_{r['name']}_{r['n']},{r['median_s']*1e6:.1f},"
              f"T_eff={r['t_eff_GBs']:.2f}GB/s frac={r['frac_of_host_peak']:.3f}")
    print(f"teff_speedup_kernel_vs_broadcast_128,{speedup:.2f},x")
    if out_rows is not None:
        out_rows.extend(all_rows)
    return all_rows


if __name__ == "__main__":
    main()
