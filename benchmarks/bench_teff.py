"""Paper Fig. 2 reproduction: T_eff of the 3-D heat diffusion solver.

Rows mirror the paper's comparison:
  * ``kernel``        — the fused stencil step (ParallelStencil analogue):
                        jnp backend under jit (XLA-fused single pass); this
                        is what runs on TPU via the Pallas kernel.
  * ``broadcast``     — "array programming" baseline: the same update as a
                        chain of unfused whole-array ops (op-by-op eager),
                        the paper's CUDA.jl / Julia-broadcast comparison.
  * ``seq_k`` /
    ``fused_k``       — temporal blocking (``--nsteps k``): k sequential
                        single-step launches with double-buffer rotation vs
                        the fused k-step path (one jit'd k-sweep program —
                        the StencilKernel.run_steps realization that maps
                        to the k-halo Pallas kernel on TPU).

T_eff = A_eff / t with A_eff = (1 write + 2 reads) * n * sizeof(f32): T2
written, T and Ci read (the paper's counting for Fig. 1). Under temporal
blocking the per-launch ideal traffic divides by k (teff.a_eff_blocked),
so both the *classic* fraction (per-sweep traffic) and the *blocked*
fraction (per-launch traffic) are reported. T_peak for the CPU rows is a
measured STREAM-copy bandwidth; the TPU v5e roofline fraction is *derived*
in the README §Roofline from the dry-run (no TPU here).

``--nsteps k`` also records the comparison to ``BENCH_teff_n{N}_k{K}.json``
so perf regressions of the fused path are visible in CI.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.diffusion3d import BENCH_128, BENCH_256, Diffusion3DConfig
from repro.core import Grid, fd3d as fd, init_parallel_stencil, teff
from repro.kernels import ops, ref
from repro.launch import roofline as _roofline

try:
    from ._meta import bench_meta   # imported as benchmarks.bench_teff
except ImportError:
    from _meta import bench_meta    # run as a script


def _diffusion_kernel(ps):
    @ps.parallel(outputs=("T2",), rotations={"T2": "T"})
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx ** 2 + fd.d2_yi(T) * _dy ** 2 +
            fd.d2_zi(T) * _dz ** 2))}
    return kern


def _analytic(shape):
    """IR-derived accounting for the Fig. 1 solver: exact A_eff (replaces
    the hand-counted n_read=2/n_write=1) and the analytic cost model for
    roofline records."""
    kern = _diffusion_kernel(init_parallel_stencil(backend="jnp", ndims=3))
    sc = dict(lam=1.0, dt=1.0, _dx=1.0, _dy=1.0, _dz=1.0)
    ir = kern.stencil_ir(T2=shape, T=shape, Ci=shape, **sc)
    cost = kern.cost_model(T2=shape, T=shape, Ci=shape, **sc)
    return ir, cost


def _setup(cfg: Diffusion3DConfig):
    g = Grid(cfg.shape, (cfg.lx, cfg.ly, cfg.lz))
    key = jax.random.PRNGKey(0)
    T = jax.random.uniform(key, cfg.shape, jnp.float32) + 1.0
    T2 = T.copy()  # distinct write buffer, as the solvers allocate
    Ci = jnp.full(cfg.shape, 1.0 / cfg.c0, jnp.float32)
    dt = g.stable_diffusion_dt(cfg.lam / cfg.c0)
    return g, T, T2, Ci, dt


def bench(cfg: Diffusion3DConfig = BENCH_128, iters: int = 20,
          host_bw: float | None = None):
    g, T, T2, Ci, dt = _setup(cfg)
    inv = g.inv_spacing
    # A_eff from the traced stencil IR (reads {T, Ci}, writes {T2}) —
    # identical to the paper's hand count of 3 fields, but derived.
    ir, _ = _analytic(cfg.shape)
    a_eff = teff.a_eff_from_ir(ir, itemsize=4)
    if host_bw is None:
        host_bw = teff.measure_host_bandwidth()
    rows = []

    # fused kernel (jit) — distinct T2/T double buffer
    step = jax.jit(lambda T2, T: ref.diffusion3d_step(T2, T, Ci, cfg.lam, dt,
                                                      *inv))
    m = teff.measure(lambda: step(T2, T), iters=iters)
    rows.append(("kernel_jit", m, a_eff, 1))

    # broadcast baseline: op-by-op, unfused, materializing temporaries
    def broadcast_step(T2, T):
        d2x = (T[2:, 1:-1, 1:-1] - 2 * T[1:-1, 1:-1, 1:-1] + T[:-2, 1:-1, 1:-1])
        d2x = d2x * inv[0] ** 2
        d2y = (T[1:-1, 2:, 1:-1] - 2 * T[1:-1, 1:-1, 1:-1] + T[1:-1, :-2, 1:-1])
        d2y = d2y * inv[1] ** 2
        d2z = (T[1:-1, 1:-1, 2:] - 2 * T[1:-1, 1:-1, 1:-1] + T[1:-1, 1:-1, :-2])
        d2z = d2z * inv[2] ** 2
        lap = d2x + d2y + d2z
        upd = T[1:-1, 1:-1, 1:-1] + dt * (cfg.lam * Ci[1:-1, 1:-1, 1:-1] * lap)
        return T2.at[1:-1, 1:-1, 1:-1].set(upd)

    with jax.disable_jit():
        m = teff.measure(lambda: broadcast_step(T2, T), iters=max(iters // 2, 5))
    rows.append(("broadcast_eager", m, a_eff, 1))

    out = []
    for name, m, a, k in rows:
        out.append(_row(name, cfg, m, a, k, host_bw))
    return out


def _row(name, cfg, m, a_eff_step, nsteps, host_bw, fused=False):
    """Per-step timing row. ``fused`` marks a genuinely k-fused launch:
    only then does the per-launch ideal traffic divide by k — k *separate*
    launches still move the full A_eff each, so their blocked fraction
    equals the classic one."""
    per_step_s = m.median_s / nsteps
    t_eff = a_eff_step / per_step_s  # classic: each sweep moves the fields
    n = cfg.nx
    a_blocked = a_eff_step / nsteps if fused else a_eff_step
    return {
        "name": name, "n": n, "nsteps": nsteps,
        "median_s": m.median_s,
        "per_step_s": per_step_s,
        "ci95_s": m.ci95_s,
        # jitter percentiles over the raw samples (per LAUNCH, like
        # median_s): the median hides straggling iterations — GC pauses,
        # noisy neighbors — which is what a perf trajectory wants to see
        **m.percentiles(),
        "t_eff_GBs": t_eff / 1e9,
        "host_bw_GBs": host_bw / 1e9,
        "frac_of_host_peak": t_eff / host_bw,
        "frac_of_host_peak_blocked": (a_blocked / per_step_s) / host_bw,
    }


def bench_march(cfg: Diffusion3DConfig, march_axis: int, iters: int = 20,
                host_bw: float | None = None, nsteps: int = 1):
    """Streamed (marching-axis) execution vs the all-parallel launch of
    the SAME @parallel kernel — the apples-to-apples pair for the
    plane-queue reuse claim. On this host both run the jnp realization
    (all-parallel: one fused whole-array pass + interior scatter;
    marched: a scan sliding cache-resident plane slabs); on TPU the same
    flag flips the Pallas launch to sequential-grid plane queues."""
    g, T, T2, Ci, dt = _setup(cfg)
    inv = g.inv_spacing
    ir, cost = _analytic(cfg.shape)
    a_eff = teff.a_eff_from_ir(ir, itemsize=4)
    if host_bw is None:
        host_bw = teff.measure_host_bandwidth()
    sc = dict(lam=cfg.lam, dt=dt, _dx=inv[0], _dy=inv[1], _dz=inv[2])

    kern = _diffusion_kernel(init_parallel_stencil(backend="jnp", ndims=3))
    marched = kern.marched(march_axis)

    pstep = jax.jit(lambda a, b: kern.run_steps(nsteps, T2=a, T=b, Ci=Ci,
                                                **sc))
    mstep = jax.jit(lambda a, b: marched.run_steps(nsteps, T2=a, T=b, Ci=Ci,
                                                   **sc))
    # Interleave short measurement rounds: this host's throughput drifts
    # by >10% over a benchmark's lifetime (shared cores), so back-to-back
    # blocks would bias whichever variant ran in the quiet window. Both
    # variants see the same noise profile; pooled medians decide.
    rounds = max(iters // 3, 1)
    par_samples, mar_samples = [], []
    m_par = m_mar = None
    for _ in range(rounds):
        m_par = teff.measure(lambda: pstep(T2, T), iters=3, warmup=1)
        m_mar = teff.measure(lambda: mstep(T2, T), iters=3, warmup=1)
        par_samples += m_par.samples_s
        mar_samples += m_mar.samples_s
    m_par = dataclasses.replace(m_par, median_s=float(np.median(par_samples)),
                                samples_s=par_samples)
    m_mar = dataclasses.replace(m_mar, median_s=float(np.median(mar_samples)),
                                samples_s=mar_samples)
    np.testing.assert_allclose(np.asarray(pstep(T2, T)),
                               np.asarray(mstep(T2, T)), atol=1e-6)

    fused = nsteps > 1
    rows = [
        _row(f"parallel_k{nsteps}", cfg, m_par, a_eff, nsteps, host_bw,
             fused=fused),
        _row(f"march{march_axis}_k{nsteps}", cfg, m_mar, a_eff, nsteps,
             host_bw, fused=fused),
    ]
    speedup = m_par.median_s / m_mar.median_s
    # The tiled-launch traffic the streamed geometry eliminates: on the
    # actual Pallas launch, all-parallel tiles refetch halo-overlapped
    # windows while the marched launch fetches each plane ~once. A CPU
    # host's whole-array XLA pass never pays that refetch (its "windows"
    # are cache lines), so the measured jnp ratio above bounds below the
    # launch-geometry savings recorded here. Two ratios, two questions:
    # the honest engine-choice saving compares each launch at ITS OWN
    # derived tile (the all-parallel tile is larger — it has no queue to
    # budget for); the matched-tile ratio isolates what streaming saves
    # at the march geometry itself.
    from repro.kernels import stencil as _stencil
    _, ptile = _stencil.derive_launch(cfg.shape, 1, 3, 4, nsteps=nsteps)
    _, mtile = _stencil.derive_launch(cfg.shape, 1, 3, 4, nsteps=nsteps,
                                      march_axis=march_axis)
    streamed = cost.a_eff_streamed(mtile, nsteps, march_axis)
    rows[-1]["launch_traffic_ratio"] = (
        cost.fetched_bytes_per_step(ptile, nsteps) / streamed)
    rows[-1]["launch_traffic_ratio_matched_tile"] = (
        cost.fetched_bytes_per_step(mtile, nsteps) / streamed)
    return rows, speedup, cost


def bench_checks(cfg: Diffusion3DConfig, check_every: int, iters: int = 20,
                 host_bw: float | None = None):
    """Fused in-launch convergence check vs step + SEPARATE norm pass.

    Both variants advance ``check_every`` steps and produce
    ``err = max|T2_new - T|`` once per round. The fused variant folds the
    check inside the same compiled program as the final step (the jnp
    realization of the Pallas per-tile partials epilogue — XLA fuses the
    fold into the update loop, so the operands never cross HBM again);
    the post variant runs the m steps and then a separately compiled
    whole-array norm pass that re-reads both operand fields — the extra
    traffic the issue's accounting (``ir.check_io_bytes``) prices.
    Rounds are interleaved against host throughput drift, as bench_march.
    """
    g, T, T2, Ci, dt = _setup(cfg)
    inv = g.inv_spacing
    ir, _ = _analytic(cfg.shape)
    a_eff = teff.a_eff_from_ir(ir, itemsize=4)
    if host_bw is None:
        host_bw = teff.measure_host_bandwidth()
    sc = dict(lam=cfg.lam, dt=dt, _dx=inv[0], _dy=inv[1], _dz=inv[2])
    m = max(int(check_every), 1)

    kern = _diffusion_kernel(init_parallel_stencil(backend="jnp", ndims=3))
    rkern = kern.with_reductions({"err": "max_abs_diff(T2, T)"})
    # check traffic priced off the CHECKED kernel's IR (the plain kernel
    # declares no reductions, so its check_io_bytes is rightly zero)
    check_bytes = rkern.stencil_ir(
        T2=cfg.shape, T=cfg.shape, Ci=cfg.shape, **sc).check_io_bytes(4)

    def fused_chain(a, b):
        for _ in range(m - 1):
            out = kern(T2=a, T=b, Ci=Ci, **sc)
            a, b = b, out
        out, reds = rkern(T2=a, T=b, Ci=Ci, **sc)
        return out, reds["err"]

    def plain_chain(a, b):
        for _ in range(m - 1):
            out = kern(T2=a, T=b, Ci=Ci, **sc)
            a, b = b, out
        out = kern(T2=a, T=b, Ci=Ci, **sc)
        return out, b  # b: the pre-final-step buffer the norm diffs against

    fused = jax.jit(fused_chain)
    plain = jax.jit(plain_chain)
    norm = jax.jit(lambda x, y: jnp.max(jnp.abs(x - y)))

    def post_round():
        out, prev = plain(T2, T)
        return norm(out, prev)  # separately compiled pass: re-reads both

    # Interleaved measurement rounds (same rationale as bench_march: this
    # host's throughput drifts; both variants must see the same noise).
    rounds = max(iters // 3, 1)
    f_samples, p_samples = [], []
    m_f = m_p = None
    for _ in range(rounds):
        m_f = teff.measure(lambda: fused(T2, T), iters=3, warmup=1)
        m_p = teff.measure(post_round, iters=3, warmup=1)
        f_samples += m_f.samples_s
        p_samples += m_p.samples_s
    m_f = dataclasses.replace(m_f, median_s=float(np.median(f_samples)),
                              samples_s=f_samples)
    m_p = dataclasses.replace(m_p, median_s=float(np.median(p_samples)),
                              samples_s=p_samples)
    # parity: reductions reassociate across programs -> allclose, not ==
    np.testing.assert_allclose(float(fused(T2, T)[1]),
                               float(post_round()), rtol=1e-5)

    a_fused = teff.a_eff_checked(a_eff, check_bytes, m, fused=True)
    a_post = teff.a_eff_checked(a_eff, check_bytes, m, fused=False)
    rows = [
        _row(f"fused_check_m{m}", cfg, m_f, a_fused, m, host_bw),
        _row(f"post_check_m{m}", cfg, m_p, a_post, m, host_bw),
    ]
    rows[0]["check_every"] = rows[1]["check_every"] = m
    rows[1]["check_bytes_per_step"] = check_bytes / m
    speedup = m_p.median_s / m_f.median_s
    return rows, speedup


DTYPES = {"f32": "float32", "bf16": "bfloat16", "f16": "float16"}


def bench_mixed(cfg: Diffusion3DConfig, dtype_name: str, iters: int = 20,
                host_bw: float | None = None):
    """Low-precision STORAGE vs f32 on the same @parallel kernel — the
    mixed-precision headline pair. Both variants run the identical update
    at f32 compute; the low variant stores its fields bf16/f16, halving
    the bytes every sweep moves (the engine is bandwidth-bound, so the
    per-step time should track the byte ratio). A_eff for each row uses
    its OWN storage itemsize — per-field byte accounting keeps T_eff
    honest. Rounds are interleaved against host throughput drift, as
    bench_march."""
    import math

    if dtype_name not in DTYPES:
        raise ValueError(f"dtype must be one of {tuple(DTYPES)}")
    sdt = jnp.dtype(DTYPES[dtype_name])
    g, T, T2, Ci, dt = _setup(cfg)
    inv = g.inv_spacing
    ir, _ = _analytic(cfg.shape)
    a_eff32 = teff.a_eff_from_ir(ir, itemsize=4)
    isz = sdt.itemsize
    a_eff_lo = teff.a_eff_from_ir(
        ir, itemsize=isz,
        field_itemsizes={f: isz for f in ir.field_shapes})
    if host_bw is None:
        host_bw = teff.measure_host_bandwidth()
    sc = dict(lam=cfg.lam, dt=dt, _dx=inv[0], _dy=inv[1], _dz=inv[2])

    k32 = _diffusion_kernel(init_parallel_stencil("jnp", "float32", 3))
    klo = _diffusion_kernel(init_parallel_stencil("jnp", sdt, 3))
    Tl, T2l, Cil = (x.astype(sdt) for x in (T, T2, Ci))

    s32 = jax.jit(lambda a, b: k32(T2=a, T=b, Ci=Ci, **sc))
    slo = jax.jit(lambda a, b: klo(T2=a, T=b, Ci=Cil, **sc))

    rounds = max(iters // 3, 1)
    f32_samples, lo_samples = [], []
    m32 = mlo = None
    for _ in range(rounds):
        m32 = teff.measure(lambda: s32(T2, T), iters=3, warmup=1)
        mlo = teff.measure(lambda: slo(T2l, Tl), iters=3, warmup=1)
        f32_samples += m32.samples_s
        lo_samples += mlo.samples_s
    m32 = dataclasses.replace(m32, median_s=float(np.median(f32_samples)),
                              samples_s=f32_samples)
    mlo = dataclasses.replace(mlo, median_s=float(np.median(lo_samples)),
                              samples_s=lo_samples)
    # parity: one step of f32-compute/low-storage vs f32 differs only by
    # the storage rounding of the inputs and the one output round-trip
    eps = float(jnp.finfo(sdt).eps)
    np.testing.assert_allclose(
        np.asarray(slo(T2l, Tl), dtype=np.float32),
        np.asarray(s32(T2, T)), atol=4 * eps * float(jnp.max(jnp.abs(T))))

    rows = [_row("mixed_f32", cfg, m32, a_eff32, 1, host_bw),
            _row(f"mixed_{dtype_name}", cfg, mlo, a_eff_lo, 1, host_bw)]
    for row, dname, ib in ((rows[0], "f32", 4), (rows[1], dtype_name, isz)):
        row["dtype"] = dname
        row["field_bytes"] = {f: math.prod(s) * ib
                              for f, s in ir.field_shapes.items()}
    speedup = m32.median_s / mlo.median_s
    # what the bandwidth-bound cost model predicts for this dtype pair
    # (the byte ratio): the measured/model gap is the convert-arithmetic
    # + codegen tax, ~0 on accelerators with native narrow-float loads,
    # large on CPUs where XLA must expand every conversion in-loop.
    rows[1]["speedup_vs_f32"] = speedup
    rows[1]["model_speedup_vs_f32"] = a_eff32 / a_eff_lo
    return rows, speedup


def bench_telemetry(cfg: Diffusion3DConfig, iters: int = 20,
                    host_bw: float | None = None, max_iters: int = 30,
                    check_every: int = 5):
    """Telemetry-overhead pair: the SAME convergence-driven solve through
    ``iterate.solve_until`` with the collector forced off vs forced on
    (an in-memory collector — no filesystem in the timed path). The
    traced program is identical under the zero-host-sync rule and the
    jitted solver is shared between the variants, so the on-row's only
    extra cost is the handful of host-side record appends at the final
    carry. Rounds are interleaved against host throughput drift, as
    bench_march."""
    from repro import telemetry
    from repro.core import iterate
    from repro.telemetry import attrib

    g, T, T2, Ci, dt = _setup(cfg)
    inv = g.inv_spacing
    ir, _ = _analytic(cfg.shape)
    a_eff = teff.a_eff_from_ir(ir, itemsize=4)
    if host_bw is None:
        host_bw = teff.measure_host_bandwidth()
    sc = dict(lam=cfg.lam, dt=dt, _dx=inv[0], _dy=inv[1], _dz=inv[2])

    kern = _diffusion_kernel(init_parallel_stencil(backend="jnp", ndims=3))
    rkern = kern.with_reductions({"err": "max_abs_diff(T2, T)"})
    fields = dict(T2=T2, T=T, Ci=Ci)
    col = telemetry.Collector(None)
    # resolve the roofline peak up front so attribution never runs a
    # STREAM probe inside a timed round
    attrib.default_hardware()

    def run(sel):
        res = iterate.solve_until(rkern, fields, sc, tol=0.0,
                                  max_iters=max_iters,
                                  check_every=check_every, telemetry=sel)
        jax.block_until_ready(res.err)
        return res

    steps = int(run(False).iters)   # warms the solver cache too
    rounds = max(iters // 3, 1)
    off_samples, on_samples = [], []
    m_off = m_on = None
    for _ in range(rounds):
        m_off = teff.measure(lambda: run(False).err, iters=3, warmup=1)
        m_on = teff.measure(lambda: run(col).err, iters=3, warmup=1)
        off_samples += m_off.samples_s
        on_samples += m_on.samples_s
    m_off = dataclasses.replace(m_off, median_s=float(np.median(off_samples)),
                                samples_s=off_samples)
    m_on = dataclasses.replace(m_on, median_s=float(np.median(on_samples)),
                               samples_s=on_samples)

    rows = [_row("telemetry_off", cfg, m_off, a_eff, steps, host_bw),
            _row("telemetry_on", cfg, m_on, a_eff, steps, host_bw)]
    # the overhead verdict compares pooled MINIMA: the true cost is a
    # fixed handful of host-side record appends per solve, and the min
    # is the noise-robust estimator of that floor on a shared host
    # (interleaved medians still wobble by several % here)
    overhead = min(on_samples) / min(off_samples) - 1.0
    rows[1]["telemetry_overhead_frac"] = overhead
    rows[1]["records_per_solve"] = len(col.records) / max(rounds * 4, 1)
    return rows, overhead


def bench_temporal(cfg: Diffusion3DConfig, nsteps: int, iters: int = 20,
                   host_bw: float | None = None):
    """k sequential single-step launches vs the fused k-step path."""
    g, T, T2, Ci, dt = _setup(cfg)
    inv = g.inv_spacing
    ir, _ = _analytic(cfg.shape)
    a_eff = teff.a_eff_from_ir(ir, itemsize=4)
    if host_bw is None:
        host_bw = teff.measure_host_bandwidth()
    sc = dict(lam=cfg.lam, dt=dt, _dx=inv[0], _dy=inv[1], _dz=inv[2])

    kern = _diffusion_kernel(init_parallel_stencil(backend="jnp", ndims=3))

    # k sequential launches, rotating the double buffer between launches
    step1 = jax.jit(lambda a, b: kern(T2=a, T=b, Ci=Ci, **sc))

    def seq():
        a, b = T2, T
        for _ in range(nsteps):
            a = step1(a, b)
            a, b = b, a
        return b

    # fused: one jit'd k-step program (k unrolled sweeps; XLA elides the
    # intermediate buffers — the CPU realization of the k-halo TPU kernel)
    fused = jax.jit(lambda a, b: kern.run_steps(nsteps, T2=a, T=b, Ci=Ci, **sc))

    m_seq = teff.measure(seq, iters=iters)
    m_fused = teff.measure(lambda: fused(T2, T), iters=iters)
    np.testing.assert_array_equal(np.asarray(seq()), np.asarray(fused(T2, T)))

    rows = [
        _row(f"seq_{nsteps}x1step", cfg, m_seq, a_eff, nsteps, host_bw),
        _row(f"fused_{nsteps}step", cfg, m_fused, a_eff, nsteps, host_bw,
             fused=True),
    ]
    speedup = m_seq.median_s / m_fused.median_s
    return rows, speedup


def main(out_rows=None, nsteps: int = 1, iters: int = 20, sizes=None,
         json_path: str | None = None, march_axis: int | None = None,
         check_every: int | None = None, checks_only: bool = False,
         dtype: str | None = None, mixed_only: bool = False,
         telemetry_overhead: bool = False, telemetry_only: bool = False):
    all_rows = []
    cfgs = sizes if sizes is not None else (BENCH_128, BENCH_256)
    # one STREAM probe for the whole report: every row's roofline fraction
    # shares a single T_peak denominator
    host_bw = teff.measure_host_bandwidth()
    base_skipped = checks_only or mixed_only or telemetry_only
    speedup = None
    if not base_skipped:
        for cfg in cfgs:
            all_rows += bench(cfg, iters=iters, host_bw=host_bw)
        speedup = all_rows[0]["t_eff_GBs"] / all_rows[1]["t_eff_GBs"]
    mixed_speedups: dict[int, float] = {}
    if dtype is not None:
        for cfg in cfgs:
            rows, sp = bench_mixed(cfg, dtype, iters=iters, host_bw=host_bw)
            all_rows += rows
            mixed_speedups[cfg.nx] = sp
    temporal_speedups: dict[int, float] = {}
    if nsteps > 1 and not base_skipped:
        for cfg in cfgs:
            rows, sp = bench_temporal(cfg, nsteps, iters=iters,
                                      host_bw=host_bw)
            all_rows += rows
            temporal_speedups[cfg.nx] = sp
    march_speedups: dict[int, float] = {}
    if march_axis is not None and not base_skipped:
        for cfg in cfgs:
            rows, sp, _ = bench_march(cfg, march_axis, iters=iters,
                                      host_bw=host_bw, nsteps=nsteps)
            all_rows += rows
            march_speedups[cfg.nx] = sp
    check_speedups: dict[int, float] = {}
    if check_every is not None:
        for cfg in cfgs:
            rows, sp = bench_checks(cfg, check_every, iters=iters,
                                    host_bw=host_bw)
            all_rows += rows
            check_speedups[cfg.nx] = sp
    telemetry_overheads: dict[int, float] = {}
    if telemetry_overhead:
        for cfg in cfgs:
            rows, ov = bench_telemetry(cfg, iters=iters, host_bw=host_bw)
            all_rows += rows
            telemetry_overheads[cfg.nx] = ov
    for r in all_rows:
        print(f"teff_{r['name']}_{r['n']},{r['per_step_s']*1e6:.1f},"
              f"T_eff={r['t_eff_GBs']:.2f}GB/s frac={r['frac_of_host_peak']:.3f}"
              f" frac_blocked={r['frac_of_host_peak_blocked']:.3f}")
    if speedup is not None:
        print(f"teff_speedup_kernel_vs_broadcast_{all_rows[0]['n']},{speedup:.2f},x")
    for n, sp in temporal_speedups.items():
        print(f"teff_speedup_fused{nsteps}_vs_seq_{n},{sp:.2f},x")
    for n, sp in march_speedups.items():
        print(f"teff_speedup_march{march_axis}_vs_parallel_{n},{sp:.2f},x")
    for n, sp in check_speedups.items():
        print(f"teff_speedup_fusedcheck_vs_post_m{check_every}_{n},"
              f"{sp:.2f},x")
    for n, sp in mixed_speedups.items():
        print(f"teff_speedup_mixed_{dtype}_vs_f32_{n},{sp:.2f},x")
    for n, ov in telemetry_overheads.items():
        print(f"teff_telemetry_overhead_{n},{ov*100:.2f},%")
    if json_path:
        # per-size roofline positions from the analytic cost model (the
        # IR-traced flop/byte counts against the v5e roofline constants);
        # with a march axis the record carries both the refetched and the
        # streamed traffic of the derived launch geometry
        rooflines = {}
        for cfg in cfgs:
            _, cost = _analytic(cfg.shape)
            tile = None
            if march_axis is not None:
                from repro.kernels import stencil as _stencil
                _, tile = _stencil.derive_launch(cfg.shape, 1, 3, 4,
                                                 nsteps=nsteps,
                                                 march_axis=march_axis)
            rooflines[str(cfg.nx)] = _roofline.stencil_roofline(
                cost, nsteps=max(nsteps, 1), tile=tile,
                march_axis=march_axis)
        with open(json_path, "w") as f:
            json.dump({"rows": all_rows, "nsteps": nsteps,
                       "march_axis": march_axis,
                       "check_every": check_every,
                       "fused_vs_seq_speedup":
                           {str(n): sp for n, sp in temporal_speedups.items()},
                       "march_vs_parallel_speedup":
                           {str(n): sp for n, sp in march_speedups.items()},
                       "fusedcheck_vs_post_speedup":
                           {str(n): sp for n, sp in check_speedups.items()},
                       "dtype": dtype,
                       "mixed_vs_f32_speedup":
                           {str(n): sp for n, sp in mixed_speedups.items()},
                       "telemetry_overhead_frac":
                           {str(n): ov
                            for n, ov in telemetry_overheads.items()},
                       "roofline_v5e": rooflines,
                       "meta": bench_meta()},
                      f, indent=1)
        print(f"# wrote {json_path}")
    if out_rows is not None:
        out_rows.extend(all_rows)
    # the gate values: worst size measured, so a regression anywhere fails
    worst = min(temporal_speedups.values()) if temporal_speedups else None
    worst_march = min(march_speedups.values()) if march_speedups else None
    worst_check = min(check_speedups.values()) if check_speedups else None
    worst_mixed = min(mixed_speedups.values()) if mixed_speedups else None
    worst_tele = (max(telemetry_overheads.values())
                  if telemetry_overheads else None)
    return (all_rows, worst, worst_march, worst_check, worst_mixed,
            worst_tele)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--nsteps", type=int, default=1,
                    help="temporal blocking depth k (k>1 adds seq-vs-fused rows)")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--size", type=int, default=None,
                    help="single n^3 size instead of the default 128/256 pair")
    ap.add_argument("--march-axis", type=int, default=None,
                    help="streamed-execution axis; adds march-vs-parallel "
                         "rows and records BENCH_teff_march_n{N}.json")
    ap.add_argument("--check-every", type=int, default=None,
                    help="convergence-check cadence m; adds fused-check vs "
                         "step+separate-norm rows and records "
                         "BENCH_teff_checks_n{N}.json")
    ap.add_argument("--checks-only", action="store_true",
                    help="with --check-every: record ONLY the check rows "
                         "(keeps the committed trajectory free of "
                         "re-measured base rows)")
    ap.add_argument("--dtype", choices=tuple(DTYPES), default=None,
                    help="mixed-precision storage dtype: adds low-storage "
                         "vs f32 rows (both at f32 compute) and records "
                         "BENCH_teff_mixed_{tag}_{dtype}.json")
    ap.add_argument("--mixed-only", action="store_true",
                    help="with --dtype: record ONLY the mixed rows")
    ap.add_argument("--telemetry-overhead", action="store_true",
                    help="adds telemetry_off/telemetry_on solve_until rows "
                         "(identical traced program; interleaved rounds) "
                         "and records BENCH_teff_telemetry_{tag}.json")
    ap.add_argument("--telemetry-only", action="store_true",
                    help="with --telemetry-overhead: record ONLY the "
                         "telemetry rows")
    ap.add_argument("--check-telemetry-overhead", type=float, default=None,
                    help="exit nonzero if the measured telemetry overhead "
                         "fraction exceeds this at any size (the issue's "
                         "acceptance bound is 0.02)")
    ap.add_argument("--check-mixed-speedup", type=float, default=None,
                    help="exit nonzero unless low-storage/f32 speedup >= "
                         "this at every size; on CPU hosts the threshold "
                         "clamps to 1.0 (narrow-float converts are in-loop "
                         "arithmetic there, so the byte-ratio win applies "
                         "only to accelerator backends)")
    ap.add_argument("--json", default=None,
                    help="output JSON path (default BENCH_teff_n{N}_k{K}.json "
                         "when --nsteps > 1, BENCH_teff_march_n{N}.json with "
                         "--march-axis, BENCH_teff_checks_n{N}.json with "
                         "--check-every)")
    ap.add_argument("--check-speedup", type=float, default=None,
                    help="exit nonzero unless fused/seq speedup >= this")
    ap.add_argument("--check-march-speedup", type=float, default=None,
                    help="exit nonzero unless march/parallel speedup >= this")
    ap.add_argument("--check-reduction-speedup", type=float, default=None,
                    help="exit nonzero unless fused-check/post-check "
                         "speedup >= this")
    args = ap.parse_args()
    if args.checks_only and args.check_every is None:
        ap.error("--checks-only needs --check-every (it would otherwise "
                 "measure nothing and record an empty row set)")
    if args.mixed_only and args.dtype is None:
        ap.error("--mixed-only needs --dtype (it would otherwise measure "
                 "nothing and record an empty row set)")
    if args.telemetry_only and not args.telemetry_overhead:
        ap.error("--telemetry-only needs --telemetry-overhead (it would "
                 "otherwise measure nothing and record an empty row set)")

    sizes = None
    if args.size is not None:
        import dataclasses
        sizes = [dataclasses.replace(BENCH_128, nx=args.size, ny=args.size,
                                     nz=args.size)]
    json_path = args.json
    tag = f"n{args.size}" if args.size is not None else "n128_256"
    if json_path is None and args.telemetry_overhead:
        json_path = f"BENCH_teff_telemetry_{tag}.json"
    elif json_path is None and args.dtype is not None:
        json_path = f"BENCH_teff_mixed_{tag}_{args.dtype}.json"
    elif json_path is None and args.check_every is not None:
        json_path = f"BENCH_teff_checks_{tag}_m{args.check_every}.json"
    elif json_path is None and args.march_axis is not None:
        ktag = f"_k{args.nsteps}" if args.nsteps > 1 else ""
        json_path = f"BENCH_teff_march_{tag}{ktag}.json"
    elif json_path is None and args.nsteps > 1:
        json_path = f"BENCH_teff_{tag}_k{args.nsteps}.json"
    _, sp, spm, spc, spx, ovt = main(
        nsteps=args.nsteps, iters=args.iters,
        sizes=sizes, json_path=json_path,
        march_axis=args.march_axis,
        check_every=args.check_every,
        checks_only=args.checks_only,
        dtype=args.dtype,
        mixed_only=args.mixed_only,
        telemetry_overhead=args.telemetry_overhead,
        telemetry_only=args.telemetry_only)
    if args.check_speedup is not None:
        if sp is None or sp < args.check_speedup:
            print(f"FAIL: fused/seq speedup {sp} < {args.check_speedup}")
            sys.exit(1)
    if args.check_march_speedup is not None:
        if spm is None or spm < args.check_march_speedup:
            print(f"FAIL: march/parallel speedup {spm} < "
                  f"{args.check_march_speedup}")
            sys.exit(1)
    if args.check_reduction_speedup is not None:
        if spc is None or spc < args.check_reduction_speedup:
            print(f"FAIL: fused-check/post-check speedup {spc} < "
                  f"{args.check_reduction_speedup}")
            sys.exit(1)
    if args.check_mixed_speedup is not None:
        need = args.check_mixed_speedup
        if jax.default_backend() == "cpu" and need > 1.0:
            # The >=1.5x gate encodes the bandwidth-bound byte ratio; a
            # CPU host is convert-arithmetic-bound instead (each bf16
            # load expands to in-loop integer widening), so the honest
            # CPU requirement is "storage halving must not cost speed".
            print(f"# cpu backend: mixed-speedup gate {need} -> 1.0 "
                  "(byte-ratio target needs accelerator loads; see "
                  "README Mixed precision)")
            need = 1.0
        if spx is None or spx < need:
            print(f"FAIL: mixed {args.dtype}/f32 speedup {spx} < {need}")
            sys.exit(1)
    if args.check_telemetry_overhead is not None:
        if ovt is None or ovt > args.check_telemetry_overhead:
            print(f"FAIL: telemetry overhead {ovt} > "
                  f"{args.check_telemetry_overhead}")
            sys.exit(1)
