"""Provenance stamp for BENCH_*.json records.

Every benchmark record carries the jax/jaxlib versions, the backend
platform it actually ran on, and the repo's git revision, so the perf
trajectory stays attributable across machines and commits.
"""
from __future__ import annotations

import os
import platform
import subprocess
import time


def git_sha(short: bool = True) -> str | None:
    """Current revision of the repo containing this file (None outside a
    checkout or without git on PATH)."""
    cmd = ["git", "rev-parse"] + (["--short"] if short else []) + ["HEAD"]
    try:
        out = subprocess.run(
            cmd, capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        sha = out.stdout.strip()
        return sha or None
    except Exception:
        return None


def bench_meta() -> dict:
    """The provenance record stamped into every BENCH_*.json."""
    import jax

    try:
        import jaxlib
        jaxlib_version = getattr(jaxlib, "__version__", None)
    except Exception:
        jaxlib_version = None
    return {
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib_version,
        "backend": jax.default_backend(),
        "device": str(jax.devices()[0]) if jax.devices() else None,
        # host identity: wall-time comparisons across machines are
        # meaningless — benchmarks/compare.py refuses them on mismatch
        "hostname": platform.node() or None,
        "cpu_count": os.cpu_count(),
        "git_sha": git_sha(),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
