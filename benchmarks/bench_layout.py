"""Paper C5: declarative allocation lets the framework pick the data layout
(SoA vs AoS) — this benchmark quantifies why that choice must exist.

Workload: 3-component vector diffusion (each component a 7-point stencil),
allocated either as SoA (3 contiguous arrays — unit-stride inner axis) or
AoS (one array with trailing component axis — stride-3 inner access).
On TPU the SoA layout keeps the 128-lane minor dimension dense; on CPU it
keeps vector loads unit-stride. The FieldSet allocator defaults to SoA and
exposes AoS per field (fields.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Grid, FieldSet, fd3d as fd, teff


def _step_soa(comps, dt):
    return tuple(
        c.at[1:-1, 1:-1, 1:-1].add(dt * (fd.d2_xi(c) + fd.d2_yi(c) + fd.d2_zi(c)))
        for c in comps)


def _step_aos(arr, dt):
    def lap(c):
        return fd.d2_xi(c) + fd.d2_yi(c) + fd.d2_zi(c)
    upd = jnp.stack([lap(arr[..., i]) for i in range(arr.shape[-1])], axis=-1)
    return arr.at[1:-1, 1:-1, 1:-1, :].add(dt * upd)


def bench(n: int = 96, iters: int = 10, nsteps: int = 2):
    g = Grid((n,) * 3)
    fs = FieldSet(g)
    v_soa = fs.vector(3, init=1.0, layout="soa")
    v_aos = v_soa.as_aos()
    dt = 1e-4

    soa = jax.jit(lambda cs: _step_soa(cs, dt))
    aos = jax.jit(lambda a: _step_aos(a, dt))
    m_soa = teff.measure(lambda: soa(v_soa.components), iters=iters)
    m_aos = teff.measure(lambda: aos(v_aos.components), iters=iters)

    # temporally-blocked variants: k unrolled sweeps in one jit'd launch,
    # scored against the per-launch ideal traffic (a_eff / k)
    def _multi(step1):
        def run(x):
            for _ in range(nsteps):
                x = step1(x, dt)
            return x
        return jax.jit(run)

    soa_k = _multi(_step_soa)
    aos_k = _multi(_step_aos)
    m_soa_k = teff.measure(lambda: soa_k(v_soa.components), iters=iters)
    m_aos_k = teff.measure(lambda: aos_k(v_aos.components), iters=iters)

    a_eff = teff.a_eff(g.n_points, n_read=3, n_write=3, itemsize=4)
    a_blk = teff.a_eff_blocked(g.n_points, n_read=3, n_write=3, itemsize=4,
                               nsteps=nsteps)
    host_bw = teff.measure_host_bandwidth()
    return {
        "nsteps": nsteps,
        "soa_us": m_soa.median_s * 1e6,
        "aos_us": m_aos.median_s * 1e6,
        "soa_teff_GBs": m_soa.t_eff(a_eff) / 1e9,
        "aos_teff_GBs": m_aos.t_eff(a_eff) / 1e9,
        "soa_frac_of_host_peak": m_soa.t_eff(a_eff) / host_bw,
        "aos_frac_of_host_peak": m_aos.t_eff(a_eff) / host_bw,
        "soa_frac_of_host_peak_blocked":
            (a_blk / (m_soa_k.median_s / nsteps)) / host_bw,
        "aos_frac_of_host_peak_blocked":
            (a_blk / (m_aos_k.median_s / nsteps)) / host_bw,
        "soa_over_aos": m_aos.median_s / m_soa.median_s,
    }


def main():
    r = bench()
    print(f"layout_soa,{r['soa_us']:.1f},T_eff={r['soa_teff_GBs']:.2f}GB/s "
          f"frac={r['soa_frac_of_host_peak']:.3f} "
          f"frac_blocked_k{r['nsteps']}={r['soa_frac_of_host_peak_blocked']:.3f}")
    print(f"layout_aos,{r['aos_us']:.1f},T_eff={r['aos_teff_GBs']:.2f}GB/s "
          f"frac={r['aos_frac_of_host_peak']:.3f} "
          f"frac_blocked_k{r['nsteps']}={r['aos_frac_of_host_peak_blocked']:.3f}")
    print(f"layout_soa_speedup,{r['soa_over_aos']:.2f},x")
    return r


if __name__ == "__main__":
    main()
