"""Generate the EXPERIMENTS.md roofline/dry-run tables from results/dryrun.

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md

``--telemetry RUN.jsonl`` appends the per-phase timing and error-
trajectory tables of an instrumented run (a ``REPRO_TELEMETRY=`` JSONL
log) to the report — solve wall time split by span, the convergence
trajectory harvested at chunk boundaries, and the roofline-gap gauges.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

HW = "TPU v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link ICI"


def load(dirname="results/dryrun"):
    recs = {}
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(f))
        key = (r["arch"], r["shape"], r["mesh"])
        recs[key] = r
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.2f}ms"


def dominant(r):
    ro = r["roofline"]
    terms = {"compute": ro["t_compute"],
             "memory": r.get("t_memory_analytic", ro["t_memory"]),
             "collective": ro["t_collective"]}
    dom = max(terms, key=terms.get)
    # roofline fraction: dominant ideal time / sum of all terms (serial
    # bound; overlap can push the achieved time toward the dominant term)
    tot = sum(terms.values())
    frac = terms[dom] / tot if tot else 0.0
    return dom, terms, frac


def roofline_table(recs, mesh="16x16"):
    lines = [
        f"| arch | shape | mode | t_compute | t_memory (A_eff) | t_collective | dominant | roofline frac | MODEL/HLO flops | fits HBM |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if not r.get("runnable", True):
            lines.append(f"| {arch} | {shape} | - | - | - | - | skip | - | - | {r.get('skip_reason','')[:40]} |")
            continue
        if "error" in r:
            lines.append(f"| {arch} | {shape} | - | ERROR | | | | | | |")
            continue
        dom, terms, frac = dominant(r)
        mem = r.get("memory", {})
        temp = mem.get("temp_size_in_bytes", 0) / 2**30
        args = mem.get("argument_size_in_bytes", 0) / 2**30
        tot = temp + args
        # XLA-CPU promotes bf16 buffers to f32 (~2x inflation vs TPU-native
        # bf16); cells in the 16.5..33 G band fit on the real device.
        fits = ("yes" if tot <= 16.5 else
                f"yes† ({tot:.0f}G cpu-f32)" if tot <= 33.0 else
                f"NO ({tot:.0f}G)")
        ur = r["roofline"].get("useful_ratio", 0)
        lines.append(
            f"| {arch} | {shape} | {r['mode']} | {fmt_s(terms['compute'])} "
            f"| {fmt_s(terms['memory'])} | {fmt_s(terms['collective'])} "
            f"| {dom} | {frac:.2f} | {ur:.2f} | {fits} |")
    return "\n".join(lines)


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | compile | per-dev HLO FLOPs | per-dev bytes (HLO walk) | collective wire bytes | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in sorted(recs.items()):
        if not r.get("runnable", True):
            continue
        if "error" in r:
            lines.append(f"| {arch} | {shape} | {m} | ERROR | | | | |")
            continue
        ro = r["roofline"]
        cc = r["collectives"]["counts"]
        ccs = " ".join(f"{k.split('-')[-1][:6]}:{v}" for k, v in sorted(cc.items()))
        lines.append(
            f"| {arch} | {shape} | {m} | {r['compile_s']}s | {ro['flops']:.2e} "
            f"| {ro['hbm_bytes']:.2e} | {ro['wire_bytes']:.2e} | {ccs} |")
    return "\n".join(lines)


def telemetry_tables(log_path: str) -> str:
    """Per-phase timing + error-trajectory + roofline tables rendered
    from an instrumented run's JSONL log (same aggregation as
    ``python -m repro.telemetry.report``, embedded in this report)."""
    from repro.telemetry import report as trep, schema as tschema

    records = tschema.load_records(log_path)
    parts = [f"<!-- telemetry: {len(records)} records from {log_path} -->"]
    for title, rows, cols in (
        ("Per-phase timing (telemetry spans)", trep.phase_summary(records),
         ["phase", "count", "total_s", "mean_s", "p50_s", "p90_s", "max_s"]),
        ("Error trajectory (chunk-boundary harvest)",
         trep.error_trajectory(records), ["iters", "err", "per_step_s"]),
        ("Roofline gap (last gauges)",
         [g for g in trep.last_gauges(records)
          if g["gauge"].startswith("roofline.")],
         ["gauge", "labels", "value"]),
    ):
        t = trep.format_table(rows, cols, title)
        if t:
            parts.append("\n" + t)
    return "\n".join(parts)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--telemetry", metavar="RUN.jsonl", default=None,
                    help="append per-phase/error-trajectory tables from an "
                         "instrumented run's telemetry JSONL log")
    args = ap.parse_args(argv)
    recs = load()
    n_ok = sum(1 for r in recs.values() if r.get("runnable") and "error" not in r)
    n_skip = sum(1 for r in recs.values() if not r.get("runnable", True))
    n_err = sum(1 for r in recs.values() if "error" in r)
    print(f"<!-- {len(recs)} cells: {n_ok} compiled, {n_skip} spec-skips, {n_err} errors -->")
    print("\n## Single-pod (16x16 = 256 chips) roofline\n")
    print(roofline_table(recs, "16x16"))
    print("\n## Multi-pod (2x16x16 = 512 chips) dry-run\n")
    print(dryrun_table({k: v for k, v in recs.items() if k[2] == "2x16x16"}))
    if args.telemetry:
        print("\n" + telemetry_tables(args.telemetry))


if __name__ == "__main__":
    main()
