"""Paper §3 "solver translation" table: solvers written through the
framework's @parallel engine vs hand-fused direct-jax implementations,
plus the coupled-engine solver benchmarks.

The paper reports its translated CUDA-C solvers reach 90%/98% of the
originals; here the "original" is a hand-written jax.jit step and the
"translation" is the same physics through repro.core.parallel — the ratio
measures the abstraction's overhead (expected ~1.0: both lower to XLA).

The coupled benches time the two example solvers (reactive porosity
waves, Gross-Pitaevskii) end-to-end through the coupled multi-output
engine: pallas-vs-jnp backend ratio (on CPU hosts pallas runs in
interpret mode — the ratio is a correctness-path record, not a speed
claim) and fused k-step temporal blocking vs k sequential launches.
Results land in ``BENCH_solvers_*.json``.

    PYTHONPATH=src python benchmarks/bench_solvers.py [--quick]
        [--n-porosity 64] [--n-gp 32] [--nsteps 4] [--iters 10] [--json P]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
for _p in (_ROOT, os.path.join(_ROOT, "src")):  # examples + repro importable
    if _p not in sys.path:
        sys.path.insert(0, _p)

import jax
import jax.numpy as jnp

from repro.core import Grid, fd3d as fd, init_parallel_stencil, teff
from repro.kernels import ref


def bench_diffusion_translation(n: int = 96, iters: int = 10):
    g = Grid((n,) * 3)
    key = jax.random.PRNGKey(0)
    T = jax.random.uniform(key, g.shape, jnp.float32)
    Ci = jnp.full(g.shape, 0.5, jnp.float32)
    dt = g.stable_diffusion_dt(2.0)
    inv = g.inv_spacing

    hand = jax.jit(lambda T2, T: ref.diffusion3d_step(T2, T, Ci, 1.0, dt, *inv))

    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",))
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx ** 2 + fd.d2_yi(T) * _dy ** 2 +
            fd.d2_zi(T) * _dz ** 2))}

    framework = jax.jit(lambda T2, T: kern(T2=T2, T=T, Ci=Ci, lam=1.0, dt=dt,
                                           _dx=inv[0], _dy=inv[1], _dz=inv[2]))

    mh = teff.measure(lambda: hand(T, T), iters=iters)
    mf = teff.measure(lambda: framework(T, T), iters=iters)
    return {
        "hand_us": mh.median_s * 1e6,
        "framework_us": mf.median_s * 1e6,
        "translation_efficiency": mh.median_s / mf.median_s,
    }


def bench_gp_translation(n: int = 48, iters: int = 10):
    g = Grid((n,) * 3, (8.0,) * 3)
    key = jax.random.PRNGKey(1)
    re = jax.random.uniform(key, g.shape, jnp.float32) * 0.1
    im = jnp.zeros_like(re)
    V = jnp.zeros_like(re)
    inv2 = tuple(1.0 / d ** 2 for d in g.spacing)
    dt = 0.2 * min(g.spacing) ** 2

    def H_direct(f, re, im):
        lap = ((f[2:, 1:-1, 1:-1] - 2 * f[1:-1, 1:-1, 1:-1] + f[:-2, 1:-1, 1:-1]) * inv2[0]
               + (f[1:-1, 2:, 1:-1] - 2 * f[1:-1, 1:-1, 1:-1] + f[1:-1, :-2, 1:-1]) * inv2[1]
               + (f[1:-1, 1:-1, 2:] - 2 * f[1:-1, 1:-1, 1:-1] + f[1:-1, 1:-1, :-2]) * inv2[2])
        dens = re[1:-1, 1:-1, 1:-1] ** 2 + im[1:-1, 1:-1, 1:-1] ** 2
        return -0.5 * lap + (V[1:-1, 1:-1, 1:-1] + 0.5 * dens) * f[1:-1, 1:-1, 1:-1]

    @jax.jit
    def hand(re, im):
        re = re.at[1:-1, 1:-1, 1:-1].add(dt * H_direct(im, re, im))
        im = im.at[1:-1, 1:-1, 1:-1].add(-dt * H_direct(re, re, im))
        return re, im

    ps = init_parallel_stencil(backend="jnp", ndims=3)

    # V enters as a field argument: the stencil IR traces the kernel, so
    # every array it reads must be visible as an argument (closures over
    # full arrays are untraceable by design).
    def H(f, re, im, V, _dx2, _dy2, _dz2):
        lap = fd.d2_xi(f) * _dx2 + fd.d2_yi(f) * _dy2 + fd.d2_zi(f) * _dz2
        dens = fd.inn(re) ** 2 + fd.inn(im) ** 2
        return -0.5 * lap + (fd.inn(V) + 0.5 * dens) * fd.inn(f)

    @ps.parallel(outputs=("re2",))
    def step_re(re2, re, im, V, dt, _dx2, _dy2, _dz2):
        return {"re2": fd.inn(re) + dt * H(im, re, im, V, _dx2, _dy2, _dz2)}

    @ps.parallel(outputs=("im2",))
    def step_im(im2, re, im, V, dt, _dx2, _dy2, _dz2):
        return {"im2": fd.inn(im) - dt * H(re, re, im, V, _dx2, _dy2, _dz2)}

    @jax.jit
    def framework(re, im):
        re = step_re(re2=re, re=re, im=im, V=V, dt=dt, _dx2=inv2[0],
                     _dy2=inv2[1], _dz2=inv2[2])
        im = step_im(im2=im, re=re, im=im, V=V, dt=dt, _dx2=inv2[0],
                     _dy2=inv2[1], _dz2=inv2[2])
        return re, im

    mh = teff.measure(lambda: hand(re, im), iters=iters)
    mf = teff.measure(lambda: framework(re, im), iters=iters)
    return {
        "hand_us": mh.median_s * 1e6,
        "framework_us": mf.median_s * 1e6,
        "translation_efficiency": mh.median_s / mf.median_s,
    }


# --------------------------------------------------------------------------
# coupled-engine solver benches (pallas-vs-jnp, fused-vs-sequential)
# --------------------------------------------------------------------------
def _measure_backends(make_step_fn, iters):
    """Per-step median seconds per backend for a ``step()`` closure maker."""
    out = {}
    for backend in ("jnp", "pallas"):
        fn = make_step_fn(backend)
        m = teff.measure(fn, iters=iters, warmup=2)
        out[backend] = m.median_s
    out["pallas_over_jnp"] = out["pallas"] / out["jnp"]
    return out


def _fused_vs_sequential(kern, fields, scalars, nsteps, iters):
    """run_steps(k) — ONE temporally-blocked launch — vs k sequential
    rotated calls, per-step seconds. ``kern`` should be a pallas-backend
    kernel: on the jnp backend run_steps IS an unrolled sequential chain,
    so the comparison would measure jit noise. Field arrays are passed as
    jit *arguments* (a zero-arg closure would let XLA constant-fold the
    whole chain and time a no-op)."""
    rot = kern.rotations
    names = tuple(fields)

    def seq_chain(*arrs):
        cur = dict(zip(names, arrs))
        for _ in range(nsteps):
            outs = kern(**cur, **scalars)
            for o, tgt in rot.items():
                cur[o], cur[tgt] = cur[tgt], outs[o]
        return tuple(cur[tgt] for tgt in rot.values())

    def fused_chain(*arrs):
        outs = kern.run_steps(nsteps, **dict(zip(names, arrs)), **scalars)
        return tuple(outs[o] for o in kern.outputs)

    arrs = tuple(fields[n] for n in names)
    ms = teff.measure(lambda: jax.jit(seq_chain)(*arrs), iters=iters, warmup=2)
    mf = teff.measure(lambda: jax.jit(fused_chain)(*arrs), iters=iters,
                      warmup=2)
    return {
        "nsteps": nsteps,
        "backend": kern.ps.backend,
        "sequential_per_step_us": ms.median_s / nsteps * 1e6,
        "fused_per_step_us": mf.median_s / nsteps * 1e6,
        "fused_speedup": ms.median_s / mf.median_s,
    }


def _march_rows(kern, fields, scalars, march_axis: int, iters: int):
    """Streamed-vs-all-parallel record for one coupled kernel: per-step
    medians on both backends through ``kern.marched`` plus a parity check
    against the all-parallel jnp step (CI compiles the streamed path for
    every solver this way)."""
    import numpy as np

    out = {"axis": march_axis}
    ref = kern.marched(None)(**fields, **scalars)
    names = tuple(fields)
    arrs = tuple(fields[n] for n in names)
    for backend in ("jnp", "pallas"):
        k = kern if kern.ps.backend == backend else None
        if k is None:
            from repro.core import init_parallel_stencil
            ps = init_parallel_stencil(backend=backend, ndims=kern.ps.ndims,
                                       dtype=kern.ps.dtype)
            k = ps.parallel(outputs=kern.outputs, tile=kern.tile,
                            rotations=kern.rotations, bc=kern.bc)(kern.fn)
        m = k.marched(march_axis)
        # field arrays as jit *arguments* — a zero-arg closure would let
        # XLA constant-fold the whole chain and time a no-op
        step = jax.jit(lambda *a, m=m: m(**dict(zip(names, a)), **scalars))
        meas = teff.measure(lambda: step(*arrs), iters=iters, warmup=2)
        out[f"{backend}_us"] = meas.median_s * 1e6
        got = step(*arrs)
        for o in kern.outputs:
            np.testing.assert_allclose(np.asarray(got[o]), np.asarray(ref[o]),
                                       atol=1e-5)
    return out


def bench_porosity_coupled(n: int = 64, iters: int = 10, nsteps: int = 4,
                           march_axis: int | None = None):
    """Reactive porosity waves through the coupled (phi, Pe) engine."""
    from examples import porosity_waves as pw

    rows = {"n": n}

    def make(backend):
        cfg = pw.PorosityConfig(n=n, backend=backend)
        grid, phi, Pe = pw.init_state(cfg)
        dtau = pw.timestep(cfg, grid)
        step = jax.jit(pw.make_step(grid, cfg))
        return lambda: step(phi, Pe, dtau)

    b = _measure_backends(make, iters)
    rows["jnp_us"] = b["jnp"] * 1e6
    rows["pallas_us"] = b["pallas"] * 1e6
    rows["pallas_over_jnp"] = b["pallas_over_jnp"]

    cfg = pw.PorosityConfig(n=n, backend="pallas")
    grid, phi, Pe = pw.init_state(cfg)
    kern = pw.make_step(grid, cfg).kernels[0]
    rows["temporal"] = _fused_vs_sequential(
        kern, dict(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe),
        dict(dtau=pw.timestep(cfg, grid)), nsteps, iters)
    if march_axis is not None:
        rows["march"] = _march_rows(
            kern, dict(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe),
            dict(dtau=pw.timestep(cfg, grid)), march_axis, iters)
    return rows


def bench_gp_coupled(n: int = 32, iters: int = 10, nsteps: int = 2,
                     march_axis: int | None = None):
    """Gross-Pitaevskii through the fused coupled radius-2 kernel, plus
    the one-fused-launch vs two-launch comparison."""
    from examples import gross_pitaevskii as gp

    rows = {"n": n}

    def make(backend, fused=True):
        cfg = gp.GPConfig(n=n, backend=backend, fused=fused)
        grid, re, im, V = gp.init_state(cfg)
        dt = gp.timestep(grid)
        step = jax.jit(gp.make_step(grid, cfg))
        return lambda: step(re, im, dt, V)

    b = _measure_backends(make, iters)
    rows["jnp_us"] = b["jnp"] * 1e6
    rows["pallas_us"] = b["pallas"] * 1e6
    rows["pallas_over_jnp"] = b["pallas_over_jnp"]

    m2 = teff.measure(make("jnp", fused=False), iters=iters, warmup=2)
    rows["two_launch_us"] = m2.median_s * 1e6
    rows["fused_over_two_launch"] = rows["jnp_us"] / rows["two_launch_us"]

    cfg = gp.GPConfig(n=n, backend="pallas")
    grid, re, im, V = gp.init_state(cfg)
    dt = gp.timestep(grid)
    kern = gp.make_step(grid, cfg).kernels[0]
    inv2 = tuple(1.0 / d ** 2 for d in grid.spacing)
    rows["temporal"] = _fused_vs_sequential(
        kern, dict(re2=re, im2=im, re=re, im=im, V=V),
        dict(g=cfg.g, dt=dt, _dx2=inv2[0], _dy2=inv2[1], _dz2=inv2[2]),
        nsteps, iters)
    if march_axis is not None:
        rows["march"] = _march_rows(
            kern, dict(re2=re, im2=im, re=re, im=im, V=V),
            dict(g=cfg.g, dt=dt, _dx2=inv2[0], _dy2=inv2[1], _dz2=inv2[2]),
            march_axis, iters)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small grids / few iters (CI smoke)")
    ap.add_argument("--n-porosity", type=int, default=64)
    ap.add_argument("--n-gp", type=int, default=32)
    ap.add_argument("--nsteps", type=int, default=4)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--json", default=None,
                    help="output path (default BENCH_solvers_p{N}_g{N}.json)")
    ap.add_argument("--skip-coupled", action="store_true",
                    help="translation-efficiency table only, no JSON")
    ap.add_argument("--march-axis", type=int, default=None,
                    help="also time the streamed (marching) coupled step "
                         "on both backends and check parity")
    args = ap.parse_args(argv)
    n_diff, n_gp_tr, tr_iters = 96, 48, 10
    if args.quick:
        args.n_porosity = min(args.n_porosity, 32)
        args.n_gp = min(args.n_gp, 16)
        args.iters = min(args.iters, 3)
        n_diff, n_gp_tr, tr_iters = 48, 24, 3

    d = bench_diffusion_translation(n=n_diff, iters=tr_iters)
    print(f"solvers_diffusion_translation,{d['framework_us']:.1f},"
          f"eff={d['translation_efficiency']:.3f}")
    g = bench_gp_translation(n=n_gp_tr, iters=tr_iters)
    print(f"solvers_gp_translation,{g['framework_us']:.1f},"
          f"eff={g['translation_efficiency']:.3f}")
    record = {"diffusion": d, "gp": g}
    if args.skip_coupled:
        return record

    p = bench_porosity_coupled(args.n_porosity, args.iters, args.nsteps,
                               march_axis=args.march_axis)
    print(f"solvers_porosity_coupled_{p['n']},{p['jnp_us']:.1f},"
          f"pallas/jnp={p['pallas_over_jnp']:.2f}")
    print(f"solvers_porosity_fused_k{p['temporal']['nsteps']},"
          f"{p['temporal']['fused_per_step_us']:.1f},"
          f"speedup={p['temporal']['fused_speedup']:.2f}")
    if "march" in p:
        print(f"solvers_porosity_march{p['march']['axis']},"
              f"{p['march']['jnp_us']:.1f},us")
    gc = bench_gp_coupled(args.n_gp, args.iters, max(2, args.nsteps // 2),
                          march_axis=args.march_axis)
    print(f"solvers_gp_coupled_{gc['n']},{gc['jnp_us']:.1f},"
          f"pallas/jnp={gc['pallas_over_jnp']:.2f}")
    print(f"solvers_gp_fused_vs_two_launch,{gc['jnp_us']:.1f},"
          f"ratio={gc['fused_over_two_launch']:.2f}")
    if "march" in gc:
        print(f"solvers_gp_march{gc['march']['axis']},"
              f"{gc['march']['jnp_us']:.1f},us")
    record["porosity_coupled"] = p
    record["gp_coupled"] = gc

    path = args.json or f"BENCH_solvers_p{p['n']}_g{gc['n']}.json"
    try:
        from ._meta import bench_meta
    except ImportError:
        from _meta import bench_meta
    with open(path, "w") as f:
        json.dump({"rows": record,
                   "backend": jax.default_backend(),
                   "note": ("pallas interpret-mode on non-TPU hosts; "
                            "ratios are correctness-path records there"),
                   "meta": bench_meta()},
                  f, indent=1)
    print(f"# wrote {path}")
    return record


if __name__ == "__main__":
    main()
