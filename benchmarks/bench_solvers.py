"""Paper §3 "solver translation" table: solvers written through the
framework's @parallel engine vs hand-fused direct-jax implementations.

The paper reports its translated CUDA-C solvers reach 90%/98% of the
originals; here the "original" is a hand-written jax.jit step and the
"translation" is the same physics through repro.core.parallel — the ratio
measures the abstraction's overhead (expected ~1.0: both lower to XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import Grid, fd3d as fd, init_parallel_stencil, teff
from repro.kernels import ref


def bench_diffusion_translation(n: int = 96, iters: int = 10):
    g = Grid((n,) * 3)
    key = jax.random.PRNGKey(0)
    T = jax.random.uniform(key, g.shape, jnp.float32)
    Ci = jnp.full(g.shape, 0.5, jnp.float32)
    dt = g.stable_diffusion_dt(2.0)
    inv = g.inv_spacing

    hand = jax.jit(lambda T2, T: ref.diffusion3d_step(T2, T, Ci, 1.0, dt, *inv))

    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",))
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx ** 2 + fd.d2_yi(T) * _dy ** 2 +
            fd.d2_zi(T) * _dz ** 2))}

    framework = jax.jit(lambda T2, T: kern(T2=T2, T=T, Ci=Ci, lam=1.0, dt=dt,
                                           _dx=inv[0], _dy=inv[1], _dz=inv[2]))

    mh = teff.measure(lambda: hand(T, T), iters=iters)
    mf = teff.measure(lambda: framework(T, T), iters=iters)
    return {
        "hand_us": mh.median_s * 1e6,
        "framework_us": mf.median_s * 1e6,
        "translation_efficiency": mh.median_s / mf.median_s,
    }


def bench_gp_translation(n: int = 48, iters: int = 10):
    g = Grid((n,) * 3, (8.0,) * 3)
    key = jax.random.PRNGKey(1)
    re = jax.random.uniform(key, g.shape, jnp.float32) * 0.1
    im = jnp.zeros_like(re)
    V = jnp.zeros_like(re)
    inv2 = tuple(1.0 / d ** 2 for d in g.spacing)
    dt = 0.2 * min(g.spacing) ** 2

    def H_direct(f, re, im):
        lap = ((f[2:, 1:-1, 1:-1] - 2 * f[1:-1, 1:-1, 1:-1] + f[:-2, 1:-1, 1:-1]) * inv2[0]
               + (f[1:-1, 2:, 1:-1] - 2 * f[1:-1, 1:-1, 1:-1] + f[1:-1, :-2, 1:-1]) * inv2[1]
               + (f[1:-1, 1:-1, 2:] - 2 * f[1:-1, 1:-1, 1:-1] + f[1:-1, 1:-1, :-2]) * inv2[2])
        dens = re[1:-1, 1:-1, 1:-1] ** 2 + im[1:-1, 1:-1, 1:-1] ** 2
        return -0.5 * lap + (V[1:-1, 1:-1, 1:-1] + 0.5 * dens) * f[1:-1, 1:-1, 1:-1]

    @jax.jit
    def hand(re, im):
        re = re.at[1:-1, 1:-1, 1:-1].add(dt * H_direct(im, re, im))
        im = im.at[1:-1, 1:-1, 1:-1].add(-dt * H_direct(re, re, im))
        return re, im

    ps = init_parallel_stencil(backend="jnp", ndims=3)

    def H(f, re, im, _dx2, _dy2, _dz2):
        lap = fd.d2_xi(f) * _dx2 + fd.d2_yi(f) * _dy2 + fd.d2_zi(f) * _dz2
        dens = fd.inn(re) ** 2 + fd.inn(im) ** 2
        return -0.5 * lap + (fd.inn(V) + 0.5 * dens) * fd.inn(f)

    @ps.parallel(outputs=("re2",))
    def step_re(re2, re, im, dt, _dx2, _dy2, _dz2):
        return {"re2": fd.inn(re) + dt * H(im, re, im, _dx2, _dy2, _dz2)}

    @ps.parallel(outputs=("im2",))
    def step_im(im2, re, im, dt, _dx2, _dy2, _dz2):
        return {"im2": fd.inn(im) - dt * H(re, re, im, _dx2, _dy2, _dz2)}

    @jax.jit
    def framework(re, im):
        re = step_re(re2=re, re=re, im=im, dt=dt, _dx2=inv2[0], _dy2=inv2[1],
                     _dz2=inv2[2])
        im = step_im(im2=im, re=re, im=im, dt=dt, _dx2=inv2[0], _dy2=inv2[1],
                     _dz2=inv2[2])
        return re, im

    mh = teff.measure(lambda: hand(re, im), iters=iters)
    mf = teff.measure(lambda: framework(re, im), iters=iters)
    return {
        "hand_us": mh.median_s * 1e6,
        "framework_us": mf.median_s * 1e6,
        "translation_efficiency": mh.median_s / mf.median_s,
    }


def main():
    d = bench_diffusion_translation()
    print(f"solvers_diffusion_translation,{d['framework_us']:.1f},"
          f"eff={d['translation_efficiency']:.3f}")
    g = bench_gp_translation()
    print(f"solvers_gp_translation,{g['framework_us']:.1f},"
          f"eff={g['translation_efficiency']:.3f}")
    return {"diffusion": d, "gp": g}


if __name__ == "__main__":
    main()
