"""Benchmark driver — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV lines per the harness contract:
  * Fig. 2  — T_eff of 3-D diffusion, fused kernel vs array broadcasting
  * §3      — solver-translation efficiency (diffusion + Gross-Pitaevskii)
  * §3      — weak scaling, sequential vs hidden-communication halo steps
  * §Roofline — summary of the dry-run derived rooflines (reads
               results/dryrun if present; see launch/dryrun.py)
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def roofline_summary(dryrun_dir: str = "results/dryrun"):
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*__single.json")))
    if not files:
        print("roofline_summary,0,no dry-run records (run repro.launch.dryrun)")
        return []
    rows = []
    for f in files:
        r = json.load(open(f))
        if not r.get("runnable") or "roofline" not in r:
            continue
        ro = r["roofline"]
        t_mem = r.get("t_memory_analytic", ro["t_memory"])
        terms = {"compute": ro["t_compute"], "memory": t_mem,
                 "collective": ro["t_collective"]}
        dom = max(terms, key=terms.get)
        bound = terms[dom]
        rows.append({"arch": r["arch"], "shape": r["shape"], "dominant": dom,
                     **{f"t_{k}": v for k, v in terms.items()}})
        print(f"roofline_{r['arch']}_{r['shape']},{bound*1e6:.0f},dom={dom}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-scaling", action="store_true")
    args = ap.parse_args()

    from benchmarks import bench_teff, bench_solvers

    print("# --- Fig. 2: T_eff, kernel vs broadcast ---")
    if args.quick:
        from repro.configs.diffusion3d import BENCH_128
        rows = bench_teff.bench(BENCH_128, iters=5)
        for r in rows:
            print(f"teff_{r['name']}_{r['n']},{r['median_s']*1e6:.1f},"
                  f"T_eff={r['t_eff_GBs']:.2f}GB/s")
    else:
        bench_teff.main()

    print("# --- paper S3: solver translation efficiency ---")
    bench_solvers.main(["--skip-coupled"] if args.quick else [])

    print("# --- paper C5: SoA vs AoS data layout ---")
    from benchmarks import bench_layout
    bench_layout.main()

    if not args.skip_scaling:
        print("# --- paper S3: weak scaling w/ hidden communication ---")
        from benchmarks import bench_scaling
        bench_scaling.main(["--quick"] if args.quick else [])

    print("# --- roofline: dry-run derived ---")
    roofline_summary()


if __name__ == "__main__":
    main()
