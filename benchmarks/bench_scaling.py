"""Paper §3 scaling claim (95% parallel efficiency at 1024 GPUs via hidden
communication) plus the PR-6 fault-tolerance cost model, measured on fake
CPU devices through the real engine:

* **Weak scaling** (``scale_seq_r{R}`` / ``scale_ovl_r{R}`` rows): fixed
  local block per rank, domain grows with the rank count; one
  ``overlap.sequential_step`` vs ``overlap.overlapped_step`` timing per
  mesh size via ``shard_map`` — the same code path the distributed tests
  and ``elastic_solve_until`` drive.
* **Checkpoint overhead** (``ckpt_m{M}`` rows): the chunked
  ``solve_until`` driver with async checkpointing at save-every-M checks
  (M in {10, 100}) vs the uninterrupted single-``while_loop`` solve
  (``ckpt_minf``).  Per-step times are the difference of a LONG and a
  SHORT run, so one-off jit compile cost cancels and the rows measure
  pure steady-state step+save cost.  The PR-6 acceptance bar: the
  ``ckpt_m100`` row must sit within 5% of ``ckpt_minf``
  (``--check-overhead`` turns that into a hard exit code).

Each measurement runs in a subprocess so the parent keeps one device and
the XLA device-count flag can vary per row.  Rows carry ``name`` / ``n``
/ ``nsteps`` / ``per_step_s`` so ``benchmarks/compare.py`` guards them
like any other teff-family record (``BENCH_scaling*.json``).

    PYTHONPATH=src python benchmarks/bench_scaling.py [--quick] [--json]
        [--check-overhead]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

try:
    from ._meta import bench_meta   # imported as benchmarks.bench_scaling
except ImportError:
    from _meta import bench_meta    # run as a script

_SCALE_CHILD = r"""
import json, os, numpy as np, jax, jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import init_parallel_stencil, fd3d as fd
from repro.distributed import overlap
from repro.launch.mesh import make_mesh
import repro.core.teff as teff

n_dev = int(jax.device_count())
LOC = int(os.environ["BENCH_LOC"])
ITERS = int(os.environ["BENCH_ITERS"])
mesh = make_mesh((n_dev,), ("x",))
ps = init_parallel_stencil(backend="jnp", ndims=3)

@ps.parallel(outputs=("T2",))
def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
    return {"T2": fd.inn(T) + dt*(lam*fd.inn(Ci)*(fd.d2_xi(T)*_dx**2
            + fd.d2_yi(T)*_dy**2 + fd.d2_zi(T)*_dz**2))}

sc = dict(lam=1.0, dt=1e-4, _dx=1.0, _dy=1.0, _dz=1.0)
rng = np.random.RandomState(0)
# weak scaling: fixed local block (planes of a 3-D bar), domain grows
# with devices
shape = (n_dev, LOC + 2, 64, 64)
T = jnp.asarray(rng.rand(*shape), jnp.float32)
Ci = jnp.ones_like(T)

def make(step_fn):
    def local(Tl, Cl):
        Tl, Cl = Tl[0], Cl[0]
        out, _ = step_fn(kern, dict(T2=Tl, T=Tl, Ci=Cl), sc, ("T",), ("x",))
        return out[None]
    f = shard_map(local, mesh=mesh, in_specs=(P("x"), P("x")),
                  out_specs=P("x"), check_vma=False)
    return jax.jit(f)

res = {}
for name, fn in [("sequential", overlap.sequential_step),
                 ("overlapped", overlap.overlapped_step)]:
    step = make(fn)
    m = teff.measure(lambda: step(T, Ci), iters=ITERS, warmup=3)
    res[name] = m.median_s
print("RESULT " + json.dumps(res))
"""

_CKPT_CHILD = r"""
import json, os, shutil, tempfile, time
import jax.numpy as jnp
from repro.core import init_parallel_stencil, fd3d as fd, iterate

N = int(os.environ["BENCH_N"])
SHORT = int(os.environ["BENCH_SHORT"])
LONG = int(os.environ["BENCH_LONG"])
M = int(os.environ["BENCH_M"])          # <= 0: no checkpointing

ps = init_parallel_stencil(backend="jnp", ndims=3)

@ps.parallel(outputs=("T2",), rotations={"T2": "T"},
             reductions={"err": "max_abs_diff(T2, T)"})
def kern(T2, T, dt):
    return {"T2": fd.inn(T) + dt * (fd.d2_xi(T) + fd.d2_yi(T)
                                    + fd.d2_zi(T))}

T0 = jnp.zeros((N, N, N), jnp.float32).at[N // 2, N // 2, N // 2].set(1.0)

def run(iters):
    ck, tmp = None, None
    if M > 0:
        tmp = tempfile.mkdtemp(prefix="bench_ck_")
        ck = iterate.Checkpointing(tmp, save_every=M, resume=False,
                                   blocking=False)
    t0 = time.perf_counter()
    res = iterate.solve_until(kern, dict(T2=T0, T=T0), dict(dt=1e-4),
                              tol=0.0, max_iters=iters, check_every=1,
                              checkpoint=ck)
    n_done = int(res.iters)          # block: the plain path is async
    dt = time.perf_counter() - t0
    assert n_done == iters, (n_done, iters)
    if tmp is not None:
        shutil.rmtree(tmp, ignore_errors=True)
    return dt

# LONG - SHORT cancels the (identical) jit compile of the two runs,
# leaving LONG-SHORT steps of steady-state step + amortized save cost.
t_short = run(SHORT)
t_long = run(LONG)
per_step = (t_long - t_short) / (LONG - SHORT)
print("RESULT " + json.dumps({"per_step_s": per_step}))
"""


def _run_child(code: str, n_dev: int, env_extra: dict) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = "src"
    env.update({k: str(v) for k, v in env_extra.items()})
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=560,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))) or ".")
    if p.returncode != 0:
        raise RuntimeError(p.stderr[-2000:])
    for line in p.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError("no RESULT line in child output")


def weak_scaling_rows(devices, loc: int, iters: int) -> list[dict]:
    rows, base = [], None
    for n in devices:
        r = _run_child(_SCALE_CHILD, n,
                       {"BENCH_LOC": loc, "BENCH_ITERS": iters})
        seq, ovl = r["sequential"], r["overlapped"]
        if base is None:
            base = ovl
        eff = base / ovl  # weak scaling: perfect = 1.0
        rows.append({"name": f"scale_seq_r{n}", "n": loc, "nsteps": iters,
                     "per_step_s": seq, "ranks": n})
        rows.append({"name": f"scale_ovl_r{n}", "n": loc, "nsteps": iters,
                     "per_step_s": ovl, "ranks": n,
                     "weak_efficiency": eff, "overlap_gain": seq / ovl})
        print(f"scale r={n}: seq {seq*1e6:.0f}us ovl {ovl*1e6:.0f}us "
              f"eff={eff:.3f} overlap_gain={seq/ovl:.3f}")
    return rows


def checkpoint_rows(n: int, short: int, long_: int,
                    save_everys=(10, 100), repeats: int = 3) -> list[dict]:
    """``ckpt_m{M}`` rows vs the ``ckpt_minf`` no-checkpoint baseline;
    min of ``repeats`` child runs per configuration (the noise floor —
    medians still carry scheduler jitter comparable to the 5% gate)."""

    def measure(m):
        vals = [_run_child(_CKPT_CHILD, 1,
                           {"BENCH_N": n, "BENCH_SHORT": short,
                            "BENCH_LONG": long_, "BENCH_M": m})["per_step_s"]
                for _ in range(repeats)]
        return min(vals)

    base = measure(0)
    rows = [{"name": "ckpt_minf", "n": n, "nsteps": long_ - short,
             "per_step_s": base}]
    print(f"ckpt m=inf: {base*1e6:.0f}us/step (no checkpointing)")
    for m in save_everys:
        t = measure(m)
        frac = t / base - 1.0
        rows.append({"name": f"ckpt_m{m}", "n": n,
                     "nsteps": long_ - short, "per_step_s": t,
                     "save_every": m, "overhead_frac": frac})
        print(f"ckpt m={m}: {t*1e6:.0f}us/step "
              f"(overhead {frac:+.1%} vs no-checkpoint)")
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 1-2 ranks, short runs, 1 repeat")
    ap.add_argument("--json", action="store_true",
                    help="record rows to BENCH_scaling_r{max}.json")
    ap.add_argument("--check-overhead", action="store_true",
                    help="exit 1 unless the ckpt_m100 row is within 5%% "
                         "of the no-checkpoint baseline")
    args = ap.parse_args(argv)

    if args.quick:
        devices, loc, iters = (1, 2), 32, 5
        n, short, long_, repeats = 32, 50, 250, 1
    else:
        devices, loc, iters = (1, 2, 4, 8), 64, 10
        n, short, long_, repeats = 64, 100, 500, 3

    rows = weak_scaling_rows(devices, loc, iters)
    rows += checkpoint_rows(n, short, long_, repeats=repeats)

    if args.json:
        path = f"BENCH_scaling_r{max(devices)}.json"
        with open(path, "w") as f:
            json.dump({"rows": rows, "meta": bench_meta()}, f, indent=1)
        print(f"wrote {path}")

    if args.check_overhead:
        m100 = next(r for r in rows if r["name"] == "ckpt_m100")
        if m100["overhead_frac"] >= 0.05:
            print(f"FAIL: save-every-100 checkpoint overhead "
                  f"{m100['overhead_frac']:.1%} >= 5%")
            return 1
        print(f"checkpoint overhead gate OK: "
              f"{m100['overhead_frac']:+.1%} < 5%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
