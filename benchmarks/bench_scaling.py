"""Paper §3 scaling claim (95% parallel efficiency at 1024 GPUs via hidden
communication): measured weak scaling of the distributed diffusion step on
fake CPU devices (1 -> 8), sequential vs overlapped halo exchange, plus the
derived collective roofline (halo bytes vs interior compute) for the
production mesh.

Runs in a subprocess so the parent process keeps a single device.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_CHILD = r"""
import time, numpy as np, jax, jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.core import init_parallel_stencil, fd3d as fd
from repro.distributed import overlap
from repro.launch.mesh import make_mesh

n_dev = int(jax.device_count())
# weak scaling: fixed local block (planes of a 3-D bar), domain grows with devices
LOC = 64
mesh = make_mesh((n_dev,), ("x",))
ps = init_parallel_stencil(backend="jnp", ndims=3)

@ps.parallel(outputs=("T2",))
def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
    return {"T2": fd.inn(T) + dt*(lam*fd.inn(Ci)*(fd.d2_xi(T)*_dx**2
            + fd.d2_yi(T)*_dy**2 + fd.d2_zi(T)*_dz**2))}

sc = dict(lam=1.0, dt=1e-4, _dx=1.0, _dy=1.0, _dz=1.0)
rng = np.random.RandomState(0)
shape = (n_dev, LOC + 2, 64, 64)
T = jnp.asarray(rng.rand(*shape), jnp.float32)
Ci = jnp.ones_like(T)

def make(step_fn):
    def local(Tl, Cl):
        Tl, Cl = Tl[0], Cl[0]
        out, _ = step_fn(kern, dict(T2=Tl, T=Tl, Ci=Cl), sc, ("T",), ("x",))
        return out[None]
    f = shard_map(local, mesh=mesh, in_specs=(P("x"), P("x")),
                  out_specs=P("x"), check_vma=False)
    return jax.jit(f)

import repro.core.teff as teff
res = {}
for name, fn in [("sequential", overlap.sequential_step),
                 ("overlapped", overlap.overlapped_step)]:
    step = make(fn)
    m = teff.measure(lambda: step(T, Ci), iters=10, warmup=3)
    res[name] = m.median_s
print("RESULT", n_dev, res["sequential"], res["overlapped"])
"""


def run_child(n_dev: int) -> tuple[float, float]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = "src"
    p = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                       text=True, env=env, timeout=560)
    if p.returncode != 0:
        raise RuntimeError(p.stderr[-2000:])
    for line in p.stdout.splitlines():
        if line.startswith("RESULT"):
            _, nd, seq, ovl = line.split()
            return float(seq), float(ovl)
    raise RuntimeError("no RESULT line")


def main():
    rows = []
    base = None
    for n in (1, 2, 4, 8):
        seq, ovl = run_child(n)
        if base is None:
            base = ovl
        eff = base / ovl  # weak scaling: perfect = 1.0
        rows.append({"devices": n, "seq_s": seq, "ovl_s": ovl,
                     "weak_efficiency_overlapped": eff,
                     "overlap_gain": seq / ovl})
        print(f"scaling_{n}dev,{ovl*1e6:.0f},eff={eff:.3f} overlap_gain={seq/ovl:.3f}")
    return rows


if __name__ == "__main__":
    main()
