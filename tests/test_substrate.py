"""Losses, optimizer, data, checkpoint, fault handling, HLO analyzer."""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import losses as lo
from repro.optim import adamw, schedules
from repro.checkpoint import CheckpointManager
from repro.distributed import fault
from repro.launch.hlo_analysis import ModuleCost


# ---------------- losses ----------------
def test_chunked_xent_matches_direct(rng):
    B, L, D, V = 2, 24, 16, 64
    h = jnp.asarray(rng.randn(B, L, D), jnp.float32)
    w = jnp.asarray(rng.randn(D, V) * 0.1, jnp.float32)
    labels = jnp.asarray(rng.randint(0, V, (B, L)), jnp.int32)
    labels = labels.at[0, :5].set(lo.IGNORE)
    got = lo.chunked_softmax_xent(h, w, labels, chunk=7)
    logits = h @ w
    logp = jax.nn.log_softmax(logits, -1)
    mask = labels != lo.IGNORE
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    want = -jnp.sum(jnp.where(mask, ll, 0)) / jnp.sum(mask)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_xent_grad_matches(rng):
    B, L, D, V = 1, 16, 8, 32
    h = jnp.asarray(rng.randn(B, L, D), jnp.float32)
    w = jnp.asarray(rng.randn(D, V) * 0.1, jnp.float32)
    labels = jnp.asarray(rng.randint(0, V, (B, L)), jnp.int32)
    g1 = jax.grad(lambda w: lo.chunked_softmax_xent(h, w, labels, chunk=4))(w)
    def direct(w):
        logp = jax.nn.log_softmax(h @ w, -1)
        ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
        return -jnp.mean(ll)
    g2 = jax.grad(direct)(w)
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-4)


# ---------------- optimizer ----------------
def test_adamw_bf16_master_weights():
    cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.0, warmup_steps=1,
                            total_steps=100, schedule="const")
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw.init(params, cfg)
    assert "master" in st and st["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 1e-3, jnp.float32)}
    p1, st, _ = adamw.apply(params, g, st, cfg)
    # master accumulates small updates that bf16 alone would lose
    for _ in range(10):
        p1, st, _ = adamw.apply(p1, g, st, cfg)
    assert p1["w"].dtype == jnp.bfloat16
    assert float(st["master"]["w"][0]) < 1.0


def test_grad_accumulation_matches_full_batch(rng):
    W = jnp.asarray(rng.randn(8, 4), jnp.float32)
    xs = jnp.asarray(rng.randn(16, 8), jnp.float32)
    ys = jnp.asarray(rng.randn(16, 4), jnp.float32)

    def loss(p, b):
        return jnp.mean((b["x"] @ p - b["y"]) ** 2)

    full_g = jax.grad(loss)(W, {"x": xs, "y": ys})
    mb = {"x": xs.reshape(4, 4, 8), "y": ys.reshape(4, 4, 4)}
    acc_g, _ = adamw.accumulate_grads(loss, W, mb, 4)
    np.testing.assert_allclose(acc_g, full_g, atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0), "b": jnp.full((10,), -10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 40


def test_wsd_schedule_phases():
    f = schedules.wsd
    total, warm = 1000, 50
    assert float(f(10, 1.0, warm, total)) == pytest.approx(0.2)
    assert float(f(500, 1.0, warm, total)) == 1.0       # stable plateau
    assert float(f(999, 1.0, warm, total)) < 0.05        # decay tail
    # monotone decay in the tail
    xs = [float(f(s, 1.0, warm, total)) for s in range(900, 1000, 10)]
    assert all(a >= b for a, b in zip(xs, xs[1:]))


# ---------------- checkpoint ----------------
def test_checkpoint_atomicity_on_partial_write(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"w": jnp.asarray(rng.randn(4, 4), jnp.float32)}
    mgr.save(1, tree)
    # simulate a crashed writer: leave a stale tmp dir + torn manifest
    os.makedirs(tmp_path / "step_000000002.tmp")
    with open(tmp_path / "step_000000002.tmp" / "manifest.json", "w") as f:
        f.write('{"truncat')
    restored, extra = mgr.restore({"w": jnp.zeros((4, 4))})
    assert extra["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((5,))})


def test_async_save_error_surfaces(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(np, "save", boom)
    with pytest.raises((RuntimeError, OSError)):
        mgr.save(1, {"w": jnp.zeros((2,))}, blocking=False)
        mgr.wait()


# ---------------- fault / stragglers ----------------
def test_straggler_and_dead_detection(tmp_path):
    mons = [fault.StepMonitor(host_id=i, heartbeat_dir=str(tmp_path),
                              straggler_factor=1.5, timeout_s=100)
            for i in range(4)]
    now = time.time()
    for i, m in enumerate(mons):
        for step in range(5):
            m.record(step, 1.0 if i != 2 else 3.0)  # host 2 is slow
    health = mons[0].check_peers()
    assert health["stragglers"] == [2]
    assert health["dead"] == []
    # host 3 goes silent
    data = json.load(open(tmp_path / "host_3.json"))
    data["t"] = now - 1000
    json.dump(data, open(tmp_path / "host_3.json", "w"))
    health = mons[0].check_peers()
    assert 3 in health["dead"]


def test_retry_recovers():
    calls = {"n": 0}
    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return 42
    assert fault.retry(flaky, attempts=5, backoff_s=0.0) == 42


# ---------------- HLO analyzer calibration ----------------
def test_analyzer_matches_cost_analysis_on_matmul():
    def f(x, w):
        return x @ w
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 16), jnp.float32)).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    mc = ModuleCost(c.as_text()).cost()
    assert mc.flops == pytest.approx(float(ca["flops"]))


def test_analyzer_multiplies_scan_trip_count():
    def f(x, W):
        def body(h, w):
            return h @ w, None
        return jax.lax.scan(body, x, W)[0]
    flops = {}
    for n in (2, 8):
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((16, 32), jnp.float32),
            jax.ShapeDtypeStruct((n, 32, 32), jnp.float32)).compile()
        flops[n] = ModuleCost(c.as_text()).cost().flops
        assert flops[n] == pytest.approx(n * 2 * 16 * 32 * 32)
    # and cost_analysis does NOT (the reason the analyzer exists)
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert float(ca["flops"]) < flops[8]


def test_analyzer_inplace_cache_update_bytes():
    def g(cache, upd, i):
        return jax.lax.dynamic_update_slice_in_dim(cache, upd, i, axis=0)
    c = jax.jit(g, donate_argnums=(0,)).lower(
        jax.ShapeDtypeStruct((100000, 64), jnp.float32),
        jax.ShapeDtypeStruct((1, 64), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32)).compile()
    mc = ModuleCost(c.as_text()).cost()
    assert mc.bytes < 10000  # touched bytes only, not the 25 MB cache
