"""Fused in-launch reduction epilogues + device-resident iteration.

Tolerance contract (the reassociation rule): a reduction's value is
bitwise-reproducible only WITHIN one compiled program. jnp-vs-pallas,
fused-vs-post-pass and fused-vs-host-loop comparisons are two separately
compiled programs that fold in different orders (and contract FMAs
differently), so every cross-program assertion here is ``allclose``
(atol ~1e-6 / small rtol), never equality.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import fd2d, fd3d, init_parallel_stencil, iterate, teff
from repro.ir import Reduction

ALL_REDS = {"err": "max_abs_diff(T2, T)", "mx": "max_abs(T2)",
            "s": "sum(T2)", "m2": "sum_sq(T2)"}


def diffusion_kernel(backend, reductions=ALL_REDS, tile=None, bc=None,
                     march_axis=None):
    ps = init_parallel_stencil(backend=backend, ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"}, tile=tile, bc=bc,
                 march_axis=march_axis, reductions=reductions)
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd3d.inn(T) + dt * (lam * fd3d.inn(Ci) * (
            fd3d.d2_xi(T) * _dx ** 2 + fd3d.d2_yi(T) * _dy ** 2 +
            fd3d.d2_zi(T) * _dz ** 2))}

    return kern


def setup3d(rng, shape=(16, 16, 16)):
    T = jnp.asarray(rng.rand(*shape), jnp.float32)
    Ci = jnp.asarray(rng.rand(*shape) + 0.5, jnp.float32)
    sc = dict(lam=1.0, dt=1e-3, _dx=1.0, _dy=1.0, _dz=1.0)
    return T, Ci, sc


def post_pass(out, T):
    """The separate-norm-pass reference for ALL_REDS."""
    return {"err": jnp.max(jnp.abs(out - T)), "mx": jnp.max(jnp.abs(out)),
            "s": jnp.sum(out), "m2": jnp.sum(out ** 2)}


def assert_reds_close(got, want, rtol=1e-4):
    assert set(got) == set(want)
    for n in want:
        np.testing.assert_allclose(float(got[n]), float(want[n]), rtol=rtol,
                                   err_msg=n)


# ---------------------------------------------------------------- spec layer
def test_reduction_spec_validation():
    with pytest.raises(ValueError, match="must be one of"):
        Reduction("l7_norm", "T2")
    with pytest.raises(ValueError, match="two operands"):
        Reduction("max_abs_diff", "T2")
    with pytest.raises(ValueError, match="one operand"):
        Reduction("sum", "T2", "T")
    r = Reduction("max_abs_diff", "T2", "T")
    assert r.operands == ("T2", "T") and r.combine == "max"
    assert Reduction("sum_sq", "psi").combine == "sum"


def test_reduction_string_parsing(rng):
    # compact string form == explicit dataclass form
    T, Ci, sc = setup3d(rng)
    ka = diffusion_kernel("jnp", {"err": "max_abs_diff(T2, T)"})
    kb = diffusion_kernel("jnp", {"err": Reduction("max_abs_diff",
                                                   "T2", "T")})
    _, ra = ka(T2=T, T=T, Ci=Ci, **sc)
    _, rb = kb(T2=T, T=T, Ci=Ci, **sc)
    assert float(ra["err"]) == float(rb["err"])
    with pytest.raises(ValueError, match="cannot parse"):
        diffusion_kernel("jnp", {"err": "max_abs_diff"})(
            T2=T, T=T, Ci=Ci, **sc)


def test_unknown_operand_rejected(rng):
    T, Ci, sc = setup3d(rng)
    kern = diffusion_kernel("jnp", {"err": "max_abs(Q)"})
    with pytest.raises(ValueError, match="not a field"):
        kern(T2=T, T=T, Ci=Ci, **sc)


def test_periodic_bc_incompatible():
    from repro.ir import BoundaryCondition
    with pytest.raises(ValueError, match="periodic"):
        diffusion_kernel("jnp", bc={"T2": BoundaryCondition("periodic")})


# ------------------------------------------------------------ backend parity
def test_jnp_fused_equals_post_pass(rng):
    T, Ci, sc = setup3d(rng)
    kern = diffusion_kernel("jnp")
    out, reds = kern(T2=T, T=T, Ci=Ci, **sc)
    assert_reds_close(reds, post_pass(out, T), rtol=1e-6)


def test_pallas_fused_vs_jnp_and_post_pass(rng):
    T, Ci, sc = setup3d(rng)
    out_j, reds_j = diffusion_kernel("jnp")(T2=T, T=T, Ci=Ci, **sc)
    out_p, reds_p = diffusion_kernel("pallas")(T2=T, T=T, Ci=Ci, **sc)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_j),
                               atol=1e-6)
    assert_reds_close(reds_p, reds_j)
    assert_reds_close(reds_p, post_pass(out_j, T))


def test_pallas_multiblock_partials(rng):
    # several grid tiles per axis: the per-tile partials must tile the
    # whole-array fold without overlap or holes
    T, Ci, sc = setup3d(rng, shape=(16, 16, 16))
    kern = diffusion_kernel("pallas", tile=(4, 8, 16))
    out, reds = kern(T2=T, T=T, Ci=Ci, **sc)
    assert kern.launch_info  # compiled
    (info,) = kern.launch_info.values()
    assert info["grid"] == (4, 2, 1)
    assert_reds_close(reds, post_pass(out, T))


def test_bc_applied_before_reduction(rng):
    # a dirichlet ring pins T2's faces to 0, so max_abs(T2) must see the
    # POST-bc values — fused path == post-pass on the bc'd output
    from repro.ir import BoundaryCondition
    T, Ci, sc = setup3d(rng)
    bc = {"T2": BoundaryCondition("dirichlet", value=0.0)}
    for backend in ("jnp", "pallas"):
        kern = diffusion_kernel(backend, bc=bc)
        out, reds = kern(T2=T, T=T, Ci=Ci, **sc)
        assert_reds_close(reds, post_pass(out, T))


def test_run_steps_reduces_final_sweep_only(rng):
    # k-fused launch's reduction == the check a sequential k-step loop
    # computes after its LAST step (diff of step k vs step k-1)
    T, Ci, sc = setup3d(rng)
    for backend in ("jnp", "pallas"):
        kern = diffusion_kernel(backend)
        plain = kern.with_reductions(None)
        cur = dict(T2=T, T=T)
        for _ in range(3):
            prev = cur["T"]
            out = plain(T2=cur["T2"], T=cur["T"], Ci=Ci, **sc)
            cur = dict(T2=prev, T=out)
        want = post_pass(cur["T"], cur["T2"])
        outk, redk = kern.run_steps(3, T2=T, T=T, Ci=Ci, **sc)
        np.testing.assert_allclose(np.asarray(outk), np.asarray(cur["T"]),
                                   atol=1e-6)
        assert_reds_close(redk, want)


# ------------------------------------------------------------ streamed path
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_march_reduction_parity(backend, rng):
    # the lagged partials of the sequential march grid (priming writes
    # overwritten, drain flushing the tail) must equal the all-parallel
    # fold
    T, Ci, sc = setup3d(rng, shape=(24, 16, 16))
    kern = diffusion_kernel(backend)
    out_ref, reds_ref = kern(T2=T, T=T, Ci=Ci, **sc)
    marched = kern.marched(0)
    out_m, reds_m = marched(T2=T, T=T, Ci=Ci, **sc)
    if backend == "pallas":
        (info,) = (v for v in marched._cache.values())
        assert info.march_axis == 0 and not info.march_fallback
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_ref),
                               atol=1e-6)
    assert_reds_close(reds_m, reds_ref)


def test_march_ksteps_reduction_parity(rng):
    T, Ci, sc = setup3d(rng, shape=(24, 16, 16))
    for backend in ("jnp", "pallas"):
        kern = diffusion_kernel(backend)
        _, reds_ref = kern.run_steps(2, T2=T, T=T, Ci=Ci, **sc)
        out, reds = kern.marched(0).run_steps(2, T2=T, T=T, Ci=Ci, **sc)
        assert_reds_close(reds, reds_ref)


# ---------------------------------------------------------- coupled systems
def porosity_kernel(backend, reductions):
    ps = init_parallel_stencil(backend=backend, ndims=2)

    @ps.parallel(outputs=("phi2", "Pe2"),
                 rotations={"phi2": "phi", "Pe2": "Pe"},
                 reductions=reductions)
    def update(phi2, Pe2, phi, Pe, dtau):
        k = (phi / 0.01) ** 3
        qx = -fd2d.av_xa(k) * fd2d.d_xa(Pe)
        qy = -fd2d.av_ya(k) * (fd2d.d_ya(Pe) - 30.0 * (fd2d.av_ya(phi)
                                                       - 0.01))
        div_q = fd2d.d_xa(qx[:, 1:-1]) + fd2d.d_ya(qy[1:-1, :])
        Pe_new = fd2d.inn(Pe) + dtau * (-(div_q + fd2d.inn(Pe)))
        phi_new = fd2d.inn(phi) + dtau * (-(1.0 - fd2d.inn(phi)) * Pe_new)
        return {"phi2": phi_new, "Pe2": Pe_new}

    return update


def test_coupled_per_output_reductions(rng):
    # per-output reductions on a coupled system: residual on Pe, bounds
    # on phi, in ONE launch, both backends
    n = 24
    phi = jnp.asarray(0.01 * (1 + 0.1 * rng.rand(n, n)), jnp.float32)
    Pe = jnp.asarray(0.01 * rng.rand(n, n), jnp.float32)
    reds = {"err": "max_abs_diff(Pe2, Pe)", "phimax": "max_abs(phi2)",
            "mass": "sum(phi2)"}
    outs_j, reds_j = porosity_kernel("jnp", reds)(
        phi2=phi, Pe2=Pe, phi=phi, Pe=Pe, dtau=1e-4)
    want = {"err": jnp.max(jnp.abs(outs_j["Pe2"] - Pe)),
            "phimax": jnp.max(jnp.abs(outs_j["phi2"])),
            "mass": jnp.sum(outs_j["phi2"])}
    assert_reds_close(reds_j, want, rtol=1e-5)
    outs_p, reds_p = porosity_kernel("pallas", reds)(
        phi2=phi, Pe2=Pe, phi=phi, Pe=Pe, dtau=1e-4)
    for o in outs_j:
        np.testing.assert_allclose(np.asarray(outs_p[o]),
                                   np.asarray(outs_j[o]), atol=1e-6)
    assert_reds_close(reds_p, reds_j)


def test_staggered_operand_rejected(rng):
    # reducing a face-centered (staggered) field is a pointed error
    n = 16
    ps = init_parallel_stencil(backend="jnp", ndims=2)

    @ps.parallel(outputs=("qx",), reductions={"q": "max_abs(qx)"})
    def fluxes(qx, Pe):
        return {"qx": -fd2d.d_xa(Pe)}

    Pe = jnp.asarray(np.random.RandomState(0).rand(n, n), jnp.float32)
    qx = jnp.zeros((n - 1, n), jnp.float32)
    with pytest.raises(ValueError, match="staggered"):
        fluxes(qx=qx, Pe=Pe)


# ------------------------------------------------------------ IR accounting
def test_ir_and_cost_accounting(rng):
    T, Ci, sc = setup3d(rng)
    kern = diffusion_kernel("jnp", {"err": "max_abs_diff(T2, T)"})
    shape = tuple(T.shape)
    ir = kern.stencil_ir(T2=shape, T=shape, Ci=shape, **sc)
    assert set(ir.reductions) == {"err"}
    assert ir.check_read_fields == ("T2", "T")
    assert ir.check_io_bytes(4) == 2 * T.size * 4
    assert "max_abs_diff(T2, T)" in ir.describe()
    # the traced check expression: |T2 - T| = one sub + one abs per
    # element, plus the fold's combine op
    cost = kern.cost_model(T2=shape, T=shape, Ci=shape, **sc)
    assert cost.n_reductions == 1
    assert cost.check_read_bytes == ir.check_io_bytes(4)
    assert cost.check_flops.adds == 3 * T.size
    # separate check pass re-reads both operands; fused pays one partial
    # per tile
    tile = (8, 8, 16)
    sep = cost.check_bytes_per_step(check_every=4, fused=False)
    assert sep == ir.check_io_bytes(4) / 4
    fused = cost.check_bytes_per_step(check_every=4, fused=True, tile=tile)
    assert 0 < fused <= (2 * 2 * 1) * 4 / 4
    assert cost.fetched_bytes_per_step(tile, 1, check_every=4,
                                       fused_checks=False) == \
        cost.fetched_bytes_per_step(tile, 1) + sep
    # teff-level helper mirrors the same accounting
    a = teff.a_eff(T.size, 2, 1, 4)
    assert teff.a_eff_checked(a, ir.check_io_bytes(4), 4, fused=True) == a
    assert teff.a_eff_checked(a, ir.check_io_bytes(4), 4, fused=False) == \
        a + ir.check_io_bytes(4) / 4


def test_plain_kernel_has_no_check_accounting(rng):
    T, Ci, sc = setup3d(rng)
    kern = diffusion_kernel("jnp", reductions=None)
    shape = tuple(T.shape)
    ir = kern.stencil_ir(T2=shape, T=shape, Ci=shape, **sc)
    assert ir.reductions == {} and ir.check_io_bytes(4) == 0
    cost = kern.cost_model(T2=shape, T=shape, Ci=shape, **sc)
    assert cost.check_bytes_per_step(1, fused=False) == 0.0


def test_with_reductions_variants_memoized(rng):
    kern = diffusion_kernel("jnp")
    plain = kern.with_reductions(None)
    assert plain.reductions == {}
    assert kern.with_reductions(None) is plain
    assert plain.with_reductions(ALL_REDS).reductions == kern.reductions
    assert kern.with_reductions(ALL_REDS) is kern
    # marched variants carry the reduction set along
    assert kern.marched(1).reductions == kern.reductions


# ------------------------------------------------- device-resident iteration
def test_solve_until_matches_host_loop(rng):
    T, Ci, sc = setup3d(rng, shape=(12, 12, 12))
    sc = dict(sc, dt=0.05)  # near the stability limit: fast decay
    kern = diffusion_kernel("jnp", {"err": "max_abs_diff(T2, T)"})
    res = iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=2e-5,
                              max_iters=400, check_every=5)
    plain = kern.with_reductions(None)
    cur, it, err = dict(T2=T, T=T), 0, np.inf
    while err > 2e-5 and it < 400:
        for _ in range(5):
            out = plain(T2=cur["T2"], T=cur["T"], Ci=Ci, **sc)
            cur["T2"], cur["T"] = cur["T"], out
            it += 1
        err = float(jnp.max(jnp.abs(cur["T"] - cur["T2"])))
    assert 0 < it < 400, "host loop should converge before the cap"
    assert int(res.iters) == it
    np.testing.assert_allclose(float(res.err), err, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(res.fields["T"]),
                               np.asarray(cur["T"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(res.output(kern)),
                               np.asarray(cur["T"]), atol=1e-6)


def test_solve_until_pallas_backend(rng):
    T, Ci, sc = setup3d(rng, shape=(12, 12, 12))
    kj = diffusion_kernel("jnp", {"err": "max_abs_diff(T2, T)"})
    kp = diffusion_kernel("pallas", {"err": "max_abs_diff(T2, T)"})
    rj = iterate.solve_until(kj, dict(T2=T, T=T, Ci=Ci), sc, tol=5e-5,
                             max_iters=200, check_every=10)
    rp = iterate.solve_until(kp, dict(T2=T, T=T, Ci=Ci), sc, tol=5e-5,
                             max_iters=200, check_every=10)
    assert int(rp.iters) == int(rj.iters)
    np.testing.assert_allclose(np.asarray(rp.fields["T"]),
                               np.asarray(rj.fields["T"]), atol=1e-5)


def test_solve_until_until_above(rng):
    # drift-guard polarity: iterate while the monitored value stays UNDER
    # tol; the growing sum_sq of an unstable-dt diffusion trips it
    T, Ci, sc = setup3d(rng, shape=(10, 10, 10))
    kern = diffusion_kernel("jnp", {"m": "sum_sq(T2)"})
    m0 = float(jnp.sum(T ** 2))
    res = iterate.solve_until(
        kern, dict(T2=T, T=T, Ci=Ci), sc, tol=1e-12, max_iters=50,
        check_every=5, error=lambda r: jnp.abs(r["m"] - m0) / m0,
        until="above")
    assert int(res.iters) == 5  # first check already exceeds a 1e-12 drift
    assert float(res.err) > 1e-12


def test_solve_until_zero_host_transfers():
    # trace assertion: the whole solve is ONE lax.while_loop — no eqn in
    # the driver's jaxpr moves data to the host between checks
    rng = np.random.RandomState(0)
    T = jnp.asarray(rng.rand(10, 10, 10), jnp.float32)
    Ci = jnp.ones_like(T)
    sc = dict(lam=1.0, dt=1e-3, _dx=1.0, _dy=1.0, _dz=1.0)
    kern = diffusion_kernel("jnp", {"err": "max_abs_diff(T2, T)"})
    solver = iterate.make_solver(kern, sc, check_every=3)
    jaxpr = jax.make_jaxpr(solver)(dict(T2=T, T=T, Ci=Ci), 1e-5, 100)
    names = [e.primitive.name for e in jaxpr.eqns]
    assert names.count("while") == 1
    forbidden = {"io_callback", "pure_callback", "device_put",
                 "debug_callback"}
    all_names = set(names)
    for e in jaxpr.eqns:
        for sub in e.params.values():
            if hasattr(sub, "jaxpr"):
                all_names |= {q.primitive.name for q in sub.jaxpr.eqns}
    assert not (all_names & forbidden)


def test_solve_until_errors(rng):
    T, Ci, sc = setup3d(rng, shape=(8, 8, 8))
    plain = diffusion_kernel("jnp", reductions=None)
    with pytest.raises(ValueError, match="fused reductions"):
        iterate.solve_until(plain, dict(T2=T, T=T, Ci=Ci), sc, tol=1e-5,
                            max_iters=10)
    kern = diffusion_kernel("jnp")
    with pytest.raises(ValueError, match="error="):
        iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=1e-5,
                            max_iters=10)  # 4 reductions, ambiguous
    with pytest.raises(ValueError, match="not a declared reduction"):
        iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=1e-5,
                            max_iters=10, error="nope")
    with pytest.raises(ValueError, match="until"):
        iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=1e-5,
                            max_iters=10, error="err", until="sideways")
    with pytest.raises(ValueError, match="check_every"):
        iterate.solve_until(kern, dict(T2=T, T=T, Ci=Ci), sc, tol=1e-5,
                            max_iters=10, error="err", check_every=0)
    # missing rotations
    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), reductions={"err": "max_abs(T2)"})
    def norot(T2, T, dt):
        return {"T2": fd3d.inn(T) * dt}

    with pytest.raises(ValueError, match="rotations"):
        iterate.solve_until(norot, dict(T2=T, T=T), dict(dt=0.5), tol=1e-5,
                            max_iters=10)


# ------------------------------------------------------------- example wiring
def test_porosity_tol_mode_matches_fixed_steps():
    from examples import porosity_waves as pw

    # tol small enough that the cap binds: --tol must reproduce the
    # plain nt-step run exactly (same kernel, same rotation order)
    base = pw.PorosityConfig(n=24, nt=30)
    r_fix = pw.solve(base)
    r_tol = pw.solve(pw.PorosityConfig(n=24, nt=30, tol=1e-12,
                                       check_every=10))
    assert r_tol["iters"] == 30
    np.testing.assert_allclose(np.asarray(r_tol["phi"]),
                               np.asarray(r_fix["phi"]), atol=1e-6)
    # a loose tol stops early, at a check boundary
    r_loose = pw.solve(pw.PorosityConfig(n=24, nt=300, tol=1e-3,
                                         check_every=5))
    assert r_loose["iters"] < 300 and r_loose["iters"] % 5 == 0
    assert r_loose["residual"] < 1e-3


def test_porosity_tol_mode_rejects_flux_split_and_periodic():
    from examples import porosity_waves as pw

    with pytest.raises(ValueError, match="flux-split"):
        pw.solve(pw.PorosityConfig(n=24, nt=10, tol=1e-3, flux_split=True))
    with pytest.raises(ValueError, match="periodic"):
        pw.solve(pw.PorosityConfig(n=24, nt=10, tol=1e-3, bc="periodic"))


def test_gp_drift_guard():
    from examples import gross_pitaevskii as gp

    # generous tol: runs to the cap, drift equals the plain solve's
    r_fix = gp.solve(gp.GPConfig(n=12, nt=20))
    r = gp.solve(gp.GPConfig(n=12, nt=20, tol=0.5, check_every=10))
    assert r["iters"] == 20 and not r["tripped"]
    np.testing.assert_allclose(r["drift"], r_fix["drift"], rtol=1e-3,
                               atol=1e-7)
    # tripwire tol: stops at the first check that exceeds it
    r2 = gp.solve(gp.GPConfig(n=12, nt=200, tol=1e-6, check_every=5))
    assert r2["tripped"] and r2["iters"] < 200


# ---------------------------------------------------------------- distributed
def test_distributed_partials_pmax_psum():
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core import init_parallel_stencil, fd3d as fd
from repro.distributed import halo, overlap
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("x", "y"))
Ng, Nz = 34, 10
rng = np.random.RandomState(0)
Tg = jnp.asarray(rng.rand(Ng, Ng, Nz), jnp.float32)
Cig = jnp.asarray(rng.rand(Ng, Ng, Nz) + 0.5, jnp.float32)
sc = dict(lam=1.0, dt=1e-4, _dx=1.0, _dy=1.0, _dz=1.0)

ps = init_parallel_stencil(backend="jnp", ndims=3)
@ps.parallel(outputs=("T2",), rotations={"T2": "T"},
             reductions={"err": "max_abs_diff(T2, T)"})
def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
    return {"T2": fd.inn(T) + dt*(lam*fd.inn(Ci)*(fd.d2_xi(T)*_dx**2
            + fd.d2_yi(T)*_dy**2 + fd.d2_zi(T)*_dz**2))}

# single-device reference: one step + whole-array check
Tr, reds_ref = kern(T2=Tg, T=Tg, Ci=Cig, **sc)
err_ref = float(reds_ref["err"])

lT = halo.global_to_local(Tg, (2, 2)); lC = halo.global_to_local(Cig, (2, 2))
ls = lT[0].shape
Ts = jnp.asarray(np.stack(lT).reshape(2, 2, *ls))
Cs = jnp.asarray(np.stack(lC).reshape(2, 2, *ls))

def one(Tl, Cl):
    Tl, Cl = Tl[0, 0], Cl[0, 0]
    (out, reds), fresh = overlap.sequential_step(
        kern, dict(T2=Tl, T=Tl, Ci=Cl), sc, ("T",), ("x", "y"))
    (out2, reds2), _ = overlap.overlapped_step(
        kern, dict(T2=Tl, T=Tl, Ci=Cl), sc, ("T",), ("x", "y"))
    return out[None, None], reds["err"][None], reds2["err"][None]

f = shard_map(one, mesh=mesh, in_specs=(P("x","y"), P("x","y")),
              out_specs=(P("x","y"), P("x"), P("x")), check_vma=False)
outs, errs, errs2 = f(Ts, Cs)
# the pmax'd error is replicated across ranks and equals the global check
errs = np.unique(np.asarray(errs)); errs2 = np.unique(np.asarray(errs2))
assert errs.size == 1 and errs2.size == 1, (errs, errs2)
print("PMAX_ERRS", float(errs[0]), float(errs2[0]), err_ref)
np.testing.assert_allclose(errs[0], err_ref, rtol=1e-5)
np.testing.assert_allclose(errs2[0], err_ref, rtol=1e-5)

# psum partials: each rank's fused sum_sq value is a valid partial —
# ONE psum combines them to the sum of the rank-local folds (equal to
# the global fold exactly when rank domains are disjoint; these local
# arrays carry ghost rings, so the reference below folds the same
# ghost-extended domains)
@ps.parallel(outputs=("T2",), rotations={"T2": "T"},
             reductions={"m": "sum_sq(T2)"})
def kern2(T2, T, Ci, lam, dt, _dx, _dy, _dz):
    return {"T2": fd.inn(T) + dt*(lam*fd.inn(Ci)*(fd.d2_xi(T)*_dx**2
            + fd.d2_yi(T)*_dy**2 + fd.d2_zi(T)*_dz**2))}

def rank_sum(Tl, Cl):
    Tl, Cl = Tl[0, 0], Cl[0, 0]
    out, reds = kern2(T2=Tl, T=Tl, Ci=Cl, **sc)
    total = overlap.finish_reductions(kern2, reds, ("x", "y"))
    return out[None, None], total["m"][None]

g = shard_map(rank_sum, mesh=mesh, in_specs=(P("x","y"), P("x","y")),
              out_specs=(P("x","y"), P("x")), check_vma=False)
outs2, masses = g(Ts, Cs)
# reference: the same per-shard kernel runs on host; psum == sum of the
# disjoint shard folds
want = sum(float(jnp.sum(kern2.with_reductions(None)(
    T2=jnp.asarray(t), T=jnp.asarray(t), Ci=jnp.asarray(c), **sc) ** 2))
    for t, c in zip(lT, lC))
masses = np.asarray(masses)
print("PSUM_MASS", float(masses[0]), want)
np.testing.assert_allclose(masses, want, rtol=1e-5)
print("DIST_REDS_OK")
""", n_devices=4)
    assert "DIST_REDS_OK" in out
