"""Example solvers as importable modules (paper §3's translated solvers):
golden-value regression, conservation bounds, and jnp-vs-pallas backend
parity of the coupled stencil engine on small grids."""
import jax.numpy as jnp
import numpy as np
import pytest

from examples import gross_pitaevskii as gp
from examples import porosity_waves as pw

# jnp backend, n=32, nt=40 (default physics constants). Regenerate with:
#   PYTHONPATH=src:. python -c "from examples.porosity_waves import *; \
#       print(solve(PorosityConfig(n=32, nt=40)))"
POROSITY_GOLDEN = {
    "phi_min": 0.009992753155529499,
    "phi_max": 0.010957718826830387,
    "pe_absmax": 0.0024534445255994797,
    "phi_sum": 10.255167961120605,
}


def test_porosity_golden_regression():
    r = pw.solve(pw.PorosityConfig(n=32, nt=40))
    assert np.isclose(r["phi_min"], POROSITY_GOLDEN["phi_min"], rtol=1e-4)
    assert np.isclose(r["phi_max"], POROSITY_GOLDEN["phi_max"], rtol=1e-4)
    assert np.isclose(r["pe_absmax"], POROSITY_GOLDEN["pe_absmax"], rtol=5e-4)
    assert np.isclose(float(jnp.sum(r["phi"])), POROSITY_GOLDEN["phi_sum"],
                      rtol=1e-5)


def test_porosity_backend_parity():
    """Same coupled one-launch update on jnp and interpreted pallas."""
    outs = {
        b: pw.solve(pw.PorosityConfig(n=24, nt=8, backend=b))
        for b in ("jnp", "pallas")
    }
    np.testing.assert_allclose(np.asarray(outs["jnp"]["phi"]),
                               np.asarray(outs["pallas"]["phi"]), atol=2e-6)
    np.testing.assert_allclose(np.asarray(outs["jnp"]["Pe"]),
                               np.asarray(outs["pallas"]["Pe"]), atol=2e-6)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_porosity_flux_split_matches_fused(backend):
    """Explicit staggered flux fields (mixed-shape two-launch scheme) must
    reproduce the fused in-kernel-flux scheme."""
    fused = pw.solve(pw.PorosityConfig(n=24, nt=8, backend=backend))
    split = pw.solve(pw.PorosityConfig(n=24, nt=8, backend=backend,
                                       flux_split=True))
    np.testing.assert_allclose(np.asarray(fused["phi"]),
                               np.asarray(split["phi"]), atol=1e-7)
    np.testing.assert_allclose(np.asarray(fused["Pe"]),
                               np.asarray(split["Pe"]), atol=1e-7)


def test_gp_mass_conservation():
    r = gp.solve(gp.GPConfig(n=16, nt=40))
    assert np.isfinite(r["mass"])
    assert r["drift"] < 0.05
    # the wavefunction stays localized (no boundary blow-up)
    assert float(jnp.abs(r["re"][0]).max()) < 0.05


def test_gp_two_launch_mass_conservation():
    r = gp.solve(gp.GPConfig(n=16, nt=40, fused=False))
    assert r["drift"] < 0.05


def test_gp_backend_parity():
    """Fused coupled radius-2 kernel: jnp vs interpreted pallas."""
    outs = {
        b: gp.solve(gp.GPConfig(n=12, nt=6, backend=b)) for b in ("jnp", "pallas")
    }
    np.testing.assert_allclose(np.asarray(outs["jnp"]["re"]),
                               np.asarray(outs["pallas"]["re"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(outs["jnp"]["im"]),
                               np.asarray(outs["pallas"]["im"]), atol=1e-6)
    assert abs(outs["jnp"]["drift"] - outs["pallas"]["drift"]) < 1e-5


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_gp_fused_kernel_run_steps_bitwise(backend):
    """The radius-2 coupled GP kernel under k-step temporal blocking: one
    fused launch == k sequential coupled calls, bit for bit."""
    cfg = gp.GPConfig(n=12, backend=backend)
    grid, re, im, V = gp.init_state(cfg)
    dt = gp.timestep(grid)
    kern = gp.make_step(grid, cfg).kernels[0]
    inv2 = tuple(1.0 / d ** 2 for d in grid.spacing)
    sc = dict(V=V, g=cfg.g, dt=dt, _dx2=inv2[0], _dy2=inv2[1], _dz2=inv2[2])
    a, b, ia, ib = re, re.copy(), im, im.copy()
    for _ in range(2):
        o = kern(re2=b, im2=ib, re=a, im=ia, **sc)
        a, b = o["re2"], a
        ia, ib = o["im2"], ia
    got = kern.run_steps(2, re2=re.copy(), im2=im.copy(), re=re, im=im, **sc)
    np.testing.assert_array_equal(np.asarray(got["re2"]), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(got["im2"]), np.asarray(ia))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("bc", ["neumann", "dirichlet", "periodic"])
def test_porosity_fused_bc_matches_postpass(backend, bc):
    """The --bc path (engine-fused boundary conditions) must equal the
    raw @inn kernel followed by the core.boundary post-pass: bitwise on
    jnp (identical program); to 1 ulp on pallas, where the bc and no-bc
    variants are two separately compiled programs whose interior
    arithmetic may contract FMAs differently (the per-kind bitwise
    in-program equality is covered in test_ir.py)."""
    from repro.core import boundary

    cfg_bc = pw.PorosityConfig(n=24, nt=6, backend=backend, bc=bc)
    cfg_raw = pw.PorosityConfig(n=24, nt=6, backend=backend, bc="none")
    grid, phi, Pe = pw.init_state(cfg_bc)
    dtau = pw.timestep(cfg_bc, grid)
    step_bc = pw.make_step(grid, cfg_bc)
    step_raw = pw.make_step(grid, cfg_raw)
    post = {
        "neumann": boundary.neumann0,
        "dirichlet": lambda a, v: boundary.dirichlet(a, v),
        "periodic": boundary.periodic,
    }
    p1, e1 = phi, Pe
    p2, e2 = phi, Pe
    for _ in range(cfg_bc.nt):
        p1, e1 = step_bc(p1, e1, dtau)
        rp, re_ = step_raw(p2, e2, dtau)
        if bc == "dirichlet":
            p2, e2 = post[bc](rp, cfg_bc.phi0), post[bc](re_, 0.0)
        else:
            p2, e2 = post[bc](rp), post[bc](re_)
    if backend == "jnp":
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    else:
        np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                                   rtol=1e-5, atol=1e-12)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                                   rtol=1e-5, atol=1e-12)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_porosity_flux_split_bc_parity(backend):
    """The fused-BC path composes with the staggered flux-split scheme."""
    fused = pw.solve(pw.PorosityConfig(n=24, nt=8, backend=backend,
                                       bc="dirichlet"))
    split = pw.solve(pw.PorosityConfig(n=24, nt=8, backend=backend,
                                       bc="dirichlet", flux_split=True))
    np.testing.assert_allclose(np.asarray(fused["phi"]),
                               np.asarray(split["phi"]), atol=1e-7)


@pytest.mark.parametrize("bc", ["neumann", "dirichlet", "periodic"])
def test_gp_fused_bc_matches_postpass(bc):
    """GP --bc routed through the fused coupled kernel == raw kernel +
    post-pass (jnp backend; pallas parity is covered per-kind in
    test_ir.py)."""
    from repro.core import boundary

    cfg_bc = gp.GPConfig(n=12, nt=4, bc=bc)
    cfg_raw = gp.GPConfig(n=12, nt=4, bc="none")
    grid, re, im, V = gp.init_state(cfg_bc)
    dt = gp.timestep(grid)
    step_bc = gp.make_step(grid, cfg_bc)
    step_raw = gp.make_step(grid, cfg_raw)
    post = {"neumann": boundary.neumann0,
            "dirichlet": lambda a: boundary.dirichlet(a, 0.0),
            "periodic": boundary.periodic}[bc]
    r1, i1 = re, im
    r2, i2 = re, im
    for _ in range(cfg_bc.nt):
        r1, i1 = step_bc(r1, i1, dt, V)
        rr, ri = step_raw(r2, i2, dt, V)
        r2, i2 = post(rr), post(ri)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_cli_main_smoke(capsys):
    pw.main(["--n", "32", "--nt", "3"])
    assert "porosity wave" in capsys.readouterr().out
    pw.main(["--n", "32", "--nt", "3", "--bc", "periodic"])
    assert "bc=periodic" in capsys.readouterr().out
    gp.main(["--n", "12", "--nt", "2"])
    assert "GP:" in capsys.readouterr().out
    gp.main(["--n", "12", "--nt", "2", "--bc", "dirichlet"])
    assert "GP:" in capsys.readouterr().out
