"""Mixed-precision storage (PR 7): fields stored bf16/f16, all stencil
arithmetic in f32. Parity of every execution shape (plain / coupled +
staggered / nsteps=k / march / solve_until) against the f32 reference
within the analytic storage-rounding bound, f32 accumulation of the
fused reduction epilogues, the dtype-aware autotune cache key, and the
int8 compressed-collective properties (round-trip error <= scale/2 per
block, int-sized psum payload on the wire)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import fd2d, fd3d, init_parallel_stencil, iterate
from repro.distributed import compression
from repro.kernels import autotune

SHAPE = (16, 12, 20)
SC = dict(lam=1.0, dt=1e-3, _dx=1.0, _dy=1.0, _dz=1.0)
LOW = ("bfloat16", "float16")


def _eps(dtype):
    return float(jnp.finfo(jnp.dtype(dtype)).eps)


def _diffusion(backend, dtype="float32", reductions=None, march=None,
               tile=None):
    ps = init_parallel_stencil(backend=backend, dtype=dtype, ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"}, tile=tile,
                 march_axis=march, reductions=reductions)
    def kern(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd3d.inn(T) + dt * (lam * fd3d.inn(Ci) * (
            fd3d.d2_xi(T) * _dx ** 2 + fd3d.d2_yi(T) * _dy ** 2 +
            fd3d.d2_zi(T) * _dz ** 2))}

    return kern


def _fields(rng, shape=SHAPE):
    T = jnp.asarray(rng.rand(*shape), jnp.float32)
    Ci = jnp.asarray(rng.rand(*shape) + 0.5, jnp.float32)
    return T, Ci


# -- parity: low storage vs f32 reference -------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("dtype", LOW)
def test_parity_plain(backend, dtype, rng):
    T, Ci = _fields(rng)
    want = np.asarray(_diffusion("jnp")(T2=T, T=T, Ci=Ci, **SC))
    k = _diffusion(backend, dtype)
    got = k(T2=T.astype(dtype), T=T.astype(dtype), Ci=Ci.astype(dtype), **SC)
    assert got.dtype == jnp.dtype(dtype)
    # inputs are rounded to storage once, the output once: a handful of
    # ulps around the f32 trajectory
    atol = 4 * _eps(dtype) * float(jnp.max(jnp.abs(T)))
    np.testing.assert_allclose(np.asarray(got, np.float32), want, atol=atol)
    # the untouched boundary is a pure storage copy — exact
    np.testing.assert_array_equal(np.asarray(got[0], np.float32),
                                  np.asarray(T.astype(dtype)[0], np.float32))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_parity_coupled_staggered(backend, rng):
    n = 24
    phi = jnp.asarray(rng.rand(n, n), jnp.float32)
    Pe = jnp.asarray(rng.rand(n, n), jnp.float32)
    qx = jnp.asarray(rng.rand(n - 1, n), jnp.float32)

    def make(backend, dtype):
        ps = init_parallel_stencil(backend=backend, dtype=dtype, ndims=2)

        @ps.parallel(outputs=("phi2", "Pe2"),
                     rotations={"phi2": "phi", "Pe2": "Pe"})
        def kern(phi2, Pe2, phi, Pe, qx, dtau):
            div = qx[1:, 1:-1] - qx[:-1, 1:-1]
            return {"phi2": fd2d.inn(phi) + dtau * (
                        fd2d.d2_xi(phi) + fd2d.d2_yi(phi) - div),
                    "Pe2": fd2d.inn(Pe) + dtau * (
                        fd2d.d2_xi(Pe) + fd2d.d2_yi(Pe) + fd2d.inn(phi))}
        return kern

    args = dict(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe, qx=qx, dtau=1e-3)
    want = make("jnp", "float32")(**args)
    lo = {k: (v.astype(jnp.bfloat16) if hasattr(v, "astype") else v)
          for k, v in args.items()}
    got = make(backend, "bfloat16")(**lo)
    atol = 4 * _eps("bfloat16")
    for o in ("phi2", "Pe2"):
        assert got[o].dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got[o], np.float32),
                                   np.asarray(want[o]), atol=atol)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("k", [2, 4])
def test_parity_nsteps(backend, k, rng):
    T, Ci = _fields(rng)
    want = np.asarray(_diffusion("jnp").run_steps(k, T2=T, T=T, Ci=Ci, **SC))
    kern = _diffusion(backend, "bfloat16")
    got = kern.run_steps(k, T2=T.astype(jnp.bfloat16),
                         T=T.astype(jnp.bfloat16),
                         Ci=Ci.astype(jnp.bfloat16), **SC)
    # storage rounding re-enters the stencil every step: linear-in-k bound
    atol = 4 * k * _eps("bfloat16") * float(jnp.max(jnp.abs(T)))
    np.testing.assert_allclose(np.asarray(got, np.float32), want, atol=atol)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_parity_march(backend, rng):
    T, Ci = _fields(rng, (16, 12, 16))
    lo = dict(T2=T.astype(jnp.bfloat16), T=T.astype(jnp.bfloat16),
              Ci=Ci.astype(jnp.bfloat16))
    plain = _diffusion(backend, "bfloat16", tile=(4, 4, 8))(**lo, **SC)
    marched = _diffusion(backend, "bfloat16", march=0,
                         tile=(4, 4, 8))(**lo, **SC)
    # same math in two launch geometries: at most one bf16 ulp apart
    np.testing.assert_allclose(np.asarray(marched, np.float32),
                               np.asarray(plain, np.float32),
                               atol=_eps("bfloat16"))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_parity_solve_until(backend, rng):
    T, Ci = _fields(rng)
    reds = {"err": "max_abs_diff(T2, T)"}
    # a bf16-storage solve cannot resolve below one storage ulp of the
    # field (~2^-9 here): the tolerance must sit above it
    tol = 1e-2
    assert tol > _eps("bfloat16") * float(jnp.max(jnp.abs(T)))
    ref = iterate.solve_until(
        _diffusion("jnp", reductions=reds), dict(T2=T, T=T, Ci=Ci), SC,
        tol=tol, max_iters=200, check_every=4)
    kern = _diffusion(backend, "bfloat16", reductions=reds)
    res = iterate.solve_until(
        kern, dict(T2=T.astype(jnp.bfloat16), T=T.astype(jnp.bfloat16),
                   Ci=Ci.astype(jnp.bfloat16)), SC,
        tol=tol, max_iters=200, check_every=4)
    # the device-resident carry keeps the storage dtype end to end
    assert res.fields["T2"].dtype == jnp.bfloat16
    assert res.err <= tol and res.iters <= ref.iters + 8
    atol = 8 * _eps("bfloat16") * float(jnp.max(jnp.abs(T)))
    np.testing.assert_allclose(np.asarray(res.fields["T2"], np.float32),
                               np.asarray(ref.fields["T2"]), atol=atol)


# -- reductions accumulate at f32 under low-precision storage -----------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_reductions_accumulate_f32(backend, rng):
    # 32^3 = 32768 summands: naive bf16 accumulation stalls once the
    # partial sum reaches ~256 (1 ulp = 2), losing the convergence
    # signal entirely; f32 accumulation tracks the f64 host reference.
    shape = (32, 32, 32)
    T = jnp.asarray(rng.rand(*shape), jnp.float32)
    Ci = jnp.asarray(rng.rand(*shape) + 0.5, jnp.float32)
    reds = {"s": "sum(T2)", "m2": "sum_sq(T2)", "mx": "max_abs(T2)"}
    kern = _diffusion(backend, "bfloat16", reductions=reds)
    out, got = kern(T2=T.astype(jnp.bfloat16), T=T.astype(jnp.bfloat16),
                    Ci=Ci.astype(jnp.bfloat16), **SC)
    host = np.asarray(out, np.float64)
    want = {"s": host.sum(), "m2": (host * host).sum(),
            "mx": np.abs(host).max()}
    for name, w in want.items():
        g = float(got[name])
        assert np.dtype(np.asarray(got[name]).dtype).itemsize >= 4, name
        rel = abs(g - w) / max(abs(w), 1e-30)
        # f32 accumulation: ~1e-5 relative; bf16 accumulation would be
        # off by >50% for the sums
        assert rel < 1e-3, (name, g, w, rel)


# -- autotune cache: dtype-aware key, old formats ignored ---------------


def test_autotune_cache_key_carries_dtypes():
    base = dict(shape=(32, 32), radius=1, n_fields=3, tag="t")
    k32 = autotune.cache_key(dtype="float32", dtypes=("float32", "float32"),
                             **base)
    kbf = autotune.cache_key(dtype="bfloat16", dtypes=("bfloat16", "float32"),
                             **base)
    assert k32 != kbf


def test_autotune_old_cache_format_ignored(tmp_path, rng):
    cache = str(tmp_path / "tune.json")
    stale = {"version": 3, "entries": {"whatever": {
        "tile": [1, 1], "nsteps": 1, "per_step_s": 0.0,
        "candidates_tried": 1}}}
    with open(cache, "w") as f:
        json.dump(stale, f)
    assert autotune._load_cache(cache) == {}

    shape = (16, 16)
    U = jnp.asarray(rng.rand(*shape), jnp.float32)

    def make_step(tile, k):
        ps = init_parallel_stencil(backend="jnp", ndims=2)
        kern = ps.parallel(outputs=("U2",), rotations={"U2": "U"})(
            lambda U2, U, dt: {"U2": fd2d.inn(U) + dt * (
                fd2d.d2_xi(U) + fd2d.d2_yi(U))})
        return lambda: kern.run_steps(k, U2=U, U=U, dt=1e-3)

    r = autotune.autotune(make_step, shape=shape, dtype="float32", radius=1,
                          n_fields=2, nsteps_candidates=(1,), iters=1,
                          tag="unit", cache_path=cache)
    assert r.nsteps == 1
    with open(cache) as f:
        disk = json.load(f)
    assert disk["version"] == autotune.CACHE_VERSION
    # the rewritten cache replaces (not merges) the stale-schema entries
    assert "whatever" not in disk["entries"]


def test_autotune_separate_entries_per_dtype(tmp_path, rng):
    cache = str(tmp_path / "tune.json")
    shape = (16, 16)

    def run(dtype):
        U = jnp.asarray(rng.rand(*shape), jnp.float32).astype(dtype)

        def make_step(tile, k):
            ps = init_parallel_stencil(backend="jnp", dtype=dtype, ndims=2)
            kern = ps.parallel(outputs=("U2",), rotations={"U2": "U"})(
                lambda U2, U, dt: {"U2": fd2d.inn(U) + dt * (
                    fd2d.d2_xi(U) + fd2d.d2_yi(U))})
            return lambda: kern.run_steps(k, U2=U, U=U, dt=1e-3)

        return autotune.autotune(make_step, shape=shape, dtype=dtype,
                                 radius=1, n_fields=2, nsteps_candidates=(1,),
                                 iters=1, tag="unit-dtype-pair",
                                 cache_path=cache)

    run("float32")
    run("bfloat16")
    with open(cache) as f:
        disk = json.load(f)
    assert len(disk["entries"]) == 2  # one per (storage, compute) pair


# -- int8 compressed collectives ----------------------------------------


def test_int8_roundtrip_error_bound_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis.extra import numpy as hnp

    @settings(max_examples=50, deadline=None)
    @given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=2,
                                                   min_side=1, max_side=300),
                      elements={"allow_nan": False, "allow_infinity": False,
                                "min_value": -1e30, "max_value": 1e30}))
    def check(x):
        g = jnp.asarray(x)
        q, scale, meta = compression.quantize_int8(g)
        assert q.dtype == jnp.int8
        dq = compression.dequantize_int8(q, scale, meta)
        # per-block bound: |x - dq| <= scale/2 everywhere in the block
        err = jnp.abs(dq - g)
        nb = scale.shape[0]
        flat = jnp.reshape(err, (-1,))
        pad = nb * compression.BLOCK - flat.shape[0]
        blocked = jnp.reshape(jnp.pad(flat, (0, pad)), (nb, -1))
        bound = jnp.maximum(scale[:, 0], 0.0) / 2 * (1 + 1e-6) + 1e-30
        assert bool(jnp.all(blocked <= bound[:, None]))

    check()


def test_compressed_psum_wire_payload_is_int_sized():
    # jaxpr inspection: the only array-valued psum must carry the int32-
    # accumulated int8 codes — never a dequantized float payload. (Scales
    # travel via pmax/psum of one scalar per block, a 1/BLOCK-sized side
    # channel.)
    g = jnp.zeros((4096,), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda x: compression.compressed_psum(x, "i"),
        axis_env=[("i", 4)])(g)
    psums = [e for e in jaxpr.jaxpr.eqns if e.primitive.name == "psum"]
    assert psums, "compressed_psum lost its psum"
    payload_bytes = 0
    for e in psums:
        for v in e.invars:
            assert not jnp.issubdtype(v.aval.dtype, jnp.floating), (
                f"float payload {v.aval} crossed the wire")
            payload_bytes += v.aval.dtype.itemsize * int(
                np.prod(v.aval.shape))
    # int32 accumulation of int8 codes: 4 B/elt on the wire upper-bounds
    # the transport; the quantized representation itself is 1 B/elt + the
    # per-block scale side channel
    assert payload_bytes <= 4 * g.size + 8 * (g.size // compression.BLOCK + 1)


def test_compressed_psum_exactness_shared_scale():
    # shared per-block scales make dequantize(psum(int32)) EQUAL to
    # psum(dequantize): s * sum(q_r) == sum(s * q_r) exactly in f32,
    # because every rank multiplies by the same power-free shared scale
    out = run_subprocess("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed import compression
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("pod",))
rng = np.random.RandomState(7)
g = jnp.asarray(rng.randn(4, 2048), jnp.float32)
def f(gl):
    red, _ = compression.compressed_psum(gl[0], "pod")
    return red[None]
red = shard_map(f, mesh=mesh, in_specs=(P("pod"),), out_specs=P("pod"),
                check_vma=False)(g)[0]
# host replay of the wire protocol: every rank quantizes against the
# SHARED per-block scale, codes sum in int32, one dequantize at the end
blocked = [compression._blockify(g[r])[0] for r in range(4)]
meta = compression._blockify(g[0])[1]
shared = jnp.max(jnp.stack(
    [jnp.max(jnp.abs(b), axis=1, keepdims=True) / 127.0
     for b in blocked]), 0)
shared = jnp.where(shared > 0, shared, 1.0)
codes = sum(jnp.clip(jnp.round(b / shared), -127, 127).astype(jnp.int32)
            for b in blocked)
want = compression.dequantize_int8(codes, shared, meta)
np.testing.assert_array_equal(np.asarray(red), np.asarray(want))
print("SHARED_SCALE_EXACT")
""", n_devices=4)
    assert "SHARED_SCALE_EXACT" in out
