"""Finite-difference operator semantics (the math-close layer, paper C2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fd as fd_mod
from repro.core.fd import fd1d, fd2d, fd3d


@pytest.mark.parametrize("fd,nd", [(fd1d, 1), (fd2d, 2), (fd3d, 3)])
def test_shapes(fd, nd, rng):
    A = jnp.asarray(rng.rand(*(7,) * nd), jnp.float32)
    assert fd.inn(A).shape == (5,) * nd
    assert fd.av(A).shape == (6,) * nd
    assert fd.maxloc(A).shape == (5,) * nd
    names = "xyz"[:nd]
    for ax, nm in enumerate(names):
        da = getattr(fd, f"d_{nm}a")(A)
        assert da.shape[ax] == 6 and all(
            s == 7 for i, s in enumerate(da.shape) if i != ax)
        di = getattr(fd, f"d_{nm}i")(A)
        assert di.shape[ax] == 6 and all(
            s == 5 for i, s in enumerate(di.shape) if i != ax)
        d2 = getattr(fd, f"d2_{nm}i")(A)
        assert d2.shape == (5,) * nd


def test_d2_is_d_of_d(rng):
    A = jnp.asarray(rng.rand(9, 9, 9), jnp.float32)
    # d2_xi == d_xa applied twice then inner in y,z
    dd = fd3d.d_xa(fd3d.d_xa(A))[:, 1:-1, 1:-1]
    np.testing.assert_allclose(fd3d.d2_xi(A), dd, rtol=1e-5, atol=1e-6)


def test_linear_field_has_zero_laplacian():
    x, y, z = jnp.meshgrid(*(jnp.linspace(0, 1, 8),) * 3, indexing="ij")
    A = 2.0 * x + 3.0 * y - z
    lap = fd3d.laplacian(A, (7.0, 7.0, 7.0))
    np.testing.assert_allclose(np.asarray(lap), 0.0, atol=1e-4)


def test_quadratic_field_has_constant_laplacian():
    n = 16
    xs = jnp.linspace(0.0, 1.0, n)
    x, y, z = jnp.meshgrid(xs, xs, xs, indexing="ij")
    A = x ** 2
    inv = float(n - 1)
    lap = fd3d.laplacian(A, (inv, inv, inv))
    np.testing.assert_allclose(np.asarray(lap), 2.0, rtol=1e-3)


def test_av_is_midpoint(rng):
    A = jnp.asarray(rng.rand(6, 6), jnp.float32)
    got = fd2d.av(A)
    want = (A[1:, 1:] + A[1:, :-1] + A[:-1, 1:] + A[:-1, :-1]) / 4
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_maxloc_dominates_inn(rng):
    A = jnp.asarray(rng.rand(8, 8, 8), jnp.float32)
    assert bool(jnp.all(fd3d.maxloc(A) >= fd3d.inn(A)))


def test_operators_are_linear(rng):
    A = jnp.asarray(rng.rand(8, 8, 8), jnp.float32)
    B = jnp.asarray(rng.rand(8, 8, 8), jnp.float32)
    for op in (fd3d.d2_xi, fd3d.d_ya, fd3d.av, fd3d.inn):
        np.testing.assert_allclose(op(2 * A + 3 * B), 2 * op(A) + 3 * op(B),
                                   rtol=1e-5, atol=1e-6)
