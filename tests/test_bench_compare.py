"""benchmarks/compare.py robustness: thin/missing/corrupt record sets
must produce clean operator-facing notices (and a distinct exit code),
never a traceback — a CI perf gate that crashes on its own inputs is
worse than no gate."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import compare  # noqa: E402


def _record(path, per_step_s, ts="2026-01-01T00:00:00"):
    with open(path, "w") as f:
        json.dump({"rows": [{"name": "diffusion3d", "n": 64, "nsteps": 10,
                             "dtype": "float32",
                             "per_step_s": per_step_s}],
                   "meta": {"timestamp_utc": ts, "backend": "cpu",
                            "hostname": "h", "jax_version": "0.4.37"}}, f)


def test_scan_group_single_record_is_clean_notice(tmp_path, capsys):
    _record(str(tmp_path / "BENCH_teff_a.json"), 1e-3)
    failures = compare.scan_group(str(tmp_path), "BENCH_teff*.json", 0.15)
    out = capsys.readouterr().out
    assert failures == []
    assert "1 readable record(s)" in out and "nothing to compare" in out


def test_scan_group_skips_corrupt_records(tmp_path, capsys):
    _record(str(tmp_path / "BENCH_teff_a.json"), 1e-3)
    _record(str(tmp_path / "BENCH_teff_c.json"), 1.1e-3,
            ts="2026-01-02T00:00:00")
    with open(tmp_path / "BENCH_teff_b.json", "w") as f:
        f.write("{torn")                       # torn write
    with open(tmp_path / "BENCH_teff_d.json", "w") as f:
        f.write("[1, 2]")                      # not an object
    failures = compare.scan_group(str(tmp_path), "BENCH_teff*.json", 0.15)
    out = capsys.readouterr().out
    assert failures == []                      # the two good records compare
    assert out.count("# skip:") == 2
    assert "not valid JSON" in out and "not a JSON object" in out
    assert "OK" in out


def test_explicit_pair_missing_file_is_rc2_not_traceback(tmp_path, capsys):
    good = str(tmp_path / "BENCH_teff_a.json")
    _record(good, 1e-3)
    rc = compare.main([good, str(tmp_path / "never_written.json")])
    out = capsys.readouterr().out
    assert rc == 2
    assert "cannot read bench record" in out


def test_explicit_pair_regression_still_detected(tmp_path, capsys):
    old = str(tmp_path / "BENCH_teff_old.json")
    new = str(tmp_path / "BENCH_teff_new.json")
    _record(old, 1e-3)
    _record(new, 2e-3, ts="2026-01-02T00:00:00")
    assert compare.main([old, new]) == 1
    assert "REGRESSION" in capsys.readouterr().out
