import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own flags in a
# subprocess). Multi-device tests spawn subprocesses with their own
# XLA_FLAGS (see _multidev.py helpers).
os.environ.setdefault("XLA_FLAGS", "")
os.environ["JAX_PLATFORMS"] = "cpu"

# Make the example solvers importable as `examples.<name>` (they are
# library modules with a thin CLI; tests drive their step()/solve() APIs).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest

SEED = 20260714


@pytest.fixture()
def rng():
    return np.random.RandomState(SEED)


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run a python snippet with N fake devices; returns stdout, asserts rc=0."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert p.returncode == 0, f"subprocess failed:\n{p.stdout}\n{p.stderr}"
    return p.stdout
