"""Core API coverage: Grid, FieldSet/VectorField (SoA/AoS, C5), boundary
conditions, T_eff accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Grid, FieldSet, VectorField, boundary, teff
from repro.core.grid import human_bytes, volume_bytes


def test_grid_properties():
    g = Grid((65, 33, 17), (1.0, 2.0, 4.0))
    assert g.spacing == (1.0 / 64, 2.0 / 32, 4.0 / 16)
    assert g.interior_shape == (63, 31, 15)
    assert g.n_points == 65 * 33 * 17
    dt = g.stable_diffusion_dt(2.0)
    assert dt == pytest.approx(min(g.spacing) ** 2 / 2.0 / 6.1)
    with pytest.raises(ValueError):
        Grid((2, 2), radius=1)


def test_grid_subgrid_decomposition():
    g = Grid((34, 34), (1.0, 1.0))
    sub = g.subgrid((2, 4))
    assert sub.shape == (18, 10)
    with pytest.raises(ValueError):
        g.subgrid((3, 4))  # 32 % 3 != 0


def test_fieldset_alloc_and_registry():
    g = Grid((8, 8, 8))
    fs = FieldSet(g, dtype=jnp.float32)
    T = fs.ones("T")
    C = fs.full(2.5, "C")
    assert T.shape == g.shape and float(C[0, 0, 0]) == 2.5
    x = fs.from_fn(lambda x, y, z: x + y + z, "X")
    assert float(x[-1, -1, -1]) == pytest.approx(3.0)
    assert set(fs.names()) == {"T", "C", "X"}
    assert fs.nbytes() == 3 * 8 ** 3 * 4


def test_vector_field_layouts():
    g = Grid((6, 6))
    fs = FieldSet(g, layout="soa")
    v = fs.vector(3, init=1.0, name="V")
    assert v.layout == "soa" and v.ncomp == 3
    assert v[0].shape == (6, 6)
    aos = v.as_aos()
    assert aos.components.shape == (6, 6, 3)
    np.testing.assert_array_equal(np.asarray(aos[1]), np.asarray(v[1]))
    back = aos.as_soa()
    assert back.layout == "soa" and len(back.components) == 3
    doubled = v.map(lambda c: c * 2)
    assert float(doubled[2][0, 0]) == 2.0


def test_boundary_conditions(rng):
    A = jnp.asarray(rng.rand(6, 6), jnp.float32)
    d = boundary.dirichlet(A, 9.0)
    assert float(d[0, 3]) == 9.0 and float(d[3, -1]) == 9.0
    n = boundary.neumann0(A, axes=(0,))
    np.testing.assert_array_equal(np.asarray(n[0]), np.asarray(n[1]))
    p = boundary.periodic(A, axes=(1,))
    np.testing.assert_array_equal(np.asarray(p[:, 0]), np.asarray(p[:, -2]))
    np.testing.assert_array_equal(np.asarray(p[:, -1]), np.asarray(p[:, 1]))


def test_boundary_depth2_dirichlet(rng):
    A = jnp.asarray(rng.rand(8, 9), jnp.float32)
    d = np.asarray(boundary.dirichlet(A, 7.0, depth=2))
    assert (d[:2] == 7.0).all() and (d[-2:] == 7.0).all()
    assert (d[:, :2] == 7.0).all() and (d[:, -2:] == 7.0).all()
    # interior untouched
    np.testing.assert_array_equal(d[2:-2, 2:-2], np.asarray(A)[2:-2, 2:-2])


def test_boundary_depth2_neumann0(rng):
    A = jnp.asarray(rng.rand(8, 9), jnp.float32)
    n = np.asarray(boundary.neumann0(A, axes=(0,), depth=2))
    # both face layers copy the matching interior source layers
    np.testing.assert_array_equal(n[0], n[2])
    np.testing.assert_array_equal(n[1], n[3])
    np.testing.assert_array_equal(n[-1], n[-3])
    np.testing.assert_array_equal(n[-2], n[-4])
    np.testing.assert_array_equal(n[2:-2], np.asarray(A)[2:-2])


def test_boundary_depth2_periodic(rng):
    A = jnp.asarray(rng.rand(9, 8), jnp.float32)
    p = np.asarray(boundary.periodic(A, axes=(0,), depth=2))
    a = np.asarray(A)
    # low ghosts mirror the far interior, high ghosts the near interior
    np.testing.assert_array_equal(p[0:2], a[-4:-2])
    np.testing.assert_array_equal(p[-2:], a[2:4])
    np.testing.assert_array_equal(p[2:-2], a[2:-2])


def test_boundary_face_smaller_than_depth_raises(rng):
    A = jnp.asarray(rng.rand(5, 12), jnp.float32)
    with pytest.raises(ValueError, match="smaller than"):
        boundary.dirichlet(A, 0.0, axes=(0,), depth=3)   # 5 < 2*3
    with pytest.raises(ValueError, match="smaller than"):
        boundary.neumann0(A, axes=(0,), depth=2)         # 5 < 3*2
    with pytest.raises(ValueError, match="smaller than"):
        boundary.periodic(A, axes=(0,), depth=2)
    with pytest.raises(ValueError, match="depth must be"):
        boundary.neumann0(A, axes=(1,), depth=0)
    # the depth that *does* fit still works on the same array
    boundary.dirichlet(A, 0.0, axes=(0,), depth=2)
    boundary.neumann0(A, axes=(1,), depth=4)


def test_teff_accounting():
    a = teff.a_eff(n_points=512 ** 3, n_read=2, n_write=1, itemsize=4)
    assert a == 3 * 512 ** 3 * 4
    # paper numbers: A100 93%, P100 88% at their measured T_eff
    assert teff.fraction(1262e9, teff.A100_SXM4) == pytest.approx(0.93, abs=0.01)
    assert teff.fraction(496e9, teff.P100_PCIE) == pytest.approx(0.88, abs=0.01)
    m = teff.measure(lambda: jnp.ones(16).block_until_ready(), iters=5, warmup=1)
    assert m.median_s > 0 and m.ci95_s[0] <= m.median_s <= m.ci95_s[1] * 1.5


def test_human_bytes():
    assert human_bytes(512) == "512.00 B"
    assert human_bytes(2 * 1024 ** 3) == "2.00 GiB"
    assert volume_bytes((4, 4), jnp.float32) == 64
