"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dt):
    return TOL[dt]


# --------------------------------------------------------------------------
# conv1d
# --------------------------------------------------------------------------
@pytest.mark.parametrize("B,L,C,K", [(1, 16, 8, 2), (2, 48, 16, 4),
                                     (3, 100, 24, 4), (2, 33, 8, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_conv1d_sweep(B, L, C, K, dtype, rng):
    x = jnp.asarray(rng.randn(B, L, C), dtype)
    w = jnp.asarray(rng.randn(K, C), dtype)
    b = jnp.asarray(rng.randn(C), dtype)
    want = ref.conv1d_causal(x, w, b)
    got = ops.conv1d_causal(x, w, b, impl="pallas")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=_tol(dtype),
                               rtol=_tol(dtype))


def test_conv1d_silu_matches(rng):
    x = jnp.asarray(rng.randn(2, 32, 8), jnp.float32)
    w = jnp.asarray(rng.randn(4, 8), jnp.float32)
    got = ops.conv1d_causal(x, w, None, silu=True, impl="pallas")
    want = ops.conv1d_causal(x, w, None, silu=True, impl="chunked")
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_conv1d_bf16(rng):
    x = jnp.asarray(rng.randn(2, 32, 16), jnp.bfloat16)
    w = jnp.asarray(rng.randn(4, 16), jnp.bfloat16)
    want = ref.conv1d_causal(x, w, None)
    got = ops.conv1d_causal(x, w, None, impl="pallas")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2, rtol=3e-2)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("B,Hq,Hkv,L,D", [
    (1, 4, 4, 64, 16),    # MHA
    (2, 8, 2, 128, 32),   # GQA
    (1, 8, 1, 96, 16),    # MQA, non-pow2 length
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 37),
                                           (False, None)])
def test_attention_impls_agree(B, Hq, Hkv, L, D, causal, window, rng):
    q = jnp.asarray(rng.randn(B, Hq, L, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Hkv, L, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Hkv, L, D), jnp.float32)
    want = ref.attention(q, k, v, causal=causal, window=window)
    got_c = ops.attention(q, k, v, causal=causal, window=window,
                          impl="chunked", q_chunk=32, k_chunk=48)
    np.testing.assert_allclose(got_c, want, atol=2e-5, rtol=2e-5)
    got_p = ops.attention(q, k, v, causal=causal, window=window, impl="pallas")
    np.testing.assert_allclose(got_p, want, atol=2e-5, rtol=2e-5)


def test_attention_bf16(rng):
    q = jnp.asarray(rng.randn(2, 4, 64, 32), jnp.bfloat16)
    k = jnp.asarray(rng.randn(2, 2, 64, 32), jnp.bfloat16)
    v = jnp.asarray(rng.randn(2, 2, 64, 32), jnp.bfloat16)
    want = ref.attention(q, k, v)
    for impl in ("chunked", "pallas"):
        got = ops.attention(q, k, v, impl=impl, q_chunk=32, k_chunk=32)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=3e-2, rtol=3e-2)


def test_decode_attention_matches_last_row(rng):
    B, Hq, Hkv, S, D = 2, 8, 2, 64, 16
    kc = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    vc = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    q = jnp.asarray(rng.randn(B, Hq, D), jnp.float32)
    want = ref.attention(q[:, :, None], kc, vc, causal=True)[:, :, 0]
    got = ops.decode_attention(q, kc, vc)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_pos_masks_future(rng):
    B, Hq, Hkv, S, D = 1, 2, 2, 32, 8
    kc = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    vc = jnp.asarray(rng.randn(B, Hkv, S, D), jnp.float32)
    q = jnp.asarray(rng.randn(B, Hq, D), jnp.float32)
    pos = 10
    got = ops.decode_attention(q, kc, vc, pos=jnp.asarray(pos))
    got2 = ops.decode_attention(q, kc[:, :, : pos + 1], vc[:, :, : pos + 1])
    np.testing.assert_allclose(got, got2, atol=2e-5, rtol=2e-5)


def test_attention_grad_matches_ref(rng):
    B, Hq, Hkv, L, D = 1, 4, 2, 64, 16
    q = jnp.asarray(rng.randn(B, Hq, L, D), jnp.float32)
    k = jnp.asarray(rng.randn(B, Hkv, L, D), jnp.float32)
    v = jnp.asarray(rng.randn(B, Hkv, L, D), jnp.float32)
    g_ref = jax.grad(lambda q: jnp.sum(ref.attention(q, k, v) ** 2))(q)
    g_chk = jax.grad(lambda q: jnp.sum(
        ops.attention(q, k, v, impl="chunked", q_chunk=16, k_chunk=16) ** 2))(q)
    np.testing.assert_allclose(g_chk, g_ref, atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# SSD
# --------------------------------------------------------------------------
@pytest.mark.parametrize("B,L,H,P,G,N,chunk", [
    (1, 32, 2, 4, 1, 8, 8),
    (2, 64, 4, 8, 2, 16, 16),
    (1, 80, 4, 8, 4, 8, 32),   # L not divisible by chunk -> falls back
])
def test_ssd_sweep(B, L, H, P, G, N, chunk, rng):
    x = jnp.asarray(rng.randn(B, L, H, P) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, L, H)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(rng.rand(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, L, G, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, L, G, N) * 0.3, jnp.float32)
    D = jnp.asarray(rng.randn(H), jnp.float32)
    want, hw = ref.ssd_scan(x, dt, A, Bm, Cm, D=D)
    for impl in ("chunked", "pallas"):
        got, h = ops.ssd(x, dt, A, Bm, Cm, D=D, impl=impl, chunk=chunk)
        np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-4,
                                   err_msg=impl)
        np.testing.assert_allclose(h, hw, atol=3e-5, rtol=3e-4, err_msg=impl)


def test_ssd_decode_chain_equals_scan(rng):
    B, L, H, P, G, N = 2, 16, 4, 8, 2, 16
    x = jnp.asarray(rng.randn(B, L, H, P) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, L, H)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(rng.rand(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, L, G, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, L, G, N) * 0.3, jnp.float32)
    want, _ = ref.ssd_scan(x, dt, A, Bm, Cm)
    Bh = jnp.repeat(Bm, H // G, axis=2)
    Ch = jnp.repeat(Cm, H // G, axis=2)
    h = jnp.zeros((B, H, P, N), jnp.float32)
    outs = []
    for t in range(L):
        y, h = ops.ssd_decode_step(h, x[:, t], dt[:, t], A, Bh[:, t], Ch[:, t])
        outs.append(y)
    np.testing.assert_allclose(jnp.stack(outs, 1), want, atol=1e-5, rtol=1e-4)


def test_ssd_h0_continuation(rng):
    """Splitting a sequence in two with state carry == one long scan."""
    B, L, H, P, G, N = 1, 32, 2, 4, 1, 8
    x = jnp.asarray(rng.randn(B, L, H, P) * 0.5, jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, L, H)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(rng.rand(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, L, G, N) * 0.3, jnp.float32)
    Cm = jnp.asarray(rng.randn(B, L, G, N) * 0.3, jnp.float32)
    full, hf = ops.ssd(x, dt, A, Bm, Cm, impl="chunked", chunk=8)
    half = L // 2
    y1, h1 = ops.ssd(x[:, :half], dt[:, :half], A, Bm[:, :half], Cm[:, :half],
                     impl="chunked", chunk=8)
    y2, h2 = ops.ssd(x[:, half:], dt[:, half:], A, Bm[:, half:], Cm[:, half:],
                     h0=h1, impl="chunked", chunk=8)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), full,
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(h2, hf, atol=2e-5, rtol=2e-4)


# --------------------------------------------------------------------------
# diffusion3d (paper Fig. 1 kernel)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(16, 16, 16), (32, 24, 40), (8, 8, 128)])
def test_diffusion3d_pallas_vs_ref(shape, rng):
    T = jnp.asarray(rng.rand(*shape), jnp.float32)
    T2 = T.copy()
    Ci = jnp.asarray(rng.rand(*shape) + 0.5, jnp.float32)
    args = (1.0, 1e-4, float(shape[0] - 1), float(shape[1] - 1),
            float(shape[2] - 1))
    want = ref.diffusion3d_step(T2, T, Ci, *args)
    got = ops.diffusion3d_step(T2, T, Ci, *args, impl="pallas")
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_diffusion3d_boundary_preserved(rng):
    shape = (16, 16, 16)
    T = jnp.asarray(rng.rand(*shape), jnp.float32)
    T2 = jnp.full(shape, 7.0, jnp.float32)
    got = ops.diffusion3d_step(T2, T, jnp.ones(shape), 1.0, 1e-4, 15.0, 15.0,
                               15.0, impl="pallas")
    # boundary cells must keep T2's values (the paper's @inn semantics)
    np.testing.assert_array_equal(np.asarray(got[0]), 7.0)
    np.testing.assert_array_equal(np.asarray(got[-1]), 7.0)
    np.testing.assert_array_equal(np.asarray(got[:, 0]), 7.0)
    np.testing.assert_array_equal(np.asarray(got[:, :, -1]), 7.0)
