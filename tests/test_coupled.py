"""Coupled multi-field engine: multiple outputs per launch, mixed-shape
staggered fields, per-axis write-mode derivation, k-step coupled rotation
(bitwise vs sequential), and the error surface for inconsistent systems."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fd2d as fd, init_parallel_stencil
from repro.kernels import autotune
from repro.kernels.stencil import derive_launch, field_geometry

SHAPE = (20, 24)


def _arr(rng, shape=SHAPE):
    return jnp.asarray(rng.rand(*shape), jnp.float32)


def _coupled_kernel(ps):
    """Two diffusing fields with a reaction coupling."""
    @ps.parallel(outputs=("A2", "B2"), rotations={"A2": "A", "B2": "B"})
    def kern(A2, B2, A, B, dt):
        return {
            "A2": fd.inn(A) + dt * (fd.d2_xi(A) + fd.d2_yi(A)) + dt * fd.inn(B),
            "B2": fd.inn(B) + dt * (fd.d2_xi(B) + fd.d2_yi(B)) - dt * fd.inn(A),
        }
    return kern


def _stag_kernel(ps):
    """Cell field T coupled to a rotated face-centered field q (x-faces)."""
    @ps.parallel(outputs=("T2", "q2"), rotations={"T2": "T", "q2": "q"})
    def kern(T2, q2, T, q, dt):
        return {"T2": fd.inn(T) + dt * fd.d_xi(q),
                "q2": 0.7 * q + 0.3 * fd.av_xa(T)}
    return kern


# --------------------------------------------------------------------------
# coupled k-step rotation
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("k", [2, 3])
def test_coupled_run_steps_bitwise_matches_sequential(backend, k, rng):
    A, B = _arr(rng), _arr(rng)
    kern = _coupled_kernel(init_parallel_stencil(backend=backend, ndims=2))
    a, b, a2, b2 = A, B, A.copy(), B.copy()
    for _ in range(k):
        o = kern(A2=a2, B2=b2, A=a, B=b, dt=1e-3)
        a, a2 = o["A2"], a
        b, b2 = o["B2"], b
    got = kern.run_steps(k, A2=A.copy(), B2=B.copy(), A=A, B=B, dt=1e-3)
    np.testing.assert_array_equal(np.asarray(got["A2"]), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(got["B2"]), np.asarray(b))


@pytest.mark.parametrize("k", [2, 3])
def test_coupled_run_steps_backends_agree(k, rng):
    A, B = _arr(rng), _arr(rng)
    outs = {}
    for backend in ("jnp", "pallas"):
        kern = _coupled_kernel(init_parallel_stencil(backend=backend, ndims=2))
        outs[backend] = kern.run_steps(k, A2=A.copy(), B2=B.copy(), A=A, B=B,
                                       dt=1e-3)
    for o in ("A2", "B2"):
        np.testing.assert_allclose(np.asarray(outs["jnp"][o]),
                                   np.asarray(outs["pallas"][o]), atol=5e-6)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_staggered_rotation_run_steps_bitwise(backend, rng):
    """A face-centered field in the double-buffer rotation: the fused
    3-step launch must equal 3 sequential coupled calls bit-for-bit."""
    T, q = _arr(rng), _arr(rng, (SHAPE[0] - 1, SHAPE[1]))
    kern = _stag_kernel(init_parallel_stencil(backend=backend, ndims=2))
    a, b, qa, qb = T, T.copy(), q, q.copy()
    for _ in range(3):
        o = kern(T2=b, q2=qb, T=a, q=qa, dt=1e-3)
        a, b = o["T2"], a
        qa, qb = o["q2"], qa
    got = kern.run_steps(3, T2=T.copy(), q2=q.copy(), T=T, q=q, dt=1e-3)
    np.testing.assert_array_equal(np.asarray(got["T2"]), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(got["q2"]), np.asarray(qa))


# --------------------------------------------------------------------------
# mixed-shape staggered fields, single step
# --------------------------------------------------------------------------
def test_staggered_all_write_outputs_backend_parity(rng):
    """Face-centered flux outputs (`@all` write derived from the update's
    full-window extent) agree between backends, including at the domain
    boundary faces."""
    n, m = SHAPE
    phi, Pe = _arr(rng), _arr(rng)
    qx0 = jnp.zeros((n - 1, m), jnp.float32)
    qy0 = jnp.zeros((n, m - 1), jnp.float32)

    def flux(qx, qy, phi, Pe, dx, dy):
        k = (phi + 0.5) ** 2
        return {"qx": -fd.av_xa(k) * fd.d_xa(Pe) / dx,
                "qy": -fd.av_ya(k) * (fd.d_ya(Pe) / dy - 3.0 * fd.av_ya(phi))}

    outs = {}
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)
        kern = ps.parallel(outputs=("qx", "qy"))(flux)
        outs[backend] = kern(qx=qx0, qy=qy0, phi=phi, Pe=Pe, dx=0.1, dy=0.1)
    assert outs["jnp"]["qx"].shape == (n - 1, m)
    assert outs["jnp"]["qy"].shape == (n, m - 1)
    for o in ("qx", "qy"):
        assert outs["pallas"][o].shape == outs["jnp"][o].shape
        np.testing.assert_allclose(np.asarray(outs["jnp"][o]),
                                   np.asarray(outs["pallas"][o]), atol=1e-6)


def test_mixed_shape_inputs_backend_parity(rng):
    """Cell-centered outputs consuming face-centered inputs (the porosity
    flux-split update) agree between backends."""
    n, m = SHAPE
    phi, Pe = _arr(rng), _arr(rng)
    qx = _arr(rng, (n - 1, m))
    qy = _arr(rng, (n, m - 1))

    def upd(phi2, Pe2, phi, Pe, qx, qy, dtau):
        div_q = fd.d_xa(qx[:, 1:-1]) / 0.1 + fd.d_ya(qy[1:-1, :]) / 0.1
        Pe_new = fd.inn(Pe) + dtau * (-(div_q + fd.inn(Pe)))
        phi_new = fd.inn(phi) + dtau * (-(1.0 - fd.inn(phi)) * Pe_new)
        return {"Pe2": Pe_new, "phi2": phi_new}

    outs = {}
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)
        kern = ps.parallel(outputs=("phi2", "Pe2"))(upd)
        outs[backend] = kern(phi2=phi, Pe2=Pe, phi=phi, Pe=Pe, qx=qx, qy=qy,
                             dtau=0.01)
    for o in ("phi2", "Pe2"):
        np.testing.assert_allclose(np.asarray(outs["jnp"][o]),
                                   np.asarray(outs["pallas"][o]), atol=1e-6)


def test_all_write_collocated_covers_boundary(rng):
    """A full-extent update on a cell-centered output writes the boundary
    ring too (`@all` semantics on off=0 axes)."""
    U = _arr(rng)
    for backend in ("jnp", "pallas"):
        ps = init_parallel_stencil(backend=backend, ndims=2)

        @ps.parallel(outputs=("U2",))
        def kern(U2, U):
            return {"U2": 2.0 * U}

        got = np.asarray(kern(U2=jnp.zeros_like(U), U=U))
        np.testing.assert_allclose(got, 2.0 * np.asarray(U), atol=1e-6)


# --------------------------------------------------------------------------
# error surface
# --------------------------------------------------------------------------
def test_inconsistent_field_shape_raises(rng):
    ps = init_parallel_stencil(backend="pallas", ndims=2)

    @ps.parallel(outputs=("U2",))
    def kern(U2, U, W):
        return {"U2": fd.inn(U)}

    U = _arr(rng)
    with pytest.raises(ValueError, match="staggering band"):
        kern(U2=U, U=U, W=jnp.zeros((8, 8), jnp.float32))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_staggered_interior_write_raises(backend, rng):
    """An `inn`-style write on a staggered axis would leave block-boundary
    faces unwritten — rejected with a pointed message on BOTH backends
    (a kernel that traces on jnp must trace on pallas and vice versa)."""
    ps = init_parallel_stencil(backend=backend, ndims=2)

    @ps.parallel(outputs=("q2",))
    def kern(q2, q, T):
        return {"q2": fd.inn(q)}

    q = _arr(rng, (SHAPE[0] - 1, SHAPE[1]))
    with pytest.raises(ValueError, match="staggered along axis 0"):
        kern(q2=q, q=q, T=_arr(rng))


def test_overlapped_step_staggered_output_rejected(rng):
    """Outputs staggered along a decomposed axis are out of overlapped_
    step's contract (shared rank faces) — rejected before any collective."""
    from repro.distributed import overlap

    ps = init_parallel_stencil(backend="jnp", ndims=2)

    @ps.parallel(outputs=("q2",))
    def kern(q2, q, T):
        return {"q2": 0.5 * q + 0.5 * fd.av_xa(T)}

    q = _arr(rng, (SHAPE[0] - 1, SHAPE[1]))
    fields = dict(q2=q, q=q, T=_arr(rng))
    with pytest.raises(NotImplementedError, match="staggered along decomposed"):
        overlap.overlapped_step(kern, fields, {}, ("T",), ("x",))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_unrecognized_update_extent_raises(backend, rng):
    ps = init_parallel_stencil(backend=backend, ndims=2)

    @ps.parallel(outputs=("U2",))
    def kern(U2, U):
        return {"U2": U[:-1, :]}  # neither `all` nor `inn` extent

    with pytest.raises(ValueError, match="expected"):
        kern(U2=_arr(rng), U=_arr(rng))


def test_rotation_shape_mismatch_raises(rng):
    ps = init_parallel_stencil(backend="pallas", ndims=2)

    @ps.parallel(outputs=("T2",), rotations={"T2": "q"})
    def kern(T2, T, q):
        return {"T2": fd.inn(T)}

    T, q = _arr(rng), _arr(rng, (SHAPE[0] - 1, SHAPE[1]))
    with pytest.raises(ValueError, match="different"):
        kern.run_steps(2, T2=T.copy(), T=T, q=q)


def test_partial_rotations_raise(rng):
    """Every output of a coupled system must rotate for nsteps > 1."""
    ps = init_parallel_stencil(backend="jnp", ndims=2)

    @ps.parallel(outputs=("A2", "B2"), rotations={"A2": "A"})
    def kern(A2, B2, A, B):
        return {"A2": fd.inn(A), "B2": fd.inn(B)}

    A, B = _arr(rng), _arr(rng)
    with pytest.raises(ValueError, match="rotations"):
        kern.run_steps(2, A2=A.copy(), B2=B.copy(), A=A, B=B)


def test_field_geometry_validation():
    shapes, offsets = field_geometry(
        (16, 16), ("a", "q"), {"q": (15, 16)}, radius=1)
    assert shapes["a"] == (16, 16) and offsets["q"] == (1, 0)
    with pytest.raises(ValueError, match="staggering band"):
        field_geometry((16, 16), ("q",), {"q": (13, 16)}, radius=1)
    with pytest.raises(ValueError, match="rank"):
        field_geometry((16, 16), ("q",), {"q": (16,)}, radius=1)


# --------------------------------------------------------------------------
# launch derivation / autotune keyed on the field set's footprint
# --------------------------------------------------------------------------
def test_derive_launch_sums_field_set_footprint():
    """The VMEM fit must budget the SUM of the per-field windows: a larger
    coupled system yields smaller (or equal) blocks under one budget."""
    shape = (256, 256)
    budget = 1 << 17
    _, b2 = derive_launch(shape, 1, 2, 4, vmem_budget=budget,
                          field_offsets=[(0, 0)] * 2)
    _, b6 = derive_launch(shape, 1, 6, 4, vmem_budget=budget,
                          field_offsets=[(0, 0)] * 6)
    assert np.prod(b6) <= np.prod(b2)
    window6 = 6 * np.prod([b + 2 for b in b6]) * 4
    assert window6 <= budget
    # staggered fields shave their offsets off the window accounting
    offs = [(0, 0), (1, 0), (0, 1)]
    _, blk = derive_launch(shape, 1, 3, 4, vmem_budget=budget,
                           field_offsets=offs)
    window = sum(np.prod([b + 2 - o for b, o in zip(blk, off)])
                 for off in offs) * 4
    assert window <= budget


def test_autotune_keyed_on_field_offsets(tmp_path):
    """Two systems with the same field count but different staggering must
    tune independently (different VMEM footprints)."""
    calls = []

    def make_step(tile, k):
        def run():
            calls.append((tile, k))
            return jnp.zeros(())
        return run

    kw = dict(shape=(16, 16), dtype="float32", radius=1, n_fields=3,
              nsteps_candidates=(1,), iters=1, tag="offsets-unit")
    r1 = autotune.autotune(make_step, field_offsets=[(0, 0)] * 3, **kw)
    n1 = len(calls)
    r2 = autotune.autotune(make_step,
                           field_offsets=[(0, 0), (1, 0), (0, 1)], **kw)
    assert len(calls) > n1  # re-measured, not inherited
    k1 = autotune.cache_key(**{k: v for k, v in kw.items()
                               if k not in ("iters",)},
                            field_offsets=[(0, 0)] * 3)
    k2 = autotune.cache_key(**{k: v for k, v in kw.items()
                               if k not in ("iters",)},
                            field_offsets=[(0, 0), (1, 0), (0, 1)])
    assert k1 != k2
    assert r1.nsteps == r2.nsteps == 1
