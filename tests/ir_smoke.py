"""CI smoke: inferred footprints reproduce the declared geometry of every
existing kernel family — diffusion3d (r=1), Gross-Pitaevskii fused (r=2),
porosity flux-split (staggered face offsets, one-sided halos).

    PYTHONPATH=src:. python tests/ir_smoke.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                ".."))

from repro.core import init_parallel_stencil, fd3d as fd  # noqa: E402


def main():
    from examples import gross_pitaevskii as gp
    from examples import porosity_waves as pw

    # Fig. 1 diffusion: r = 1, symmetric
    ps = init_parallel_stencil(ndims=3)

    @ps.parallel(outputs=("T2",))
    def diff(T2, T, Ci, lam, dt, _dx, _dy, _dz):
        return {"T2": fd.inn(T) + dt * (lam * fd.inn(Ci) * (
            fd.d2_xi(T) * _dx ** 2 + fd.d2_yi(T) * _dy ** 2 +
            fd.d2_zi(T) * _dz ** 2))}

    s = (16, 16, 16)
    ir = diff.stencil_ir(T2=s, T=s, Ci=s, lam=1.0, dt=1.0,
                         _dx=1.0, _dy=1.0, _dz=1.0)
    assert ir.inferred_radius == 1, ir.halo
    assert ir.halo == ((1, 1),) * 3, ir.halo
    print(f"diffusion3d: inferred r={ir.inferred_radius} halo={ir.halo}")

    # Gross-Pitaevskii fused coupled kernel: r = 2
    cfg = gp.GPConfig(n=12)
    grid, re, im, V = gp.init_state(cfg)
    kern = gp.make_step(grid, cfg).kernels[0]
    ir = kern.stencil_ir(re2=re, im2=im, re=re, im=im, V=V, g=cfg.g,
                         dt=0.1, _dx2=1.0, _dy2=1.0, _dz2=1.0)
    assert ir.inferred_radius == 2, ir.halo
    assert ir.halo == ((2, 2),) * 3, ir.halo
    print(f"gross-pitaevskii fused: inferred r={ir.inferred_radius} "
          f"halo={ir.halo} field depths im={ir.field_halo['im']} "
          f"re={ir.field_halo['re']}")

    # porosity flux-split: staggered face offsets + one-sided halos
    pcfg = pw.PorosityConfig(n=24, flux_split=True)
    fluxes, update = pw.make_step(pw.make_grid(pcfg), pcfg).kernels
    n = pcfg.n
    ir = fluxes.stencil_ir(qx=(n - 1, n), qy=(n, n - 1), phi=(n, n),
                           Pe=(n, n))
    assert ir.offsets["qx"] == (1, 0) and ir.offsets["qy"] == (0, 1), ir.offsets
    assert ir.halo == ((0, 1), (0, 1)), ir.halo
    ir_u = update.stencil_ir(phi2=(n, n), Pe2=(n, n), phi=(n, n), Pe=(n, n),
                             qx=(n - 1, n), qy=(n, n - 1), dtau=0.0)
    assert ir_u.inferred_radius == 1, ir_u.halo
    print(f"porosity flux-split: offsets qx={ir.offsets['qx']} "
          f"qy={ir.offsets['qy']} halo={ir.halo}")
    print("IR smoke: inferred footprints reproduce all declared geometry")


if __name__ == "__main__":
    main()
