"""The hardened serving layer: batch-axis solves, queue semantics,
deadlines, NaN quarantine, retries, circuit breaker, fault injections.

The headline acceptance test (`test_mixed_batch_zero_lost_requests`)
drives a batch containing healthy, NaN-diverging, and deadline-expired
requests through the full server and asserts every healthy sample
completes, every degraded one fails with a pointed typed error, and no
request is lost.

Worker-kill (os._exit) lives in a real subprocess at the bottom —
in-process threads cannot survive it by definition.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.core import fd3d, init_parallel_stencil, iterate
from repro.distributed import fault
from repro.ir import Reduction
from repro.serve import (BudgetExhausted, DeadlineExceeded, QueueFull,
                         RequestQueue, SampleQuarantined, ServePolicy,
                         ServerClosed, SimulationServer, SolveRequest)
from repro.serve.engine import BatchEngine


def run_proc(code: str, env_extra: dict | None = None,
             timeout: int = 560) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop(fault.PLAN_ENV, None)
    env.pop("REPRO_TELEMETRY", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)


@pytest.fixture()
def active_plan(monkeypatch):
    def install(plan: fault.FaultPlan):
        monkeypatch.setenv(fault.PLAN_ENV, plan.to_env())
        fault.FaultPlan.reset_active()
        return fault.FaultPlan.active()
    yield install
    fault.FaultPlan.reset_active()


@pytest.fixture()
def collector():
    col = telemetry.configure(path=None)
    yield col
    telemetry.reset()


def diffusion_kernel(backend="jnp"):
    ps = init_parallel_stencil(backend=backend, ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"},
                 reductions={"err": "max_abs_diff(T2, T)"})
    def kern(T2, T, dt):
        return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                          + fd3d.d2_zi(T))}

    return kern


def spike(n=12, amp=1.0):
    T = np.zeros((n, n, n), np.float32)
    T[n // 2, n // 2, n // 2] = amp
    return T


def req(n=12, amp=1.0, dt=0.08, tol=1e-5, max_iters=600, **kw):
    return SolveRequest(fields={"T": spike(n, amp), "T2": spike(n, amp)},
                        scalars={"dt": dt}, tol=tol, max_iters=max_iters,
                        **kw)


# ---------------------------------------------------------------------------
# satellite 1: finite / nan_count reduction kinds as primitives
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_finite_and_nan_count_reductions(backend):
    ps = init_parallel_stencil(backend=backend, ndims=3)

    @ps.parallel(outputs=("T2",),
                 reductions={"bad": "finite(T2)", "nbad": "nan_count(T2)"})
    def step(T2, T):
        return {"T2": fd3d.inn(T) * 2.0}

    n = 8
    clean = np.ones((n, n, n), np.float32)
    _, reds = step(T2=clean.copy(), T=clean)
    assert float(reds["bad"]) == 0.0
    assert float(reds["nbad"]) == 0.0

    poisoned = clean.copy()
    poisoned[4, 4, 4] = np.nan
    poisoned[2, 2, 2] = np.inf
    _, reds = step(T2=clean.copy(), T=poisoned)
    assert float(reds["bad"]) == 1.0
    assert float(reds["nbad"]) == 2.0
    # the folded indicator itself is NaN-free (safe for while_loop)
    assert np.isfinite(float(reds["bad"]))


def test_finite_reduction_ir_trace_and_cost():
    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), reductions={"bad": "finite(T2)"})
    def step(T2, T):
        return {"T2": fd3d.inn(T)}

    n = 8
    T = np.ones((n, n, n), np.float32)
    ir = step.stencil_ir(T2=T.copy(), T=T)
    assert "bad" in ir.red_exprs
    cost = step.cost_model(T2=T.copy(), T=T)
    # the indicator map is priced into the fused check epilogue
    assert cost.check_flops.total() > 0 and cost.n_reductions == 1

    with pytest.raises(ValueError, match="one operand"):
        Reduction("finite", "a", "b")
    with pytest.raises(ValueError, match="one of"):
        Reduction("bogus_kind", "a")


# ---------------------------------------------------------------------------
# tentpole core: the batch-axis solver
# ---------------------------------------------------------------------------
def test_solve_batch_matches_solo_bitwise():
    kern = diffusion_kernel()
    B, n = 4, 12
    dts = np.array([0.08, 0.10, 0.12, 0.09], np.float32)
    amps = np.array([1.0, 2.0, 0.5, 1.5], np.float32)
    T0 = np.stack([spike(n, a) for a in amps])
    solo = [iterate.solve_until(kern, {"T": T0[b], "T2": T0[b]},
                                {"dt": float(dts[b])}, tol=1e-5,
                                max_iters=500, check_every=4)
            for b in range(B)]
    res = iterate.solve_batch(kern, {"T": T0, "T2": T0}, {"dt": dts},
                              tol=1e-5, max_iters=500, check_every=4)
    assert bool(res.converged.all()) and not bool(res.bad.any())
    for b in range(B):
        # same backend, same per-step math, frozen after convergence:
        # the batched sample IS the solo solve bitwise
        np.testing.assert_array_equal(np.asarray(res.fields["T"][b]),
                                      np.asarray(solo[b].fields["T"]))
        assert int(res.iters[b]) == int(solo[b].iters)
        assert float(res.err[b]) == float(solo[b].err)


def test_solve_batch_quarantines_nan_and_respects_budget():
    kern = diffusion_kernel()
    n = 12
    # sample 1: dt far above the CFL limit -> divergence -> NaN
    dts = np.array([0.08, 5.0, 0.10], np.float32)
    T0 = np.stack([spike(n) for _ in range(3)])
    res = iterate.solve_batch(kern, {"T": T0, "T2": T0}, {"dt": dts},
                              tol=1e-5,
                              max_iters=np.array([500, 500, 8]),
                              check_every=4)
    assert bool(res.converged[0]) and not bool(res.bad[0])
    assert bool(res.bad[1]) and not bool(res.converged[1])
    assert bool(res.expired[2]) and int(res.iters[2]) == 8
    # the poisoned neighbor did not contaminate the healthy sample
    solo = iterate.solve_until(kern, {"T": T0[0], "T2": T0[0]},
                               {"dt": 0.08}, tol=1e-5, max_iters=500,
                               check_every=4)
    np.testing.assert_array_equal(np.asarray(res.fields["T"][0]),
                                  np.asarray(solo.fields["T"]))


def test_solve_batch_pallas_kernel_routes_through_jnp_twin():
    kern = diffusion_kernel("pallas")
    ref = diffusion_kernel("jnp")
    n = 12
    T0 = np.stack([spike(n), spike(n, 2.0)])
    dts = np.array([0.08, 0.10], np.float32)
    rp = iterate.solve_batch(kern, {"T": T0, "T2": T0}, {"dt": dts},
                             tol=1e-5, max_iters=400, check_every=4)
    rj = iterate.solve_batch(ref, {"T": T0, "T2": T0}, {"dt": dts},
                             tol=1e-5, max_iters=400, check_every=4)
    assert bool(rp.converged.all())
    for b in range(2):
        np.testing.assert_array_equal(np.asarray(rp.fields["T"][b]),
                                      np.asarray(rj.fields["T"][b]))


def test_solve_batch_requires_reductions_and_rotations():
    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",))
    def no_reds(T2, T):
        return {"T2": fd3d.inn(T)}

    T0 = np.stack([spike(), spike()])
    with pytest.raises(ValueError, match="fused reductions"):
        iterate.solve_batch(no_reds, {"T": T0, "T2": T0}, tol=1e-5,
                            max_iters=10)


def test_guard_name_reserved():
    ps = init_parallel_stencil(backend="jnp", ndims=3)

    @ps.parallel(outputs=("T2",), rotations={"T2": "T"},
                 reductions={iterate.GUARD_NAME: "max_abs(T2)"})
    def kern(T2, T):
        return {"T2": fd3d.inn(T)}

    T0 = np.stack([spike()])
    with pytest.raises(ValueError, match="reserved"):
        iterate.solve_batch(kern, {"T": T0, "T2": T0}, tol=1e-5,
                            max_iters=10, error=iterate.GUARD_NAME)


# ---------------------------------------------------------------------------
# queue: backpressure, shed, deadlines, requeue
# ---------------------------------------------------------------------------
def test_queue_sheds_at_capacity_with_typed_error(collector):
    q = RequestQueue(capacity=2)
    q.submit(req())
    q.submit(req())
    with pytest.raises(QueueFull) as ei:
        q.submit(req())
    assert ei.value.capacity == 2
    assert ei.value.reason == "queue_full"
    assert collector.counters[("serve.admitted", ())] == 2
    assert collector.counters[("serve.shed", ())] == 1


def test_queue_rejects_after_close_and_fails_on_drop(collector):
    q = RequestQueue(capacity=4)
    t = q.submit(req())
    q.close(drain=False)
    with pytest.raises(ServerClosed):
        q.submit(req())
    with pytest.raises(ServerClosed):
        t.result(timeout=1.0)


def test_queue_expires_stale_requests_at_dispatch(collector):
    q = RequestQueue(capacity=4)
    t1 = q.submit(req(deadline_s=0.001))
    t2 = q.submit(req())
    time.sleep(0.01)
    batch = q.take_batch(4, timeout=0.1)
    assert [t is t2 for t in batch] == [True]
    with pytest.raises(DeadlineExceeded) as ei:
        t1.result(timeout=1.0)
    assert ei.value.where == "queued"


def test_queue_buckets_by_grid_and_scalar_names():
    q = RequestQueue(capacity=8)
    a1 = q.submit(req(n=12))
    a2 = q.submit(req(n=12))
    b1 = q.submit(req(n=16))
    batch = q.take_batch(8, timeout=0.1)
    assert set(id(t) for t in batch) == {id(a1), id(a2)}
    batch2 = q.take_batch(8, timeout=0.1)
    assert [id(t) for t in batch2] == [id(b1)]


def test_requeue_goes_to_front():
    q = RequestQueue(capacity=8)
    t1 = q.submit(req())
    t2 = q.submit(req())
    got = q.take_batch(2, timeout=0.1)
    assert got == [t1, t2]
    t3 = q.submit(req())
    q.requeue([t1, t2])
    got2 = q.take_batch(3, timeout=0.1)
    assert got2 == [t1, t2, t3]


def test_fault_plan_reject_after_sheds(collector, active_plan):
    active_plan(fault.FaultPlan(reject_after=2))
    q = RequestQueue(capacity=100)
    q.submit(req())
    q.submit(req())
    with pytest.raises(QueueFull):
        q.submit(req())


# ---------------------------------------------------------------------------
# the server: end-to-end robustness
# ---------------------------------------------------------------------------
POLICY = ServePolicy(max_batch=4, chunk_steps=16, check_every=4,
                     collect_window_s=0.01, queue_capacity=64)


def test_server_solves_and_matches_direct(collector):
    kern = diffusion_kernel()
    direct = iterate.solve_until(kern, {"T": spike(), "T2": spike()},
                                 {"dt": 0.08}, tol=1e-5, max_iters=600,
                                 check_every=4)
    with SimulationServer(kern, POLICY) as server:
        out = server.solve(req(dt=0.08), timeout=120.0)
    assert out["iters"] == int(direct.iters)
    np.testing.assert_array_equal(out["fields"]["T"],
                                  np.asarray(direct.fields["T"]))


def test_mixed_batch_zero_lost_requests(collector):
    """ACCEPTANCE: healthy + NaN-diverging + deadline-expired requests in
    one serving run — healthy complete, degraded fail with pointed typed
    errors, zero requests lost."""
    kern = diffusion_kernel()
    with SimulationServer(kern, POLICY) as server:
        healthy = [server.submit(req(amp=1.0 + 0.3 * i,
                                     dt=0.08 + 0.005 * (i % 3)))
                   for i in range(6)]
        nan_req = server.submit(req(dt=5.0))                 # diverges
        late_req = server.submit(req(tol=1e-12, max_iters=10**6,
                                     deadline_s=0.03))       # hopeless
        budget_req = server.submit(req(tol=1e-12, max_iters=8))

        outcomes = {}
        for t in healthy:
            out = t.result(timeout=120.0)
            assert out["iters"] > 0 and np.isfinite(out["err"])
            assert np.isfinite(out["fields"]["T"]).all()
            outcomes[t.request.request_id] = "ok"
        with pytest.raises(SampleQuarantined) as qi:
            nan_req.result(timeout=120.0)
        assert qi.value.step > 0
        assert "NaN/Inf guard" in str(qi.value)
        with pytest.raises(DeadlineExceeded) as di:
            late_req.result(timeout=120.0)
        assert di.value.where in ("queued", "in_batch")
        with pytest.raises(BudgetExhausted) as bi:
            budget_req.result(timeout=120.0)
        assert bi.value.iters >= 8

    c = collector.counters
    assert c[("serve.admitted", ())] == 9
    resolved = (c.get(("serve.completed", ()), 0)
                + c.get(("serve.quarantined", ()), 0)
                + c.get(("serve.budget_exhausted", ()), 0)
                + sum(v for (n, _), v in c.items() if n == "serve.expired"))
    assert resolved == 9, f"lost requests: {dict(c)}"
    spans = [r for r in collector.records
             if r["kind"] == "span" and r["name"] == "serve.request"]
    assert len(spans) == 9       # per-request latency recorded


def test_nan_at_step_fault_injection_quarantines(collector, active_plan):
    active_plan(fault.FaultPlan(nan_at_step=8, nan_sample=0))
    kern = diffusion_kernel()
    with SimulationServer(kern, POLICY) as server:
        t0 = server.submit(req(dt=0.08))
        t1 = server.submit(req(dt=0.09))
        # slot 0 is poisoned by the plan at the first chunk boundary
        # past step 8; the DEVICE-side guard must catch it
        with pytest.raises(SampleQuarantined):
            t0.result(timeout=120.0)
        out = t1.result(timeout=120.0)
        assert np.isfinite(out["fields"]["T"]).all()
    ev = [r for r in collector.records if r["kind"] == "event"
          and r["name"] == "serve.fault_injected"]
    assert len(ev) == 1 and ev[0]["attrs"]["kind"] == "nan"


def test_transient_batch_failures_are_retried(collector, active_plan):
    active_plan(fault.FaultPlan(batch_errors=2))
    kern = diffusion_kernel()
    pol = ServePolicy(max_batch=2, chunk_steps=16, check_every=4,
                      retry_attempts=3, retry_backoff_s=0.001)
    with SimulationServer(kern, pol) as server:
        out = server.solve(req(dt=0.08), timeout=120.0)
    assert out["iters"] > 0
    assert collector.counters[("serve.batch_retries", ())] == 2


def test_breaker_trips_and_supervisor_restarts_worker(collector,
                                                      active_plan):
    # 7 transient failures vs 2 attempts/batch: each batch exhausts its
    # retries (strike), breaker threshold 2 trips the worker, the
    # supervisor restarts one, and the request STILL completes
    active_plan(fault.FaultPlan(batch_errors=7))
    kern = diffusion_kernel()
    pol = ServePolicy(max_batch=2, chunk_steps=16, check_every=4,
                      retry_attempts=2, retry_backoff_s=0.001,
                      breaker_threshold=2, max_worker_restarts=2)
    with SimulationServer(kern, pol) as server:
        out = server.solve(req(dt=0.08), timeout=120.0)
    assert out["iters"] > 0
    assert collector.counters[("serve.worker_restarts", ())] >= 1
    trips = [r for r in collector.records if r["kind"] == "event"
             and r["name"] == "serve.breaker_tripped"]
    assert trips, "breaker never tripped"
    assert collector.counters[("serve.requeued", ())] >= 1


def test_batch_timeout_fails_stragglers_pointedly(collector):
    kern = diffusion_kernel()
    pol = ServePolicy(max_batch=2, chunk_steps=8, check_every=4,
                      batch_timeout_s=0.05)
    with SimulationServer(kern, pol) as server:
        t = server.submit(req(tol=1e-13, max_iters=10**7))
        with pytest.raises(DeadlineExceeded) as ei:
            t.result(timeout=120.0)
    assert ei.value.where == "batch_timeout"


def test_continuous_refill_joins_mid_batch(collector):
    kern = diffusion_kernel()
    pol = ServePolicy(max_batch=2, chunk_steps=8, check_every=4,
                      collect_window_s=0.01)
    with SimulationServer(kern, pol) as server:
        tickets = [server.submit(req(amp=1.0 + 0.2 * i)) for i in range(6)]
        for t in tickets:
            out = t.result(timeout=120.0)
            assert out["iters"] > 0
    # 6 requests through 2 slots: at least 4 joined via refill or later
    # batches; refill must have fired at least once
    c = collector.counters
    assert (c.get(("serve.refilled", ()), 0)
            + c.get(("serve.batches", ()), 0)) >= 3


def test_engine_partial_batch_dead_slots_frozen(collector):
    kern = diffusion_kernel()
    pol = ServePolicy(max_batch=4, chunk_steps=16, check_every=4)
    eng = BatchEngine(kern, pol)
    q = RequestQueue(8)
    t = q.submit(req(dt=0.08))
    state = eng.start([t])
    assert state.n_live == 1
    dead_before = np.asarray(state.carry.fields["T"][2]).copy()
    while state.n_live:
        eng.run_chunk(state)
        eng.harvest(state)
    out = t.result(timeout=1.0)
    assert out["iters"] > 0
    np.testing.assert_array_equal(np.asarray(state.carry.fields["T"][2]),
                                  dead_before)


# ---------------------------------------------------------------------------
# worker kill: a real process death (subprocess; supervisor recovers)
# ---------------------------------------------------------------------------
KILL_WORKER_CODE = r"""
import json, numpy as np
from repro import telemetry
from repro.core import fd3d, init_parallel_stencil
from repro.serve import ServePolicy, SimulationServer, SolveRequest

col = telemetry.configure(path=None)
ps = init_parallel_stencil(backend="jnp", ndims=3)

@ps.parallel(outputs=("T2",), rotations={"T2": "T"},
             reductions={"err": "max_abs_diff(T2, T)"})
def kern(T2, T, dt):
    return {"T2": fd3d.inn(T) + dt * (fd3d.d2_xi(T) + fd3d.d2_yi(T)
                                      + fd3d.d2_zi(T))}

def spike(n=12):
    T = np.zeros((n, n, n), np.float32); T[6, 6, 6] = 1.0
    return T

pol = ServePolicy(max_batch=2, chunk_steps=16, check_every=4)
with SimulationServer(kern, pol) as server:
    ts = [server.submit(SolveRequest(
        fields={"T": spike(), "T2": spike()}, scalars={"dt": 0.08},
        tol=1e-5, max_iters=600)) for _ in range(3)]
    outs = [t.result(timeout=120.0) for t in ts]
print(json.dumps({"iters": [o["iters"] for o in outs]}))
"""


@pytest.mark.distributed
def test_worker_kill_injection_dies_with_plan_exit_code():
    # sanity arm: with the plan armed the process dies at the scheduled
    # batch with the planned exit code (the injection is real)
    plan = fault.FaultPlan(kill_worker_after=1)
    r = run_proc(KILL_WORKER_CODE,
                 env_extra={fault.PLAN_ENV: plan.to_env()})
    assert r.returncode == fault.KILL_EXIT_CODE, r.stderr


@pytest.mark.distributed
def test_worker_kill_clean_run_completes():
    r = run_proc(KILL_WORKER_CODE)
    assert r.returncode == 0, r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert all(i > 0 for i in out["iters"])
